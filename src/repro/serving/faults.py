"""Deterministic fault injection for the serving tier (ISSUE 10).

The paper's recovery argument only means something if failures are
*replayable*: the same seed must fail the same transfers, lose the same host
pages and crash at the same tick on every run. So the injector is stateless
where it can be — each decision is a pure hash of ``(seed, kind, key,
attempt)`` — and keeps only the minimum mutable state (per-page loss
generations, injection tallies) needed to avoid livelock and to report what
it did.

Two fault families:

* **Transfer faults** (fail / delay a single D2H or H2D submission) are
  consumed by :class:`~repro.serving.tiering.TransferPipeline`. They are
  *timing-only* with respect to token output: the pipeline retries with
  backoff and, past the attempt budget, falls back to a synchronous copy —
  placement decisions never consult the injector, so the decoded stream is
  bit-identical to the fault-free run (pinned by the chaos property test).
* **State faults** (lose a spilled host page, stall a drainer shard, crash
  at a tick boundary) do change engine state and are handled one level up:
  a lost page raises :class:`LostPageError` and the scheduler sheds the row
  back to ``waiting`` for re-prefill; a crash raises :class:`CrashFault`
  after the tick's journal append and :meth:`ServingEngine.recover` replays
  the journal.
"""
from __future__ import annotations

import hashlib
import struct
from dataclasses import dataclass, field
from typing import Optional, Tuple


class CrashFault(RuntimeError):
    """Simulated process crash at a scheduler tick boundary."""

    def __init__(self, tick: int):
        super().__init__(f"injected crash at tick {tick}")
        self.tick = tick


class LostPageError(RuntimeError):
    """A spilled host page is gone (corrupt/lost NVMM-side copy).

    Raised from the demand-fault path; carries the victim sequence so the
    scheduler can shed exactly that row.
    """

    def __init__(self, seq: int, logical: int):
        super().__init__(f"host page lost: seq={seq} logical={logical}")
        self.seq = seq
        self.logical = logical


@dataclass(frozen=True)
class FaultEvent:
    """One scripted fault: fire ``kind`` at scheduler tick ``tick``.

    kinds: ``"shard_stall"`` (key = shard index or None, value = stall
    seconds), ``"page_lost"`` (key = (seq, logical) or seq), ``"crash"``.
    """
    tick: int
    kind: str
    key: object = None
    value: object = None


@dataclass(frozen=True)
class FaultPlan:
    """Seeded rates + optional explicit script. Frozen so a plan can be
    shared between the faulty run and its replay/recovery run."""
    seed: int = 0
    transfer_fail_rate: float = 0.0     # P(one submission attempt fails)
    transfer_delay_rate: float = 0.0    # P(a submission is slowed)
    transfer_delay_s: float = 5e-4      # added service time when delayed
    page_loss_rate: float = 0.0         # P(a spilled host page is lost)
    crash_at_tick: Optional[int] = None
    script: Tuple[FaultEvent, ...] = ()


def _u01(*parts) -> float:
    """Pure uniform(0,1) from a blake2b of the parts — the determinism
    backbone: no RNG state, so injection order cannot perturb decisions."""
    h = hashlib.blake2b(repr(parts).encode(), digest_size=8).digest()
    return struct.unpack(">Q", h)[0] / float(1 << 64)


@dataclass
class FaultInjector:
    plan: FaultPlan
    # (seq, logical) → how many times this page was already lost; folded
    # into the loss hash so a re-spilled page rolls a fresh die (else a
    # "lost" page would be lost again forever and the row could livelock
    # through shed → re-prefill → re-spill → lost).
    _loss_gen: dict = field(default_factory=dict)
    _forced_lost: set = field(default_factory=set)   # scripted page losses
    counts: dict = field(default_factory=lambda: {
        "transfer_fail": 0, "transfer_delay": 0, "page_lost": 0,
        "shard_stall": 0, "crash": 0,
    })

    # -- transfer-level hooks (TransferPipeline) ----------------------------
    def transfer_fails(self, key, attempt: int) -> bool:
        r = self.plan.transfer_fail_rate
        if r <= 0.0:
            return False
        if _u01(self.plan.seed, "xfail", key, attempt) < r:
            self.counts["transfer_fail"] += 1
            return True
        return False

    def transfer_delay(self, key) -> float:
        r = self.plan.transfer_delay_rate
        if r <= 0.0:
            return 0.0
        if _u01(self.plan.seed, "xdelay", key) < r:
            self.counts["transfer_delay"] += 1
            return self.plan.transfer_delay_s
        return 0.0

    # -- page-level hook (PagedKVCache._fault_page) -------------------------
    def arm_page_loss(self, key) -> None:
        """Force the next read of one spilled page (``(seq, logical)``, or
        every page of ``seq`` when key is a bare int) to come up lost —
        the scripted-event form of ``page_loss_rate``."""
        self._forced_lost.add(key)

    def page_lost(self, seq: int, logical: int) -> bool:
        if (seq, logical) in self._forced_lost or seq in self._forced_lost:
            self._forced_lost.discard((seq, logical))
            self._forced_lost.discard(seq)
            self.counts["page_lost"] += 1
            return True
        r = self.plan.page_loss_rate
        if r <= 0.0:
            return False
        gen = self._loss_gen.get((seq, logical), 0)
        if _u01(self.plan.seed, "plost", seq, logical, gen) < r:
            self._loss_gen[(seq, logical)] = gen + 1
            self.counts["page_lost"] += 1
            return True
        return False

    # -- tick-level hooks (Scheduler) ---------------------------------------
    def begin_tick(self, tick: int):
        """Scripted events due at this tick (crash events excluded — the
        crash fires *after* the journal append, via :meth:`crash_now`)."""
        out = []
        for ev in self.plan.script:
            if ev.tick == tick and ev.kind != "crash":
                self.counts[ev.kind] = self.counts.get(ev.kind, 0) + 1
                out.append(ev)
        return out

    def crash_now(self, tick: int) -> bool:
        hit = (self.plan.crash_at_tick is not None
               and tick == self.plan.crash_at_tick)
        hit = hit or any(ev.tick == tick and ev.kind == "crash"
                         for ev in self.plan.script)
        if hit:
            self.counts["crash"] += 1
        return hit

    def injected(self) -> int:
        return sum(self.counts.values())
