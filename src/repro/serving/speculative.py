"""Draft proposers for speculative multi-token decode (ISSUE 7).

Draft-and-verify decoding rides the fused ragged tick: each running decode
row contributes ``1 + k`` query slots — the real next token plus ``k``
drafts — and the model's per-slot logits verify every draft in the same
launch. Accepted runs commit through the engines' partial-commit surface
(``commit_step`` with ``prepared``); rejected tails roll back via the
masked ``mode="drop"`` scatter discipline, so they never become visible
pool or mirror state. Greedy acceptance keeps the committed stream
bit-for-bit identical to ``generate_sequential``, whatever the proposer
suggests — a bad proposer only costs speed, never correctness.

This module holds the proposer side: the :class:`DraftProposer` protocol
(so a small draft model from ``repro/configs`` can slot in later) and the
default self-drafting :class:`NGramProposer`.
"""
from __future__ import annotations

from typing import Dict, List, Protocol, Sequence, Tuple, runtime_checkable


@runtime_checkable
class DraftProposer(Protocol):
    """Anything that can guess a row's next tokens.

    The scheduler calls :meth:`propose` once per fused tick per decode
    row with the row's FULL committed token stream (prompt + generated,
    including the tick's own argmax token, which is committed by
    construction). Proposals must be deterministic in ``tokens`` — the
    stream is the only state that survives preemption, so a proposer must
    be rebuildable from it (the scheduler re-feeds the whole stream after
    a restore and on every call). Returning fewer than ``k`` drafts (or
    none) is always legal: the row simply speculates less this tick.

    A model-backed proposer (a small draft config from ``repro/configs``)
    implements the same two methods: ``propose`` runs the draft model
    greedily over ``tokens`` for ``k`` steps; ``drop`` frees its per-row
    state (e.g. the draft model's KV cache row).
    """

    def propose(self, seq: int, tokens: Sequence[int], k: int) -> List[int]:
        """Up to ``k`` draft tokens continuing ``tokens``."""
        ...

    def drop(self, seq: int) -> None:
        """Forget per-sequence state (the row finished or was released)."""
        ...


class NGramProposer:
    """Self-drafting suffix-order n-gram proposer.

    Per sequence, keeps one table per context order ``n ∈ [1, max_n]``
    mapping the last-``n``-token context to the continuation most recently
    observed after it in the committed stream. Proposal walks the suffix
    ladder longest-context-first (order ``max_n`` down to 1) and extends
    greedily until ``k`` drafts are out or no context matches — untrained
    and repetitive streams (greedy argmax loops, templated text) hit the
    high orders almost immediately, which is exactly the decode-heavy
    traffic speculation is for.

    Ingestion is incremental: each :meth:`propose` call feeds only the
    tokens beyond what was already seen, and a diverging prefix (never
    produced by the scheduler, but cheap to guard) rebuilds from scratch.
    State is purely a function of the committed stream, so preemption and
    restore need no hooks here.
    """

    def __init__(self, max_n: int = 3):
        self.max_n = max(int(max_n), 1)
        self._hist: Dict[int, List[int]] = {}
        self._tables: Dict[int, List[Dict[Tuple[int, ...], int]]] = {}

    def _ingest(self, seq: int, tokens: Sequence[int]) -> None:
        hist = self._hist.setdefault(seq, [])
        tables = self._tables.setdefault(
            seq, [{} for _ in range(self.max_n)])
        toks = [int(t) for t in tokens]
        if toks[:len(hist)] != hist:
            hist.clear()
            for t in tables:
                t.clear()
        for i in range(len(hist), len(toks)):
            for n in range(1, min(self.max_n, i) + 1):
                tables[n - 1][tuple(toks[i - n:i])] = toks[i]
            hist.append(toks[i])

    def propose(self, seq: int, tokens: Sequence[int], k: int) -> List[int]:
        self._ingest(seq, tokens)
        tables = self._tables[seq]
        work = list(self._hist[seq])
        out: List[int] = []
        for _ in range(max(int(k), 0)):
            nxt = None
            for n in range(min(self.max_n, len(work)), 0, -1):
                nxt = tables[n - 1].get(tuple(work[-n:]))
                if nxt is not None:
                    break
            if nxt is None:
                break
            out.append(nxt)
            work.append(nxt)
        return out

    def drop(self, seq: int) -> None:
        self._hist.pop(seq, None)
        self._tables.pop(seq, None)
