"""Asynchronous tier-transfer pipeline + hot/cold victim model (ISSUE 8).

The serving translation of the paper's drain-pipeline lesson: NVLog wins
writes because the log drains in the *background* while the foreground keeps
appending — NVPages pays page-granular transfer latency on the critical
path. The pooled KV engine had exactly the NVPages problem: every D2H page
spill and H2D fault-in stalled the fused tick. This module gives it the
NVLog discipline:

* :class:`TransferPipeline` — two analytic :class:`~repro.core.clock
  .DrainQueue` channels (one per direction, the double-buffer) behind the
  same :class:`~repro.core.clock.ShardedDrainer` machinery the log engines
  drain through. A *submit* tallies the transfer's bytes and enqueues its
  service time without advancing the foreground clock; a *barrier* waits
  for one keyed transfer's finish — the coherence rule is that any read of
  an in-flight page barriers first, and nothing else ever waits.
* :class:`PageHeat` — the deterministic hot/cold re-reference model that
  replaces pure-LRU spill victim selection. Per-page priority is
  ``hotness(p) = freq_ema(p) / (1 + age(p))``: an EMA of access counts
  (the hot/cold split) discounted by a logical age in *touch events*, the
  working-set form of the Che-approximation re-reference probability
  ``P(reuse) ≈ exp(-age / T_c)`` from the hybrid-cache hit-rate model
  (PAPERS.md, "Stochastic Modeling of Hybrid Cache Systems"). Every page
  has the same miss cost (one page-sized H2D), so ranking by re-reference
  probability alone minimizes expected miss cost. Deliberately clock-free
  and sampling-free (grl2's proportional replay priorities, made
  deterministic): victim choice must be bit-identical whether transfers
  run sync or async, or token identity across the two modes breaks.
"""
from __future__ import annotations

from typing import Hashable, Optional

from repro.core.clock import ShardedDrainer, SimClock
from repro.roofline.hw import TierSpec


class TransferPipeline:
    """Double-buffered background D2H/H2D transfer queues over a SimClock.

    Keys are caller-chosen (the pooled engine uses ``("d2h", seq, logical)``
    / ``("h2d", seq, logical)``); one key names at most one in-flight
    transfer. Ordering within a direction is FIFO (one
    :class:`~repro.core.clock.DrainQueue` per direction), and a dependency
    across directions is expressed with ``after=`` — a fault-in chained
    after its page-out's finish time models "the H2D reads the staging
    buffer once the D2H has landed" without stalling the foreground.
    """

    D2H = 0
    H2D = 1

    BACKOFF_CAP = 6           # exponential backoff multiplier capped at 2^6

    def __init__(self, clock: SimClock, stats: Optional[dict] = None,
                 injector=None, max_retries: int = 3,
                 backoff_s: float = 1e-4):
        self.clock = clock
        self.drainer = ShardedDrainer(2)          # shard 0: D2H, shard 1: H2D
        self.stats = stats                        # engine's uniform stats dict
        self.injector = injector                  # FaultInjector or None
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.degraded = False     # terminal failure flipped us to sync tiering
        self._inflight: dict[Hashable, float] = {}   # key → finish time
        # key → (direction, ledger token): which channel holds the live
        # reservation; tokens are unique per submit so a resubmitted key
        # never aliases a stale ledger entry
        self._chan: dict[Hashable, tuple] = {}
        self._epoch: dict[Hashable, int] = {}     # key → submit count
        self._retried: set = set()  # keys whose last submit needed a retry

    def _count(self, name: str, delta: int = 1) -> None:
        if self.stats is not None:
            self.stats[name] = self.stats.get(name, 0) + delta

    def submit(self, direction: int, key: Hashable, tier: TierSpec, op: str,
               nbytes: int, *, random_access: bool = True,
               after: float = 0.0) -> float:
        """Enqueue one background transfer; returns its finish time.

        Tallies the bytes on the clock WITHOUT advancing it (the transfer
        runs beside the foreground); the channel serves it FIFO starting at
        ``max(now, after, channel backlog)``.

        With a fault injector attached, a submission attempt may fail: the
        failed attempt still occupied the channel (history, never refunded),
        and the retry re-enters the FIFO after a capped exponential backoff
        — all charged to the analytic clock, none of it stalling the
        foreground. Past ``max_retries`` the pipeline escalates: it waits
        out the last failed attempt, performs the copy synchronously on the
        foreground clock (the model's always-succeeds slow path), and flips
        ``degraded`` so the engine falls back to synchronous tiering.
        Placement never consults the injector, so faults are timing-only.
        """
        cost = self.clock.charge(tier, op, nbytes,
                                 random_access=random_access, advance=False)
        arrival = max(self.clock.now, after)
        inj = self.injector
        epoch = self._epoch[key] = self._epoch.get(key, 0) + 1
        self._retried.discard(key)
        if inj is not None:
            cost += inj.transfer_delay((key, epoch))
            attempt = 0
            while inj.transfer_fails((key, epoch), attempt):
                # the failed attempt occupied the link: untracked push
                # (history — a later cancel must not reclaim it)
                finish = self.drainer.push(direction, arrival, cost)
                self._count("transfer_failures")
                if attempt >= self.max_retries:
                    # terminal: drain the channel, copy synchronously
                    self.clock.wait_until(finish)
                    self.clock.charge(tier, op, nbytes,
                                      random_access=random_access)
                    self.degraded = True
                    if self.stats is not None:
                        self.stats["tiering_degraded"] = 1
                    self._inflight[key] = self.clock.now
                    self._chan.pop(key, None)
                    return self.clock.now
                self._count("transfer_retries")
                self._retried.add(key)
                attempt += 1
                backoff = self.backoff_s * (1 << min(attempt,
                                                     self.BACKOFF_CAP))
                arrival = finish + backoff
        token = (key, epoch)
        self._inflight[key] = self.drainer.push(direction, arrival, cost,
                                                token=token)
        self._chan[key] = (direction, token)
        return self._inflight[key]

    def finish_of(self, key: Hashable) -> Optional[float]:
        """Finish time of an in-flight transfer, or None."""
        return self._inflight.get(key)

    def took_retries(self, key: Hashable) -> bool:
        """True iff ``key``'s most recent submit needed ≥1 retry; clears
        the flag (the caller classifies the fault once)."""
        if key in self._retried:
            self._retried.discard(key)
            return True
        return False

    def _settle(self, key: Hashable, fallback: float) -> float:
        d = self._chan.pop(key, None)
        if d is None:
            return fallback
        direction, token = d
        f = self.drainer.queues[direction].settle(token)
        return fallback if f is None else f

    def barrier(self, key: Hashable) -> float:
        """Coherence barrier: wait until ``key``'s transfer has finished.
        Returns the foreground stall in seconds — 0.0 when the transfer
        was fully hidden behind compute (or wasn't in flight)."""
        finish = self._inflight.pop(key, None)
        if finish is None:
            return 0.0
        # the ledger may have compacted this entry earlier after a cancel
        finish = min(finish, self._settle(key, finish))
        stall = max(0.0, finish - self.clock.now)
        self.clock.wait_until(finish)
        return stall

    def cancel(self, key: Hashable, reclaim: bool = False) -> bool:
        """Drop the barrier obligation for ``key``. By default the channel
        time already reserved is not refunded — the link was genuinely busy
        (e.g. the staging D2H a chained fault-in read from). With
        ``reclaim=True`` (released sequence, rolled-back speculative pages)
        the unserved portion of the reservation is returned to the channel,
        so backlog stops counting work that will never run."""
        present = self._inflight.pop(key, None) is not None
        d = self._chan.pop(key, None)
        if d is not None:
            direction, token = d
            q = self.drainer.queues[direction]
            if reclaim:
                q.cancel(token, self.clock.now)
            else:
                q.settle(token)
        return present

    def cancel_seq(self, seq: int) -> int:
        """Cancel every in-flight transfer of one sequence (released or
        preempted: its ``(dir, seq, logical)`` keys must not collide with a
        later sequence reusing the id). Unserved channel reservations are
        reclaimed — a released row's queued transfers never run."""
        doomed = [k for k in self._inflight if k[1] == seq]
        for k in doomed:
            self.cancel(k, reclaim=True)
        return len(doomed)

    def stall_channel(self, direction: int, seconds: float) -> float:
        """Inject a drainer-shard stall: the channel serves nothing for
        ``seconds`` starting now (queued transfers finish later). Models a
        stuck drainer shard; foreground is not stalled."""
        self._count("shard_stalls")
        return self.drainer.push(direction, self.clock.now, seconds)

    @property
    def pending(self) -> int:
        return len(self._inflight)

    def backlog_s(self) -> float:
        """Worst per-channel backlog still draining right now."""
        return max(q.backlog(self.clock.now) for q in self.drainer.queues)

    def flush(self) -> float:
        """Full drain: wait for every in-flight transfer; returns the
        stall. Run-end accounting (and whole-pipeline sync points) only —
        per-page barriers are the steady-state coherence mechanism."""
        if not self._inflight:
            return 0.0
        finish = 0.0
        for key, f in list(self._inflight.items()):
            finish = max(finish, min(f, self._settle(key, f)))
        self._inflight.clear()
        self._chan.clear()
        stall = max(0.0, finish - self.clock.now)
        self.clock.wait_until(finish)
        return stall


class PageHeat:
    """Deterministic per-page re-reference estimator for spill ranking.

    ``touch`` advances a global logical tick and bumps the page's access
    EMA; ``hotness`` is that EMA discounted by the page's age in ticks —
    high for pages touched often and recently, decaying toward 0 as a page
    goes cold. ``assign`` resets a physical slot when allocation hands it
    to a new page, so a slot never inherits its previous tenant's heat.
    No wall/sim time enters, so sync and async runs score identically.
    """

    DECAY = 0.5

    def __init__(self):
        self.tick = 0
        self._freq: dict[int, float] = {}
        self._last: dict[int, int] = {}

    def assign(self, phys: int) -> None:
        self._freq[phys] = 0.0
        self._last[phys] = self.tick

    def touch(self, phys: int) -> None:
        self.tick += 1
        self._freq[phys] = 1.0 + self.DECAY * self._freq.get(phys, 0.0)
        self._last[phys] = self.tick

    def hotness(self, phys: int) -> float:
        age = self.tick - self._last.get(phys, self.tick)
        return self._freq.get(phys, 0.0) / (1.0 + age)
