"""Ragged-batch bookkeeping for continuous-batching decode.

A running batch holds sequences of different lengths, each prefilled at
batch=1. Per-sequence decode caches are *rows*: every cache array keeps its
batch dimension at size 1. The scheduler concatenates rows into one batched
cache for a single ``decode_step`` over the whole batch, and splits the
result back into rows afterwards — raggedness is carried entirely by the
per-row ``pos`` entries (every KV array is already padded to ``max_len`` by
prefill, and the decode attention masks by position), so no re-padding is
ever needed.

The batch axis differs per cache key (``model.prefill`` stacks layer scans
differently per family):

* ``pos`` — shape ``(B,)``: axis 0;
* ``seg_conv`` / ``seg_ssm`` (Zamba2 hybrid) — shape
  ``(n_seg, seg_len, B, ...)`` from the nested segment scan: axis 2;
* everything else (``k``/``v``/``c``/``kr``/``conv``/``ssm``/``shared_*``/
  ``tail_*``/``ek``/``ev``/quant scales) — shape ``(L, B, ...)``: axis 1.

Host round-trips (``row_to_host``/``row_to_device``) are exact — preempting
a row to host memory and restoring it later changes no bits, which is what
makes preemption invisible in the generated tokens.

On the **pooled (mirror-free) decode path** a row's cache is just
``{"pos"}`` — its KV lives in the engine-owned device page pool, addressed
through the block table, so concat/split/round-trip shrink to the position
vector and the scatter/gather helpers below move prompt KV between the
dense prefill cache and the pool entirely on device.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

#: cache keys whose batch axis is not the default 1
#: (``conv_steps``/``ssm_steps`` are the ragged SSM step's per-slot state
#: stacks, shaped ``(L, Qmax, B, ...)`` — slot axis before batch axis)
_SPECIAL_BATCH_AXIS = {"pos": 0, "seg_conv": 2, "seg_ssm": 2,
                       "conv_steps": 2, "ssm_steps": 2}


def batch_axis(key: str) -> int:
    """The batch dimension of cache entry ``key``."""
    return _SPECIAL_BATCH_AXIS.get(key, 1)


def concat_rows(rows: list[dict]) -> dict:
    """Concatenate per-sequence cache rows (batch dim 1 each) into one
    batched cache, preserving row order."""
    first = rows[0]
    return {k: jnp.concatenate([r[k] for r in rows], axis=batch_axis(k))
            for k in first}


def split_row(cache: dict, i: int) -> dict:
    """Slice row ``i`` back out of a batched cache (keeps batch dim 1)."""
    out = {}
    for k, v in cache.items():
        ax = batch_axis(k)
        idx = [slice(None)] * v.ndim
        idx[ax] = slice(i, i + 1)
        out[k] = v[tuple(idx)]
    return out


def row_to_host(row: dict) -> dict:
    """Materialize a cache row into host numpy arrays (preemption spill)."""
    return {k: np.asarray(v) for k, v in row.items()}


def row_to_device(row: dict) -> dict:
    """Bring a spilled cache row back onto the device (restore)."""
    return {k: jnp.asarray(v) for k, v in row.items()}


def bucket_pow2(n: int) -> int:
    """Smallest power of two ≥ n — the jit-shape ladder (pad + mask).

    The serving engine pads batch width and Qmax up to this ladder before
    every fused/batched step so the jitted model entries see a small fixed
    set of shapes instead of recompiling per width; padding rows carry
    ``q_len = 0`` (masked by the kernels) or are dummy dense rows whose
    outputs are discarded.
    """
    return 1 << max(int(n) - 1, 0).bit_length()


def gather_new_kv(cache_k, cache_v, positions):
    """On-device gather of the tokens a decode step just wrote.

    cache_k/cache_v: ``(L, B, T, K, D)``; positions: ``(B,)`` — the write
    index each row used. Returns ``(B, L, 2, K, D)`` float16, still on
    device: the caller transfers exactly one token per sequence per step
    instead of round-tripping whole cache rows through host memory.
    """
    B = positions.shape[0]
    b_idx = jnp.arange(B)
    k = cache_k[:, b_idx, positions]          # (L, B, K, D)
    v = cache_v[:, b_idx, positions]
    return jnp.stack([k, v], axis=2).transpose(1, 0, 2, 3, 4).astype(
        jnp.float16)                          # (B, L, 2, K, D)


def gather_new_kv_ragged(cache_k, cache_v, ctx_lens, qmax: int):
    """On-device gather of the tokens a fused ragged step just wrote.

    cache_k/cache_v: ``(L, B, T, K, D)``; ctx_lens: ``(B,)`` — each row's
    chunk started there, so its new tokens sit at ``ctx_lens[b] + i`` for
    ``i < qmax`` (slots past the row's ``q_len`` hold padding the caller
    slices off host-side). Returns ``(B, qmax, L, 2, K, D)`` float16, still
    on device: one transfer mirrors a whole mixed tick — decode rows and
    prefill-chunk rows alike.
    """
    B = ctx_lens.shape[0]
    pos = ctx_lens[:, None] + jnp.arange(qmax, dtype=jnp.int32)[None, :]
    pos = jnp.minimum(pos, cache_k.shape[2] - 1)     # clamp padding slots
    b_idx = jnp.arange(B)[:, None]
    k = cache_k[:, b_idx, pos]                       # (L, B, qmax, K, D)
    v = cache_v[:, b_idx, pos]
    return jnp.stack([k, v], axis=2).transpose(1, 3, 0, 2, 4, 5).astype(
        jnp.float16)                                 # (B, qmax, L, 2, K, D)


def gather_prefill_kv(cache_k, cache_v, n: int):
    """On-device slice of a prompt's prefilled KV: ``(L, 2, n, K, D)``
    float16 for one batch-1 row, cast before transfer so the host copy is
    the mirror's dtype (half the bytes of the fp32 cache)."""
    k = cache_k[:, 0, :n]                     # (L, n, K, D)
    v = cache_v[:, 0, :n]
    return jnp.stack([k, v], axis=1).astype(jnp.float16)


def gather_kv_range(cache_k, cache_v, lo: int, hi: int):
    """On-device slice of cache positions ``[lo, hi)`` for one batch-1 row:
    ``(L, 2, hi-lo, K, D)`` float16. The chunked-prefill mirror path uses
    this to append each processed chunk as ONE batched transfer instead of
    one per token."""
    k = cache_k[:, 0, lo:hi]
    v = cache_v[:, 0, lo:hi]
    return jnp.stack([k, v], axis=1).astype(jnp.float16)


def scatter_prefill_planes(pools, caches, phys, n: int):
    """Scatter a batch-1 prompt's prefilled cache planes into its pool
    pages ON DEVICE (the mirror-free admission path: a device-to-device
    copy, zero bytes over the device→host link).

    pools: one ``(L, P, T, *shape)`` array per descriptor plane; caches:
    the matching prefill cache planes ``(L, 1, max_len, *shape)`` in the
    same order; phys: ``(npages,)`` int32 physical pages owning logical
    pages ``0..npages-1``. Slots past ``n`` inside the last page carry
    prefill padding — callers mask them with ``lengths`` (the kernel
    contract) and later appends overwrite them in place.
    """
    npages = phys.shape[0]
    out = []
    for pool, cache in zip(pools, caches):
        L, _, T = pool.shape[:3]
        tail = pool.shape[3:]
        c = cache[:, 0, :npages * T].reshape((L, npages, T) + tail)
        out.append(pool.at[:, phys].set(c.astype(pool.dtype)))
    return tuple(out)


def scatter_prefill_pages(pool_k, pool_v, cache_k, cache_v, phys, n: int):
    """Dense ``(k, v)`` special case of :func:`scatter_prefill_planes`."""
    return scatter_prefill_planes((pool_k, pool_v), (cache_k, cache_v),
                                  phys, n)


def copy_pool_page_planes(pools, src: int, dst: int):
    """Duplicate one physical page group on device across every plane
    (prefix-sharing COW: the writer takes the copy at ``dst``, readers
    keep ``src``). One HBM read + write of a page group, zero host
    traffic."""
    return tuple(p.at[:, dst].set(p[:, src]) for p in pools)


def copy_pool_page(pool_k, pool_v, src: int, dst: int):
    """Dense ``(k, v)`` special case of :func:`copy_pool_page_planes`."""
    return copy_pool_page_planes((pool_k, pool_v), src, dst)
