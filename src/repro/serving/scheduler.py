"""Continuous-batching scheduler with preemption under HBM pressure.

The serving translation of the paper's thesis: log-vs-page tradeoffs only
appear under *concurrent mixed* load, so the engine must actually run
concurrent mixed load. The scheduler keeps three queues:

* **waiting** — submitted, not yet prefetched (FIFO by submission order);
* **running** — sequences decoding (or still prefilling in chunks)
  together; every tick steps ALL of them through a single fused ragged
  forward (see below) and mirrors the new tokens into the tiered
  :class:`~repro.core.engines.kv.KVCacheEngine` in one ``append_many``
  batch;
* **preempted** — spilled under HBM pressure: the model cache row lives in
  host memory (exact numpy round-trip), the tiered KV on the disk tier via
  ``KVCacheEngine.preempt``; re-admission restores both.

State machine::

    waiting --admit/prefill--> running --max_new reached--> finished
                                  |  ^
               pressure >= 1.0 -> |  | re-admit (FIFO, ahead of waiting)
                                  v  |
                               preempted

**Admission** fills the batch up to ``max_batch_seqs`` / ``max_batch_tokens``,
re-admitting preempted sequences ahead of new arrivals (the starvation
guard: a preempted request can only wait behind finitely many decode steps).
New admissions stop while the engine reports full pressure (or, for pooled
engines, while ``can_admit_tokens`` says the page pool cannot place the
candidate), but an empty batch always force-admits — the scheduler never
deadlocks with work queued.

**Chunked prefill** (ISSUE 4): when a token cap is set, prompts longer than
the chunk budget (``prefill_chunk_tokens``, defaulting to
``max_batch_tokens``) admit with only their first chunk prefilled; the rest
of the prompt rides along as the row's ``pending`` tail and is processed
one chunk per tick before the row joins batched decoding. Chunked rows
preempt/restore like any other row, and the result is token-identical to
one-shot prefill (locked down by test).

**Fused mixed-batch ticks** (ISSUE 5): on ragged-capable models (the
default) every tick is exactly ONE forward — decode rows argmax their
pending logits and contribute one token, mid-prefill rows contribute their
next chunk, and :meth:`ServingEngine.step_batch` runs them all in the same
ragged launch (chunk rows no longer sit out the batched step or run at
batch=1). A forward-progress guard backs this up: any row that sits in the
running batch without advancing a token or chunk for
``progress_tick_limit`` consecutive ticks raises — the chunk-row
starvation class is a hard error, not a slowdown. ``fuse_ticks=False`` (or
a model family without a ragged step) keeps the old structure: one chunk
per mid-prefill row at batch=1 (``extend_one``), then one batched decode
step over the fully-prefilled rows.

**Preemption** triggers when ``KVCacheEngine.pressure()`` reaches 1.0 (the
engine's HBM accounting has hit its budget). The victim comes from
``victim_hint`` — ``kvhybrid`` answers from its router's per-sequence reuse
histogram (coldest sequence first) — with an LRU fallback for ``paged`` /
``log`` (least recently admitted/restored, ties broken toward the largest
``resident_bytes``). At least ``min_running`` sequences always keep
running, so every tick makes progress and every admitted request finishes.

**Coherence rule:** a sequence is preempted only *between* decode steps,
after its step's KV token has been mirrored (append-then-preempt order), so
the spilled tiered image always equals the model cache row it shadows, and
restore changes no bits. Greedy decode is therefore token-identical to the
sequential reference for ANY admission order, batch size, HBM budget, or
preemption schedule (``tests/test_scheduler.py`` locks this down).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Optional

import jax.numpy as jnp
import numpy as np

from repro.serving import batching
from repro.serving.faults import CrashFault, LostPageError

if TYPE_CHECKING:                      # engine.py imports us for generate()
    from repro.serving.engine import Request, ServingEngine


@dataclass
class _Running:
    """A sequence in the running batch: its batch-1 model-cache row, the
    logits its next token will be argmaxed from, and LRU bookkeeping."""
    req: "Request"
    cache: dict                        # device arrays, batch dim 1
    logits: object                     # (1, 1, V) device array; None for a
                                       # freshly spliced row (its first chunk
                                       # pass produces the first logits)
    length: int                        # tokens in the cache row (pos)
    mirrored: bool                     # has KV in the tiered engine
    admitted_tick: int                 # last admission/restore tick (LRU)
    pending: Optional[np.ndarray] = None   # unprocessed prompt tail (chunked)
    stalled_ticks: int = 0             # consecutive running ticks w/o advance


@dataclass
class _Preempted:
    """A spilled sequence: model cache row in host memory, tiered KV on the
    disk tier (when the family mirrors KV at all)."""
    req: "Request"
    cache: dict                        # host numpy arrays
    logits: np.ndarray
    length: int
    mirrored: bool
    pending: Optional[np.ndarray] = None
    stalled_ticks: int = 0


@dataclass
class SchedulerStats:
    """Scheduler-level counters (engine-level ones live in tiered.stats)."""
    ticks: int = 0
    admitted: int = 0
    finished: int = 0
    preempts: int = 0
    restores: int = 0
    peak_running: int = 0
    prefill_chunks: int = 0            # chunk-continuation rows stepped
    fused_ticks: int = 0               # ticks run as ONE mixed ragged step
    stalled_row_ticks: int = 0         # running rows that missed a tick (0!)
    spliced: int = 0                   # admissions served from the prefix
                                       # cache (block-table splice, zero
                                       # prefill compute for the covered part)
    decode_rows: int = 0               # decode row-launches: one per decode
                                       # row per tick; with speculation each
                                       # commits 1 + accepted tokens, so
                                       # committed/decode_rows > 1 is the
                                       # accepted-tokens-per-launch win
    rows_shed: int = 0                 # rows shed back to waiting after a
                                       # lost spilled host page (ISSUE 10) —
                                       # re-prefilled, never token-divergent
    degraded_ticks: int = 0            # ticks run with the transfer pipeline
                                       # in degraded (synchronous) mode

    def as_dict(self) -> dict:
        return {f"sched_{k}": v for k, v in self.__dict__.items()}


class Scheduler:
    """Drives one batch of requests to completion over a ServingEngine."""

    def __init__(self, engine: "ServingEngine", requests: list["Request"]):
        self.engine = engine
        cfg = engine.cfg
        self.max_batch_seqs = max(cfg.max_batch_seqs, 1)
        self.max_batch_tokens: Optional[int] = cfg.max_batch_tokens
        self.chunk_tokens: Optional[int] = (cfg.prefill_chunk_tokens
                                            or cfg.max_batch_tokens)
        self.min_running = max(cfg.min_running, 1)
        self.progress_tick_limit = max(getattr(cfg, "progress_tick_limit", 4),
                                       1)
        self.waiting: deque["Request"] = deque(requests)
        self.running: list[_Running] = []
        self.preempted: deque[_Preempted] = deque()
        self.stats = SchedulerStats()

    # -------------------------------------------------------------- admission
    def _batch_tokens(self) -> int:
        return sum(r.length for r in self.running)

    def _has_room(self, cand_tokens: int) -> bool:
        if len(self.running) >= self.max_batch_seqs:
            return False
        if not self.running:
            return True                # force progress: never deadlock
        if self.engine.tiered.pressure() >= 1.0:
            return False               # admitting now would preempt someone
        if not self.engine.tiered.can_admit_tokens(cand_tokens):
            return False               # pooled: no pages to place it
        if self.max_batch_tokens is not None and \
                self._batch_tokens() + cand_tokens > self.max_batch_tokens:
            return False
        return True

    def _first_chunk(self, prompt_len: int) -> int:
        """Tokens the admission prefill processes (the rest rides as the
        row's pending tail)."""
        if self.chunk_tokens is None:
            return prompt_len
        return min(prompt_len, max(self.chunk_tokens, 1))

    @staticmethod
    def _full_prompt(req: "Request") -> np.ndarray:
        """The token prefix admission must prefill: the prompt, plus any
        already-committed tokens for a row that re-enters the waiting queue
        (shed after a lost host page, or rebuilt by crash recovery)."""
        if not req.generated:
            return req.prompt
        prompt = np.asarray(req.prompt)
        return np.concatenate(
            [prompt, np.asarray(req.generated, dtype=prompt.dtype)])

    def _admit(self) -> None:
        # preempted sequences re-admit ahead of new arrivals (starvation
        # guard: FIFO, and nothing can overtake them). A row mid-prefill
        # re-admits against its NEXT CHUNK, not one token — restoring a
        # row whose chunk cannot be placed would bounce it straight back
        # through the fused tick's tight-pool guard (restore/preempt churn
        # with no progress)
        while self.preempted and self._has_room(
                self.preempted[0].length + (
                    self._chunk_len(self.preempted[0].pending)
                    if self.preempted[0].pending is not None
                    and len(self.preempted[0].pending) else 1)):
            pre = self.preempted.popleft()
            if pre.mirrored:
                self.engine.tiered.restore(pre.req.rid)
            self.running.append(_Running(
                req=pre.req, cache=batching.row_to_device(pre.cache),
                logits=(None if pre.logits is None
                        else jnp.asarray(pre.logits)), length=pre.length,
                mirrored=pre.mirrored, admitted_tick=self.stats.ticks,
                pending=pre.pending, stalled_ticks=pre.stalled_ticks))
            self.stats.restores += 1
        while self.waiting and self._has_room(
                self._first_chunk(len(self._full_prompt(self.waiting[0])))
                + 1):
            req = self.waiting.popleft()
            # effective prompt: a shed or crash-recovered row re-prefills
            # its prompt PLUS its already-committed tokens (ISSUE 10) —
            # greedy decode then resumes exactly where the committed
            # stream left off, so degradation never diverges tokens
            full = self._full_prompt(req)
            # prefix-cache splice (ISSUE 6): a cached prefix admits as a
            # block-table alias — no prefill launch for the covered tokens;
            # the uncovered tail rides as the row's pending chunk tail and
            # its first chunk pass produces the row's first logits
            spliced = (self.engine.admit_prefix(req)
                       if not req.generated else None)
            if spliced is not None:
                cache, covered = spliced
                self.running.append(_Running(
                    req=req, cache=cache, logits=None, length=covered,
                    mirrored=True, admitted_tick=self.stats.ticks,
                    pending=req.prompt[covered:]))
                self.stats.admitted += 1
                self.stats.spliced += 1
                continue
            first = self._first_chunk(len(full))
            logits, cache = self.engine.prefill_one(req, first, tokens=full)
            pending = full[first:] if first < len(full) else None
            self.running.append(_Running(
                req=req, cache=cache, logits=logits, length=first,
                mirrored="k" in cache or self.engine.pooled,
                admitted_tick=self.stats.ticks, pending=pending))
            if pending is None:
                self.engine.on_prompt_complete(req.rid, full)
            self.stats.admitted += 1
        self.stats.peak_running = max(self.stats.peak_running,
                                      len(self.running))

    # ------------------------------------------------------------------ step
    def _chunk_len(self, pending) -> int:
        if self.chunk_tokens is None:
            return len(pending)
        return min(max(self.chunk_tokens, 1), len(pending))

    def _prefill_chunks(self) -> None:
        """UNFUSED fallback: advance every mid-prefill row by one chunk
        (through the decode path at batch=1). Rows still holding a pending
        tail sit out the batched decode step — their logits only become
        meaningful once the whole prompt has been processed."""
        for r in self.running:
            if r.pending is None or not len(r.pending):
                r.pending = None
                continue
            m = self._chunk_len(r.pending)
            r.logits, r.cache = self.engine.extend_one(
                r.req.rid, r.cache, r.pending[:m], r.length, r.mirrored)
            r.length += m
            r.pending = r.pending[m:] if m < len(r.pending) else None
            if r.pending is None:
                self.engine.on_prompt_complete(r.req.rid, r.req.prompt)
            self.stats.prefill_chunks += 1

    def _step(self) -> None:
        """UNFUSED fallback: one batched decode step over every
        fully-prefilled running sequence — argmax each row's pending
        logits, decode all rows at once through
        :meth:`ServingEngine.decode_batch`, split the rows back out."""
        rows = [r for r in self.running if r.pending is None]
        if not rows:
            return
        tokens = []
        for r in rows:
            nxt = int(jnp.argmax(r.logits[:, -1], -1)[0])
            r.req.generated.append(nxt)
            tokens.append(nxt)
            self.stats.decode_rows += 1
        # one batch = one model family, so either every row mirrors or none
        try:
            logits, caches = self.engine.decode_batch(
                [r.req.rid for r in rows], [r.cache for r in rows], tokens,
                rows[0].mirrored)
        except Exception:
            # the argmaxed tokens were appended BEFORE the model step: a
            # failed step (poisoned tick, lost host page) must pop them or
            # the retried tick would double-append and diverge
            for r in rows:
                r.req.generated.pop()
            raise
        for i, r in enumerate(rows):
            r.cache = caches[i]
            r.logits = logits[i:i + 1]
            r.length += 1

    def _plan_decode(self, r: _Running, k: int):
        """Plan a decode row's tick: argmax its pending logits (the one
        token sequential decode would emit — nothing is committed here, so
        a row the tight-pool guard sheds re-plans identically later) and,
        with speculation on, propose up to ``k`` drafts capped so the row
        can neither outrun ``max_new`` nor its ``max_len`` cache/page span.
        The proposer sees the full committed stream plus the argmaxed
        token — all derivable state, so preemption needs no proposer
        hooks."""
        nxt = int(jnp.argmax(r.logits[:, -1], -1)[0])
        drafts: list = []
        if k:
            room = min(self.engine.cfg.max_len - (r.length + 1),
                       r.req.max_new - len(r.req.generated) - 1)
            if room > 0:
                hist = ([int(t) for t in r.req.prompt]
                        + [int(t) for t in r.req.generated] + [nxt])
                drafts = self.engine.proposer.propose(
                    r.req.rid, hist, min(k, room))
        return nxt, drafts

    def _fused_step(self) -> None:
        """The tentpole: ONE fused forward over the whole running batch —
        decode rows argmax their pending logits and contribute ``1 + k``
        tokens (the next token plus up to ``speculate_k`` drafts, verified
        by the same launch's per-slot logits), mid-prefill rows contribute
        their next chunk (no more batch=1 chunk launches), and everyone
        advances in the same ragged launch through
        :meth:`ServingEngine.step_batch`. A chunk row whose tail empties
        this tick comes out holding its prompt-final logits, exactly as
        one-shot prefill would have left it; a speculative row comes out
        holding its last ACCEPTED slot's logits, exactly as sequential
        decode would after the same tokens."""
        for r in self.running:
            if r.pending is not None and not len(r.pending):
                r.pending = None
        # plan every decode row's tokens up front so the tight-pool guard
        # below sheds against the true per-row slot counts (1 + drafts),
        # not an assumed single token
        k = self.engine.speculate_k
        plan = {r.req.rid: self._plan_decode(r, k)
                for r in self.running if r.pending is None}
        # tight-pool guard: prepare_step pins every batch row while it
        # allocates chunk pages, so a pool that cannot place this tick's
        # chunks with the whole batch pinned must shed a row FIRST —
        # graceful preemption instead of the pool-exhausted hard error.
        # Placement beats the min_running floor here (an unplaceable step
        # makes no progress at all); the liveness floor guarantees a lone
        # row always places (the draft cap keeps even a speculative row
        # inside one max_len page span), so shedding always terminates.
        while len(self.running) > 1 and \
                not self.engine.can_step_fused(
                    [r.req.rid for r in self.running],
                    [self._chunk_len(r.pending) if r.pending is not None
                     else 1 + len(plan[r.req.rid][1])
                     for r in self.running]):
            self._preempt_one()
        rows, toks, spec, appended = [], [], [], []
        for r in self.running:
            if r.pending is not None:
                m = self._chunk_len(r.pending)
                rows.append(r)
                toks.append(np.asarray(r.pending[:m], np.int32))
                spec.append(0)
                appended.append(0)
                self.stats.prefill_chunks += 1
            else:
                nxt, drafts = plan[r.req.rid]
                r.req.generated.append(nxt)
                rows.append(r)
                toks.append(np.asarray([nxt] + drafts, np.int32))
                spec.append(len(drafts))
                appended.append(1)
                self.stats.decode_rows += 1
        try:
            logits, caches, committed = self.engine.step_batch(
                [r.req.rid for r in rows], [r.cache for r in rows], toks,
                rows[0].mirrored, spec_lens=spec)
        except Exception:
            # decode rows appended their argmaxed token BEFORE the fused
            # forward: a failed step (poisoned tick, lost host page) must
            # pop them, or the row would double-append when it re-plans —
            # the plan is pure (argmax of unchanged logits), so the retried
            # tick replans the identical token
            for r, a in zip(rows, appended):
                if a:
                    r.req.generated.pop()
            raise
        self.stats.fused_ticks += 1
        for i, r in enumerate(rows):
            r.cache = caches[i]
            r.logits = logits[i]
            m = committed[i]
            if spec[i]:
                # the argmaxed token is already in generated; the accepted
                # drafts (tokens 1..m-1 of the row) extend it — the exact
                # sequential greedy run, rejected tail already rolled back
                r.req.generated.extend(int(t) for t in toks[i][1:m])
            r.length += m
            if r.pending is not None:
                r.pending = r.pending[m:] if m < len(r.pending) else None
                if r.pending is None:
                    self.engine.on_prompt_complete(r.req.rid, r.req.prompt)

    def _check_progress(self, lengths_before: dict) -> None:
        """Forward-progress guard (the chunk-row starvation pin): every row
        that sat in the running batch this tick must have advanced by at
        least one token or chunk within ``progress_tick_limit`` consecutive
        such ticks — a row holding a pending prefill tail must never
        silently sit out ticks while pressure churns. Rows the tick
        preempted BEFORE they could step (the tight-pool guard) count too:
        restore→preempt churn without progress is the same starvation in a
        different queue."""
        def observe(row, rid, pending):
            if row.length > lengths_before.get(rid, -1):
                row.stalled_ticks = 0
                return
            row.stalled_ticks += 1
            self.stats.stalled_row_ticks += 1
            if row.stalled_ticks >= self.progress_tick_limit:
                raise RuntimeError(
                    f"scheduler starvation: request {rid} sat in the "
                    f"running batch for {row.stalled_ticks} ticks without "
                    f"advancing a token or prefill chunk (pending tail: "
                    f"{0 if pending is None else len(pending)} tokens)")

        for r in self.running:
            observe(r, r.req.rid, r.pending)
        for p in self.preempted:
            if p.req.rid in lengths_before:    # was running at tick start
                observe(p, p.req.rid, p.pending)

    def _finish_done(self) -> None:
        still = []
        for r in self.running:
            if len(r.req.generated) >= r.req.max_new:
                r.req.done = True
                if r.mirrored:
                    self.engine.tiered.release(r.req.rid)
                if self.engine.proposer is not None:
                    self.engine.proposer.drop(r.req.rid)
                self.stats.finished += 1
            else:
                still.append(r)
        self.running = still

    # ------------------------------------------------------------ preemption
    def _pick_victim(self) -> _Running:
        candidates = [r for r in self.running]
        hint = self.engine.tiered.victim_hint(
            [r.req.rid for r in candidates if r.mirrored])
        if hint is not None:
            return next(r for r in candidates if r.req.rid == hint)
        # LRU fallback: least recently (re)admitted, ties toward the row
        # whose preemption frees the most HBM
        return min(candidates, key=lambda r: (
            r.admitted_tick, -self.engine.tiered.resident_bytes(r.req.rid)))

    def _over_budget(self) -> bool:
        """HBM pressure at the ceiling, or the running batch has decoded
        its way past the token cap (admission checks only the first step's
        headroom; growth is reclaimed here)."""
        if self.engine.tiered.pressure() >= 1.0:
            return True
        return (self.max_batch_tokens is not None
                and self._batch_tokens() > self.max_batch_tokens)

    def _preempt_one(self) -> None:
        victim = self._pick_victim()
        self.running.remove(victim)
        if victim.mirrored:
            self.engine.tiered.preempt(victim.req.rid)
        self.preempted.append(_Preempted(
            req=victim.req, cache=batching.row_to_host(victim.cache),
            logits=(None if victim.logits is None
                    else np.asarray(victim.logits)), length=victim.length,
            mirrored=victim.mirrored, pending=victim.pending,
            stalled_ticks=victim.stalled_ticks))
        self.stats.preempts += 1

    def _preempt_under_pressure(self) -> None:
        while self._over_budget() and \
                len(self.running) > self.min_running:
            self._preempt_one()

    # --------------------------------------------------- faults & shedding
    def _shed_seq(self, seq: int) -> None:
        """Graceful degradation for a lost spilled host page (ISSUE 10):
        the row's pool state is suspect, so release ALL of it and send the
        request back to the FRONT of the waiting queue — re-admission
        re-prefills ``prompt + generated`` and greedy decode resumes
        exactly where the committed stream stopped. Tokens never diverge;
        the row only pays the re-prefill."""
        row = next((r for r in self.running if r.req.rid == seq), None)
        if row is None:
            return
        self.running.remove(row)
        if row.mirrored:
            self.engine.tiered.release(seq)
        if self.engine.proposer is not None:
            self.engine.proposer.drop(seq)
        self.waiting.appendleft(row.req)
        self.stats.rows_shed += 1

    # ------------------------------------------------------------------- run
    def tick(self) -> bool:
        """One scheduling round: admit → step → journal → retire finished →
        preempt under pressure → progress check → (maybe) crash. On the
        fused path (the default for ragged-capable models) the step is ONE
        mixed ragged forward over decode rows and prefill-chunk rows
        together; the unfused fallback (``fuse_ticks=False`` or a family
        without a ragged step) keeps the chunk-at-batch-1 then
        batched-decode structure. Returns False when all work is done.

        Fault hooks (ISSUE 10): scripted injector events fire at tick
        start; a :class:`LostPageError` from the step sheds exactly the
        losing row back to waiting (the step committed nothing — the
        pre-appended argmax tokens were popped by the step wrappers); the
        tick's committed tokens append to the journal BEFORE a scripted
        crash fires, so every durable tick is replayable — a crash placed
        before the append would simply lose that tick's tokens and
        recovery would re-decode them identically."""
        self._admit()
        self._finish_done()    # max_new=0 rows retire without decoding
        if not self.running:
            return bool(self.waiting or self.preempted)
        self.stats.ticks += 1
        inj = self.engine.injector
        if inj is not None:
            for ev in inj.begin_tick(self.stats.ticks):
                if ev.kind == "shard_stall":
                    self.engine.tiered.stall_transfers(
                        int(ev.key or 0), float(ev.value or 1e-3))
                elif ev.kind == "page_lost":
                    inj.arm_page_loss(ev.key)
        lengths_before = {r.req.rid: r.length for r in self.running}
        gen_before = {r.req.rid: len(r.req.generated) for r in self.running}
        shed = None
        try:
            if self.engine.fused:
                self._fused_step()
            else:
                self._prefill_chunks()
                self._step()
        except LostPageError as e:
            self._shed_seq(e.seq)
            shed = e
        if self.engine.journal is not None:
            commits = [(r.req.rid, gen_before[r.req.rid],
                        r.req.generated[gen_before[r.req.rid]:])
                       for r in self.running
                       if r.req.rid in gen_before
                       and len(r.req.generated) > gen_before[r.req.rid]]
            if commits:
                self.engine.journal.append_tick(self.stats.ticks, commits)
        if self.engine.degraded():
            self.stats.degraded_ticks += 1
        self._finish_done()
        self._preempt_under_pressure()
        if shed is None:
            # a shed tick made no progress by design (the injected loss
            # aborted the whole step) — that is degradation, not the
            # starvation class the progress guard hunts
            self._check_progress(lengths_before)
        self._publish_plan()
        if inj is not None and inj.crash_now(self.stats.ticks):
            raise CrashFault(self.stats.ticks)
        return bool(self.waiting or self.running or self.preempted)

    def _publish_plan(self) -> None:
        """Tell the engine what next tick's batch looks like (ISSUE 8):
        every surviving running row plus how many token slots it will claim
        — its next chunk length mid-prefill, ``1 + speculate_k`` decoding.
        The async tiering pipeline uses this to prefetch spilled pages
        before ``prepare_step`` demand-faults them; on sync or non-pooled
        engines the publication is a no-op."""
        if not self.running:
            return
        seqs, ntoks = [], []
        k = self.engine.speculate_k
        for r in self.running:
            seqs.append(r.req.rid)
            ntoks.append(self._chunk_len(r.pending)
                         if r.pending is not None and len(r.pending)
                         else 1 + k)
        self.engine.publish_plan(seqs, ntoks)

    def run(self) -> None:
        while self.tick():
            pass
