"""Batched serving engine: continuous-batching decode over the tiered KV
cache (DESIGN.md §2a).

The engine keeps the model's working KV cache in "HBM" (device arrays) and
mirrors every appended token into the tiered cache (paged or log design) so
sequences can be preempted/offloaded and restored — the serving translation
of the paper's cache. The tiered mirror's simulated tier-times and
amplification stats are what kvcache_bench reports against the paper's
expectations.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clock import SimClock
from repro.core.kvcache import KVSpec, LogKVCache, PagedKVCache


@dataclass
class ServeConfig:
    max_len: int = 512
    design: str = "log"            # "log" | "paged" — the paper's switch
    page_tokens: int = 16
    hbm_budget_bytes: int = 64 << 20
    hot_window_tokens: int = 128
    greedy: bool = True


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    generated: list = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        mcfg = model.cfg
        self.clock = SimClock()
        kv_heads = max(mcfg.num_kv_heads, 1)
        head_dim = max(mcfg.head_dim, 1)
        spec = KVSpec(num_layers=mcfg.num_layers, kv_heads=kv_heads,
                      head_dim=head_dim, page_tokens=cfg.page_tokens)
        if cfg.design == "paged":
            self.tiered = PagedKVCache(spec, self.clock,
                                       hbm_budget_bytes=cfg.hbm_budget_bytes)
        else:
            self.tiered = LogKVCache(spec, self.clock,
                                     hot_window_tokens=cfg.hot_window_tokens)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cfg.max_len))
        self._decode = jax.jit(model.decode_step)

    def _mirror_kv(self, rid: int, cache, pos: int):
        """Mirror the newly appended token's KV into the tiered cache."""
        if "k" not in cache:
            return                      # SSM-family: O(1) state, nothing to page
        k = np.asarray(cache["k"][:, 0, pos])    # (L, K, D) (batch idx 0)
        v = np.asarray(cache["v"][:, 0, pos])
        tok = np.stack([k, v], axis=1)           # (L, 2, K, D)
        self.tiered.append(rid, tok.astype(np.float16))

    def generate(self, requests: list[Request]) -> list[Request]:
        """Sequential continuous decode (batch=1 per request on CPU tests)."""
        for req in requests:
            batch = {"tokens": jnp.asarray(req.prompt[None, :])}
            logits, cache = self._prefill(self.params, batch)
            for p in range(req.prompt.shape[0]):
                self._mirror_kv(req.rid, cache, p)
            for _ in range(req.max_new):
                nxt = int(jnp.argmax(logits[:, -1], -1)[0])
                req.generated.append(nxt)
                pos = cache["pos"]
                logits, cache = self._decode(
                    self.params, cache, jnp.asarray([[nxt]], jnp.int32), pos)
                self._mirror_kv(req.rid, cache, int(pos[0]))
            req.done = True
        return requests

    def stats(self) -> dict:
        return {"sim_time_s": self.clock.now, **self.tiered.stats}
