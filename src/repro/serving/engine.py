"""Batched serving engine: continuous-batching decode over the tiered KV
cache (DESIGN.md §2a).

The engine keeps the model's working KV cache in "HBM" (device arrays) and
mirrors every appended token into the tiered cache so sequences can be
preempted/offloaded and restored — the serving translation of the paper's
cache. The tiered mirror is a :class:`repro.core.engines.kv.KVCacheEngine`
constructed through the KV registry from the same :class:`EngineSpec` the
FS tier uses, so a serving config and an FS config are one object. Prefill
mirrors as ONE batched append (a large write — under ``kvhybrid`` it routes
to the page side), decode steps as single-token appends (small writes — the
log side). The mirror's simulated tier-times and amplification stats are
what kvcache_bench reports against the paper's expectations.

``generate()`` runs requests through the continuous-batching
:class:`~repro.serving.scheduler.Scheduler`: requests are admitted into a
running batch, every scheduler tick steps the whole batch through a single
batched ``decode_step``, and sequences are preempted to the disk tier (and
later restored) when the engine's HBM accounting hits its budget.
``generate_sequential()`` keeps the one-request-at-a-time loop as the
reference implementation the scheduler must match token-for-token.

Mirror transfers are sliced **on device**: each decode step moves exactly
one ``(L, 2, K, D)`` float16 token per sequence over the device→host link
(counted in ``stats()["mirror_d2h_bytes"]``), never a whole cache row.

**Mirror-free pooled decode (ISSUE 4, generalized by ISSUE 9).** When the
KV engine owns a device-resident page pool (``paged``) and the model's
:class:`~repro.core.engines.desc.CacheDescriptor` exists, the dense mirror
disappears entirely: admission scatters the prompt's prefilled cache
planes into pool pages on device, every decode step runs the family's
paged kernel over the pool with block-table indirection, and the engine's
block-table/LRU accounting advances through ``prepare_step``/
``commit_step_planes`` with no device→host copy at all:
``mirror_d2h_bytes`` stays **zero** on this path (pinned by test). The
descriptor — not a ``supports_*`` gate — decides the layout: dense GQA
pools ``(k, v)``, int8 pools quantized pages next to their bf16 scale
planes (half the HBM bytes/token), MLA pools the latent ``(c, kr)``
planes, and SSM pools ZERO pages — its fixed-size state rows ride in the
engine (``state_views``/``commit_state``) alongside the block tables.
Engines without a pool (``log``, ``kvhybrid``) and families without a
descriptor (hybrid, encdec) fall back to the mirrored path transparently;
``ServeConfig.paged_decode`` forces either path.

**Fused mixed-batch ticks (ISSUE 5).** The paper's batched-submission
lesson, applied to the tick itself: instead of one batched decode launch
plus N batch=1 prefill-chunk launches, every scheduler tick is exactly ONE
ragged forward (:meth:`ServingEngine.step_batch`) — decode rows contribute
one new token (``q_len = 1``), mid-prefill rows contribute their next
chunk (``q_len ≤ chunk_tokens``), and the ``paged_attention_ragged``
kernel (pooled) or the ragged dense step (mirrored) attends them all in
the same launch with intra-chunk causal masking. Batch width and Qmax pad
up a power-of-two bucketing ladder (padding rows carry ``q_len = 0`` and
are masked end to end, including their pool scatters), so the jitted steps
stop recompiling per width — ``step_compiles``/``step_cache_hits`` in
``stats()`` pin it. ``ServeConfig.fuse_ticks=False`` keeps the
batch=1-per-chunk baseline (``kvcache_bench``'s fused gate measures the
gap), and model families without a cache descriptor (hybrid, encdec)
fall back to it transparently.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clock import SimClock
from repro.core.engines import EngineSpec, create_kv_engine
from repro.core.kvcache import KVSpec
from repro.serving import batching


@dataclass
class ServeConfig:
    # field order keeps legacy positional construction working: the new
    # engine_spec field comes last
    max_len: int = 512
    design: Optional[str] = None   # legacy switch: "log" | "paged" | name
    page_tokens: int = 16          # geometry (KVSpec): composes with either
    hbm_budget_bytes: Optional[int] = None   # legacy → EngineSpec.kv_hbm_bytes
    hot_window_tokens: Optional[int] = None  # legacy → EngineSpec.kv_hot_window
    greedy: bool = True
    # the shared config object; None → built from the legacy fields above
    engine_spec: Optional[EngineSpec] = None
    # continuous-batching scheduler knobs
    max_batch_seqs: int = 8        # running-batch width cap
    max_batch_tokens: Optional[int] = None   # running-batch token cap
    min_running: int = 1           # preemption floor: progress guarantee
    # mirror-free pooled decode: None = auto (pooled when the engine has a
    # device page pool AND the model family supports paged decode), True =
    # require it (raise if unsupported), False = always mirror
    paged_decode: Optional[bool] = None
    # chunked prefill: prompts longer than this admit chunk by chunk across
    # ticks (None → max_batch_tokens; chunking off when both are None)
    prefill_chunk_tokens: Optional[int] = None
    # fused mixed-batch ticks (ISSUE 5): every scheduler tick is ONE ragged
    # forward over decode rows AND prefill-chunk rows together. False keeps
    # the batch=1-per-chunk baseline (the --no-fuse comparison in
    # kvcache_bench); models without a ragged step fall back automatically.
    fuse_ticks: bool = True
    # forward-progress guard: a row present in the running batch must
    # advance (≥1 token or chunk) within this many consecutive running
    # ticks, else the scheduler raises — the chunk-row starvation pin
    progress_tick_limit: int = 4
    # speculative multi-token decode (ISSUE 7): each running decode row
    # proposes up to k draft tokens per fused tick, verified by the same
    # ragged forward; accepted runs commit, rejected tails roll back.
    # 0 = off. Greedy outputs stay token-identical either way.
    speculate_k: int = 0
    # proposer override: any DraftProposer (serving/speculative.py) — e.g.
    # a small draft model from repro/configs; None → the self-drafting
    # NGramProposer
    draft_proposer: Optional[object] = None
    # fault tolerance (ISSUE 10): a FaultPlan (serving/faults.py) turns on
    # deterministic fault injection — failed/delayed transfers, lost host
    # pages, drainer-shard stalls, a crash at a tick boundary. None = no
    # injection (and zero fault counters).
    fault_plan: Optional[object] = None
    # crash-consistent token journal (serving/journal.py): every scheduler
    # tick appends its committed tokens through the NVMM log tier; after a
    # CrashFault a fresh engine sharing the SAME journal object calls
    # recover() to rebuild and resume. None = no journal.
    journal: Optional[object] = None

    def resolved_spec(self) -> EngineSpec:
        """One EngineSpec no matter which knobs the caller used.

        Mixing a full ``engine_spec`` with the legacy tier knobs raises:
        silently preferring one of the two would run a wrong config (same
        loud-conflict rule as ``CheckpointManager``/``NVCacheFS``).
        """
        legacy = {k: v for k, v in
                  (("design", self.design),
                   ("hbm_budget_bytes", self.hbm_budget_bytes),
                   ("hot_window_tokens", self.hot_window_tokens))
                  if v is not None}
        if self.engine_spec is not None:
            if not isinstance(self.engine_spec, EngineSpec):
                raise TypeError(
                    f"engine_spec must be an EngineSpec, got "
                    f"{type(self.engine_spec).__name__!s}: "
                    f"{self.engine_spec!r}")
            if legacy:
                raise TypeError(
                    f"pass KV-tier parameters inside engine_spec, not as "
                    f"ServeConfig fields (got both a spec and "
                    f"{sorted(legacy)})")
            return self.engine_spec
        return EngineSpec(
            engine=self.design or "log",
            kv_hbm_bytes=(64 << 20 if self.hbm_budget_bytes is None
                          else self.hbm_budget_bytes),
            kv_hot_window=(128 if self.hot_window_tokens is None
                           else self.hot_window_tokens))


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    generated: list = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        mcfg = model.cfg
        self.clock = SimClock()
        kv_heads = max(mcfg.num_kv_heads, 1)
        head_dim = max(mcfg.head_dim, 1)
        # the model family's cache-layout descriptor (None → hybrid/encdec:
        # mirror-only). It rides inside KVSpec so a pool-capable engine
        # sizes, allocates and byte-accounts the pool from the SAME plane
        # list the model's paged/ragged steps consume.
        self.desc = model.cache_descriptor(cfg.page_tokens)
        spec = KVSpec(num_layers=mcfg.num_layers, kv_heads=kv_heads,
                      head_dim=head_dim, page_tokens=cfg.page_tokens,
                      desc=self.desc)
        self.tiered = create_kv_engine(cfg.resolved_spec(), spec, self.clock)
        # deterministic fault injection + crash-consistent journal (I10).
        # The injector attaches BEFORE init_pool so the transfer pipeline
        # is constructed with it; the journal's WAL region survives a
        # simulated crash (the object outlives the engine), only its clock
        # is re-attached to this engine's fresh one.
        self.injector = None
        if cfg.fault_plan is not None:
            from repro.serving.faults import FaultInjector
            self.injector = FaultInjector(cfg.fault_plan)
            self.tiered.set_fault_injector(self.injector)
        self.journal = cfg.journal
        if self.journal is not None:
            self.journal.attach_clock(self.clock)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cfg.max_len))
        self._decode = jax.jit(model.decode_step)
        self._gather_new_kv = jax.jit(batching.gather_new_kv)
        self._gather_prefill_kv = jax.jit(batching.gather_prefill_kv,
                                          static_argnums=2)
        self._gather_kv_range = jax.jit(batching.gather_kv_range,
                                        static_argnums=(2, 3))
        self.mirror_d2h_bytes = 0      # device→host mirror traffic (exact)
        self.sched_stats: dict = {}    # last generate()'s scheduler counters
        # host-facing mirror appends are dense-layout: a pooled engine with
        # a non-dense descriptor (int8/MLA pages, SSM state rows) cannot
        # absorb them, so the sequential reference counts its mirror bytes
        # but skips the tiered append (generate() never mirrors when pooled)
        self._mirror_appends_ok = True
        # ---------------------------------------------- fused mixed-batch tick
        # one ragged forward per tick (decode rows + prefill-chunk rows in
        # the same launch); families without a cache descriptor (hybrid,
        # encdec) keep the batch=1-per-chunk fallback transparently
        self.fused = bool(cfg.fuse_ticks) and model.supports_ragged_step()
        if self.fused:
            self._step_ragged = jax.jit(model.step_ragged)
            self._gather_new_kv_ragged = jax.jit(
                batching.gather_new_kv_ragged, static_argnums=3)
        # jit-shape ladder bookkeeping: every batched/fused step buckets its
        # (path, batch-width, Qmax) to powers of two (pad + mask), and these
        # counters pin that the jits stop recompiling per width
        self.jit_stats = {"prefill_calls": 0, "step_calls": 0,
                          "fused_steps": 0, "step_compiles": 0,
                          "step_cache_hits": 0}
        self._step_shapes: set = set()
        # ------------------------------------------- mirror-free pooled path
        self.max_pages = -(-cfg.max_len // cfg.page_tokens)
        budget = cfg.resolved_spec().kv_hbm_bytes
        if self.desc is None:
            pool_fits, budget_pages = False, 0
        elif self.desc.has_pages:
            # liveness floor: the pool must hold one max-length sequence
            # plus a reserve page, or a lone running sequence could exhaust
            # it with nothing left to preempt
            budget_pages = budget // self.desc.page_group_bytes
            pool_fits = budget_pages >= self.max_pages + 1
        else:
            # state-row family (SSM): fixed-size rows, need one running row
            # plus one restore in flight
            budget_pages = budget // max(self.desc.seq_state_bytes, 1)
            pool_fits = budget_pages >= 2
        pool_ok = self.tiered.supports_pool() and self.desc is not None
        if cfg.paged_decode and not (pool_ok and pool_fits):
            raise ValueError(
                f"paged_decode=True needs a pool-capable KV engine, a model "
                f"family with a cache descriptor, and an HBM budget of at "
                f"least {self.max_pages + 1} pool pages; got engine="
                f"{self.tiered.engine_name!r} (supports_pool="
                f"{self.tiered.supports_pool()}), family="
                f"{model.cfg.family!r}, budget_pages={budget_pages}")
        self.pooled = (pool_ok and pool_fits) if cfg.paged_decode is None \
            else bool(cfg.paged_decode)
        if self.pooled:
            if self.desc.has_pages and cfg.max_len % cfg.page_tokens:
                raise ValueError(
                    f"pooled decode needs max_len ({cfg.max_len}) to be a "
                    f"multiple of page_tokens ({cfg.page_tokens})")
            # the descriptor already carries each plane's dtype (the dense
            # planes are the model's compute dtype, so pooled decode stays
            # numerically identical to the dense path; int8 pages keep
            # int8 next to their bf16 scale planes)
            self.tiered.init_pool()
            self._mirror_appends_ok = self.desc.kernel == "dense"
            self._decode_paged = jax.jit(model.decode_step_paged)
            self._step_paged_ragged = jax.jit(model.step_paged_ragged)
            self._scatter_prefill = jax.jit(batching.scatter_prefill_planes,
                                            static_argnums=3)
        # ----------------------------------------- speculative decode (I7)
        # draft-and-verify over the ragged entries: decode rows carry
        # 1 + k query slots, the per-slot logits of the SAME fused forward
        # verify the drafts, and rejected tails roll back (partial commit
        # on the pooled path, truncated mirror transfer on the dense path)
        self.speculate_k = max(int(cfg.speculate_k), 0)
        if self.speculate_k and not self.fused:
            raise ValueError(
                f"speculate_k={self.speculate_k} needs fused ragged ticks "
                f"(fuse_ticks=True and a model family with a ragged step); "
                f"got fuse_ticks={cfg.fuse_ticks}, "
                f"supports_ragged_step={model.supports_ragged_step()}")
        self.proposer = None
        if self.speculate_k:
            if cfg.draft_proposer is not None:
                self.proposer = cfg.draft_proposer
            else:
                from repro.serving.speculative import NGramProposer
                self.proposer = NGramProposer()
        self.spec_stats = {"spec_proposed": 0, "spec_accepted": 0}
        # ------------------------------------------ cross-request prefix cache
        # token-keyed radix index over shared pool pages (ISSUE 6): cache-hit
        # admission splices the block table instead of prefilling. Requires
        # the pooled path; engines without a pool keep sharing off (their
        # admission behavior is unchanged, still token-identical)
        self.prefix_cache = None
        pc_tokens = cfg.resolved_spec().prefix_cache_tokens
        if self.pooled and pc_tokens > 0 and self.desc.has_pages:
            from repro.serving.prefix_cache import PrefixCache
            self.prefix_cache = PrefixCache(self.tiered,
                                            capacity_tokens=pc_tokens)

    # -------------------------------------------------------------- mirroring
    def _mirror_kv(self, rid: int, cache, pos: int):
        """Mirror the newly appended token's KV into the tiered cache.

        The ``(L, K, D)`` token is sliced and stacked ON DEVICE
        (:func:`batching.gather_new_kv`) so only the single fp16 token
        crosses the device→host link — never the whole padded cache row.
        """
        if "k" not in cache:
            return                      # SSM-family: O(1) state, nothing to page
        tok = np.asarray(self._gather_new_kv(
            cache["k"], cache["v"], jnp.asarray([pos], jnp.int32)))[0]
        self.mirror_d2h_bytes += tok.nbytes
        if self._mirror_appends_ok:
            self.tiered.append(rid, tok)

    def mirror_decode_batch(self, rids: list, cache, positions) -> None:
        """Mirror one decode step's tokens for a whole running batch: one
        on-device gather, ONE device→host transfer of ``(B, L, 2, K, D)``
        fp16, one batched ``append_many`` into the tiered engine. Bucket
        -ladder padding rows (``positions`` may be longer than ``rids``)
        are sliced off ON DEVICE before the transfer, so the byte
        accounting stays exact: one fp16 token per real sequence."""
        if "k" not in cache or not rids:
            return
        toks_dev = self._gather_new_kv(
            cache["k"], cache["v"], jnp.asarray(positions, jnp.int32))
        toks = np.asarray(toks_dev[:len(rids)])
        self.mirror_d2h_bytes += toks.nbytes
        self.tiered.append_many(
            [(rid, toks[i]) for i, rid in enumerate(rids)])

    def _mirror_step_ragged(self, rids: list, cache, ctx, q_lens,
                            qmax: int, committed=None) -> None:
        """Mirror one fused mixed tick's new tokens: ONE on-device ragged
        gather, then at most TWO device→host transfers — the decode rows
        (``q_len == 1``) as exactly one fp16 token each (the PR 3 byte
        accounting, unchanged), and the chunk rows as one
        ``(n_chunk, Qmax, ...)`` block whose only padding is each chunk's
        own Qmax remainder. Per-row appends follow — a chunk row lands as
        one multi-token append, so ``kvhybrid`` still routes it by size.

        ``committed`` (speculative decode) caps each row's transfer at its
        accepted token count: a rejected draft tail is truncated ON DEVICE
        before the block crosses the link, so it never reaches the mirror
        and never inflates the byte accounting."""
        if "k" not in cache or not rids:
            return
        committed = (list(q_lens) if committed is None
                     else [int(c) for c in committed])
        toks_dev = self._gather_new_kv_ragged(
            cache["k"], cache["v"], jnp.asarray(ctx, jnp.int32), qmax)
        dec = [i for i, m in enumerate(committed) if m == 1]
        chk = [i for i, m in enumerate(committed)
               if m > 1 and m == q_lens[i]]
        part = [i for i, m in enumerate(committed) if 1 < m < q_lens[i]]
        items = []
        if dec:
            toks1 = np.asarray(toks_dev[jnp.asarray(dec), 0])
            self.mirror_d2h_bytes += toks1.nbytes  # (n_dec, L, 2, K, D)
            items += [(rids[i], toks1[j]) for j, i in enumerate(dec)]
        if chk:
            toksn = np.asarray(toks_dev[jnp.asarray(chk)])
            self.mirror_d2h_bytes += toksn.nbytes  # (n_chk, qmax, L, 2, K, D)
            items += [(rids[i], toksn[j, :q_lens[i]].transpose(1, 2, 0, 3, 4))
                      for j, i in enumerate(chk)]
        for i in part:   # accepted run of a speculative row, tail dropped
            tk = np.asarray(toks_dev[i, :committed[i]])
            self.mirror_d2h_bytes += tk.nbytes     # (accepted, L, 2, K, D)
            items.append((rids[i], tk.transpose(1, 2, 0, 3, 4)))
        # append in original row order (FIFO drain order is per-seq, but
        # keep the schedule deterministic)
        items.sort(key=lambda kv: rids.index(kv[0]))
        self.tiered.append_many(items)

    def _mirror_prefill(self, rid: int, cache, n: int):
        """Mirror the whole prompt's KV as one batched append (sliced to the
        prompt's ``n`` live tokens on device, cast to fp16 before transfer)."""
        if "k" not in cache or n == 0:
            return
        toks = np.asarray(self._gather_prefill_kv(cache["k"], cache["v"], n))
        self.mirror_d2h_bytes += toks.nbytes
        if self._mirror_appends_ok:
            self.tiered.append(rid, toks)

    # ------------------------------------------------------------- generation
    def prefill_one(self, req: Request, n: Optional[int] = None,
                    tokens: Optional[np.ndarray] = None):
        """Prefill one request at batch=1 (the first ``n`` prompt tokens
        when chunked admission splits it) and land its KV in the tiered
        engine — mirrored as one batched append, or scattered into pool
        pages on device on the mirror-free path. ``tokens`` overrides the
        prompt for re-admission of a shed or crash-recovered row (its
        prompt plus already-committed tokens). Returns (logits, cache row)
        for the scheduler to admit."""
        src = req.prompt if tokens is None else tokens
        toks = src if n is None else src[:n]
        batch = {"tokens": jnp.asarray(toks[None, :])}
        self.jit_stats["prefill_calls"] += 1
        logits, cache = self._prefill(self.params, batch)
        if self.pooled:
            cache = self._pool_admit(req.rid, cache, toks.shape[0])
        else:
            self._mirror_prefill(req.rid, cache, toks.shape[0])
        return logits, cache

    def admit_prefix(self, req: Request):
        """Try a prefix-cache splice for ``req``: on a hit the sequence
        adopts the shared pool pages covering its longest cached prefix —
        ZERO prefill compute for the covered tokens (no ``_prefill`` call,
        no scatter) — and returns ``(cache_row, covered)``; the scheduler
        prefills only ``prompt[covered:]``. None on a miss or when sharing
        is off."""
        if self.prefix_cache is None:
            return None
        covered = self.prefix_cache.match_and_splice(req.rid, req.prompt)
        if covered <= 0:
            return None
        return {"pos": jnp.asarray([covered], jnp.int32)}, covered

    def on_prompt_complete(self, rid: int, prompt: np.ndarray) -> None:
        """A request's FULL prompt is now in the pool: publish its pages
        into the prefix index so later admissions can splice them."""
        if self.prefix_cache is not None:
            self.prefix_cache.insert(rid, prompt)

    def _pool_admit(self, rid: int, cache, n: int) -> dict:
        """Move a fresh prompt's prefilled cache into the engine-owned pool
        (one on-device scatter — zero device→host bytes) and shrink the
        row's cache to its position vector. Paged families scatter every
        descriptor plane into pool pages; the state-row family (SSM)
        commits the prompt-final state rows instead — either way the dense
        prefill cache is dropped and the row carries only ``pos``."""
        if n == 0:
            return {"pos": cache["pos"]}
        if not self.desc.has_pages:
            self.tiered.commit_state(
                [rid], [n],
                tuple(cache[p.name] for p in self.desc.seq_planes))
            return {"pos": cache["pos"]}
        phys = self.tiered.alloc_prefill(rid, n)
        pools = self._scatter_prefill(
            self.tiered.pool_views(),
            tuple(cache[p.name] for p in self.desc.paged_planes),
            jnp.asarray(phys, jnp.int32), n)
        self.tiered.commit_prefill_planes(pools, rid, n)
        return {"pos": cache["pos"]}

    def _count_step(self, path: str, width: int, qmax: int) -> None:
        """Track jitted-step shape reuse. The power-of-two bucketing ladder
        makes ``(path, width, qmax)`` a small fixed set, so after warmup
        every step is a cache hit — ``step_compiles`` stops growing with
        batch width / chunk size (pinned by tests/test_scheduler.py)."""
        self.jit_stats["step_calls"] += 1
        key = (path, width, qmax)
        if key in self._step_shapes:
            self.jit_stats["step_cache_hits"] += 1
        else:
            self._step_shapes.add(key)
            self.jit_stats["step_compiles"] += 1

    def decode_batch(self, rids: list, caches: list, tokens: list,
                     mirrored: bool):
        """One batched single-token decode step over per-sequence cache
        rows (the unfused baseline's batched launch, and the only batched
        path for model families without a ragged step).

        Mirror path: dense batched ``decode_step`` + one device→host token
        transfer per sequence, width-bucketed with dummy rows so
        ``_decode`` stops recompiling per batch width. Pooled path: the
        ragged step at ``q_len = 1`` — its masked scatter is what lets
        bucket-ladder padding rows exist without ever touching the shared
        device pool. Returns (logits, new cache rows).
        """
        if self.pooled:
            logit_rows, rows, _ = self.step_batch(
                rids, caches, [np.asarray([t], np.int32) for t in tokens],
                mirrored, fused=False)
            return jnp.concatenate(logit_rows, axis=0), rows
        B = len(caches)
        pad = batching.bucket_pow2(B) - B
        batch = batching.concat_rows(caches + [caches[0]] * pad)
        positions = batch["pos"]
        tok_arr = jnp.asarray(list(tokens) + [0] * pad, jnp.int32)[:, None]
        self._count_step("decode", B + pad, 1)
        logits, batch = self._decode(self.params, batch, tok_arr, positions)
        self.mirror_decode_batch(rids if mirrored else [], batch,
                                 np.asarray(positions))
        return logits[:B], [batching.split_row(batch, i) for i in range(B)]

    def publish_plan(self, rids: list, n_tokens: list) -> int:
        """Scheduler lookahead (ISSUE 8): next tick's planned batch — rids
        with the token slots each will claim. Pooled engines forward it to
        the async tiering pipeline, which starts H2D fault-ins for any
        spilled page of a planned row so ``prepare_step`` finds the
        transfer already in flight; everywhere else it is a no-op."""
        if not self.pooled:
            return 0
        return self.tiered.prefetch(rids, n_tokens)

    def can_step_fused(self, rids: list, n_tokens: list) -> bool:
        """Can this tick's mixed batch be placed in one fused step?
        Pooled engines answer through :meth:`KVCacheEngine.can_place_step`
        (prepare_step pins the whole batch, so a tight pool may need a
        preemption first — the scheduler's pre-step guard); the mirrored
        path always fits."""
        if not self.pooled:
            return True
        return self.tiered.can_place_step(rids, n_tokens)

    def _verify_drafts(self, logits, tok_rows, q_lens, spec) -> list:
        """Greedy draft verification against the SAME fused forward's
        per-slot logits. Row ``i``'s tokens are ``[t0, d1..ds]``
        (``s = spec[i]`` trailing drafts): slot ``j``'s argmax is the
        greedy token after consuming token ``j``, so draft ``d_{j+1}`` is
        accepted iff it equals ``argmax(slot j)`` AND every earlier draft
        was — the longest accepted prefix is exactly the sequential greedy
        run. Returns per-row committed counts (``1 + accepted``; chunk and
        plain decode rows commit everything)."""
        B = len(tok_rows)
        committed = list(q_lens)
        need = [i for i in range(B) if spec[i] > 0]
        if not need:
            return committed
        args = np.asarray(jnp.argmax(logits[:B], axis=-1))   # (B, Qb)
        for i in need:
            q, s = q_lens[i], spec[i]
            acc = 0
            for j in range(s):
                if int(tok_rows[i][q - s + j]) != int(args[i, q - s + j - 1]):
                    break
                acc += 1
            committed[i] = q - s + acc
            self.spec_stats["spec_proposed"] += s
            self.spec_stats["spec_accepted"] += acc
        return committed

    def step_batch(self, rids: list, caches: list, tok_rows: list,
                   mirrored: bool, fused: bool = True,
                   spec_lens: Optional[list] = None):
        """ONE fused forward over a mixed ragged batch — the tentpole
        launch: decode rows carry 1 new token (plus up to ``speculate_k``
        draft tokens when speculation is on), prefill-chunk rows up to
        ``chunk_tokens``, and all of them attend in the same jitted step
        (``model.step_paged_ragged`` over the device pool, or
        ``model.step_ragged`` over the dense mirror). Batch width and Qmax
        pad up the power-of-two ladder; padding rows ride with
        ``q_len = 0`` and are masked end to end.

        ``spec_lens[i]`` marks how many TRAILING tokens of ``tok_rows[i]``
        are unverified drafts: they scatter speculatively (the same masked
        ``mode="drop"`` discipline that protects padding), are verified
        against this forward's own per-slot logits, and the rejected tail
        rolls back before anything else sees it — partial ``commit_step``
        on the pooled path, truncated mirror transfer + a rewound ``pos``
        on the dense path.

        Returns ``(logit_rows, new_rows, committed)``: per-row logits for
        each row's committed slots (``(1, committed[i], V)`` — the LAST
        slot is what the next tick's argmax reads), the new per-row
        caches, and the per-row committed token counts.
        """
        B = len(rids)
        q_lens = [len(t) for t in tok_rows]
        spec = [0] * B if spec_lens is None else [int(s) for s in spec_lens]
        Bb = batching.bucket_pow2(B)
        Qb = batching.bucket_pow2(max(q_lens))
        tokens = np.zeros((Bb, Qb), np.int32)
        for i, t in enumerate(tok_rows):
            tokens[i, :len(t)] = t
        qarr = np.zeros(Bb, np.int32)
        qarr[:B] = q_lens
        tok_j = jnp.asarray(tokens)
        qlen_j = jnp.asarray(qarr)
        if fused:       # the unfused pooled decode reuses this entry at
            self.jit_stats["fused_steps"] += 1   # q_len=1; don't count it

        if self.pooled and not self.desc.has_pages:
            return self._step_state_batch(rids, caches, tok_rows, tok_j,
                                          qlen_j, q_lens, spec, Bb, Qb)
        if self.pooled:
            names = [p.name for p in self.desc.paged_planes]
            # fault containment (ISSUE 10 satellite): any exception between
            # prepare_step and commit_step — a lost host page surfacing as
            # LostPageError, a drift check, a kernel error — must rewind
            # the pages prepare_step allocated for this tick, or a poisoned
            # tick pins them forever (the pool leak the regression test in
            # tests/test_tiering.py hunts)
            try:
                tbl, ctx = self.tiered.prepare_step(rids, q_lens,
                                                    self.max_pages)
                model_pos = np.concatenate([np.asarray(c["pos"])
                                            for c in caches])
                if not np.array_equal(ctx, model_pos):
                    raise RuntimeError(
                        f"pool/table drift: engine lengths {ctx.tolist()} "
                        f"!= model positions {model_pos.tolist()}")
                tbl_p = np.zeros((Bb, self.max_pages), np.int32)
                tbl_p[:B] = tbl
                ctx_p = np.zeros(Bb, np.int32)
                ctx_p[:B] = ctx
                cache = {"block_table": jnp.asarray(tbl_p)}
                for n, v in zip(names, self.tiered.pool_views()):
                    cache["pool_" + n] = v
                self._count_step("pool", Bb, Qb)
                logits, out = self._step_paged_ragged(
                    self.params, cache, tok_j, jnp.asarray(ctx_p), qlen_j)
                committed = self._verify_drafts(logits, tok_rows, q_lens,
                                                spec)
                self.tiered.commit_step_planes(
                    tuple(out["pool_" + n] for n in names), rids, committed,
                    prepared=q_lens)
            except Exception:
                self.tiered.abort_step(rids)
                raise
            new_rows = [
                {"pos": out["pos"][i:i + 1]} if committed[i] == q_lens[i]
                else {"pos": jnp.asarray([int(ctx[i]) + committed[i]],
                                         jnp.int32)}
                for i in range(B)]
        else:
            batch = batching.concat_rows(caches + [caches[0]] * (Bb - B))
            ctx = batch["pos"]
            self._count_step("mirror", Bb, Qb)
            logits, nbatch = self._step_ragged(self.params, batch, tok_j,
                                               ctx, qlen_j)
            committed = self._verify_drafts(logits, tok_rows, q_lens, spec)
            if mirrored:
                self._mirror_step_ragged(rids, nbatch, ctx, q_lens, Qb,
                                         committed)
            nbatch = self._select_state_slots(nbatch, committed, B)
            new_rows = [batching.split_row(nbatch, i) for i in range(B)]
            ctx_np = np.asarray(ctx)
            for i in range(B):
                if committed[i] != q_lens[i]:
                    # rewind past the rejected tail: its dense-cache KV is
                    # masked (kv_pos > pos) and overwritten in place by the
                    # row's next committed tokens
                    new_rows[i]["pos"] = jnp.asarray(
                        [int(ctx_np[i]) + committed[i]], jnp.int32)
        logit_rows = [logits[i:i + 1, :committed[i]] for i in range(B)]
        return logit_rows, new_rows, committed

    def _step_state_batch(self, rids: list, caches: list, tok_rows: list,
                          tok_j, qlen_j, q_lens: list, spec: list,
                          Bb: int, Qb: int):
        """Fused ragged tick for the state-row (SSM) family: the engine's
        pool holds per-sequence state rows instead of pages, so the tick
        reads them back as batched views, runs the ragged state scan (which
        emits PER-SLOT states), and commits each row's committed slot —
        committing an earlier slot IS the speculative rollback, and a
        fully-rejected or padding row (``committed == 0``) commits nothing.
        Zero device→host bytes, same as the paged branch."""
        B = len(rids)
        ctx = np.concatenate([np.asarray(c["pos"]) for c in caches])
        eng_len = [int(self.tiered.seq_len.get(r, 0)) for r in rids]
        if eng_len != [int(c) for c in ctx]:
            raise RuntimeError(
                f"state-row drift: engine lengths {eng_len} != model "
                f"positions {ctx.tolist()}")
        ctx_p = np.zeros(Bb, np.int32)
        ctx_p[:B] = ctx
        # bucket-ladder padding rows replicate row 0's state: they carry
        # q_len = 0, so their outputs are discarded and nothing commits
        views = self.tiered.state_views(list(rids) + [rids[0]] * (Bb - B))
        cache = {p.name: v for p, v in zip(self.desc.seq_planes, views)}
        self._count_step("pool", Bb, Qb)
        logits, out = self._step_paged_ragged(
            self.params, cache, tok_j, jnp.asarray(ctx_p), qlen_j)
        committed = self._verify_drafts(logits, tok_rows, q_lens, spec)
        states = []
        for j, p in enumerate(self.desc.seq_planes):
            steps = out[p.name + "_steps"]       # (L, Qmax, B, ...)
            states.append(jnp.stack(
                [steps[:, committed[i] - 1, i] if committed[i] > 0
                 else views[j][:, i] for i in range(B)], axis=1))
        self.tiered.commit_state(rids, committed, tuple(states))
        new_rows = [{"pos": jnp.asarray([int(ctx[i]) + committed[i]],
                                        jnp.int32)} for i in range(B)]
        logit_rows = [logits[i:i + 1, :committed[i]] for i in range(B)]
        return logit_rows, new_rows, committed

    def _select_state_slots(self, batch: dict, committed: list, B: int):
        """Mirror-path twin of the state commit: fold the ragged SSM step's
        per-slot state stacks (``<plane>_steps``, shaped
        ``(L, Qmax, B, ...)``) down to each row's committed slot before the
        batch splits back into rows. Rows with ``committed == 0`` keep the
        step's INPUT state (the rolled-back row re-plans next tick); the
        ``_steps`` stacks never leave this method."""
        step_keys = [k for k in batch if k.endswith("_steps")]
        if not step_keys:
            return batch
        out = {k: v for k, v in batch.items() if k not in step_keys}
        for key in step_keys:
            name = key[:-len("_steps")]
            steps = batch[key]
            out[name] = jnp.stack(
                [steps[:, committed[i] - 1, i] if i < B and committed[i] > 0
                 else batch[name][:, i] for i in range(steps.shape[2])],
                axis=1)
        return out

    def extend_one(self, rid: int, cache, toks: np.ndarray, start: int,
                   mirrored: bool):
        """UNFUSED fallback (``fuse_ticks=False`` or a family without a
        ragged step): process ``toks`` additional prompt tokens for one
        admitted row, each token through the decode path at batch=1; the
        chunk's KV lands in the tiered engine as ONE batched append
        (mirror path) or directly in its pool pages (pooled path —
        per-token page allocation, still zero device→host bytes). The
        fused path replaces all of this with the chunk riding inside
        :meth:`step_batch`. Returns (logits, cache) positioned after the
        chunk."""
        logits = None
        if self.pooled and not self.desc.has_pages:
            # state-row family: check the rows out of the engine, run the
            # chunk through decode_step at batch=1, commit the final state
            views = self.tiered.state_views([rid])
            pc = {"pos": cache["pos"]}
            for p, v in zip(self.desc.seq_planes, views):
                pc[p.name] = v
            for t in toks:
                self._count_step("pool-chunk1", 1, 1)
                logits, pc = self._decode(
                    self.params, pc, jnp.asarray([[int(t)]], jnp.int32),
                    pc["pos"])
            self.tiered.commit_state(
                [rid], [len(toks)],
                tuple(pc[p.name] for p in self.desc.seq_planes))
            return logits, {"pos": pc["pos"]}
        if self.pooled:
            names = [p.name for p in self.desc.paged_planes]
            for t in toks:
                tbl, _ = self.tiered.prepare_decode([rid], self.max_pages)
                pc = {"pos": cache["pos"],
                      "block_table": jnp.asarray(tbl)}
                for n, v in zip(names, self.tiered.pool_views()):
                    pc["pool_" + n] = v
                self._count_step("pool-chunk1", 1, 1)
                logits, out = self._decode_paged(
                    self.params, pc, jnp.asarray([[int(t)]], jnp.int32),
                    cache["pos"])
                self.tiered.commit_step_planes(
                    tuple(out["pool_" + n] for n in names), [rid], [1])
                cache = {"pos": out["pos"]}
            return logits, cache
        for t in toks:
            self._count_step("mirror-chunk1", 1, 1)
            logits, cache = self._decode(
                self.params, cache, jnp.asarray([[int(t)]], jnp.int32),
                cache["pos"])
        if mirrored and len(toks):
            kv = np.asarray(self._gather_kv_range(
                cache["k"], cache["v"], start, start + len(toks)))
            self.mirror_d2h_bytes += kv.nbytes
            self.tiered.append(rid, kv)
        return logits, cache

    def degraded(self) -> bool:
        """True once persistent async transfer faults flipped the tiering
        pipeline to its synchronous fallback (the degradation ladder's
        second rung — see engines/README.md)."""
        pipe = getattr(self.tiered, "_pipeline", None)
        return bool(pipe is not None and pipe.degraded)

    def generate(self, requests: list[Request]) -> list[Request]:
        """Continuous-batching decode: all requests share one running batch,
        stepped together and preempted/restored under HBM pressure. Greedy
        outputs are token-identical to :meth:`generate_sequential`."""
        from repro.serving.scheduler import Scheduler
        sched = Scheduler(self, requests)
        try:
            sched.run()
        finally:
            # a CrashFault abandons the run mid-tick, but the scheduler
            # counters gathered so far are still what the caller inspects
            self.sched_stats = sched.stats.as_dict()
        self.tiered.flush_transfers()   # run-end drain: sim_time_s includes
        return requests                 # in-flight transfer tails

    def recover(self, requests: list[Request]) -> list[Request]:
        """Crash recovery (ISSUE 10): replay the journal this engine shares
        with the crashed one, rebuild each request's committed stream, and
        resume decoding the unfinished rows through the normal scheduler —
        re-admission prefills ``prompt + committed`` so greedy decode
        continues exactly where the last durable tick stopped. The result
        is token-identical to an uninterrupted run (property-tested).
        ``requests`` must be fresh Request objects carrying the original
        prompts/rids; their ``generated`` fields are overwritten from the
        journal."""
        if self.journal is None:
            raise RuntimeError(
                "recover() needs the crashed run's journal: construct this "
                "engine with ServeConfig(journal=<same ServingJournal>)")
        state, _last_tick = self.journal.replay()
        pending = []
        for req in requests:
            toks = state.get(req.rid, [])
            req.generated = [int(t) for t in toks[:req.max_new]]
            req.done = len(req.generated) >= req.max_new
            if not req.done:
                pending.append(req)
        if pending:
            self.generate(pending)
        return requests

    def generate_sequential(self, requests: list[Request]) -> list[Request]:
        """Sequential reference: one request at a time, batch=1 decode over
        the dense cache with the mirrored tiered append — ALWAYS, even on a
        pool-enabled engine, because this is the reference the pooled path
        must match token-for-token."""
        for req in requests:
            batch = {"tokens": jnp.asarray(req.prompt[None, :])}
            logits, cache = self._prefill(self.params, batch)
            self._mirror_prefill(req.rid, cache, req.prompt.shape[0])
            for _ in range(req.max_new):
                nxt = int(jnp.argmax(logits[:, -1], -1)[0])
                req.generated.append(nxt)
                pos = cache["pos"]
                logits, cache = self._decode(
                    self.params, cache, jnp.asarray([[nxt]], jnp.int32), pos)
                self._mirror_kv(req.rid, cache, int(pos[0]))
            req.done = True
        return requests

    def stats(self) -> dict:
        journal = {} if self.journal is None else dict(self.journal.stats)
        return {"sim_time_s": self.clock.now,
                "mirror_d2h_bytes": self.mirror_d2h_bytes,
                **self.jit_stats, **self.spec_stats, **self.sched_stats,
                **journal, **self.tiered.stats}
