"""Batched serving engine: continuous-batching decode over the tiered KV
cache (DESIGN.md §2a).

The engine keeps the model's working KV cache in "HBM" (device arrays) and
mirrors every appended token into the tiered cache so sequences can be
preempted/offloaded and restored — the serving translation of the paper's
cache. The tiered mirror is a :class:`repro.core.engines.kv.KVCacheEngine`
constructed through the KV registry from the same :class:`EngineSpec` the
FS tier uses, so a serving config and an FS config are one object. Prefill
mirrors as ONE batched append (a large write — under ``kvhybrid`` it routes
to the page side), decode steps as single-token appends (small writes — the
log side). The mirror's simulated tier-times and amplification stats are
what kvcache_bench reports against the paper's expectations.

``generate()`` runs requests through the continuous-batching
:class:`~repro.serving.scheduler.Scheduler`: requests are admitted into a
running batch, every scheduler tick steps the whole batch through a single
batched ``decode_step``, and sequences are preempted to the disk tier (and
later restored) when the engine's HBM accounting hits its budget.
``generate_sequential()`` keeps the one-request-at-a-time loop as the
reference implementation the scheduler must match token-for-token.

Mirror transfers are sliced **on device**: each decode step moves exactly
one ``(L, 2, K, D)`` float16 token per sequence over the device→host link
(counted in ``stats()["mirror_d2h_bytes"]``), never a whole cache row.

**Mirror-free pooled decode (ISSUE 4).** When the KV engine owns a device
-resident page pool (``paged``) and the model family supports it, the
dense mirror disappears entirely: admission scatters the prompt's prefilled
KV into pool pages on device, every decode step runs
``model.decode_step_paged`` — the ``paged_attention`` Pallas kernel over
the pool with block-table indirection — and the engine's block-table/LRU
accounting advances through ``prepare_decode``/``commit_decode`` with no
device→host copy at all: ``mirror_d2h_bytes`` stays **zero** on this path
(pinned by test). Engines without a pool (``log``, ``kvhybrid``) and model
families without a plain (k, v) cache fall back to the mirrored path
transparently; ``ServeConfig.paged_decode`` forces either path.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clock import SimClock
from repro.core.engines import EngineSpec, create_kv_engine
from repro.core.kvcache import KVSpec
from repro.serving import batching


@dataclass
class ServeConfig:
    # field order keeps legacy positional construction working: the new
    # engine_spec field comes last
    max_len: int = 512
    design: Optional[str] = None   # legacy switch: "log" | "paged" | name
    page_tokens: int = 16          # geometry (KVSpec): composes with either
    hbm_budget_bytes: Optional[int] = None   # legacy → EngineSpec.kv_hbm_bytes
    hot_window_tokens: Optional[int] = None  # legacy → EngineSpec.kv_hot_window
    greedy: bool = True
    # the shared config object; None → built from the legacy fields above
    engine_spec: Optional[EngineSpec] = None
    # continuous-batching scheduler knobs
    max_batch_seqs: int = 8        # running-batch width cap
    max_batch_tokens: Optional[int] = None   # running-batch token cap
    min_running: int = 1           # preemption floor: progress guarantee
    # mirror-free pooled decode: None = auto (pooled when the engine has a
    # device page pool AND the model family supports paged decode), True =
    # require it (raise if unsupported), False = always mirror
    paged_decode: Optional[bool] = None
    # chunked prefill: prompts longer than this admit chunk by chunk across
    # ticks (None → max_batch_tokens; chunking off when both are None)
    prefill_chunk_tokens: Optional[int] = None

    def resolved_spec(self) -> EngineSpec:
        """One EngineSpec no matter which knobs the caller used.

        Mixing a full ``engine_spec`` with the legacy tier knobs raises:
        silently preferring one of the two would run a wrong config (same
        loud-conflict rule as ``CheckpointManager``/``NVCacheFS``).
        """
        legacy = {k: v for k, v in
                  (("design", self.design),
                   ("hbm_budget_bytes", self.hbm_budget_bytes),
                   ("hot_window_tokens", self.hot_window_tokens))
                  if v is not None}
        if self.engine_spec is not None:
            if not isinstance(self.engine_spec, EngineSpec):
                raise TypeError(
                    f"engine_spec must be an EngineSpec, got "
                    f"{type(self.engine_spec).__name__!s}: "
                    f"{self.engine_spec!r}")
            if legacy:
                raise TypeError(
                    f"pass KV-tier parameters inside engine_spec, not as "
                    f"ServeConfig fields (got both a spec and "
                    f"{sorted(legacy)})")
            return self.engine_spec
        return EngineSpec(
            engine=self.design or "log",
            kv_hbm_bytes=(64 << 20 if self.hbm_budget_bytes is None
                          else self.hbm_budget_bytes),
            kv_hot_window=(128 if self.hot_window_tokens is None
                           else self.hot_window_tokens))


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    generated: list = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        mcfg = model.cfg
        self.clock = SimClock()
        kv_heads = max(mcfg.num_kv_heads, 1)
        head_dim = max(mcfg.head_dim, 1)
        spec = KVSpec(num_layers=mcfg.num_layers, kv_heads=kv_heads,
                      head_dim=head_dim, page_tokens=cfg.page_tokens)
        self.tiered = create_kv_engine(cfg.resolved_spec(), spec, self.clock)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cfg.max_len))
        self._decode = jax.jit(model.decode_step)
        self._gather_new_kv = jax.jit(batching.gather_new_kv)
        self._gather_prefill_kv = jax.jit(batching.gather_prefill_kv,
                                          static_argnums=2)
        self._gather_kv_range = jax.jit(batching.gather_kv_range,
                                        static_argnums=(2, 3))
        self.mirror_d2h_bytes = 0      # device→host mirror traffic (exact)
        self.sched_stats: dict = {}    # last generate()'s scheduler counters
        # ------------------------------------------- mirror-free pooled path
        self.max_pages = -(-cfg.max_len // cfg.page_tokens)
        pool_dtype = np.dtype(model.compute_dtype)
        # liveness floor: the pool must hold one max-length sequence plus a
        # reserve page, or a lone running sequence could exhaust it with
        # nothing left to preempt
        group_bytes = (mcfg.num_layers * 2 * cfg.page_tokens * kv_heads
                       * head_dim * pool_dtype.itemsize)
        budget_pages = cfg.resolved_spec().kv_hbm_bytes // group_bytes
        pool_fits = budget_pages >= self.max_pages + 1
        pool_ok = (self.tiered.supports_pool()
                   and model.supports_paged_decode())
        if cfg.paged_decode and not (pool_ok and pool_fits):
            raise ValueError(
                f"paged_decode=True needs a pool-capable KV engine, a "
                f"dense-GQA model, and an HBM budget of at least "
                f"{self.max_pages + 1} pool pages; got engine="
                f"{self.tiered.engine_name!r} (supports_pool="
                f"{self.tiered.supports_pool()}), family="
                f"{model.cfg.family!r}, budget_pages={budget_pages}")
        self.pooled = (pool_ok and pool_fits) if cfg.paged_decode is None \
            else bool(cfg.paged_decode)
        if self.pooled:
            if cfg.max_len % cfg.page_tokens:
                raise ValueError(
                    f"pooled decode needs max_len ({cfg.max_len}) to be a "
                    f"multiple of page_tokens ({cfg.page_tokens})")
            # the pool is the model's decode cache: same dtype as the dense
            # path so pooled decode is numerically identical to it
            self.tiered.init_pool(dtype=pool_dtype)
            self._decode_paged = jax.jit(model.decode_step_paged)
            self._scatter_prefill = jax.jit(batching.scatter_prefill_pages,
                                            static_argnums=5)

    # -------------------------------------------------------------- mirroring
    def _mirror_kv(self, rid: int, cache, pos: int):
        """Mirror the newly appended token's KV into the tiered cache.

        The ``(L, K, D)`` token is sliced and stacked ON DEVICE
        (:func:`batching.gather_new_kv`) so only the single fp16 token
        crosses the device→host link — never the whole padded cache row.
        """
        if "k" not in cache:
            return                      # SSM-family: O(1) state, nothing to page
        tok = np.asarray(self._gather_new_kv(
            cache["k"], cache["v"], jnp.asarray([pos], jnp.int32)))[0]
        self.mirror_d2h_bytes += tok.nbytes
        self.tiered.append(rid, tok)

    def mirror_decode_batch(self, rids: list, cache, positions) -> None:
        """Mirror one decode step's tokens for a whole running batch: one
        on-device gather, ONE device→host transfer of ``(B, L, 2, K, D)``
        fp16, one batched ``append_many`` into the tiered engine."""
        if "k" not in cache or not rids:
            return
        toks = np.asarray(self._gather_new_kv(
            cache["k"], cache["v"], jnp.asarray(positions, jnp.int32)))
        self.mirror_d2h_bytes += toks.nbytes
        self.tiered.append_many(
            [(rid, toks[i]) for i, rid in enumerate(rids)])

    def _mirror_prefill(self, rid: int, cache, n: int):
        """Mirror the whole prompt's KV as one batched append (sliced to the
        prompt's ``n`` live tokens on device, cast to fp16 before transfer)."""
        if "k" not in cache or n == 0:
            return
        toks = np.asarray(self._gather_prefill_kv(cache["k"], cache["v"], n))
        self.mirror_d2h_bytes += toks.nbytes
        self.tiered.append(rid, toks)

    # ------------------------------------------------------------- generation
    def prefill_one(self, req: Request, n: Optional[int] = None):
        """Prefill one request at batch=1 (the first ``n`` prompt tokens
        when chunked admission splits it) and land its KV in the tiered
        engine — mirrored as one batched append, or scattered into pool
        pages on device on the mirror-free path. Returns (logits, cache
        row) for the scheduler to admit."""
        toks = req.prompt if n is None else req.prompt[:n]
        batch = {"tokens": jnp.asarray(toks[None, :])}
        logits, cache = self._prefill(self.params, batch)
        if self.pooled:
            cache = self._pool_admit(req.rid, cache, toks.shape[0])
        else:
            self._mirror_prefill(req.rid, cache, toks.shape[0])
        return logits, cache

    def _pool_admit(self, rid: int, cache, n: int) -> dict:
        """Move a fresh prompt's prefilled KV into the device pool (one
        on-device scatter — zero device→host bytes) and shrink the row's
        cache to its position vector."""
        if n == 0:
            return {"pos": cache["pos"]}
        phys = self.tiered.alloc_prefill(rid, n)
        pool_k, pool_v = self.tiered.pool_views()
        pool_k, pool_v = self._scatter_prefill(
            pool_k, pool_v, cache["k"], cache["v"],
            jnp.asarray(phys, jnp.int32), n)
        self.tiered.commit_prefill(pool_k, pool_v, rid, n)
        return {"pos": cache["pos"]}

    def decode_batch(self, rids: list, caches: list, tokens: list,
                     mirrored: bool):
        """One batched decode step over per-sequence cache rows.

        Mirror path: dense batched ``decode_step`` + one device→host token
        transfer per sequence. Pooled path: ``decode_step_paged`` directly
        over the engine's device page pool (block-table indirection inside
        the kernel) — the engine's page accounting advances through
        ``prepare_decode``/``commit_decode`` and nothing crosses the
        device→host link. Returns (logits, new cache rows).
        """
        batch = batching.concat_rows(caches)
        positions = batch["pos"]
        tok_arr = jnp.asarray(tokens, jnp.int32)[:, None]
        if self.pooled:
            tbl, lens = self.tiered.prepare_decode(rids, self.max_pages)
            if not np.array_equal(lens, np.asarray(positions)):
                raise RuntimeError(
                    f"pool/table drift: engine lengths {lens.tolist()} != "
                    f"model positions {np.asarray(positions).tolist()}")
            pool_k, pool_v = self.tiered.pool_views()
            cache = {"pos": positions, "pool_k": pool_k, "pool_v": pool_v,
                     "block_table": jnp.asarray(tbl)}
            logits, out = self._decode_paged(self.params, cache, tok_arr,
                                             positions)
            self.tiered.commit_decode(out["pool_k"], out["pool_v"], rids)
            batch = {"pos": out["pos"]}
        else:
            logits, batch = self._decode(self.params, batch, tok_arr,
                                         positions)
            self.mirror_decode_batch(rids if mirrored else [], batch,
                                     np.asarray(positions))
        return logits, [batching.split_row(batch, i)
                        for i in range(len(caches))]

    def extend_one(self, rid: int, cache, toks: np.ndarray, start: int,
                   mirrored: bool):
        """Process ``toks`` additional prompt tokens for one admitted row
        (chunked prefill): each token runs through the decode path at
        batch=1, and the chunk's KV lands in the tiered engine as ONE
        batched append (mirror path) or directly in its pool pages (pooled
        path — per-token page allocation, still zero device→host bytes).
        Returns (logits, cache) positioned after the chunk."""
        logits = None
        if self.pooled:
            for t in toks:
                tbl, _ = self.tiered.prepare_decode([rid], self.max_pages)
                pc = {"pos": cache["pos"],
                      "block_table": jnp.asarray(tbl)}
                pc["pool_k"], pc["pool_v"] = self.tiered.pool_views()
                logits, out = self._decode_paged(
                    self.params, pc, jnp.asarray([[int(t)]], jnp.int32),
                    cache["pos"])
                self.tiered.commit_decode(out["pool_k"], out["pool_v"],
                                          [rid])
                cache = {"pos": out["pos"]}
            return logits, cache
        for t in toks:
            logits, cache = self._decode(
                self.params, cache, jnp.asarray([[int(t)]], jnp.int32),
                cache["pos"])
        if mirrored and len(toks):
            kv = np.asarray(self._gather_kv_range(
                cache["k"], cache["v"], start, start + len(toks)))
            self.mirror_d2h_bytes += kv.nbytes
            self.tiered.append(rid, kv)
        return logits, cache

    def generate(self, requests: list[Request]) -> list[Request]:
        """Continuous-batching decode: all requests share one running batch,
        stepped together and preempted/restored under HBM pressure. Greedy
        outputs are token-identical to :meth:`generate_sequential`."""
        from repro.serving.scheduler import Scheduler
        sched = Scheduler(self, requests)
        sched.run()
        self.sched_stats = sched.stats.as_dict()
        return requests

    def generate_sequential(self, requests: list[Request]) -> list[Request]:
        """Sequential reference: one request at a time, batch=1 decode over
        the dense cache with the mirrored tiered append — ALWAYS, even on a
        pool-enabled engine, because this is the reference the pooled path
        must match token-for-token."""
        for req in requests:
            batch = {"tokens": jnp.asarray(req.prompt[None, :])}
            logits, cache = self._prefill(self.params, batch)
            self._mirror_prefill(req.rid, cache, req.prompt.shape[0])
            for _ in range(req.max_new):
                nxt = int(jnp.argmax(logits[:, -1], -1)[0])
                req.generated.append(nxt)
                pos = cache["pos"]
                logits, cache = self._decode(
                    self.params, cache, jnp.asarray([[nxt]], jnp.int32), pos)
                self._mirror_kv(req.rid, cache, int(pos[0]))
            req.done = True
        return requests

    def stats(self) -> dict:
        return {"sim_time_s": self.clock.now,
                "mirror_d2h_bytes": self.mirror_d2h_bytes,
                **self.sched_stats, **self.tiered.stats}
