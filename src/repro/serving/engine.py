"""Batched serving engine: continuous-batching decode over the tiered KV
cache (DESIGN.md §2a).

The engine keeps the model's working KV cache in "HBM" (device arrays) and
mirrors every appended token into the tiered cache so sequences can be
preempted/offloaded and restored — the serving translation of the paper's
cache. The tiered mirror is a :class:`repro.core.engines.kv.KVCacheEngine`
constructed through the KV registry from the same :class:`EngineSpec` the
FS tier uses, so a serving config and an FS config are one object. Prefill
mirrors as ONE batched append (a large write — under ``kvhybrid`` it routes
to the page side), decode steps as single-token appends (small writes — the
log side). The mirror's simulated tier-times and amplification stats are
what kvcache_bench reports against the paper's expectations.

``generate()`` runs requests through the continuous-batching
:class:`~repro.serving.scheduler.Scheduler`: requests are admitted into a
running batch, every scheduler tick steps the whole batch through a single
batched ``decode_step``, and sequences are preempted to the disk tier (and
later restored) when the engine's HBM accounting hits its budget.
``generate_sequential()`` keeps the one-request-at-a-time loop as the
reference implementation the scheduler must match token-for-token.

Mirror transfers are sliced **on device**: each decode step moves exactly
one ``(L, 2, K, D)`` float16 token per sequence over the device→host link
(counted in ``stats()["mirror_d2h_bytes"]``), never a whole cache row.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clock import SimClock
from repro.core.engines import EngineSpec, create_kv_engine
from repro.core.kvcache import KVSpec
from repro.serving import batching


@dataclass
class ServeConfig:
    # field order keeps legacy positional construction working: the new
    # engine_spec field comes last
    max_len: int = 512
    design: Optional[str] = None   # legacy switch: "log" | "paged" | name
    page_tokens: int = 16          # geometry (KVSpec): composes with either
    hbm_budget_bytes: Optional[int] = None   # legacy → EngineSpec.kv_hbm_bytes
    hot_window_tokens: Optional[int] = None  # legacy → EngineSpec.kv_hot_window
    greedy: bool = True
    # the shared config object; None → built from the legacy fields above
    engine_spec: Optional[EngineSpec] = None
    # continuous-batching scheduler knobs
    max_batch_seqs: int = 8        # running-batch width cap
    max_batch_tokens: Optional[int] = None   # running-batch token cap
    min_running: int = 1           # preemption floor: progress guarantee

    def resolved_spec(self) -> EngineSpec:
        """One EngineSpec no matter which knobs the caller used.

        Mixing a full ``engine_spec`` with the legacy tier knobs raises:
        silently preferring one of the two would run a wrong config (same
        loud-conflict rule as ``CheckpointManager``/``NVCacheFS``).
        """
        legacy = {k: v for k, v in
                  (("design", self.design),
                   ("hbm_budget_bytes", self.hbm_budget_bytes),
                   ("hot_window_tokens", self.hot_window_tokens))
                  if v is not None}
        if self.engine_spec is not None:
            if not isinstance(self.engine_spec, EngineSpec):
                raise TypeError(
                    f"engine_spec must be an EngineSpec, got "
                    f"{type(self.engine_spec).__name__!s}: "
                    f"{self.engine_spec!r}")
            if legacy:
                raise TypeError(
                    f"pass KV-tier parameters inside engine_spec, not as "
                    f"ServeConfig fields (got both a spec and "
                    f"{sorted(legacy)})")
            return self.engine_spec
        return EngineSpec(
            engine=self.design or "log",
            kv_hbm_bytes=(64 << 20 if self.hbm_budget_bytes is None
                          else self.hbm_budget_bytes),
            kv_hot_window=(128 if self.hot_window_tokens is None
                           else self.hot_window_tokens))


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    generated: list = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        mcfg = model.cfg
        self.clock = SimClock()
        kv_heads = max(mcfg.num_kv_heads, 1)
        head_dim = max(mcfg.head_dim, 1)
        spec = KVSpec(num_layers=mcfg.num_layers, kv_heads=kv_heads,
                      head_dim=head_dim, page_tokens=cfg.page_tokens)
        self.tiered = create_kv_engine(cfg.resolved_spec(), spec, self.clock)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cfg.max_len))
        self._decode = jax.jit(model.decode_step)
        self._gather_new_kv = jax.jit(batching.gather_new_kv)
        self._gather_prefill_kv = jax.jit(batching.gather_prefill_kv,
                                          static_argnums=2)
        self.mirror_d2h_bytes = 0      # device→host mirror traffic (exact)
        self.sched_stats: dict = {}    # last generate()'s scheduler counters

    # -------------------------------------------------------------- mirroring
    def _mirror_kv(self, rid: int, cache, pos: int):
        """Mirror the newly appended token's KV into the tiered cache.

        The ``(L, K, D)`` token is sliced and stacked ON DEVICE
        (:func:`batching.gather_new_kv`) so only the single fp16 token
        crosses the device→host link — never the whole padded cache row.
        """
        if "k" not in cache:
            return                      # SSM-family: O(1) state, nothing to page
        tok = np.asarray(self._gather_new_kv(
            cache["k"], cache["v"], jnp.asarray([pos], jnp.int32)))[0]
        self.mirror_d2h_bytes += tok.nbytes
        self.tiered.append(rid, tok)

    def mirror_decode_batch(self, rids: list, cache, positions) -> None:
        """Mirror one decode step's tokens for a whole running batch: one
        on-device gather, ONE device→host transfer of ``(B, L, 2, K, D)``
        fp16, one batched ``append_many`` into the tiered engine."""
        if "k" not in cache or not rids:
            return
        toks = np.asarray(self._gather_new_kv(
            cache["k"], cache["v"], jnp.asarray(positions, jnp.int32)))
        self.mirror_d2h_bytes += toks.nbytes
        self.tiered.append_many(
            [(rid, toks[i]) for i, rid in enumerate(rids)])

    def _mirror_prefill(self, rid: int, cache, n: int):
        """Mirror the whole prompt's KV as one batched append (sliced to the
        prompt's ``n`` live tokens on device, cast to fp16 before transfer)."""
        if "k" not in cache or n == 0:
            return
        toks = np.asarray(self._gather_prefill_kv(cache["k"], cache["v"], n))
        self.mirror_d2h_bytes += toks.nbytes
        self.tiered.append(rid, toks)

    # ------------------------------------------------------------- generation
    def prefill_one(self, req: Request):
        """Prefill one request at batch=1 and mirror its prompt KV; returns
        (logits, cache row) for the scheduler to admit."""
        batch = {"tokens": jnp.asarray(req.prompt[None, :])}
        logits, cache = self._prefill(self.params, batch)
        self._mirror_prefill(req.rid, cache, req.prompt.shape[0])
        return logits, cache

    def generate(self, requests: list[Request]) -> list[Request]:
        """Continuous-batching decode: all requests share one running batch,
        stepped together and preempted/restored under HBM pressure. Greedy
        outputs are token-identical to :meth:`generate_sequential`."""
        from repro.serving.scheduler import Scheduler
        sched = Scheduler(self, requests)
        sched.run()
        self.sched_stats = sched.stats.as_dict()
        return requests

    def generate_sequential(self, requests: list[Request]) -> list[Request]:
        """Sequential reference: one request at a time, batch=1 decode. The
        scheduler's batched path must match this token-for-token."""
        for req in requests:
            logits, cache = self.prefill_one(req)
            for _ in range(req.max_new):
                nxt = int(jnp.argmax(logits[:, -1], -1)[0])
                req.generated.append(nxt)
                pos = cache["pos"]
                logits, cache = self._decode(
                    self.params, cache, jnp.asarray([[nxt]], jnp.int32), pos)
                self._mirror_kv(req.rid, cache, int(pos[0]))
            req.done = True
        return requests

    def stats(self) -> dict:
        return {"sim_time_s": self.clock.now,
                "mirror_d2h_bytes": self.mirror_d2h_bytes,
                **self.sched_stats, **self.tiered.stats}
