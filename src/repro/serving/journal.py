"""Crash-consistent serving journal over the NVMM log tier (ISSUE 10).

The paper's thesis applied to serving: NVLog survives power loss because
every mutation hits a sequential write-ahead log before it is acknowledged,
while page-structured state must be reconstructed. The serving tier's
equivalent of "acknowledged state" is the committed token stream — so each
scheduler tick appends one CRC-framed record of that tick's committed
tokens through the same :class:`~repro.core.wal.CircularWAL` machinery the
log engines persist through, charged to the analytic clock as a sequential
NVMM write (the cheap append the paper measures).

Record format (JSON payloads inside WAL frames):

* tick record  — ``{"t": tick, "c": [[rid, start, [tok, ...]], ...]}``:
  request ``rid`` committed ``len(toks)`` tokens starting at generated
  index ``start``. Records are idempotent under replay (``start`` is an
  absolute index, so re-applying writes the same values in place), which
  is what makes a crash *during recovery* re-replayable.
* snapshot record — ``{"t": tick, "snap": {rid: [tok, ...]}}``: the full
  committed state at compaction time. The WAL is circular; when an append
  finds it full the journal reclaims everything and seeds the new tail
  with a snapshot, so replay never needs records that were reclaimed.

Replay rule: scan durable records oldest→newest (``recover_scan`` stops at
the first torn or out-of-sequence frame — a crash mid-append simply loses
that tick's record, never corrupts earlier ones); a snapshot resets the
state, a tick record overlays its commits. The recovered map {rid →
committed tokens} plus the original request list is everything
:meth:`ServingEngine.recover` needs to rebuild rows and resume decoding.
"""
from __future__ import annotations

import json
from typing import Optional

from repro.core.wal import CircularWAL
from repro.roofline.hw import NVMM


class ServingJournal:
    """Per-tick committed-token journal with snapshot compaction."""

    def __init__(self, capacity: int = 1 << 20,
                 clock=None, charge_clock: bool = True):
        self.wal = CircularWAL(capacity)
        self.clock = clock
        self.charge_clock = charge_clock
        self._state: dict[int, list] = {}     # rid → committed tokens
        self._tick = -1
        self.stats = {"journal_appends": 0, "journal_bytes": 0,
                      "journal_compactions": 0}

    def attach_clock(self, clock) -> None:
        """A recovered engine re-attaches its (fresh) clock — the WAL region
        survives the crash, the clock does not."""
        self.clock = clock

    # -- append -------------------------------------------------------------
    def _charge(self, nbytes: int) -> None:
        if self.clock is not None and self.charge_clock:
            # sequential NVMM append on the foreground: the WAL persist is
            # the acknowledgement point, so it is critical-path time
            self.clock.charge(NVMM, "write", nbytes, random_access=False)

    def _append(self, obj: dict) -> None:
        payload = json.dumps(obj, separators=(",", ":"),
                             sort_keys=True).encode()
        try:
            self.wal.append(0, payload)
        except BufferError:
            self._compact()
            self.wal.append(0, payload)   # snapshot guarantees room or raises
        self.stats["journal_appends"] += 1
        self.stats["journal_bytes"] += len(payload)
        self._charge(len(payload))

    def append_tick(self, tick: int, commits) -> None:
        """Persist one tick: ``commits`` is ``[(rid, start, tokens), ...]``
        (``start`` = the row's generated length before this tick's tokens).
        Folds the commits into the in-memory state first so a compaction
        triggered by this very append snapshots a superset — replaying the
        tick record over the snapshot is idempotent."""
        for rid, start, toks in commits:
            self._apply(self._state, int(rid), int(start), toks)
        self._tick = tick
        self._append({"t": tick,
                      "c": [[int(rid), int(start),
                             [int(t) for t in toks]]
                            for rid, start, toks in commits]})

    def _compact(self) -> None:
        """Reclaim the full ring and seed it with a snapshot of the current
        committed state. Runs atomically inside an append (crashes fire at
        tick boundaries, never inside one), so the reclaim+snapshot pair is
        never torn apart by a simulated crash."""
        self.wal.reclaim_to(self.wal.head, self.wal.next_seqno)
        payload = json.dumps(
            {"t": self._tick,
             "snap": {str(r): [int(t) for t in toks]
                      for r, toks in sorted(self._state.items())}},
            separators=(",", ":"), sort_keys=True).encode()
        if self.wal.record_size(len(payload)) > self.wal.capacity:
            raise BufferError(
                f"journal capacity {self.wal.capacity} cannot hold one "
                f"snapshot ({len(payload)} bytes); raise the capacity")
        self.wal.append(0, payload)
        self.stats["journal_compactions"] += 1
        self.stats["journal_bytes"] += len(payload)
        self._charge(len(payload))

    @staticmethod
    def _apply(state: dict, rid: int, start: int, toks) -> None:
        lst = state.setdefault(rid, [])
        if start > len(lst):
            raise ValueError(
                f"journal gap for rid {rid}: record starts at {start}, "
                f"only {len(lst)} tokens committed")
        lst[start:start + len(toks)] = [int(t) for t in toks]

    # -- recovery -----------------------------------------------------------
    def replay(self) -> tuple[dict, int]:
        """Post-crash: scan durable records and rebuild the committed-token
        map. Returns ``({rid: [tokens]}, last_durable_tick)``. Also resets
        the in-memory state to the replayed truth so the journal can keep
        appending (a second crash during recovery replays correctly)."""
        state: dict[int, list] = {}
        tick = -1
        for rec in self.wal.recover_scan():
            obj = json.loads(rec.payload)
            if "snap" in obj:
                state = {int(r): list(map(int, toks))
                         for r, toks in obj["snap"].items()}
            else:
                for rid, start, toks in obj["c"]:
                    self._apply(state, int(rid), int(start), toks)
            tick = max(tick, int(obj["t"]))
        self._state = {r: list(t) for r, t in state.items()}
        self._tick = tick
        return state, tick

    def committed(self, rid: int) -> list:
        return list(self._state.get(int(rid), ()))
