"""Cross-request KV prefix cache over the paged device pool (ISSUE 6).

The paper's NVPages keeps a volatile radix index whose nodes point at
shared persistent pages; the serving twin is a token-keyed
:class:`~repro.core.radix.TokenRadixTree` whose value nodes point at
refcounted read-only pages in the pooled :class:`PagedKVCache`. Admission
of a prompt whose prefix is cached becomes a **block-table splice**: the
new sequence aliases the shared physical pages (pure metadata, zero
compute, zero KV movement) and prefills only the uncovered tail. The
first write that would land inside a still-shared page triggers
copy-on-write in the engine (the writer gets a private copy; readers and
the index keep the original).

Layout: each value node covers ONE page-sized token chunk — the node at
depth ``(k+1) * page_tokens`` holds ``(phys, end_tokens)`` for logical
page ``k``. A prompt's last chunk may stop mid-page (a *boundary leaf*,
``end_tokens < (k+1) * page_tokens``); a splice may adopt it, but the
match run cannot extend past it — deeper tokens of that page belong to
the donor sequence and were never published.

Refcount protocol (the engine ↔ index contract, see
``core/engines/kv.py``):

* the index **pins** pages it references (``pin_page`` / ``unpin_page``)
  — a pinned page is never spilled out from under the index silently;
  under pool pressure the engine either asks the index to drop an idle
  entry (``reclaim_one``) or tells it a single-user page is being
  spilled (``forget_phys``);
* every live sequence that trusts a node's page holds one trie refcount
  on that node — the donor acquires at :meth:`insert`, a splicer at
  :meth:`match_and_splice` — released when the sequence stops trusting
  it: COW divergence (``on_cow``) or the sequence leaving the pool
  (``on_seq_dropped``, which fires on both release and preemption);
* eviction (capacity or ``reclaim_one``) only ever drops refcount-0
  value *leaves*, LRU-first — prefix closure means ancestors outlive
  descendants, so a dropped leaf can never strand a referenced deeper
  chunk.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.core.lru import LRUList
from repro.core.radix import TokenRadixTree, TrieNode


class PrefixCache:
    """Radix index mapping token prefixes to shared pool pages.

    ``capacity_tokens`` bounds the tokens the index may keep pinned;
    eviction is LRU over evictable (refcount-0 leaf) entries. The engine
    must be pooled and sharing-capable (``supports_sharing()``).
    """

    def __init__(self, engine, capacity_tokens: int):
        if not engine.supports_sharing():
            raise RuntimeError(
                f"{type(engine).__name__} does not support prefix sharing "
                f"(pooled paged engines only)")
        if capacity_tokens <= 0:
            raise ValueError("capacity_tokens must be positive")
        self.engine = engine
        self.capacity_tokens = capacity_tokens
        self.page_tokens = engine.spec.page_tokens
        self._trie = TokenRadixTree()
        self._lru = LRUList()                     # nodes, identity-hashed
        self._by_phys: dict[int, TrieNode] = {}   # phys → its value node
        self._seq_nodes: dict[int, set] = {}      # seq → nodes it refs
        self._tokens = 0                          # tokens currently indexed
        engine.set_share_index(self)

    # ------------------------------------------------------------ admission
    def match_and_splice(self, seq: int, prompt: Sequence[int]) -> int:
        """Longest usable cached prefix of ``prompt``, spliced into
        ``seq``'s block table. Returns the number of covered tokens (0 on
        a miss — the caller prefills normally).

        Coverage is capped at ``len(prompt) - 1``: the admitted row still
        needs one forward pass over ≥ 1 pending token to produce its
        first logits, and that pass REWRITES the boundary slot with
        recomputed KV — identical values, since chunked prefill is pinned
        token-identical to one-shot.
        """
        toks = tuple(int(t) for t in prompt)
        if len(toks) < 2:
            return 0                  # nothing coverable under the cap
        T = self.page_tokens
        run: list[TrieNode] = []
        covered = 0
        for i, node in enumerate(self._trie.match(toks)):
            phys, end = node.value
            if (end - 1) // T != i:
                break                 # a gap: logical page i was forgotten
            run.append(node)
            covered = end
            if end != (i + 1) * T:
                break                 # boundary leaf: the run cannot extend
        covered = min(covered, len(toks) - 1)
        if covered <= 0:
            return 0
        run = run[:-(-covered // T)]
        self.engine.adopt_pages(seq, [n.value[0] for n in run], covered)
        held = self._seq_nodes.setdefault(seq, set())
        for node in run:
            self._trie.acquire(node)
            held.add(node)
            self._lru.touch(node)
        return covered

    def insert(self, seq: int, prompt: Sequence[int]) -> None:
        """Publish ``seq``'s prompt pages into the index (the donor path,
        called once the FULL prompt is prefilled). Safe no-op when the
        sequence was preempted/released meanwhile or its pages are not
        resident."""
        toks = tuple(int(t) for t in prompt)
        if not toks:
            return
        table = self.engine.block_table.get(seq)
        if not table or self.engine.seq_len.get(seq, 0) < len(toks):
            return
        T = self.page_tokens
        npages = -(-len(toks) // T)
        if npages > len(table) or any(table[k] < 0 for k in range(npages)):
            return                    # partially spilled: don't pin host pages
        held = self._seq_nodes.setdefault(seq, set())
        for k in range(npages):
            end = min((k + 1) * T, len(toks))
            phys = table[k]
            node = self._trie.find(toks[:end])
            if node is not None:
                # chunk already published; trust it only if it still names
                # OUR page (a COW'd boundary page diverged — leave the
                # original owner's entry alone)
                if node.value[0] == phys and node not in held:
                    self._trie.acquire(node)
                    held.add(node)
            else:
                if phys in self._by_phys:
                    # one page, one node: a deeper prompt re-publishing the
                    # same boundary page under a longer key would alias two
                    # entries onto one phys and corrupt forget_phys
                    continue
                node = self._trie.insert(toks[:end], (phys, end))
                self.engine.pin_page(phys)
                self._by_phys[phys] = node
                self._tokens += end - k * T
                self._trie.acquire(node)
                held.add(node)
            self._lru.touch(node)
        self._enforce_capacity()

    # ------------------------------------------------------------- eviction
    def _evict(self, node: TrieNode) -> None:
        phys, end = node.value
        self._tokens -= end - ((end - 1) // self.page_tokens) \
            * self.page_tokens
        self._trie.remove(node)
        self._lru.remove(node)
        self._by_phys.pop(phys, None)
        self.engine.unpin_page(phys)

    def _enforce_capacity(self) -> None:
        while self._tokens > self.capacity_tokens:
            victim = None
            for node in self._lru.lru_order():
                if self._trie.evictable(node):
                    victim = node
                    break
            if victim is None:
                return                # everything referenced: over-budget OK
            self._evict(victim)

    # ----------------------------------------- engine callbacks (pool side)
    def reclaim_one(self) -> Optional[int]:
        """Pool overflow: drop the LRU idle entry and return its physical
        page (now free), or None when every entry is still referenced."""
        for node in self._lru.lru_order():
            if self._trie.evictable(node):
                phys = node.value[0]
                self._evict(node)
                return phys
        return None

    def reclaimable_pages(self) -> int:
        """Upper bound on how many pool pages :meth:`reclaim_one` can free
        right now: entries no live sequence references. Refcounts are
        non-increasing with depth along any root-path (per-seq holds are
        root-contiguous runs), so every refcount-0 node eventually becomes
        an evictable leaf as shallower refcount-0 descendants are dropped —
        the count is achievable, not just a bound. The engine's headroom
        audit (``_idle_index_pages``) caps its "idle shared pages" estimate
        with this so ``can_place_step`` never promises pages the index
        cannot actually give back (ISSUE 8 satellite)."""
        return sum(1 for node in self._by_phys.values() if node.refs == 0)

    def forget_phys(self, phys: int) -> None:
        """The engine is spilling/retiring this page: drop its entry. The
        page's sole live user keeps its data (the spill blob); future
        prompts simply miss."""
        node = self._by_phys.pop(phys, None)
        if node is None:
            return
        _, end = node.value
        self._tokens -= end - ((end - 1) // self.page_tokens) \
            * self.page_tokens
        self._trie.remove(node)
        self._lru.remove(node)
        self.engine.unpin_page(phys)

    def on_cow(self, seq: int, phys: int) -> None:
        """``seq`` diverged from the shared page at ``phys`` (it now writes
        a private copy): it stops referencing that node."""
        node = self._by_phys.get(phys)
        held = self._seq_nodes.get(seq)
        if node is not None and held is not None and node in held:
            held.discard(node)
            self._trie.release(node)

    def on_seq_dropped(self, seq: int) -> None:
        """``seq`` left the pool (release or preemption): release every
        node it referenced."""
        for node in self._seq_nodes.pop(seq, ()):
            self._trie.release(node)

    # --------------------------------------------------------------- views
    def __len__(self) -> int:
        return len(self._trie)

    @property
    def indexed_tokens(self) -> int:
        return self._tokens
