"""Serving: prefill/decode engine with tiered KV offload (paper's designs)."""
from repro.serving.engine import ServeConfig, ServingEngine

__all__ = ["ServeConfig", "ServingEngine"]
