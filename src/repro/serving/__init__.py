"""Serving: continuous-batching prefill/decode engine with tiered KV
offload and preemption-under-HBM-pressure (paper's designs, serving tier)."""
from repro.serving.engine import Request, ServeConfig, ServingEngine
from repro.serving.scheduler import Scheduler
from repro.serving.speculative import DraftProposer, NGramProposer

__all__ = ["Request", "ServeConfig", "ServingEngine", "Scheduler",
           "DraftProposer", "NGramProposer"]
