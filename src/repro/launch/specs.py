"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` → batch spec dict; ``abstract_state`` /
``abstract_cache`` derive parameter/cache shapes via jax.eval_shape so the
dry-run lowers exactly what the runtime executes.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.distributed import (batch_specs, cache_specs, param_specs,
                               zero1_specs)
from repro.training.optimizer import adamw_init
from repro.training.step import TrainState

ENC_FRAMES = 4096          # stub audio frontend length for enc-dec shapes


def input_specs(cfg: ModelConfig, shape: InputShape, dtype=jnp.bfloat16,
                microbatches: int = 1) -> dict:
    """ShapeDtypeStructs for one batch of this (arch × input-shape) cell.

    With microbatches > 1 (training), leaves are (mb, B/mb, ...) — the data
    pipeline delivers this layout so grad-accumulation scans need no
    resharding.
    """
    B, S = shape.global_batch, shape.seq_len

    def tok(s):
        if microbatches > 1:
            s = (microbatches, s[0] // microbatches) + s[1:]
        return jax.ShapeDtypeStruct(s, jnp.int32)

    def emb(s):
        if microbatches > 1:
            s = (microbatches, s[0] // microbatches) + s[1:]
        return jax.ShapeDtypeStruct(s, dtype)

    if shape.kind in ("train", "prefill"):
        batch = {"tokens": tok((B, S)), "labels": tok((B, S))}
    else:  # decode: one new token against an S-token cache
        batch = {"tokens": tok((B, 1))}
    if cfg.frontend.kind == "vision" and shape.kind != "decode":
        batch["frontend_embeds"] = emb(
            (B, cfg.frontend.num_tokens, cfg.frontend.d_frontend))
    if cfg.family == "encdec" and shape.kind != "decode":
        batch["frontend_embeds"] = emb((B, ENC_FRAMES, cfg.d_model))
    return batch


def prefill_batch_for_cache(cfg: ModelConfig, shape: InputShape,
                            dtype=jnp.bfloat16) -> dict:
    """The abstract prompt used to derive decode-cache shapes."""
    B = shape.global_batch
    prompt = min(128, shape.seq_len)
    batch = {"tokens": jax.ShapeDtypeStruct((B, prompt), jnp.int32)}
    if cfg.frontend.kind == "vision":
        batch["frontend_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend.num_tokens, cfg.frontend.d_frontend), dtype)
    if cfg.family == "encdec":
        batch["frontend_embeds"] = jax.ShapeDtypeStruct(
            (B, ENC_FRAMES, cfg.d_model), dtype)
    return batch


def abstract_params(model) -> Any:
    return jax.eval_shape(model.init, jax.random.key(0))


def abstract_state(model, moment_dtype=jnp.float32) -> Any:
    """TrainState shapes (params + AdamW state) without allocation."""
    def mk(rng):
        params = model.init(rng)
        if model.compute_dtype == jnp.bfloat16:
            params = jax.tree.map(
                lambda a: a.astype(jnp.bfloat16)
                if a.dtype == jnp.float32 else a, params)
        return TrainState(params=params,
                          opt_state=adamw_init(params, moment_dtype),
                          step=jnp.zeros((), jnp.int32))
    return jax.eval_shape(mk, jax.random.key(0))


def abstract_cache(model, cfg, shape: InputShape, dtype=jnp.bfloat16) -> Any:
    """Decode-cache shapes for this cell via eval_shape of prefill."""
    batch = prefill_batch_for_cache(cfg, shape, dtype)
    _, cache = jax.eval_shape(
        lambda p, b: model.prefill(p, b, shape.seq_len),
        abstract_params(model), batch)
    return cache


def with_shardings(shape_tree: Any, spec_tree: Any, mesh) -> Any:
    """Attach NamedShardings to a ShapeDtypeStruct tree."""
    def fn(s, spec):
        return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(fn, shape_tree, spec_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def state_specs(state_shape: Any, cfg, mesh) -> Any:
    """PartitionSpecs for a TrainState."""
    return TrainState(
        params=param_specs(state_shape.params, cfg, mesh),
        opt_state={
            "mu": zero1_specs(state_shape.opt_state["mu"], cfg, mesh),
            "nu": zero1_specs(state_shape.opt_state["nu"], cfg, mesh),
            "master": zero1_specs(state_shape.opt_state["master"], cfg, mesh),
            "count": P(),
        },
        step=P(),
    )
