import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input-shape × mesh) cell against the production mesh and
record memory / cost / collective artifacts for the roofline analysis.

The two lines above MUST stay first: jax locks the device count on first
init, and only the dry-run wants 512 host devices (tests/benches see 1).

Usage:
    python -m repro.launch.dryrun --arch starcoder2-15b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
    python -m repro.launch.dryrun --arch gemma-7b --shape train_4k --layers 2
        (--layers overrides depth for the roofline L-differencing compiles)
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_IDS, SHAPES_BY_NAME, applicable_shapes,
                           get_config, skipped_shapes)
from repro.distributed import batch_specs, cache_specs, data_axes, param_specs
from repro.distributed.sharding import use_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (abstract_cache, abstract_params,
                                abstract_state, input_specs, state_specs,
                                with_shardings)
from repro.models import build_model
from repro.roofline.hlo import collective_summary
from repro.roofline.hw import V5E
from repro.training.optimizer import AdamWConfig
from repro.training.step import make_train_step

ACTIVATION_BUDGET = 4e9        # bytes/device of scan-carried residuals


def pick_microbatches(cfg, shape, mesh) -> int:
    dp = int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))
    if cfg.family in ("ssm", "hybrid"):
        dp *= mesh.shape.get("model", 1)     # model axis folded into batch
    b_loc = max(shape.global_batch // dp, 1)
    layers = cfg.num_layers + cfg.num_encoder_layers
    act = b_loc * shape.seq_len * cfg.d_model * 2 * max(layers, 1)
    mb = 1
    while act / mb > ACTIVATION_BUDGET and mb < b_loc:
        mb *= 2
    return mb


def moment_dtype_for(cfg):
    # 100B+ models need bf16 moments to fit v5e HBM (EXPERIMENTS.md math)
    return jnp.bfloat16 if cfg.param_count() > 60e9 else jnp.float32


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               layers_override=None, chunk_size: int = 512,
               mb_override=None, period_override=None,
               unroll: bool = False, kv_cache_dtype: str = "native"):
    cfg = get_config(arch)
    if layers_override:
        cfg = dataclasses.replace(cfg, num_layers=layers_override,
                                  num_encoder_layers=min(
                                      cfg.num_encoder_layers, layers_override))
        if cfg.hybrid is not None:
            period = period_override or max(layers_override // 2, 1)
            cfg = dataclasses.replace(cfg, hybrid=dataclasses.replace(
                cfg.hybrid, shared_block_period=period))
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = shape.kind

    ep = data_axes(mesh) if cfg.moe is not None else ()
    if kind == "train":
        model = build_model(cfg, param_dtype=jnp.bfloat16,
                            compute_dtype=jnp.bfloat16, remat=True,
                            chunk_size=chunk_size, ep_axes=ep,
                            scan_unroll=unroll)
        mb = mb_override or pick_microbatches(cfg, shape, mesh)
        state_shape = abstract_state(model, moment_dtype_for(cfg))
        sspecs = state_specs(state_shape, cfg, mesh)
        state_in = with_shardings(state_shape, sspecs, mesh)
        batch_shape = input_specs(cfg, shape, microbatches=mb)
        # SSM/hybrid backbones have no TP mapping for the mixer weights —
        # fold the model axis into batch so all 256 chips carry batch.
        # Pick the largest axis combination that divides the global batch
        # (multi-pod: 512 ∤ 256 → fall back to data×model).
        dp_override = None
        if cfg.family in ("ssm", "hybrid"):
            da = data_axes(mesh)
            for cand in (da + ("model",), ("data", "model"), da, ("data",)):
                cand = tuple(a for a in cand if a in mesh.axis_names)
                size = int(np.prod([mesh.shape[a] for a in cand]))
                if cand and shape.global_batch % size == 0:
                    dp_override = cand
                    break
        bspecs = batch_specs(batch_shape, mesh, microbatched=mb > 1,
                             dp_override=dp_override)
        batch_in = with_shardings(batch_shape, bspecs, mesh)
        accum_dtype = (jnp.bfloat16 if cfg.param_count() > 60e9
                       else jnp.float32)
        step = make_train_step(model, AdamWConfig(), microbatches=mb,
                               accum_dtype=accum_dtype)
        with use_mesh(mesh):
            lowered = jax.jit(
                step,
                out_shardings=(jax.tree.map(
                    lambda s: NamedSharding(mesh, s), sspecs,
                    is_leaf=lambda x: isinstance(x, P)), None),
            ).lower(state_in, batch_in)
        return lowered, {"microbatches": mb, "kind": kind}

    model = build_model(cfg, param_dtype=jnp.bfloat16,
                        compute_dtype=jnp.bfloat16, remat=False,
                        chunk_size=chunk_size, ep_axes=ep,
                        scan_unroll=unroll,
                        kv_cache_dtype=kv_cache_dtype)
    params_shape = abstract_params(model)
    pspecs = param_specs(params_shape, cfg, mesh)
    params_in = with_shardings(params_shape, pspecs, mesh)

    if kind == "prefill":
        batch_shape = input_specs(cfg, shape)
        bspecs = batch_specs(batch_shape, mesh)
        batch_in = with_shardings(batch_shape, bspecs, mesh)
        with use_mesh(mesh):
            lowered = jax.jit(
                lambda p, b: model.prefill(p, b, shape.seq_len)
            ).lower(params_in, batch_in)
        return lowered, {"kind": kind}

    # decode: one token against an S-token cache
    with use_mesh(mesh):
        cache_shape = abstract_cache(model, cfg, shape)
    cspecs = cache_specs(cache_shape, cfg, mesh)
    cache_in = with_shardings(cache_shape, cspecs, mesh)
    batch_shape = input_specs(cfg, shape)
    bspecs = batch_specs(batch_shape, mesh)
    batch_in = with_shardings(batch_shape, bspecs, mesh)
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    pos_spec = P(dp) if shape.global_batch % dp_size == 0 else P()
    pos_in = jax.ShapeDtypeStruct(
        (shape.global_batch,), jnp.int32,
        sharding=NamedSharding(mesh, pos_spec))
    with use_mesh(mesh):
        lowered = jax.jit(
            model.decode_step,
            out_shardings=(None, jax.tree.map(
                lambda s: NamedSharding(mesh, s), cspecs,
                is_leaf=lambda x: isinstance(x, P))),
        ).lower(params_in, cache_in, batch_in["tokens"], pos_in)
    return lowered, {"kind": kind}


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             layers_override=None, keep_hlo: bool = False,
             mb_override=None, period_override=None,
             unroll: bool = False, kv_cache_dtype: str = "native",
             chunk_size: int = 512) -> dict:
    mesh_name = "multipod" if multi_pod else "pod"
    tag = f"{arch}__{shape_name}__{mesh_name}"
    if layers_override:
        tag += f"__L{layers_override}"
    if period_override:
        tag += f"P{period_override}"
    if kv_cache_dtype != "native":
        tag += f"__kv{kv_cache_dtype}"
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape_name, multi_pod, layers_override,
                               mb_override=mb_override,
                               period_override=period_override,
                               unroll=unroll, kv_cache_dtype=kv_cache_dtype,
                               chunk_size=chunk_size)
    meta["kv_cache_dtype"] = kv_cache_dtype
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):      # older jax: one dict per program
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    colls = collective_summary(hlo)
    n_dev = len(jax.devices())
    mem = {
        "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
        "output_bytes": getattr(ma, "output_size_in_bytes", None),
        "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
        "generated_code_bytes": getattr(ma, "generated_code_size_in_bytes",
                                        None),
        "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
    }
    live = (mem["argument_bytes"] or 0) + (mem["temp_bytes"] or 0)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "devices": n_dev, **meta,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "per_device_live_bytes": live,
        "fits_v5e_hbm": bool(live <= V5E.hbm_bytes),
        "cost": {k: v for k, v in ca.items()
                 if "flops" in k or k == "bytes accessed"},
        "collectives": colls,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{tag}.json").write_text(json.dumps(result, indent=1))
    if keep_hlo:
        (out_dir / f"{tag}.hlo.txt").write_text(hlo)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--layers", type=int, default=None,
                    help="override depth (roofline L-differencing)")
    ap.add_argument("--mb", type=int, default=None,
                    help="override train microbatch count")
    ap.add_argument("--period", type=int, default=None,
                    help="override hybrid shared-block period (roofline)")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll every scan so cost_analysis counts all "
                         "work (roofline sample compiles)")
    ap.add_argument("--kv-dtype", default="native",
                    choices=("native", "int8"),
                    help="KV-cache dtype for decode cells (§Perf C)")
    ap.add_argument("--chunk", type=int, default=512,
                    help="attention chunk size (samples use 2048 to "
                         "bound unrolled-body count)")
    ap.add_argument("--keep-hlo", action="store_true")
    args = ap.parse_args(argv)

    out_dir = Path(args.out)
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([SHAPES_BY_NAME[args.shape]] if args.shape
                  else applicable_shapes(cfg))
        for sh in shapes:
            for mp in meshes:
                tag = f"{arch} × {sh.name} × {'multipod' if mp else 'pod'}"
                try:
                    r = run_cell(arch, sh.name, mp, out_dir,
                                 layers_override=args.layers,
                                 keep_hlo=args.keep_hlo,
                                 mb_override=args.mb,
                                 period_override=args.period,
                                 unroll=args.unroll,
                                 kv_cache_dtype=args.kv_dtype,
                                 chunk_size=args.chunk)
                    print(f"[ok] {tag}: live={r['per_device_live_bytes']/1e9:.2f}GB"
                          f" fits={r['fits_v5e_hbm']}"
                          f" colls={r['collectives'].get('num_ops', 0)}"
                          f" compile={r['compile_s']}s", flush=True)
                except Exception as e:
                    failures += 1
                    print(f"[FAIL] {tag}: {type(e).__name__}: {e}",
                          flush=True)
                    traceback.print_exc()
        for sh_name, reason in (skipped_shapes(cfg) if not args.shape else []):
            print(f"[skip] {arch} × {sh_name}: {reason}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
