"""End-to-end training driver.

Runs on whatever devices exist (CPU here; the production mesh path is
exercised by dryrun.py). Integrates: synthetic data pipeline, AdamW
(+WSD for minicpm), microbatching, checkpoint/restart through the paper's
cache designs, and crash recovery.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b-smoke \
        --steps 50 --ckpt-design log --ckpt-every 10
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import SyntheticLMDataset, make_batch_iterator
from repro.models import build_model
from repro.training import AdamWConfig, init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b-smoke")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-design", choices=("log", "paged"), default="log")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    model = build_model(cfg, remat=True)
    opt_cfg = AdamWConfig(lr=args.lr, schedule=cfg.lr_schedule,
                          warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    state = init_train_state(model, jax.random.PRNGKey(args.seed))
    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      microbatches=args.microbatches))

    ds = SyntheticLMDataset(cfg.vocab_size, args.seq, args.batch,
                            seed=args.seed)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"loss_floor≈{ds.entropy_floor:.3f}")

    mgr = None
    start_step = 0
    if args.ckpt_every:
        mgr = CheckpointManager(args.ckpt_design)
        if args.resume:
            start_step, state = mgr.restore(state)
            print(f"resumed at step {start_step}")

    it = make_batch_iterator(ds, start_step, args.microbatches)
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, metrics = step_fn(state, batch)
        if step % max(args.steps // 10, 1) == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f}")
        if mgr is not None and (step + 1) % args.ckpt_every == 0:
            t = mgr.save(step + 1, state)
            print(f"  ckpt[{args.ckpt_design}] step {step+1} "
                  f"sim_save={t*1e3:.2f}ms")
    dt = time.time() - t0
    tokens = args.steps * args.batch * args.seq
    print(f"done: {dt:.1f}s wall, {tokens/dt:.0f} tok/s (CPU)")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
