"""Serving driver: continuous-batching decode through the tiered-KV engine,
comparing the paper's designs at the KV call-site under real concurrency.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b-smoke \
        --design log --requests 4 --max-new 16 --max-batch-seqs 4

Requests share one running batch (admitted/preempted/restored by the
scheduler); ``--hbm-budget-bytes`` small enough to bind makes the
preemption path visible in the printed stats. ``--sequential`` runs the
one-at-a-time reference loop instead (same tokens, no batching).

``--paged-decode`` forces the mirror-free pooled path (decode runs the
paged_attention kernel directly over the engine's device page pool;
``mirror_d2h_bytes`` stays 0); ``--mirror-decode`` forces the dense-mirror
path; default is auto (pooled when engine + model support it).
``--prefill-chunk-tokens`` splits long prompts across scheduler ticks.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.engines import EngineSpec, list_kv_engines
from repro.models import build_model
from repro.serving import Request, ServeConfig, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b-smoke")
    ap.add_argument("--design", "--engine", dest="design",
                    choices=list_kv_engines(), default="log",
                    help="KV engine from the registry")
    ap.add_argument("--drain-shards", type=int, default=1,
                    help="per-shard drainer parallelism (log/kvhybrid)")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch-seqs", type=int, default=8,
                    help="continuous-batching width cap")
    ap.add_argument("--max-batch-tokens", type=int, default=None,
                    help="running-batch token cap (None = unlimited)")
    ap.add_argument("--hbm-budget-bytes", type=int, default=64 << 20,
                    help="KV-tier HBM budget; small values force "
                         "preempt/restore cycles")
    ap.add_argument("--sequential", action="store_true",
                    help="run the batch=1 reference loop instead of the "
                         "continuous-batching scheduler")
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--paged-decode", dest="paged_decode",
                      action="store_true", default=None,
                      help="force mirror-free decode over the device page "
                           "pool (requires a pool-capable engine)")
    mode.add_argument("--mirror-decode", dest="paged_decode",
                      action="store_false",
                      help="force the dense-mirror decode path")
    ap.add_argument("--page-tokens", type=int, default=16,
                    help="tokens per KV page (pool geometry)")
    ap.add_argument("--prefill-chunk-tokens", type=int, default=None,
                    help="split prompts longer than this across ticks "
                         "(default: max-batch-tokens)")
    ap.add_argument("--no-fuse", dest="fuse_ticks", action="store_false",
                    help="disable fused mixed-batch ticks: prefill chunks "
                         "run at batch=1 through the decode path (the "
                         "pre-fusion baseline)")
    ap.add_argument("--prefix-cache-tokens", type=int, default=0,
                    help="cross-request prefix cache capacity in tokens "
                         "(0 = off): cache-hit admissions splice shared "
                         "pool pages instead of prefilling (pooled path "
                         "only)")
    ap.add_argument("--shared-prefix-tokens", type=int, default=0,
                    help="prepend this many identical tokens to every "
                         "prompt (exercises the prefix cache)")
    ap.add_argument("--speculate-k", type=int, default=0,
                    help="speculative decode: up to k self-drafted tokens "
                         "per decode row per fused tick, verified in the "
                         "same launch (0 = off; tokens are identical "
                         "either way)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for deterministic fault injection (the same "
                         "seed replays the same faults)")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="per-attempt transfer fail AND delay probability "
                         "(>0 turns on the injector; retries/degradation "
                         "show up in the printed stats)")
    ap.add_argument("--crash-at-tick", type=int, default=None,
                    help="inject a CrashFault at this scheduler tick; with "
                         "--journal the run then recovers from the journal "
                         "and prints both halves")
    ap.add_argument("--journal", action="store_true",
                    help="append committed tokens to a crash-consistent "
                         "NVMM journal every tick (required for recovery "
                         "after --crash-at-tick)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(args.seed))
    prompt_len = args.prompt_len + args.shared_prefix_tokens
    max_len = prompt_len + args.max_new + 1
    max_len += -max_len % args.page_tokens     # pool wants page alignment

    journal = None
    if args.journal:
        from repro.serving.journal import ServingJournal
        journal = ServingJournal()
    fault_plan = None
    if args.fault_rate > 0.0 or args.crash_at_tick is not None:
        from repro.serving.faults import FaultPlan
        fault_plan = FaultPlan(seed=args.fault_seed,
                               transfer_fail_rate=args.fault_rate,
                               transfer_delay_rate=args.fault_rate,
                               crash_at_tick=args.crash_at_tick)

    def mk_engine(plan):
        return ServingEngine(model, params, ServeConfig(
            max_len=max_len, page_tokens=args.page_tokens,
            engine_spec=EngineSpec(
                engine=args.design,
                drain_shards=args.drain_shards,
                kv_hbm_bytes=args.hbm_budget_bytes,
                prefix_cache_tokens=args.prefix_cache_tokens),
            max_batch_seqs=args.max_batch_seqs,
            max_batch_tokens=args.max_batch_tokens,
            paged_decode=args.paged_decode,
            prefill_chunk_tokens=args.prefill_chunk_tokens,
            fuse_ticks=args.fuse_ticks,
            speculate_k=args.speculate_k,
            journal=journal, fault_plan=plan))

    engine = mk_engine(fault_plan)

    rng = np.random.default_rng(args.seed)
    shared = rng.integers(0, cfg.vocab_size, args.shared_prefix_tokens,
                          dtype=np.int32)
    reqs = [Request(rid=i,
                    prompt=np.concatenate([
                        shared,
                        rng.integers(0, cfg.vocab_size, args.prompt_len,
                                     dtype=np.int32)]),
                    max_new=args.max_new)
            for i in range(args.requests)]
    if args.sequential:
        engine.generate_sequential(reqs)
    else:
        try:
            engine.generate(reqs)
        except Exception as e:
            from repro.serving.faults import CrashFault
            if not isinstance(e, CrashFault):
                raise
            print(f"CRASH: {e} "
                  f"(journal stats: {engine.journal.stats if journal else None})")
            if journal is None:
                raise SystemExit(
                    "crashed without --journal: nothing durable to recover")
            # a fresh engine sharing the SAME journal resumes exactly where
            # the last durable tick stopped
            engine = mk_engine(None)
            engine.recover(reqs)
            print("RECOVERED: journal replayed, unfinished rows resumed")
    for r in reqs:
        print(f"req {r.rid}: generated {len(r.generated)} tokens "
              f"{r.generated[:8]}...")
    mode = ("sequential" if args.sequential else
            ("batched+pooled" if engine.pooled else "batched+mirror")
            + ("+fused" if engine.fused else ""))
    print(f"tiered-kv[{args.design}] ({mode}) stats: {engine.stats()}")


if __name__ == "__main__":
    main()
