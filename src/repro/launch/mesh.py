"""Production mesh builders (DESIGN.md §5).

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). Single pod: (16, 16) = 256 v5e chips, axes (data, model).
Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model); ``pod`` is the
outermost gradient-parallel axis (DCN-connected in a real deployment).
"""
from __future__ import annotations

import os

from repro.distributed.sharding import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    override = os.environ.get("REPRO_TEST_MESH")      # e.g. "2x2" or "2x2x2"
    if override:
        dims = tuple(int(x) for x in override.split("x"))
        shape = dims
        axes = ("pod", "data", "model")[-len(dims):]
    return make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-scale sharding tests (host devices)."""
    return make_mesh(shape, axes)


def required_devices(multi_pod: bool) -> int:
    return 512 if multi_pod else 256
