"""Transformer/SSM block composition + scanned layer stacks.

Every stack is scanned over stacked (L, ...) params so the HLO contains one
``while`` body per block type (bounds compile time/memory for 40-60L full
configs; the roofline module corrects cost_analysis trip counts, DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    dense_init, init_ffn, apply_ffn, init_rmsnorm, rmsnorm)


def stack_init(init_one, key, n):
    """vmap an init over n layers → params with leading (n, ...) axis."""
    return jax.vmap(init_one)(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Decoder block (dense or MoE ffn; GQA or MLA attention)
# ---------------------------------------------------------------------------
def init_decoder_block(key, cfg, dtype, *, ffn_kind: str):
    """ffn_kind: 'dense' | 'moe'."""
    k_attn, k_ffn = jax.random.split(key)
    p = {
        "ln_attn": init_rmsnorm(cfg.d_model, dtype),
        "ln_ffn": init_rmsnorm(cfg.d_model, dtype),
    }
    if cfg.mla is not None:
        p["attn"] = attn_mod.init_mla(k_attn, cfg, dtype)
    else:
        p["attn"] = attn_mod.init_attn(k_attn, cfg, dtype)
    if ffn_kind == "moe":
        p["ffn"] = moe_mod.init_moe(k_ffn, cfg, dtype)
    else:
        p["ffn"] = init_ffn(k_ffn, cfg.d_model, cfg.d_ff, cfg.ffn_activation,
                            dtype)
    return p


def apply_decoder_block(params, cfg, h, positions, *, ffn_kind: str,
                        chunk_size: int = 512, causal: bool = True,
                        ep_axes=(), unroll=False):
    """Full-sequence block. Returns (h, kv, aux_loss)."""
    rs = cfg.residual_scale
    x = rmsnorm(params["ln_attn"], h, cfg.norm_eps)
    if cfg.mla is not None:
        a, kv = attn_mod.mla_train(params["attn"], cfg, x, positions,
                                   causal=causal, chunk_size=chunk_size,
                                   unroll=unroll)
    else:
        a, kv = attn_mod.attn_train(params["attn"], cfg, x, positions,
                                    causal=causal, chunk_size=chunk_size,
                                    unroll=unroll)
    h = h + rs * a
    x = rmsnorm(params["ln_ffn"], h, cfg.norm_eps)
    if ffn_kind == "moe":
        f, aux = moe_mod.apply_moe(params["ffn"], cfg, x, ep_axes=ep_axes)
    else:
        f = apply_ffn(params["ffn"], x, cfg.ffn_activation)
        aux = jnp.zeros((), jnp.float32)
    return h + rs * f, kv, aux


def decode_decoder_block(params, cfg, h, cache, positions, *, ffn_kind: str,
                         ep_axes=()):
    """Single-token block. cache: tuple of per-layer cache arrays."""
    rs = cfg.residual_scale
    x = rmsnorm(params["ln_attn"], h, cfg.norm_eps)
    if cfg.mla is not None:
        a, c0, c1 = attn_mod.mla_decode(params["attn"], cfg, x, cache[0],
                                        cache[1], positions)
    else:
        a, c0, c1 = attn_mod.attn_decode(params["attn"], cfg, x, cache[0],
                                         cache[1], positions)
    h = h + rs * a
    x = rmsnorm(params["ln_ffn"], h, cfg.norm_eps)
    if ffn_kind == "moe":
        f, _ = moe_mod.apply_moe(params["ffn"], cfg, x, ep_axes=ep_axes)
    else:
        f = apply_ffn(params["ffn"], x, cfg.ffn_activation)
    return h + rs * f, (c0, c1)


def _apply_block_ffn(params, cfg, x, ffn_kind: str, ep_axes):
    if ffn_kind == "moe":
        f, _ = moe_mod.apply_moe(params["ffn"], cfg, x, ep_axes=ep_axes)
        return f
    return apply_ffn(params["ffn"], x, cfg.ffn_activation)


def decode_paged_block(params, cfg, h, planes, block_table, positions, *,
                       ffn_kind: str = "dense", ep_axes=()):
    """Single-token block over one layer's slice of the paged pool
    (mirror-free decode). ``planes`` is this layer's pool-plane tuple in
    descriptor order — ``(k, v)`` dense, ``(k, v, k_scale, v_scale)``
    int8, ``(c, kr)`` MLA — and attention dispatches on it."""
    rs = cfg.residual_scale
    x = rmsnorm(params["ln_attn"], h, cfg.norm_eps)
    if cfg.mla is not None:
        a, *planes = attn_mod.mla_decode_paged(
            params["attn"], cfg, x, planes[0], planes[1], block_table,
            positions)
    elif len(planes) == 4:
        a, *planes = attn_mod.attn_decode_paged_q8(
            params["attn"], cfg, x, planes[0], planes[1], planes[2],
            planes[3], block_table, positions)
    else:
        a, *planes = attn_mod.attn_decode_paged(
            params["attn"], cfg, x, planes[0], planes[1], block_table,
            positions)
    h = h + rs * a
    x = rmsnorm(params["ln_ffn"], h, cfg.norm_eps)
    f = _apply_block_ffn(params, cfg, x, ffn_kind, ep_axes)
    return h + rs * f, tuple(planes)


def step_paged_ragged_block(params, cfg, h, planes, block_table, ctx_lens,
                            q_lens, *, ffn_kind: str = "dense", ep_axes=()):
    """Ragged multi-token block over one layer's pool-plane tuple (the
    fused mixed-batch tick). Plane dispatch as ``decode_paged_block``."""
    rs = cfg.residual_scale
    x = rmsnorm(params["ln_attn"], h, cfg.norm_eps)
    if cfg.mla is not None:
        a, *planes = attn_mod.mla_step_paged_ragged(
            params["attn"], cfg, x, planes[0], planes[1], block_table,
            ctx_lens, q_lens)
    elif len(planes) == 4:
        a, *planes = attn_mod.attn_step_paged_ragged_q8(
            params["attn"], cfg, x, planes[0], planes[1], planes[2],
            planes[3], block_table, ctx_lens, q_lens)
    else:
        a, *planes = attn_mod.attn_step_paged_ragged(
            params["attn"], cfg, x, planes[0], planes[1], block_table,
            ctx_lens, q_lens)
    h = h + rs * a
    x = rmsnorm(params["ln_ffn"], h, cfg.norm_eps)
    f = _apply_block_ffn(params, cfg, x, ffn_kind, ep_axes)
    return h + rs * f, tuple(planes)


def step_ragged_block(params, cfg, h, cache, ctx_lens, q_lens, *,
                      ffn_kind: str = "dense", ep_axes=()):
    """Ragged multi-token block over the dense cache (the fused tick's
    mirrored twin). ``cache`` is this layer's plane tuple: ``(k, v)``
    dense, ``(k, v, k_scale, v_scale)`` int8, ``(c, kr)`` MLA."""
    rs = cfg.residual_scale
    x = rmsnorm(params["ln_attn"], h, cfg.norm_eps)
    if cfg.mla is not None:
        a, *cache = attn_mod.mla_decode_ragged(
            params["attn"], cfg, x, cache[0], cache[1], ctx_lens, q_lens)
    elif len(cache) == 4:
        a, *cache = attn_mod.attn_decode_ragged_q8(
            params["attn"], cfg, x, cache[0], cache[1], cache[2], cache[3],
            ctx_lens, q_lens)
    else:
        a, *cache = attn_mod.attn_decode_ragged(
            params["attn"], cfg, x, cache[0], cache[1], ctx_lens, q_lens)
    h = h + rs * a
    x = rmsnorm(params["ln_ffn"], h, cfg.norm_eps)
    f = _apply_block_ffn(params, cfg, x, ffn_kind, ep_axes)
    return h + rs * f, tuple(cache)


def step_ragged_ssm_block(params, cfg, h, conv_state, ssm_state, q_lens):
    """Ragged multi-token SSM block: scan the single-step mixer over the
    Qmax query slots, masking state updates past ``q_lens`` so padding
    slots leave the state untouched. h: (B, Qmax, d). Returns
    (h, conv_steps, ssm_steps) where the ``*_steps`` carry the PER-SLOT
    states (Qmax leading axis) — the engine picks the committed slot
    (speculative rollback = picking an earlier one)."""
    B, Qm, _ = h.shape

    def body(carry, xs):
        conv, ssm = carry
        x_t, i = xs
        x = rmsnorm(params["ln"], x_t[:, None], cfg.norm_eps)
        y, (nc, ns) = ssm_mod.ssm_decode(params["mixer"], cfg, x, conv, ssm)
        live = (i < q_lens)
        nc = jnp.where(live[:, None, None], nc, conv)
        ns = jnp.where(live[:, None, None, None], ns, ssm)
        return (nc, ns), (y[:, 0], nc, ns)

    (_, _), (ys, conv_steps, ssm_steps) = jax.lax.scan(
        body, (conv_state, ssm_state),
        (h.transpose(1, 0, 2), jnp.arange(Qm, dtype=jnp.int32)))
    return h + ys.transpose(1, 0, 2), conv_steps, ssm_steps


# ---------------------------------------------------------------------------
# Encoder block (bidirectional) and enc-dec decoder block (w/ cross-attn)
# ---------------------------------------------------------------------------
def init_encoder_block(key, cfg, dtype):
    k_attn, k_ffn = jax.random.split(key)
    return {
        "ln_attn": init_rmsnorm(cfg.d_model, dtype),
        "ln_ffn": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn_mod.init_attn(k_attn, cfg, dtype),
        "ffn": init_ffn(k_ffn, cfg.d_model, cfg.d_ff, cfg.ffn_activation,
                        dtype),
    }


def apply_encoder_block(params, cfg, h, positions, chunk_size=512,
                        unroll=False):
    x = rmsnorm(params["ln_attn"], h, cfg.norm_eps)
    a, _ = attn_mod.attn_train(params["attn"], cfg, x, positions,
                               causal=False, chunk_size=chunk_size,
                               unroll=unroll)
    h = h + a
    x = rmsnorm(params["ln_ffn"], h, cfg.norm_eps)
    return h + apply_ffn(params["ffn"], x, cfg.ffn_activation)


def init_encdec_decoder_block(key, cfg, dtype):
    k_self, k_cross, k_ffn = jax.random.split(key, 3)
    return {
        "ln_self": init_rmsnorm(cfg.d_model, dtype),
        "ln_cross": init_rmsnorm(cfg.d_model, dtype),
        "ln_ffn": init_rmsnorm(cfg.d_model, dtype),
        "self_attn": attn_mod.init_attn(k_self, cfg, dtype),
        "cross_attn": attn_mod.init_attn(k_cross, cfg, dtype),
        "ffn": init_ffn(k_ffn, cfg.d_model, cfg.d_ff, cfg.ffn_activation,
                        dtype),
    }


def cross_kv(params, cfg, enc_out):
    """Precompute cross-attention K/V from encoder output."""
    B, T, _ = enc_out.shape
    K, D = cfg.num_kv_heads, cfg.head_dim
    k = (enc_out @ params["cross_attn"]["wk"]).reshape(B, T, K, D)
    v = (enc_out @ params["cross_attn"]["wv"]).reshape(B, T, K, D)
    return k, v


def apply_encdec_decoder_block(params, cfg, h, positions, enc_k, enc_v,
                               chunk_size=512, unroll=False):
    x = rmsnorm(params["ln_self"], h, cfg.norm_eps)
    a, kv = attn_mod.attn_train(params["self_attn"], cfg, x, positions,
                                causal=True, chunk_size=chunk_size,
                                unroll=unroll)
    h = h + a
    x = rmsnorm(params["ln_cross"], h, cfg.norm_eps)
    h = h + attn_mod.attn_cross(params["cross_attn"], cfg, x, enc_k, enc_v,
                                chunk_size=chunk_size, unroll=unroll)
    x = rmsnorm(params["ln_ffn"], h, cfg.norm_eps)
    return h + apply_ffn(params["ffn"], x, cfg.ffn_activation), kv


def decode_encdec_decoder_block(params, cfg, h, cache, positions):
    ck, cv, ek, ev = cache
    x = rmsnorm(params["ln_self"], h, cfg.norm_eps)
    a, ck, cv = attn_mod.attn_decode(params["self_attn"], cfg, x, ck, cv,
                                     positions)
    h = h + a
    x = rmsnorm(params["ln_cross"], h, cfg.norm_eps)
    h = h + attn_mod.attn_cross(params["cross_attn"], cfg, x, ek, ev)
    x = rmsnorm(params["ln_ffn"], h, cfg.norm_eps)
    return h + apply_ffn(params["ffn"], x, cfg.ffn_activation), (ck, cv)


# ---------------------------------------------------------------------------
# SSM block
# ---------------------------------------------------------------------------
def init_ssm_block(key, cfg, dtype):
    return {
        "ln": init_rmsnorm(cfg.d_model, dtype),
        "mixer": ssm_mod.init_ssm(key, cfg, dtype),
    }


def apply_ssm_block(params, cfg, h, initial_state=None, unroll=False):
    x = rmsnorm(params["ln"], h, cfg.norm_eps)
    y, state = ssm_mod.apply_ssm(params["mixer"], cfg, x, initial_state,
                                 unroll=unroll)
    return h + y, state


def decode_ssm_block(params, cfg, h, conv_state, ssm_state):
    x = rmsnorm(params["ln"], h, cfg.norm_eps)
    y, (conv_state, ssm_state) = ssm_mod.ssm_decode(
        params["mixer"], cfg, x, conv_state, ssm_state)
    return h + y, conv_state, ssm_state


# ---------------------------------------------------------------------------
# Zamba2 shared block with per-invocation LoRA
# ---------------------------------------------------------------------------
def init_shared_block(key, cfg, dtype):
    """Shared attention+MLP transformer block (Zamba2)."""
    return init_decoder_block(key, cfg, dtype, ffn_kind="dense")


def init_lora(key, cfg, dtype):
    """Per-invocation LoRA on the shared block's fused qkv input projection."""
    r = cfg.hybrid.lora_rank
    qkv_out = (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim
    k1, k2 = jax.random.split(key)
    return {
        "a": dense_init(k1, cfg.d_model, r, dtype),
        "b": jnp.zeros((r, qkv_out), dtype),
    }


def _lora_patched_attn(shared_attn, lora, cfg):
    """Return attention params with LoRA delta folded into wq/wk/wv."""
    H, K, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    delta = lora["a"] @ lora["b"]                          # (d, qkv_out)
    dq, dk, dv = jnp.split(delta, [H * D, H * D + K * D], axis=-1)
    return {
        "wq": shared_attn["wq"] + dq,
        "wk": shared_attn["wk"] + dk,
        "wv": shared_attn["wv"] + dv,
        "wo": shared_attn["wo"],
    }


def apply_shared_block(shared, lora, cfg, h, positions, chunk_size=512,
                       unroll=False):
    params = dict(shared)
    params["attn"] = _lora_patched_attn(shared["attn"], lora, cfg)
    h, kv, _ = apply_decoder_block(params, cfg, h, positions,
                                   ffn_kind="dense", chunk_size=chunk_size,
                                   unroll=unroll)
    return h, kv


def decode_shared_block(shared, lora, cfg, h, cache, positions):
    params = dict(shared)
    params["attn"] = _lora_patched_attn(shared["attn"], lora, cfg)
    return decode_decoder_block(params, cfg, h, cache, positions,
                                ffn_kind="dense")
