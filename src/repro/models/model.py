"""Unified LM over all assigned families.

``build_model(cfg)`` returns an :class:`LM` exposing:

* ``init(rng) -> params``
* ``loss_fn(params, batch) -> (loss, metrics)``  (training)
* ``prefill(params, batch, max_len) -> (logits, cache)``
* ``decode_step(params, cache, tokens, positions) -> (logits, cache)``

Batches are dicts of arrays (see ``repro.data``). All layer stacks are
scanned; remat policy is configurable.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    cross_entropy_loss, dense_init, embed, init_embedding, init_rmsnorm,
    lm_logits, rmsnorm)

PyTree = Any


_REMAT_POLICIES = {
    # full per-layer recompute: only the residual-stream carry survives the
    # forward pass — the policy that fits 40-60L models in 16 GB HBM
    "nothing": jax.checkpoint_policies.nothing_saveable,
    # save matmul outputs (fastest backward, ~4-6× the live activations)
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


def _remat(fn, enabled: bool, policy: str = "nothing"):
    if not enabled:
        return fn
    return jax.checkpoint(fn, policy=_REMAT_POLICIES[policy])


class LM:
    """Decoder-only LM (dense / moe / ssm / hybrid / vlm) or enc-dec."""

    def __init__(self, cfg, *, param_dtype=jnp.float32,
                 compute_dtype=jnp.float32, chunk_size: int = 512,
                 remat: bool = True, remat_policy: str = "nothing",
                 ep_axes: tuple = (), scan_unroll: bool = False,
                 kv_cache_dtype: str = "native"):
        self.cfg = cfg
        # "int8": quantized KV cache for dense-GQA decode (§Perf hillclimb C)
        self.kv_cache_dtype = kv_cache_dtype
        self.ep_axes = tuple(ep_axes)
        # scan_unroll=True removes every while loop so cost_analysis counts
        # all work exactly — used by the roofline sample compiles (DESIGN §6)
        self.scan_unroll = scan_unroll
        self.param_dtype = param_dtype
        self.compute_dtype = compute_dtype
        self.chunk_size = chunk_size
        self.remat = remat
        self.remat_policy = remat_policy
        fam = cfg.family
        if fam == "hybrid":
            h = cfg.hybrid
            self.n_seg = cfg.num_layers // h.shared_block_period
            self.seg_len = h.shared_block_period
            self.tail_len = cfg.num_layers - self.n_seg * self.seg_len
        if fam == "moe":
            self.n_dense = cfg.moe.first_k_dense
            self.n_moe = cfg.num_layers - self.n_dense

    # ------------------------------------------------------------------ init
    def init(self, rng) -> PyTree:
        cfg, dt = self.cfg, self.param_dtype
        keys = jax.random.split(rng, 12)
        p: dict = {"embed": init_embedding(keys[0], cfg.padded_vocab,
                                           cfg.d_model, dt)}
        if not cfg.tie_embeddings:
            p["head"] = init_embedding(keys[1], cfg.padded_vocab,
                                       cfg.d_model, dt)
        p["final_ln"] = init_rmsnorm(cfg.d_model, dt)
        fam = cfg.family

        if fam in ("attn_dense", "vlm"):
            p["blocks"] = B.stack_init(
                lambda k: B.init_decoder_block(k, cfg, dt, ffn_kind="dense"),
                keys[2], cfg.num_layers)
        elif fam == "moe":
            if self.n_dense:
                p["dense_blocks"] = B.stack_init(
                    lambda k: B.init_decoder_block(k, cfg, dt,
                                                   ffn_kind="dense"),
                    keys[3], self.n_dense)
            p["moe_blocks"] = B.stack_init(
                lambda k: B.init_decoder_block(k, cfg, dt, ffn_kind="moe"),
                keys[2], self.n_moe)
        elif fam == "ssm":
            p["blocks"] = B.stack_init(
                lambda k: B.init_ssm_block(k, cfg, dt), keys[2],
                cfg.num_layers)
        elif fam == "hybrid":
            seg = B.stack_init(
                lambda k: B.stack_init(
                    lambda k2: B.init_ssm_block(k2, cfg, dt), k, self.seg_len),
                keys[2], self.n_seg)
            p["mamba_seg"] = seg
            if self.tail_len:
                p["mamba_tail"] = B.stack_init(
                    lambda k: B.init_ssm_block(k, cfg, dt), keys[3],
                    self.tail_len)
            p["shared_blocks"] = B.stack_init(
                lambda k: B.init_shared_block(k, cfg, dt), keys[4],
                cfg.hybrid.num_shared_blocks)
            p["loras"] = B.stack_init(
                lambda k: B.init_lora(k, cfg, dt), keys[5], self.n_seg)
        elif fam == "encdec":
            p["enc_blocks"] = B.stack_init(
                lambda k: B.init_encoder_block(k, cfg, dt), keys[2],
                cfg.num_encoder_layers)
            p["dec_blocks"] = B.stack_init(
                lambda k: B.init_encdec_decoder_block(k, cfg, dt), keys[3],
                cfg.num_layers)
            p["enc_ln"] = init_rmsnorm(cfg.d_model, dt)
        else:
            raise ValueError(fam)

        if cfg.frontend.kind == "vision":
            d_f = cfg.frontend.d_frontend
            ks = jax.random.split(keys[6], cfg.frontend.projector_layers)
            proj = [dense_init(ks[0], d_f, cfg.d_model, dt)]
            for i in range(1, cfg.frontend.projector_layers):
                proj.append(dense_init(ks[i], cfg.d_model, cfg.d_model, dt))
            p["projector"] = proj
        return p

    # ------------------------------------------------------------- embedding
    def _embed_tokens(self, params, tokens):
        h = embed(params["embed"], tokens, self.cfg.embedding_scale)
        return h.astype(self.compute_dtype)

    def _project_frontend(self, params, embeds):
        h = embeds.astype(self.compute_dtype)
        for i, w in enumerate(params["projector"]):
            if i:
                h = jax.nn.gelu(h, approximate=True)
            h = h @ w.astype(self.compute_dtype)
        return h

    def _logits(self, params, h):
        return lm_logits(params["embed"], params.get("head"), h,
                         self.cfg.tie_embeddings, self.cfg.logit_scale,
                         self.cfg.logit_soft_cap,
                         vocab_size=self.cfg.vocab_size)

    # ------------------------------------------------------------ backbones
    def _run_decoder_stack(self, params, h, positions, collect_kv=False):
        """Dense/MoE/VLM scanned decoder stack. Returns (h, kv_list, aux)."""
        cfg, cs = self.cfg, self.chunk_size
        aux_total = jnp.zeros((), jnp.float32)
        kvs = {}

        def make_body(ffn_kind):
            def body(carry, layer_params):
                hh = carry
                hh, kv, aux = B.apply_decoder_block(
                    layer_params, cfg, hh, positions, ffn_kind=ffn_kind,
                    chunk_size=cs, ep_axes=self.ep_axes,
                    unroll=self.scan_unroll)
                out = kv if collect_kv else (jnp.zeros((), jnp.float32),) * 2
                return hh, (out, aux)
            return body

        if cfg.family == "moe":
            if self.n_dense:
                h, (kv_d, aux_d) = jax.lax.scan(
                    _remat(make_body("dense"), self.remat, self.remat_policy), h,
                    params["dense_blocks"], unroll=self.scan_unroll)
                aux_total += jnp.sum(aux_d)
                kvs["dense"] = kv_d
            h, (kv_m, aux_m) = jax.lax.scan(
                _remat(make_body("moe"), self.remat, self.remat_policy), h,
                params["moe_blocks"], unroll=self.scan_unroll)
            aux_total += jnp.sum(aux_m)
            kvs["moe"] = kv_m
        else:
            h, (kv, aux) = jax.lax.scan(
                _remat(make_body("dense"), self.remat, self.remat_policy), h,
                params["blocks"], unroll=self.scan_unroll)
            aux_total += jnp.sum(aux)
            kvs["blocks"] = kv
        return h, kvs, aux_total

    def _run_ssm_stack(self, params, h, collect_state=False):
        cfg = self.cfg

        def body(carry, layer_params):
            hh = carry
            hh, state = B.apply_ssm_block(layer_params, cfg, hh,
                                          unroll=self.scan_unroll)
            out = state if collect_state else (
                jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
            return hh, out
        h, states = jax.lax.scan(_remat(body, self.remat, self.remat_policy),
                                 h, params["blocks"],
                                 unroll=self.scan_unroll)
        return h, states

    def _run_hybrid_stack(self, params, h, positions, collect=False):
        """Zamba2: n_seg × (seg_len mamba + shared attn w/ LoRA) + tail."""
        cfg, cs = self.cfg, self.chunk_size
        n_shared = cfg.hybrid.num_shared_blocks

        def seg_body(carry, xs):
            hh, seg_idx = carry
            seg_params, lora = xs

            def inner(c, lp):
                c2, state = B.apply_ssm_block(lp, cfg, c,
                                              unroll=self.scan_unroll)
                out = state if collect else (jnp.zeros(()), jnp.zeros(()))
                return c2, out
            hh, states = jax.lax.scan(inner, hh, seg_params,
                                      unroll=self.scan_unroll)
            shared = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(
                    a, seg_idx % n_shared, 0, keepdims=False),
                params["shared_blocks"])
            hh, kv = B.apply_shared_block(shared, lora, cfg, hh, positions,
                                          chunk_size=cs,
                                          unroll=self.scan_unroll)
            out_kv = kv if collect else (jnp.zeros(()), jnp.zeros(()))
            return (hh, seg_idx + 1), (states, out_kv)

        (h, _), (seg_states, shared_kv) = jax.lax.scan(
            _remat(seg_body, self.remat, self.remat_policy), (h, 0),
            (params["mamba_seg"], params["loras"]),
            unroll=self.scan_unroll)

        tail_states = None
        if self.tail_len:
            def tail_body(c, lp):
                c2, state = B.apply_ssm_block(lp, cfg, c,
                                              unroll=self.scan_unroll)
                out = state if collect else (jnp.zeros(()), jnp.zeros(()))
                return c2, out
            h, tail_states = jax.lax.scan(
                _remat(tail_body, self.remat, self.remat_policy), h,
                params["mamba_tail"], unroll=self.scan_unroll)
        return h, (seg_states, shared_kv, tail_states)

    def _run_encoder(self, params, src, collect=False):
        cfg, cs = self.cfg, self.chunk_size
        Bz, T, _ = src.shape
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (Bz, T))

        def body(carry, lp):
            return B.apply_encoder_block(lp, cfg, carry, positions,
                                         chunk_size=cs,
                                         unroll=self.scan_unroll), None
        h, _ = jax.lax.scan(_remat(body, self.remat, self.remat_policy),
                            src.astype(self.compute_dtype),
                            params["enc_blocks"], unroll=self.scan_unroll)
        return rmsnorm(params["enc_ln"], h, cfg.norm_eps)

    # ---------------------------------------------------------------- train
    def loss_fn(self, params, batch):
        cfg = self.cfg
        params = jax.tree.map(
            lambda a: a.astype(self.compute_dtype)
            if a.dtype in (jnp.float32, jnp.bfloat16, jnp.float16) and
            a.ndim >= 1 else a, params)
        tokens = batch["tokens"]
        Bz, S = tokens.shape
        h = self._embed_tokens(params, tokens)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (Bz, S))
        aux = jnp.zeros((), jnp.float32)
        fam = cfg.family

        if fam == "vlm":
            img = self._project_frontend(params, batch["frontend_embeds"])
            n_img = img.shape[1]
            h = jnp.concatenate([img, h], axis=1)
            total = n_img + S
            positions = jnp.broadcast_to(
                jnp.arange(total, dtype=jnp.int32), (Bz, total))
            h, _, aux = self._run_decoder_stack(params, h, positions)
            h = h[:, n_img:]
        elif fam in ("attn_dense", "moe"):
            h, _, aux = self._run_decoder_stack(params, h, positions)
        elif fam == "ssm":
            h, _ = self._run_ssm_stack(params, h)
        elif fam == "hybrid":
            h, _ = self._run_hybrid_stack(params, h, positions)
        elif fam == "encdec":
            enc_out = self._run_encoder(params, batch["frontend_embeds"])
            ek_ev = None
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                         (Bz, S))

            def body(carry, lp):
                kk, vv = B.cross_kv(lp, cfg, enc_out)
                out, _ = B.apply_encdec_decoder_block(
                    lp, cfg, carry, positions, kk, vv,
                    chunk_size=self.chunk_size, unroll=self.scan_unroll)
                return out, None
            h, _ = jax.lax.scan(_remat(body, self.remat, self.remat_policy),
                                h, params["dec_blocks"],
                                unroll=self.scan_unroll)
        else:
            raise ValueError(fam)

        h = rmsnorm(params["final_ln"], h, cfg.norm_eps)
        logits = self._logits(params, h)
        loss = cross_entropy_loss(logits, batch["labels"],
                                  batch.get("loss_mask"))
        if cfg.moe is not None:
            loss = loss + cfg.moe.router_aux_weight * aux
        metrics = {"loss": loss, "aux_loss": aux}
        return loss, metrics

    # -------------------------------------------------------------- prefill
    def _pad_kv_to(self, kv, max_len):
        """kv: (L, B, S, ...) -> padded to (L, B, max_len, ...). A frontend
        prefix (VLM image tokens) may push S past max_len — never truncate."""
        max_len = max(max_len, kv.shape[2])
        pad = max_len - kv.shape[2]
        widths = [(0, 0)] * kv.ndim
        widths[2] = (0, pad)
        return jnp.pad(kv, widths)

    def prefill(self, params, batch, max_len: int):
        """Run the prompt, return (last-position logits, decode cache)."""
        cfg = self.cfg
        params = jax.tree.map(
            lambda a: a.astype(self.compute_dtype)
            if a.dtype in (jnp.float32, jnp.bfloat16) and a.ndim >= 1 else a,
            params)
        tokens = batch["tokens"]
        Bz, S = tokens.shape
        h = self._embed_tokens(params, tokens)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (Bz, S))
        fam = cfg.family
        cache: dict = {"pos": jnp.full((Bz,), S, jnp.int32)}

        if fam == "vlm":
            img = self._project_frontend(params, batch["frontend_embeds"])
            n_img = img.shape[1]
            h = jnp.concatenate([img, h], axis=1)
            total = n_img + S
            positions = jnp.broadcast_to(jnp.arange(total, dtype=jnp.int32),
                                         (Bz, total))
            cache["pos"] = jnp.full((Bz,), total, jnp.int32)

        if fam in ("attn_dense", "moe", "vlm"):
            h, kvs, _ = self._run_decoder_stack(params, h, positions,
                                                collect_kv=True)
            if cfg.family == "moe":
                parts = [kvs[k] for k in ("dense", "moe") if k in kvs]
                kv = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *parts)
            else:
                kv = kvs["blocks"]
            if cfg.mla is not None:
                cache["c"] = self._pad_kv_to(kv[0], max_len)
                cache["kr"] = self._pad_kv_to(kv[1], max_len)
            elif self.kv_cache_dtype == "int8" and cfg.family != "moe":
                from repro.models.attention import quantize_kv
                kq, ks = quantize_kv(self._pad_kv_to(kv[0], max_len))
                vq, vs = quantize_kv(self._pad_kv_to(kv[1], max_len))
                cache["k"], cache["k_scale"] = kq, ks
                cache["v"], cache["v_scale"] = vq, vs
            else:
                cache["k"] = self._pad_kv_to(kv[0], max_len)
                cache["v"] = self._pad_kv_to(kv[1], max_len)
        elif fam == "ssm":
            h, states = self._run_ssm_stack(params, h, collect_state=True)
            cache["conv"] = states[0]
            cache["ssm"] = states[1]
        elif fam == "hybrid":
            h, (seg_states, shared_kv, tail_states) = self._run_hybrid_stack(
                params, h, positions, collect=True)
            cache["seg_conv"], cache["seg_ssm"] = seg_states
            cache["shared_k"] = self._pad_kv_to(shared_kv[0], max_len)
            cache["shared_v"] = self._pad_kv_to(shared_kv[1], max_len)
            if tail_states is not None:
                cache["tail_conv"], cache["tail_ssm"] = tail_states
        elif fam == "encdec":
            enc_out = self._run_encoder(params, batch["frontend_embeds"])

            def body(carry, lp):
                kk, vv = B.cross_kv(lp, cfg, enc_out)
                out, kv = B.apply_encdec_decoder_block(
                    lp, cfg, carry, positions, kk, vv,
                    chunk_size=self.chunk_size, unroll=self.scan_unroll)
                return out, (kv, (kk, vv))
            h, (self_kv, cross_kv_) = jax.lax.scan(
                body, h, params["dec_blocks"], unroll=self.scan_unroll)
            cache["k"] = self._pad_kv_to(self_kv[0], max_len)
            cache["v"] = self._pad_kv_to(self_kv[1], max_len)
            cache["ek"], cache["ev"] = cross_kv_
        else:
            raise ValueError(fam)

        h = rmsnorm(params["final_ln"], h, cfg.norm_eps)
        logits = self._logits(params, h[:, -1:])
        return logits, cache

    # ---------------------------------------------------- paged decode step
    def cache_descriptor(self, page_tokens: int = 16):
        """This model's :class:`~repro.core.engines.desc.CacheDescriptor`
        — the frozen plane layout that drives the pooled serving path —
        or None when the family has no pooled layout (hybrid/encdec stay
        on the mirrored dense-cache fallback)."""
        from repro.core.engines.desc import descriptor_for
        return descriptor_for(self.cfg, self.kv_cache_dtype,
                              self.compute_dtype, page_tokens)

    def supports_paged_decode(self) -> bool:
        """True when this model can decode directly over a paged pool —
        i.e. when a cache descriptor exists for its config. Dense GQA pools
        ``(k, v)`` planes, int8 adds scale planes, MLA pools the latent,
        SSM rides its state rows alongside the page tables; hybrid and
        encdec have no descriptor yet and stay mirrored."""
        return self.cache_descriptor() is not None

    def _decoder_plane_names(self):
        return tuple(p.name for p in self.cache_descriptor().paged_planes)

    def _cast_params(self, params):
        return jax.tree.map(
            lambda a: a.astype(self.compute_dtype)
            if a.dtype in (jnp.float32, jnp.bfloat16) and a.ndim >= 1 else a,
            params)

    def _scan_paged_planes(self, params, h, pools, step_fn):
        """Scan the decoder stack with per-layer pool-plane slices as xs.
        ``step_fn(ffn_kind) -> (carry, (lp, *planes)) -> (carry, planes)``.
        MoE configs split into the dense-prefix and MoE scans (same split
        as :meth:`decode_step`); everything else is one scan."""
        cfg = self.cfg
        if cfg.family == "moe":
            n_d = self.n_dense
            parts = []
            if n_d:
                h, out_d = jax.lax.scan(
                    step_fn("dense"), h,
                    (params["dense_blocks"],) + tuple(p[:n_d] for p in pools),
                    unroll=self.scan_unroll)
                parts.append(out_d)
            h, out_m = jax.lax.scan(
                step_fn("moe"), h,
                (params["moe_blocks"],) + tuple(p[n_d:] for p in pools),
                unroll=self.scan_unroll)
            parts.append(out_m)
            new_pools = tuple(
                jnp.concatenate([part[i] for part in parts], 0)
                for i in range(len(pools)))
        else:
            h, new_pools = jax.lax.scan(
                step_fn("dense"), h, (params["blocks"],) + tuple(pools),
                unroll=self.scan_unroll)
        return h, new_pools

    def decode_step_paged(self, params, cache, tokens, positions):
        """One decode step over the device-resident paged pool.

        cache: ``pos (B,)``, one ``pool_<plane>`` array per descriptor
        plane (``(L, P, T, *shape)``), and ``block_table (B, MP)`` (dead
        entries clamped/skipped by the kernel). The layer scan carries the
        pool-plane slices as xs, each layer scattering its new token into
        its page slot and attending through the family's paged kernel — no
        dense per-sequence cache row is ever materialized, which is what
        keeps the serving mirror's device→host traffic at zero. SSM
        configs have no paged planes: their state rows ARE the cache, so
        this is exactly :meth:`decode_step`.
        """
        desc = self.cache_descriptor()
        if desc is None:
            raise ValueError(
                f"no cache descriptor for family={self.cfg.family!r} "
                f"kv_cache_dtype={self.kv_cache_dtype!r}; paged decode "
                f"needs a pooled layout")
        if not desc.has_pages:
            return self.decode_step(params, cache, tokens, positions)
        cfg = self.cfg
        params = self._cast_params(params)
        h = self._embed_tokens(params, tokens)
        table = cache["block_table"]
        names = self._decoder_plane_names()
        pools = tuple(cache["pool_" + n] for n in names)

        def step_fn(ffn_kind):
            def body(carry, xs):
                hh, planes = B.decode_paged_block(
                    xs[0], cfg, carry, xs[1:], table, positions,
                    ffn_kind=ffn_kind, ep_axes=self.ep_axes)
                return hh, planes
            return body

        h, new_pools = self._scan_paged_planes(params, h, pools, step_fn)
        new_cache = {"pos": positions + 1, "block_table": table}
        for n, p in zip(names, new_pools):
            new_cache["pool_" + n] = p
        h = rmsnorm(params["final_ln"], h, cfg.norm_eps)
        logits = self._logits(params, h)
        return logits, new_cache

    # ----------------------------------------------- fused ragged step (I5)
    def supports_ragged_step(self) -> bool:
        """True when this model can run a fused mixed-batch tick: a ragged
        multi-token step where decode rows (1 new token) and prefill-chunk
        rows (several) share one forward. Same gate as paged decode — a
        cache descriptor exists; families without one keep the per-chunk
        batch=1 fallback."""
        return self.supports_paged_decode()

    def step_paged_ragged(self, params, cache, tokens, ctx_lens, q_lens):
        """One fused mixed-batch step over the device-resident paged pool.

        tokens: (B, Qmax) int32 — row ``b``'s ``q_lens[b]`` new tokens
        (decode rows hold 1, prefill-chunk rows up to the chunk budget),
        padded to the bucketing ladder's Qmax; ctx_lens: (B,) tokens already
        in the pool per row; q_lens: (B,) with 0 marking batch-width padding
        rows (they scatter nothing and their outputs are garbage to
        discard). cache: one ``pool_<plane>`` per descriptor plane +
        ``block_table (B, MP)``; SSM configs instead carry their
        ``conv``/``ssm`` state rows and return per-slot ``conv_steps``/
        ``ssm_steps`` (the engine commits the committed slot's state).
        Returns logits for every query slot ``(B, Qmax, V)`` — callers read
        slot ``q_lens[b] - 1`` — and the updated cache with
        ``pos = ctx_lens + q_lens``.
        """
        desc = self.cache_descriptor()
        if desc is None:
            raise ValueError(
                f"no cache descriptor for family={self.cfg.family!r} "
                f"kv_cache_dtype={self.kv_cache_dtype!r}; ragged paged "
                f"step needs a pooled layout")
        if not desc.has_pages:
            return self._step_ragged_ssm(params, cache, tokens, ctx_lens,
                                         q_lens)
        cfg = self.cfg
        params = self._cast_params(params)
        h = self._embed_tokens(params, tokens)
        table = cache["block_table"]
        names = self._decoder_plane_names()
        pools = tuple(cache["pool_" + n] for n in names)

        def step_fn(ffn_kind):
            def body(carry, xs):
                hh, planes = B.step_paged_ragged_block(
                    xs[0], cfg, carry, xs[1:], table, ctx_lens, q_lens,
                    ffn_kind=ffn_kind, ep_axes=self.ep_axes)
                return hh, planes
            return body

        h, new_pools = self._scan_paged_planes(params, h, pools, step_fn)
        new_cache = {"pos": ctx_lens + q_lens, "block_table": table}
        for n, p in zip(names, new_pools):
            new_cache["pool_" + n] = p
        h = rmsnorm(params["final_ln"], h, cfg.norm_eps)
        logits = self._logits(params, h)
        return logits, new_cache

    def _step_ragged_ssm(self, params, cache, tokens, ctx_lens, q_lens):
        """Ragged multi-token SSM step: each layer scans its single-step
        mixer over the Qmax slots (state updates masked past ``q_lens``)
        and emits PER-SLOT states ``conv_steps``/``ssm_steps`` shaped
        ``(L, Qmax, B, ...)`` — slot ``i`` holds the state after absorbing
        token ``i``. The caller (serving engine) selects the committed
        slot's state per row; picking an earlier slot IS the speculative
        rollback. ``cache["conv"]``/``cache["ssm"]`` stay the step's INPUT
        states so committed == 0 rows keep them unchanged."""
        cfg = self.cfg
        params = self._cast_params(params)
        h = self._embed_tokens(params, tokens)

        def body(carry, xs):
            lp, conv_s, ssm_s = xs
            hh, conv_steps, ssm_steps = B.step_ragged_ssm_block(
                lp, cfg, carry, conv_s, ssm_s, q_lens)
            return hh, (conv_steps, ssm_steps)
        h, (conv_steps, ssm_steps) = jax.lax.scan(
            body, h, (params["blocks"], cache["conv"], cache["ssm"]),
            unroll=self.scan_unroll)
        new_cache = dict(cache)
        new_cache["pos"] = ctx_lens + q_lens
        new_cache["conv_steps"] = conv_steps
        new_cache["ssm_steps"] = ssm_steps
        h = rmsnorm(params["final_ln"], h, cfg.norm_eps)
        logits = self._logits(params, h)
        return logits, new_cache

    def step_ragged(self, params, cache, tokens, ctx_lens, q_lens):
        """The fused mixed-batch step's mirrored twin: a ragged multi-token
        step over the dense padded cache planes (``(L, B, T, *shape)`` in
        descriptor order — ``k``/``v``, int8 + scales, or MLA ``c``/
        ``kr``; SSM routes to the per-slot state scan). Same contract as
        :meth:`step_paged_ragged`; with every ``q_len == 1`` this is
        ``decode_step`` exactly."""
        desc = self.cache_descriptor()
        if desc is None:
            raise ValueError(
                f"no cache descriptor for family={self.cfg.family!r} "
                f"kv_cache_dtype={self.kv_cache_dtype!r}; ragged step "
                f"needs a pooled layout")
        if not desc.has_pages:
            return self._step_ragged_ssm(params, cache, tokens, ctx_lens,
                                         q_lens)
        cfg = self.cfg
        params = self._cast_params(params)
        h = self._embed_tokens(params, tokens)
        names = self._decoder_plane_names()
        planes = tuple(cache[n] for n in names)

        def step_fn(ffn_kind):
            def body(carry, xs):
                hh, out = B.step_ragged_block(
                    xs[0], cfg, carry, xs[1:], ctx_lens, q_lens,
                    ffn_kind=ffn_kind, ep_axes=self.ep_axes)
                return hh, out
            return body

        h, new_planes = self._scan_paged_planes(params, h, planes, step_fn)
        new_cache = dict(cache)
        new_cache["pos"] = ctx_lens + q_lens
        for n, p in zip(names, new_planes):
            new_cache[n] = p
        h = rmsnorm(params["final_ln"], h, cfg.norm_eps)
        logits = self._logits(params, h)
        return logits, new_cache

    # ---------------------------------------------------------- decode step
    def decode_step(self, params, cache, tokens, positions):
        """tokens: (B, 1) int32; positions: (B,) int32 write/query index."""
        cfg = self.cfg
        params = jax.tree.map(
            lambda a: a.astype(self.compute_dtype)
            if a.dtype in (jnp.float32, jnp.bfloat16) and a.ndim >= 1 else a,
            params)
        Bz = tokens.shape[0]
        h = self._embed_tokens(params, tokens)
        fam = cfg.family
        new_cache = dict(cache)
        new_cache["pos"] = positions + 1

        if fam in ("attn_dense", "vlm", "moe"):
            c0, c1 = (("c", "kr") if cfg.mla is not None else ("k", "v"))

            if fam == "moe":
                n_d = self.n_dense
                ck, cv = cache[c0], cache[c1]
                nk_parts, nv_parts = [], []
                if n_d:
                    def body_d(carry, xs):
                        lp, k_, v_ = xs
                        hh, (nk, nv) = B.decode_decoder_block(
                            lp, cfg, carry, (k_, v_), positions,
                            ffn_kind="dense")
                        return hh, (nk, nv)
                    h, (nkd, nvd) = jax.lax.scan(
                        body_d, h, (params["dense_blocks"],
                                    ck[:n_d], cv[:n_d]),
                        unroll=self.scan_unroll)
                    nk_parts.append(nkd)
                    nv_parts.append(nvd)

                def body_m(carry, xs):
                    lp, k_, v_ = xs
                    hh, (nk, nv) = B.decode_decoder_block(
                        lp, cfg, carry, (k_, v_), positions, ffn_kind="moe",
                        ep_axes=self.ep_axes)
                    return hh, (nk, nv)
                h, (nkm, nvm) = jax.lax.scan(
                    body_m, h, (params["moe_blocks"], ck[n_d:], cv[n_d:]),
                    unroll=self.scan_unroll)
                nk_parts.append(nkm)
                nv_parts.append(nvm)
                new_cache[c0] = jnp.concatenate(nk_parts, 0)
                new_cache[c1] = jnp.concatenate(nv_parts, 0)
            elif self.kv_cache_dtype == "int8" and cfg.mla is None:
                from repro.models import attention as attn_mod
                from repro.models.layers import rmsnorm as _rms

                def body_q8(carry, xs):
                    lp, k_, v_, ks_, vs_ = xs
                    hh = carry
                    xn = _rms(lp["ln_attn"], hh, cfg.norm_eps)
                    a, nk, nv, nks, nvs = attn_mod.attn_decode_q8(
                        lp["attn"], cfg, xn, k_, v_, ks_, vs_, positions)
                    hh = hh + cfg.residual_scale * a
                    xn = _rms(lp["ln_ffn"], hh, cfg.norm_eps)
                    from repro.models.layers import apply_ffn as _ffn
                    hh = hh + cfg.residual_scale * _ffn(
                        lp["ffn"], xn, cfg.ffn_activation)
                    return hh, (nk, nv, nks, nvs)
                h, (nk, nv, nks, nvs) = jax.lax.scan(
                    body_q8, h,
                    (params["blocks"], cache["k"], cache["v"],
                     cache["k_scale"], cache["v_scale"]),
                    unroll=self.scan_unroll)
                new_cache["k"], new_cache["v"] = nk, nv
                new_cache["k_scale"], new_cache["v_scale"] = nks, nvs
            else:
                def body_s(carry, xs):
                    lp, k_, v_ = xs
                    hh, (nk, nv) = B.decode_decoder_block(
                        lp, cfg, carry, (k_, v_), positions, ffn_kind="dense")
                    return hh, (nk, nv)
                h, (nk, nv) = jax.lax.scan(
                    body_s, h, (params["blocks"], cache[c0], cache[c1]),
                    unroll=self.scan_unroll)
                new_cache[c0], new_cache[c1] = nk, nv
        elif fam == "ssm":
            def body(carry, xs):
                lp, conv_s, ssm_s = xs
                hh, nc, ns = B.decode_ssm_block(lp, cfg, carry, conv_s, ssm_s)
                return hh, (nc, ns)
            h, (nc, ns) = jax.lax.scan(
                body, h, (params["blocks"], cache["conv"], cache["ssm"]),
                unroll=self.scan_unroll)
            new_cache["conv"], new_cache["ssm"] = nc, ns
        elif fam == "hybrid":
            n_shared = cfg.hybrid.num_shared_blocks

            def seg_body(carry, xs):
                hh, seg_idx = carry
                seg_params, lora, conv_s, ssm_s, sk, sv = xs

                def inner(c, lp_states):
                    lp, cs_, ss_ = lp_states
                    c2, nc, ns = B.decode_ssm_block(lp, cfg, c, cs_, ss_)
                    return c2, (nc, ns)
                hh, (nc, ns) = jax.lax.scan(
                    inner, hh, (seg_params, conv_s, ssm_s),
                    unroll=self.scan_unroll)
                shared = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(
                        a, seg_idx % n_shared, 0, keepdims=False),
                    params["shared_blocks"])
                hh, (nsk, nsv) = B.decode_shared_block(
                    shared, lora, cfg, hh, (sk, sv), positions)
                return (hh, seg_idx + 1), (nc, ns, nsk, nsv)

            (h, _), (nc, ns, nsk, nsv) = jax.lax.scan(
                seg_body, (h, 0),
                (params["mamba_seg"], params["loras"], cache["seg_conv"],
                 cache["seg_ssm"], cache["shared_k"], cache["shared_v"]),
                unroll=self.scan_unroll)
            new_cache["seg_conv"], new_cache["seg_ssm"] = nc, ns
            new_cache["shared_k"], new_cache["shared_v"] = nsk, nsv
            if self.tail_len:
                def tail_body(c, xs):
                    lp, cs_, ss_ = xs
                    c2, ncx, nsx = B.decode_ssm_block(lp, cfg, c, cs_, ss_)
                    return c2, (ncx, nsx)
                h, (ntc, nts) = jax.lax.scan(
                    tail_body, h, (params["mamba_tail"], cache["tail_conv"],
                                   cache["tail_ssm"]),
                    unroll=self.scan_unroll)
                new_cache["tail_conv"], new_cache["tail_ssm"] = ntc, nts
        elif fam == "encdec":
            def body(carry, xs):
                lp, k_, v_, ek, ev = xs
                hh, (nk, nv) = B.decode_encdec_decoder_block(
                    lp, cfg, carry, (k_, v_, ek, ev), positions)
                return hh, (nk, nv)
            h, (nk, nv) = jax.lax.scan(
                body, h, (params["dec_blocks"], cache["k"], cache["v"],
                          cache["ek"], cache["ev"]),
                unroll=self.scan_unroll)
            new_cache["k"], new_cache["v"] = nk, nv
        else:
            raise ValueError(fam)

        h = rmsnorm(params["final_ln"], h, cfg.norm_eps)
        logits = self._logits(params, h)
        return logits, new_cache


def build_model(cfg, **kwargs) -> LM:
    return LM(cfg, **kwargs)
