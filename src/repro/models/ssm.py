"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Chunked algorithm: within-chunk quadratic (attention-like) term + inter-chunk
state recurrence (lax.scan over chunks). Single-token decode maintains
(conv_state, ssm_state) exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.ngroups * s.d_state
    return s, d_inner, nheads, conv_dim


def init_ssm(key, cfg, dtype):
    s, d_inner, nheads, conv_dim = _dims(cfg)
    keys = jax.random.split(key, 5)
    d_in_proj = 2 * d_inner + 2 * s.ngroups * s.d_state + nheads
    # dt bias initialised so softplus(dt_bias) spans [1e-3, 1e-1]
    dt = jnp.exp(jax.random.uniform(keys[2], (nheads,)) *
                 (jnp.log(1e-1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "in_proj": dense_init(keys[0], cfg.d_model, d_in_proj, dtype),
        "conv_w": (jax.random.normal(keys[1], (s.d_conv, conv_dim)) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "a_log": jnp.log(jnp.arange(1, nheads + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((nheads,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(keys[3], d_inner, cfg.d_model, dtype),
    }


def _split_in_proj(cfg, zxbcdt):
    s, d_inner, nheads, conv_dim = _dims(cfg)
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)
    return z, xbc, dt


def _gated_norm(params, y, z, eps):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps)
            * params["norm_scale"].astype(jnp.float32)).astype(y.dtype)


def _segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k] (i>=j)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk_size, initial_state=None,
                unroll: bool = False):
    """SSD over a full sequence, streamed chunk-by-chunk (lax.scan).

    x:  (b, T, H, P)   — per-head inputs
    dt: (b, T, H)      — positive step sizes (already softplus'd)
    A:  (H,)           — negative scalars
    B,C: (b, T, N)     — shared across heads (ngroups=1)
    Returns (y (b,T,H,P), final_state (b,H,P,N)).

    Scanning bounds live memory to one chunk's quadratic term (b·H·Q²) —
    at 32k/512k sequence lengths the all-chunks-at-once layout is tens of
    GB per device, the streamed one is tens of MB.
    """
    b, T, H, P = x.shape
    N = B.shape[-1]
    Q = chunk_size
    assert T % Q == 0, f"seq {T} not divisible by chunk {Q}"
    nc = T // Q

    xd = (x * dt[..., None]).astype(jnp.float32)               # fold dt into x
    dA = (dt * A[None, None, :]).astype(jnp.float32)           # (b,T,H) ≤ 0

    xc = xd.reshape(b, nc, Q, H, P).transpose(1, 0, 2, 3, 4)
    Bc = B.reshape(b, nc, Q, N).astype(jnp.float32).transpose(1, 0, 2, 3)
    Cc = C.reshape(b, nc, Q, N).astype(jnp.float32).transpose(1, 0, 2, 3)
    dAc = dA.reshape(b, nc, Q, H).transpose(1, 0, 2, 3)

    s0 = (jnp.zeros((b, H, P, N), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def body(s_prev, xs):
        xcj, Bcj, Ccj, dAcj = xs          # (b,Q,H,P) (b,Q,N) (b,Q,N) (b,Q,H)
        L = jnp.exp(_segsum(dAcj.transpose(0, 2, 1)))          # (b,H,Q,Q)
        CB = jnp.einsum("bin,bjn->bij", Ccj, Bcj)              # (b,Q,Q)
        y_diag = jnp.einsum("bij,bhij,bjhp->bihp", CB, L, xcj)
        dA_cum = jnp.cumsum(dAcj, axis=1)                      # (b,Q,H)
        decay_to_end = jnp.exp(dA_cum[:, -1:, :] - dA_cum)
        state_c = jnp.einsum("bjn,bjh,bjhp->bhpn",
                             Bcj, decay_to_end, xcj)           # (b,H,P,N)
        y_off = jnp.einsum("bin,bih,bhpn->bihp",
                           Ccj, jnp.exp(dA_cum), s_prev)
        s_new = (s_prev * jnp.exp(dA_cum[:, -1, :])[:, :, None, None]
                 + state_c)
        return s_new, (y_diag + y_off)

    s_final, ys = jax.lax.scan(body, s0, (xc, Bc, Cc, dAc), unroll=unroll)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, T, H, P)
    return y.astype(x.dtype), s_final


def apply_ssm(params, cfg, x, initial_state=None, unroll: bool = False):
    """Full-sequence Mamba-2 block. x: (B, T, d_model).

    Returns (y, (conv_state, ssm_state)) — states for decode continuation.
    """
    s, d_inner, nheads, conv_dim = _dims(cfg)
    B_, T, _ = x.shape
    zxbcdt = x @ params["in_proj"]
    z, xbc, dt = _split_in_proj(cfg, zxbcdt)

    # causal depthwise conv over xbc
    xbc_pad = jnp.pad(xbc, ((0, 0), (s.d_conv - 1, 0), (0, 0)))
    win = jnp.stack([xbc_pad[:, i:i + T] for i in range(s.d_conv)], 0)
    xbc = jax.nn.silu(jnp.einsum("kbtc,kc->btc", win, params["conv_w"])
                      + params["conv_b"])
    conv_state = xbc_pad[:, -(s.d_conv - 1):]                  # (B, d_conv-1, conv_dim)

    xs, Bmat, Cmat = jnp.split(
        xbc, [d_inner, d_inner + s.ngroups * s.d_state], axis=-1)
    xh = xs.reshape(B_, T, nheads, s.head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])   # (B,T,H)
    A = -jnp.exp(params["a_log"])                              # (H,)

    # pad T to a chunk multiple; padded steps get dt=0 (decay 1, update 0),
    # so they are exact no-ops for both outputs and the final state.
    Q = s.chunk_size
    T_pad = (-T) % Q
    if T_pad:
        xh = jnp.pad(xh, ((0, 0), (0, T_pad), (0, 0), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, T_pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, T_pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, T_pad), (0, 0)))

    y, final_state = ssd_chunked(xh, dt, A, Bmat, Cmat, s.chunk_size,
                                 initial_state, unroll=unroll)
    if T_pad:
        y = y[:, :T]
        xh = xh[:, :T]
    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B_, T, d_inner).astype(x.dtype)
    y = _gated_norm(params, y, z, cfg.norm_eps)
    return y @ params["out_proj"], (conv_state, final_state)


def ssm_decode(params, cfg, x, conv_state, ssm_state):
    """Single-token decode. x: (B, 1, d).

    conv_state: (B, d_conv-1, conv_dim); ssm_state: (B, H, P, N) fp32.
    """
    s, d_inner, nheads, conv_dim = _dims(cfg)
    B_ = x.shape[0]
    zxbcdt = x @ params["in_proj"]
    z, xbc_new, dt = _split_in_proj(cfg, zxbcdt)               # (B,1,·)

    window = jnp.concatenate([conv_state, xbc_new], axis=1)    # (B, d_conv, c)
    new_conv_state = window[:, 1:]
    xbc = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, params["conv_w"])
                      + params["conv_b"])[:, None, :]

    xs, Bmat, Cmat = jnp.split(
        xbc, [d_inner, d_inner + s.ngroups * s.d_state], axis=-1)
    xh = xs.reshape(B_, nheads, s.head_dim).astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                         + params["dt_bias"][None, :])         # (B,H)
    A = -jnp.exp(params["a_log"])
    dA = jnp.exp(dt * A[None, :])                              # (B,H)
    Bv = Bmat[:, 0].astype(jnp.float32)                        # (B,N)
    Cv = Cmat[:, 0].astype(jnp.float32)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bv)
    new_state = ssm_state * dA[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cv)
    y = y + params["d_skip"][None, :, None] * xh
    y = y.reshape(B_, 1, d_inner).astype(x.dtype)
    y = _gated_norm(params, y, z, cfg.norm_eps)
    return y @ params["out_proj"], (new_conv_state, new_state)
