"""Attention: GQA/MQA/MHA + DeepSeek MLA, with a chunked online-softmax core.

The chunked core (``chunked_attention``) is the memory-efficient XLA path used
for training/prefill (it is also the oracle for the flash_attention Pallas
kernel). Decode paths operate on KV caches; MLA decode uses the weight-absorbed
latent form (cache stores only the 512-d latent + 64-d rope key).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_rope, dense_init, init_rmsnorm, rmsnorm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Core: grouped chunked online-softmax attention
# ---------------------------------------------------------------------------
def chunked_attention(q, k, v, *, scale: float, q_positions, kv_positions,
                      causal: bool, kv_valid=None, chunk_size: int = 512,
                      unroll: bool = False):
    """Online-softmax attention, scanning over KV chunks.

    q: (B, S, K, G, D) grouped queries (H = K*G)
    k, v: (B, T, K, D)
    q_positions: (B, S) int32; kv_positions: (T,) or (B, T) int32
    kv_valid: optional (B, T) bool — False entries are masked out
    Returns (B, S, K, G, D) in q.dtype.
    """
    B, S, K, G, D = q.shape
    T = k.shape[1]
    Dv = v.shape[-1]
    if T % chunk_size != 0:
        pad = chunk_size - T % chunk_size
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_positions.ndim == 1:
            kv_positions = jnp.pad(kv_positions, (0, pad))
        else:
            kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)))
        valid = jnp.ones((B, T), bool) if kv_valid is None else kv_valid
        kv_valid = jnp.pad(valid, ((0, 0), (0, pad)))
        T = T + pad
    ncnk = T // chunk_size

    if kv_positions.ndim == 1:
        kv_positions = jnp.broadcast_to(kv_positions[None, :], (B, T))
    if kv_valid is None:
        kv_valid = jnp.ones((B, T), bool)

    qf = q.astype(jnp.float32)
    kc = k.reshape(B, ncnk, chunk_size, K, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, ncnk, chunk_size, K, Dv).transpose(1, 0, 2, 3, 4)
    pc = kv_positions.reshape(B, ncnk, chunk_size).transpose(1, 0, 2)
    mc = kv_valid.reshape(B, ncnk, chunk_size).transpose(1, 0, 2)

    m0 = jnp.full((B, K, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, S), jnp.float32)
    a0 = jnp.zeros((B, S, K, G, Dv), jnp.float32)

    def body_fixed(carry, xs):
        m, l, acc = carry
        k_j, v_j, pos_j, ok_j = xs
        s = jnp.einsum("bskgd,bckd->bkgsc", qf, k_j.astype(jnp.float32)) * scale
        allow = ok_j[:, None, :]                                   # (B, 1, C)
        if causal:
            allow = allow & (pos_j[:, None, :] <= q_positions[:, :, None])
        else:
            allow = jnp.broadcast_to(allow, (B, S, chunk_size))
        s = jnp.where(allow[:, None, None, :, :], s, NEG_INF)      # (B,K,G,S,C)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        upd = jnp.einsum("bkgsc,bckd->bskgd", p, v_j.astype(jnp.float32))
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + upd
        return (m_new, l_new, acc_new), ()

    (m, l, acc), _ = jax.lax.scan(body_fixed, (m0, l0, a0),
                                  (kc, vc, pc, mc), unroll=unroll)
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return out.astype(q.dtype)


def chunked_attention_tri(q, k, v, *, scale: float, chunk_size: int = 512,
                          unroll: bool = False):
    """Causal self-attention computing ONLY the lower-triangular chunk pairs.

    §Perf hillclimb (EXPERIMENTS.md): the plain chunked scan visits every
    (q-chunk, kv-chunk) pair and masks the upper triangle — ~2× wasted
    attention FLOPs at long sequence. Here the scan runs over the
    n(n+1)/2 live pairs (statically enumerated; chunks fetched with
    dynamic_index), so compiled FLOPs match the causal lower triangle.

    Requires S == T and S % chunk_size == 0 (self-attention, aligned) —
    callers fall back to ``chunked_attention`` otherwise.
    """
    B, S, K, G, D = q.shape
    C = chunk_size
    n = S // C
    qf = q.astype(jnp.float32).reshape(B, n, C, K, G, D)
    kc = k.reshape(B, n, C, K, D)
    vc = v.reshape(B, n, C, K, D)

    pairs = np.array([(i, j) for i in range(n) for j in range(i + 1)],
                     dtype=np.int32)                       # (P, 2)
    m0 = jnp.full((B, n, K, G, C), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, n, K, G, C), jnp.float32)
    a0 = jnp.zeros((B, n, C, K, G, D), jnp.float32)

    pos_in_chunk = jnp.arange(C, dtype=jnp.int32)

    def body(carry, pair):
        m, l, acc = carry
        i, j = pair[0], pair[1]
        q_i = jax.lax.dynamic_index_in_dim(qf, i, 1, keepdims=False)
        k_j = jax.lax.dynamic_index_in_dim(kc, j, 1, keepdims=False)
        v_j = jax.lax.dynamic_index_in_dim(vc, j, 1, keepdims=False)
        s = jnp.einsum("bskgd,bckd->bkgsc", q_i,
                       k_j.astype(jnp.float32)) * scale
        diag = i == j
        q_pos = i * C + pos_in_chunk
        k_pos = j * C + pos_in_chunk
        allow = jnp.where(diag, k_pos[None, :] <= q_pos[:, None], True)
        s = jnp.where(allow[None, None, None], s, NEG_INF)
        m_i = jax.lax.dynamic_index_in_dim(m, i, 1, keepdims=False)
        l_i = jax.lax.dynamic_index_in_dim(l, i, 1, keepdims=False)
        a_i = jax.lax.dynamic_index_in_dim(acc, i, 1, keepdims=False)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_i - m_new)
        l_new = l_i * corr + jnp.sum(p, axis=-1)
        upd = jnp.einsum("bkgsc,bckd->bskgd", p, v_j.astype(jnp.float32))
        a_new = a_i * corr.transpose(0, 3, 1, 2)[..., None] + upd
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 1)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, i, 1)
        return (m, l, acc), ()

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.asarray(pairs),
                                  unroll=unroll)
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 1, 4, 2, 3)[..., None]
    return out.reshape(B, S, K, G, D).astype(q.dtype)


def full_attention(q, k, v, *, scale, q_positions, kv_positions, causal,
                   kv_valid=None):
    """Single-einsum reference attention (small shapes / decode)."""
    B, S, K, G, D = q.shape
    T = k.shape[1]
    s = jnp.einsum("bskgd,btkd->bkgst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if kv_positions.ndim == 1:
        kv_positions = jnp.broadcast_to(kv_positions[None, :], (B, T))
    allow = jnp.ones((B, S, T), bool)
    if causal:
        allow = kv_positions[:, None, :] <= q_positions[:, :, None]
    if kv_valid is not None:
        allow = allow & kv_valid[:, None, :]
    s = jnp.where(allow[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA module
# ---------------------------------------------------------------------------
def init_attn(key, cfg, dtype, d_in: Optional[int] = None):
    d = d_in or cfg.d_model
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d, cfg.num_heads * cfg.head_dim, dtype),
        "wk": dense_init(kk, d, cfg.num_kv_heads * cfg.head_dim, dtype),
        "wv": dense_init(kv, d, cfg.num_kv_heads * cfg.head_dim, dtype),
        "wo": dense_init(ko, cfg.num_heads * cfg.head_dim, d, dtype),
    }


def _project_qkv(params, cfg, x, positions, rope: bool):
    B, S, _ = x.shape
    K, H, D = cfg.num_kv_heads, cfg.num_heads, cfg.head_dim
    G = H // K
    q = (x @ params["wq"]).reshape(B, S, H, D)
    k = (x @ params["wk"]).reshape(B, S, K, D)
    v = (x @ params["wv"]).reshape(B, S, K, D)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = q.reshape(B, S, K, G, D)
    return q, k, v


def attn_train(params, cfg, x, positions, *, causal=True, chunk_size=512,
               unroll=False, triangular=True):
    """Self-attention over a full sequence (training / prefill compute).

    ``triangular`` routes aligned causal runs through the
    lower-triangle-only scan (half the attention FLOPs at long S).
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions, rope=True)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    if S <= chunk_size:
        out = full_attention(q, k, v, scale=scale, q_positions=positions,
                             kv_positions=positions, causal=causal)
    elif causal and triangular and S % chunk_size == 0:
        out = chunked_attention_tri(q, k, v, scale=scale,
                                    chunk_size=chunk_size, unroll=unroll)
    else:
        out = chunked_attention(q, k, v, scale=scale, q_positions=positions,
                                kv_positions=positions, causal=causal,
                                chunk_size=chunk_size, unroll=unroll)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    return out @ params["wo"], (k, v)


def attn_cross(params, cfg, x, enc_k, enc_v, enc_valid=None, chunk_size=512,
               unroll=False):
    """Cross-attention: queries from decoder x, keys/values precomputed."""
    B, S, _ = x.shape
    K, H, D = cfg.num_kv_heads, cfg.num_heads, cfg.head_dim
    G = H // K
    q = (x @ params["wq"]).reshape(B, S, K, G, D)
    T = enc_k.shape[1]
    pos_q = jnp.zeros((B, S), jnp.int32)
    pos_kv = jnp.zeros((T,), jnp.int32)
    fn = full_attention if max(S, T) <= chunk_size else chunked_attention
    kwargs = ({} if fn is full_attention
              else {"chunk_size": chunk_size, "unroll": unroll})
    out = fn(q, enc_k, enc_v, scale=1.0 / math.sqrt(D), q_positions=pos_q,
             kv_positions=pos_kv, causal=False, kv_valid=enc_valid, **kwargs)
    return out.reshape(B, S, H * D) @ params["wo"]


# ---------------------------------------------------------------------------
# int8 KV cache (§Perf hillclimb C): per-(token, head) symmetric scales.
# Decode is KV-read-bound; int8 halves the HBM traffic of the dominant term.
# ---------------------------------------------------------------------------
def quantize_kv(kv):
    """kv: (..., K, D) → (int8 kv, scales (..., K))."""
    scale = jnp.max(jnp.abs(kv.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(kv.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]
            ).astype(dtype)


def attn_decode_q8(params, cfg, x, ck, cv, ck_s, cv_s, positions):
    """attn_decode over an int8 cache: dequant-on-read, quant-on-write."""
    B = x.shape[0]
    K, H, D = cfg.num_kv_heads, cfg.num_heads, cfg.head_dim
    pos2 = positions[:, None]
    q, k_new, v_new = _project_qkv(params, cfg, x, pos2, rope=True)
    b_idx = jnp.arange(B)
    kq, ks = quantize_kv(k_new[:, 0])
    vq, vs = quantize_kv(v_new[:, 0])
    ck = ck.at[b_idx, positions].set(kq)
    cv = cv.at[b_idx, positions].set(vq)
    ck_s = ck_s.at[b_idx, positions].set(ks)
    cv_s = cv_s.at[b_idx, positions].set(vs)
    k = dequantize_kv(ck, ck_s, x.dtype)
    v = dequantize_kv(cv, cv_s, x.dtype)
    T = k.shape[1]
    kv_pos = jnp.arange(T, dtype=jnp.int32)
    valid = kv_pos[None, :] <= positions[:, None]
    out = full_attention(q, k, v, scale=1.0 / math.sqrt(D),
                         q_positions=pos2, kv_positions=kv_pos, causal=False,
                         kv_valid=valid)
    out = out.reshape(B, 1, H * D) @ params["wo"]
    return out, ck, cv, ck_s, cv_s


def attn_decode(params, cfg, x, cache_k, cache_v, positions):
    """Single-step decode. cache_k/v: (B, T, K, D) updated at ``positions``.

    positions: (B,) int32 — write index per sequence (also the query position).
    Returns (out, new_cache_k, new_cache_v).
    """
    B, S, _ = x.shape
    assert S == 1
    K, H, D = cfg.num_kv_heads, cfg.num_heads, cfg.head_dim
    G = H // K
    pos2 = positions[:, None]                                      # (B, 1)
    q, k, v = _project_qkv(params, cfg, x, pos2, rope=True)
    b_idx = jnp.arange(B)
    cache_k = cache_k.at[b_idx, positions].set(k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[b_idx, positions].set(v[:, 0].astype(cache_v.dtype))
    T = cache_k.shape[1]
    kv_pos = jnp.arange(T, dtype=jnp.int32)
    valid = kv_pos[None, :] <= positions[:, None]
    out = full_attention(q, cache_k, cache_v, scale=1.0 / math.sqrt(D),
                         q_positions=pos2, kv_positions=kv_pos, causal=False,
                         kv_valid=valid)
    out = out.reshape(B, 1, H * D) @ params["wo"]
    return out, cache_k, cache_v


def attn_decode_ragged(params, cfg, x, cache_k, cache_v, ctx_lens, q_lens):
    """Ragged multi-token decode over the dense cache (the fused mixed
    -batch tick's mirrored twin). x: (B, Qmax, d); row ``b`` appends
    ``q_lens[b]`` new tokens at positions ``ctx_lens[b] + i`` and each
    attends causally to everything at or before it. Padding slots
    (``i >= q_lens[b]``) write nothing (scatter-dropped) and their outputs
    are garbage the caller must ignore. With ``q_len == 1`` everywhere this
    is ``attn_decode`` exactly (same masks, same einsums).

    Returns (out, new_cache_k, new_cache_v).
    """
    B, Qm, _ = x.shape
    K, H, D = cfg.num_kv_heads, cfg.num_heads, cfg.head_dim
    positions = ctx_lens[:, None] + jnp.arange(Qm, dtype=jnp.int32)[None, :]
    q, k, v = _project_qkv(params, cfg, x, positions, rope=True)
    T = cache_k.shape[1]
    valid = jnp.arange(Qm)[None, :] < q_lens[:, None]
    # padding slots scatter out of bounds and are dropped
    write_pos = jnp.where(valid, positions, T)
    b_idx = jnp.arange(B)[:, None]
    cache_k = cache_k.at[b_idx, write_pos].set(
        k.astype(cache_k.dtype), mode="drop")
    cache_v = cache_v.at[b_idx, write_pos].set(
        v.astype(cache_v.dtype), mode="drop")
    kv_pos = jnp.arange(T, dtype=jnp.int32)
    out = full_attention(q, cache_k, cache_v, scale=1.0 / math.sqrt(D),
                         q_positions=positions, kv_positions=kv_pos,
                         causal=True)
    out = out.reshape(B, Qm, H * D) @ params["wo"]
    return out, cache_k, cache_v


def attn_decode_paged(params, cfg, x, pool_k, pool_v, block_table,
                      positions):
    """Single-step decode directly over a paged KV pool (mirror-free path).

    pool_k/pool_v: (P, T, K, D) — one layer's slice of the device-resident
    pool; block_table: (B, MP) int32 logical→physical mapping; positions:
    (B,) int32 write/query index. The new token's K/V is scattered into its
    page slot (each sequence owns its pages exclusively, so the (phys, slot)
    targets never collide across the batch) and attention runs through the
    ``paged_attention`` kernel over the pool — no dense per-sequence cache
    row exists anywhere.

    Returns (out, new_pool_k, new_pool_v).
    """
    from repro.kernels.paged_attention import paged_attention

    B, S, _ = x.shape
    assert S == 1
    K, H, D = cfg.num_kv_heads, cfg.num_heads, cfg.head_dim
    pos2 = positions[:, None]                                      # (B, 1)
    q, k, v = _project_qkv(params, cfg, x, pos2, rope=True)
    T = pool_k.shape[1]
    b_idx = jnp.arange(B)
    phys = block_table[b_idx, positions // T]                      # (B,)
    slot = positions % T
    pool_k = pool_k.at[phys, slot].set(k[:, 0].astype(pool_k.dtype))
    pool_v = pool_v.at[phys, slot].set(v[:, 0].astype(pool_v.dtype))
    out = paged_attention(q.reshape(B, H, D), pool_k, pool_v, block_table,
                          positions + 1, scale=1.0 / math.sqrt(D))
    out = out.reshape(B, 1, H * D) @ params["wo"]
    return out, pool_k, pool_v


def attn_step_paged_ragged(params, cfg, x, pool_k, pool_v, block_table,
                           ctx_lens, q_lens):
    """Ragged multi-token step over one layer's slice of the paged KV pool
    — the fused mixed-batch tick's attention: decode rows (``q_len == 1``)
    and prefill-chunk rows (``q_len ≤ chunk``) share one launch.

    x: (B, Qmax, d_model); ctx_lens: (B,) tokens already in the pool (the
    chunk's start position); q_lens: (B,) valid new tokens per row. Each
    row's new K/V is scattered into its page slots on device (padding
    slots, including whole ``q_len == 0`` bucket-ladder rows, target an
    out-of-range page and are dropped — they can never touch another
    sequence's pages) and attention runs the ``paged_attention_ragged``
    kernel with intra-chunk causal masking against the pool.

    Returns (out, new_pool_k, new_pool_v).
    """
    from repro.kernels.paged_attention import paged_attention_ragged

    B, Qm, _ = x.shape
    K, H, D = cfg.num_kv_heads, cfg.num_heads, cfg.head_dim
    positions = ctx_lens[:, None] + jnp.arange(Qm, dtype=jnp.int32)[None, :]
    q, k, v = _project_qkv(params, cfg, x, positions, rope=True)
    P, T = pool_k.shape[0], pool_k.shape[1]
    valid = jnp.arange(Qm)[None, :] < q_lens[:, None]
    logical = jnp.clip(positions // T, 0, block_table.shape[1] - 1)
    phys = jnp.take_along_axis(block_table, logical, axis=1)       # (B, Qm)
    phys = jnp.where(valid, phys, P)               # out of range → dropped
    slot = positions % T
    pool_k = pool_k.at[phys, slot].set(k.astype(pool_k.dtype), mode="drop")
    pool_v = pool_v.at[phys, slot].set(v.astype(pool_v.dtype), mode="drop")
    out = paged_attention_ragged(
        q.reshape(B, Qm, H, D), pool_k, pool_v, block_table,
        ctx_lens + q_lens, q_lens, scale=1.0 / math.sqrt(D))
    out = out.reshape(B, Qm, H * D) @ params["wo"]
    return out, pool_k, pool_v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------
def init_mla(key, cfg, dtype):
    m = cfg.mla
    keys = jax.random.split(key, 8)
    H = cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    p = {}
    if m.q_lora_rank:
        p["w_dq"] = dense_init(keys[0], cfg.d_model, m.q_lora_rank, dtype)
        p["q_norm"] = init_rmsnorm(m.q_lora_rank, dtype)
        p["w_uq"] = dense_init(keys[1], m.q_lora_rank, H * qk_head, dtype)
    else:
        p["w_q"] = dense_init(keys[1], cfg.d_model, H * qk_head, dtype)
    p["w_dkv"] = dense_init(keys[2], cfg.d_model, m.kv_lora_rank, dtype)
    p["kv_norm"] = init_rmsnorm(m.kv_lora_rank, dtype)
    p["w_kr"] = dense_init(keys[3], cfg.d_model, m.qk_rope_head_dim, dtype)
    p["w_uk"] = dense_init(keys[4], m.kv_lora_rank,
                           H * m.qk_nope_head_dim, dtype).reshape(
        m.kv_lora_rank, H, m.qk_nope_head_dim)
    p["w_uv"] = dense_init(keys[5], m.kv_lora_rank,
                           H * m.v_head_dim, dtype).reshape(
        m.kv_lora_rank, H, m.v_head_dim)
    p["wo"] = dense_init(keys[6], H * m.v_head_dim, cfg.d_model, dtype)
    return p


def _mla_queries(params, cfg, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    if m.q_lora_rank:
        cq = rmsnorm(params["q_norm"], x @ params["w_dq"], cfg.norm_eps)
        q = (cq @ params["w_uq"]).reshape(B, S, H, qk_head)
    else:
        q = (x @ params["w_q"]).reshape(B, S, H, qk_head)
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(params, cfg, x, positions):
    m = cfg.mla
    c_kv = rmsnorm(params["kv_norm"], x @ params["w_dkv"], cfg.norm_eps)
    k_rope = (x @ params["w_kr"])[:, :, None, :]                   # (B,S,1,dr)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_train(params, cfg, x, positions, *, causal=True, chunk_size=512,
              unroll=False):
    """MLA over a full sequence. Returns (out, (c_kv, k_rope)) for caching."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope = _mla_queries(params, cfg, x, positions)
    c_kv, k_rope = _mla_latent(params, cfg, x, positions)
    k_nope = jnp.einsum("btc,chd->bthd", c_kv, params["w_uk"])
    v = jnp.einsum("btc,chd->bthd", c_kv, params["w_uv"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], axis=-1)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    qg = q[:, :, :, None, :]                                       # G=1
    fn = full_attention if S <= chunk_size else chunked_attention
    kwargs = ({} if fn is full_attention
              else {"chunk_size": chunk_size, "unroll": unroll})
    out = fn(qg, k, v, scale=scale, q_positions=positions,
             kv_positions=positions, causal=causal, **kwargs)
    out = out.reshape(B, S, H * m.v_head_dim)
    return out @ params["wo"], (c_kv, k_rope)


def mla_decode(params, cfg, x, cache_c, cache_kr, positions):
    """Weight-absorbed MLA decode. cache_c: (B,T,dc); cache_kr: (B,T,dr)."""
    m = cfg.mla
    B, S, _ = x.shape
    assert S == 1
    H = cfg.num_heads
    pos2 = positions[:, None]
    q_nope, q_rope = _mla_queries(params, cfg, x, pos2)
    c_new, kr_new = _mla_latent(params, cfg, x, pos2)
    b_idx = jnp.arange(B)
    cache_c = cache_c.at[b_idx, positions].set(c_new[:, 0].astype(cache_c.dtype))
    cache_kr = cache_kr.at[b_idx, positions].set(
        kr_new[:, 0].astype(cache_kr.dtype))
    # absorb W_uk into q:  (B,1,H,dn) x (dc,H,dn) -> (B,1,H,dc)
    q_c = jnp.einsum("bshd,chd->bshc", q_nope.astype(jnp.float32),
                     params["w_uk"].astype(jnp.float32))
    T = cache_c.shape[1]
    s = (jnp.einsum("bshc,btc->bhst", q_c, cache_c.astype(jnp.float32))
         + jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32),
                      cache_kr.astype(jnp.float32)))
    s = s / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    kv_pos = jnp.arange(T, dtype=jnp.int32)
    valid = kv_pos[None, :] <= positions[:, None]                  # (B, T)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bhst,btc->bshc", p, cache_c.astype(jnp.float32))
    o = jnp.einsum("bshc,chd->bshd", o_c,
                   params["w_uv"].astype(jnp.float32)).astype(x.dtype)
    out = o.reshape(B, 1, H * m.v_head_dim) @ params["wo"]
    return out, cache_c, cache_kr


def attn_decode_ragged_q8(params, cfg, x, ck, cv, ck_s, cv_s, ctx_lens,
                          q_lens):
    """``attn_decode_ragged`` over an int8 cache: the fused mixed-batch
    tick's mirrored twin for the int8 family. New tokens quantize on write
    (per (token, head), same grid as ``quantize_kv`` everywhere else),
    padding slots scatter-drop, and attention reads the dequantized cache.

    Returns (out, ck, cv, ck_s, cv_s).
    """
    B, Qm, _ = x.shape
    K, H, D = cfg.num_kv_heads, cfg.num_heads, cfg.head_dim
    positions = ctx_lens[:, None] + jnp.arange(Qm, dtype=jnp.int32)[None, :]
    q, k, v = _project_qkv(params, cfg, x, positions, rope=True)
    T = ck.shape[1]
    valid = jnp.arange(Qm)[None, :] < q_lens[:, None]
    write_pos = jnp.where(valid, positions, T)
    b_idx = jnp.arange(B)[:, None]
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    ck = ck.at[b_idx, write_pos].set(kq, mode="drop")
    cv = cv.at[b_idx, write_pos].set(vq, mode="drop")
    ck_s = ck_s.at[b_idx, write_pos].set(ks, mode="drop")
    cv_s = cv_s.at[b_idx, write_pos].set(vs, mode="drop")
    kf = dequantize_kv(ck, ck_s, x.dtype)
    vf = dequantize_kv(cv, cv_s, x.dtype)
    kv_pos = jnp.arange(T, dtype=jnp.int32)
    out = full_attention(q, kf, vf, scale=1.0 / math.sqrt(D),
                         q_positions=positions, kv_positions=kv_pos,
                         causal=True)
    out = out.reshape(B, Qm, H * D) @ params["wo"]
    return out, ck, cv, ck_s, cv_s


def attn_decode_paged_q8(params, cfg, x, pool_k, pool_v, pool_ks, pool_vs,
                         block_table, positions):
    """Single-step decode over an int8 paged pool (mirror-free): the new
    token quantizes on write into the int8 pages + scale planes, attention
    runs the dequant-in-kernel ``paged_attention_q8`` entry.

    pool_k/v: (P, T, K, D) int8; pool_ks/vs: (P, T, K) bf16.
    Returns (out, pool_k, pool_v, pool_ks, pool_vs).
    """
    from repro.kernels.paged_attention import paged_attention_q8

    B, S, _ = x.shape
    assert S == 1
    K, H, D = cfg.num_kv_heads, cfg.num_heads, cfg.head_dim
    pos2 = positions[:, None]
    q, k, v = _project_qkv(params, cfg, x, pos2, rope=True)
    T = pool_k.shape[1]
    b_idx = jnp.arange(B)
    phys = block_table[b_idx, positions // T]
    slot = positions % T
    kq, ks = quantize_kv(k[:, 0])
    vq, vs = quantize_kv(v[:, 0])
    pool_k = pool_k.at[phys, slot].set(kq)
    pool_v = pool_v.at[phys, slot].set(vq)
    pool_ks = pool_ks.at[phys, slot].set(ks)
    pool_vs = pool_vs.at[phys, slot].set(vs)
    out = paged_attention_q8(q.reshape(B, H, D), pool_k, pool_v, pool_ks,
                             pool_vs, block_table, positions + 1,
                             scale=1.0 / math.sqrt(D))
    out = out.reshape(B, 1, H * D) @ params["wo"]
    return out, pool_k, pool_v, pool_ks, pool_vs


def attn_step_paged_ragged_q8(params, cfg, x, pool_k, pool_v, pool_ks,
                              pool_vs, block_table, ctx_lens, q_lens):
    """Ragged multi-token step over one layer's slice of the int8 paged
    pool — ``attn_step_paged_ragged`` with quantize-on-write scatters into
    the int8 pages + scale planes and the ``paged_attention_ragged_q8``
    dequant-in-kernel launch.

    Returns (out, pool_k, pool_v, pool_ks, pool_vs).
    """
    from repro.kernels.paged_attention import paged_attention_ragged_q8

    B, Qm, _ = x.shape
    K, H, D = cfg.num_kv_heads, cfg.num_heads, cfg.head_dim
    positions = ctx_lens[:, None] + jnp.arange(Qm, dtype=jnp.int32)[None, :]
    q, k, v = _project_qkv(params, cfg, x, positions, rope=True)
    P, T = pool_k.shape[0], pool_k.shape[1]
    valid = jnp.arange(Qm)[None, :] < q_lens[:, None]
    logical = jnp.clip(positions // T, 0, block_table.shape[1] - 1)
    phys = jnp.take_along_axis(block_table, logical, axis=1)
    phys = jnp.where(valid, phys, P)
    slot = positions % T
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    pool_k = pool_k.at[phys, slot].set(kq, mode="drop")
    pool_v = pool_v.at[phys, slot].set(vq, mode="drop")
    pool_ks = pool_ks.at[phys, slot].set(ks, mode="drop")
    pool_vs = pool_vs.at[phys, slot].set(vs, mode="drop")
    out = paged_attention_ragged_q8(
        q.reshape(B, Qm, H, D), pool_k, pool_v, pool_ks, pool_vs,
        block_table, ctx_lens + q_lens, q_lens, scale=1.0 / math.sqrt(D))
    out = out.reshape(B, Qm, H * D) @ params["wo"]
    return out, pool_k, pool_v, pool_ks, pool_vs


def mla_decode_ragged(params, cfg, x, cache_c, cache_kr, ctx_lens, q_lens):
    """Ragged multi-token weight-absorbed MLA decode over the dense latent
    cache — the fused tick's mirrored twin for the MLA family. Same einsum
    chain as ``mla_decode`` with a (B, Qmax) query block and intra-chunk
    causal masking; padding slots scatter-drop and their outputs are
    garbage the caller must ignore.

    Returns (out, cache_c, cache_kr).
    """
    m = cfg.mla
    B, Qm, _ = x.shape
    H = cfg.num_heads
    positions = ctx_lens[:, None] + jnp.arange(Qm, dtype=jnp.int32)[None, :]
    q_nope, q_rope = _mla_queries(params, cfg, x, positions)
    c_new, kr_new = _mla_latent(params, cfg, x, positions)
    T = cache_c.shape[1]
    valid = jnp.arange(Qm)[None, :] < q_lens[:, None]
    write_pos = jnp.where(valid, positions, T)
    b_idx = jnp.arange(B)[:, None]
    cache_c = cache_c.at[b_idx, write_pos].set(
        c_new.astype(cache_c.dtype), mode="drop")
    cache_kr = cache_kr.at[b_idx, write_pos].set(
        kr_new.astype(cache_kr.dtype), mode="drop")
    q_c = jnp.einsum("bshd,chd->bshc", q_nope.astype(jnp.float32),
                     params["w_uk"].astype(jnp.float32))
    s = (jnp.einsum("bshc,btc->bhst", q_c, cache_c.astype(jnp.float32))
         + jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32),
                      cache_kr.astype(jnp.float32)))
    s = s / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    kv_pos = jnp.arange(T, dtype=jnp.int32)
    allow = kv_pos[None, None, :] <= positions[:, :, None]          # (B,Qm,T)
    s = jnp.where(allow[:, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bhst,btc->bshc", p, cache_c.astype(jnp.float32))
    o = jnp.einsum("bshc,chd->bshd", o_c,
                   params["w_uv"].astype(jnp.float32)).astype(x.dtype)
    out = o.reshape(B, Qm, H * m.v_head_dim) @ params["wo"]
    return out, cache_c, cache_kr


def mla_decode_paged(params, cfg, x, pool_c, pool_kr, block_table,
                     positions):
    """Single-step weight-absorbed MLA decode over the paged latent pool
    (mirror-free): the new latent/rope-key scatter into their page slots
    and attention runs the ``mla_paged_attention`` entry over the latent
    plane.

    pool_c: (P, T, dc); pool_kr: (P, T, dr).
    Returns (out, pool_c, pool_kr).
    """
    from repro.kernels.paged_attention import mla_paged_attention

    m = cfg.mla
    B, S, _ = x.shape
    assert S == 1
    H = cfg.num_heads
    pos2 = positions[:, None]
    q_nope, q_rope = _mla_queries(params, cfg, x, pos2)
    c_new, kr_new = _mla_latent(params, cfg, x, pos2)
    T = pool_c.shape[1]
    b_idx = jnp.arange(B)
    phys = block_table[b_idx, positions // T]
    slot = positions % T
    pool_c = pool_c.at[phys, slot].set(c_new[:, 0].astype(pool_c.dtype))
    pool_kr = pool_kr.at[phys, slot].set(kr_new[:, 0].astype(pool_kr.dtype))
    q_c = jnp.einsum("bshd,chd->bshc", q_nope.astype(jnp.float32),
                     params["w_uk"].astype(jnp.float32))
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    o_c = mla_paged_attention(q_c[:, 0], q_rope[:, 0].astype(jnp.float32),
                              pool_c, pool_kr, block_table, positions + 1,
                              scale=scale)
    o = jnp.einsum("bhc,chd->bhd", o_c.astype(jnp.float32),
                   params["w_uv"].astype(jnp.float32)).astype(x.dtype)
    out = o.reshape(B, 1, H * m.v_head_dim) @ params["wo"]
    return out, pool_c, pool_kr


def mla_step_paged_ragged(params, cfg, x, pool_c, pool_kr, block_table,
                          ctx_lens, q_lens):
    """Ragged multi-token weight-absorbed MLA step over the paged latent
    pool — the fused mixed-batch tick for the MLA family, one
    ``mla_paged_attention_ragged`` launch per layer.

    Returns (out, pool_c, pool_kr).
    """
    from repro.kernels.paged_attention import mla_paged_attention_ragged

    m = cfg.mla
    B, Qm, _ = x.shape
    H = cfg.num_heads
    positions = ctx_lens[:, None] + jnp.arange(Qm, dtype=jnp.int32)[None, :]
    q_nope, q_rope = _mla_queries(params, cfg, x, positions)
    c_new, kr_new = _mla_latent(params, cfg, x, positions)
    P, T = pool_c.shape[0], pool_c.shape[1]
    valid = jnp.arange(Qm)[None, :] < q_lens[:, None]
    logical = jnp.clip(positions // T, 0, block_table.shape[1] - 1)
    phys = jnp.take_along_axis(block_table, logical, axis=1)
    phys = jnp.where(valid, phys, P)
    slot = positions % T
    pool_c = pool_c.at[phys, slot].set(c_new.astype(pool_c.dtype),
                                       mode="drop")
    pool_kr = pool_kr.at[phys, slot].set(kr_new.astype(pool_kr.dtype),
                                         mode="drop")
    q_c = jnp.einsum("bshd,chd->bshc", q_nope.astype(jnp.float32),
                     params["w_uk"].astype(jnp.float32))
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    o_c = mla_paged_attention_ragged(q_c, q_rope.astype(jnp.float32),
                                     pool_c, pool_kr, block_table,
                                     ctx_lens + q_lens, q_lens, scale=scale)
    o = jnp.einsum("bqhc,chd->bqhd", o_c.astype(jnp.float32),
                   params["w_uv"].astype(jnp.float32)).astype(x.dtype)
    out = o.reshape(B, Qm, H * m.v_head_dim) @ params["wo"]
    return out, pool_c, pool_kr
