"""Mixture-of-Experts FFN: top-k routing with capacity + sort-based dispatch.

TPU-idiomatic (GShard-style capacity, but gather/scatter dispatch instead of
one-hot einsums so the compiled FLOPs are the *useful* expert matmuls — this
keeps the roofline's MODEL_FLOPS/HLO_FLOPs ratio honest).

Supports DeepSeek-V2 shared experts and Arctic's parallel dense residual.
Experts are sharded over the ``model`` mesh axis (expert parallelism).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import get_abstract_mesh, shard_map
from repro.models.layers import _act, dense_init, init_ffn, apply_ffn


def init_moe(key, cfg, dtype):
    m = cfg.moe
    d = cfg.d_model
    keys = jax.random.split(key, 6)
    p = {"router": dense_init(keys[0], d, m.num_experts, dtype)}
    ke = jax.random.split(keys[1], 3)
    p["experts"] = {
        "w_gate": jax.vmap(lambda k: dense_init(k, d, m.d_expert, dtype))(
            jax.random.split(ke[0], m.num_experts)),
        "w_up": jax.vmap(lambda k: dense_init(k, d, m.d_expert, dtype))(
            jax.random.split(ke[1], m.num_experts)),
        "w_down": jax.vmap(lambda k: dense_init(k, m.d_expert, d, dtype))(
            jax.random.split(ke[2], m.num_experts)),
    }
    if m.num_shared_experts:
        p["shared"] = init_ffn(keys[2], d, m.d_expert * m.num_shared_experts,
                               cfg.ffn_activation, dtype)
    if m.dense_residual:
        p["dense"] = init_ffn(keys[3], d, m.d_dense_residual,
                              cfg.ffn_activation, dtype)
    return p


def _route(router_w, x_flat, num_experts, top_k):
    """Returns (top_ids (T,k), top_w (T,k) fp32, aux_loss scalar)."""
    logits = (x_flat.astype(jnp.float32)
              @ router_w.astype(jnp.float32))                  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, top_k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)
    # GShard load-balancing aux loss
    T = x_flat.shape[0]
    me = jnp.mean(probs, axis=0)                               # (E,)
    ce = jnp.zeros((num_experts,), jnp.float32).at[top_ids.reshape(-1)].add(
        1.0) / (T * top_ids.shape[-1])
    aux = num_experts * jnp.sum(me * ce)
    return top_ids, top_w, aux


def moe_dispatch_combine(experts, x_flat, top_ids, top_w, num_experts,
                         capacity, activation):
    """Sort-based capacity dispatch → per-expert GLU FFN → weighted combine."""
    T, d = x_flat.shape
    k = top_ids.shape[-1]
    flat_e = top_ids.reshape(-1)                               # (T*k,)
    sort_idx = jnp.argsort(flat_e)                             # stable
    sorted_e = flat_e[sort_idx]
    counts = jnp.zeros((num_experts,), jnp.int32).at[sorted_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_e < capacity
    slot = jnp.where(keep, pos_in_e, capacity)                 # OOB → dropped
    tok_idx = sort_idx // k

    xbuf = jnp.zeros((num_experts, capacity, d), x_flat.dtype)
    xbuf = xbuf.at[sorted_e, slot].set(x_flat[tok_idx], mode="drop")

    h = (_act(activation, jnp.einsum("ecd,edf->ecf", xbuf, experts["w_gate"]))
         * jnp.einsum("ecd,edf->ecf", xbuf, experts["w_up"]))
    ybuf = jnp.einsum("ecf,efd->ecd", h, experts["w_down"])

    gathered = ybuf.at[sorted_e, slot].get(mode="fill", fill_value=0)  # (T*k, d)
    w_sorted = top_w.reshape(-1)[sort_idx].astype(gathered.dtype)
    contrib = gathered * (w_sorted * keep.astype(gathered.dtype))[:, None]
    y = jnp.zeros((T, d), x_flat.dtype).at[tok_idx].add(
        contrib.astype(x_flat.dtype))
    return y


def apply_moe(params, cfg, x, ep_axes=()):
    """x: (B, S, d). Returns (y, aux_loss).

    With ``ep_axes`` set (distributed runs), dispatch goes through the
    shard_map EP path; otherwise the single-device XLA path.
    """
    m = cfg.moe
    B, S, d = x.shape
    x_flat = x.reshape(B * S, d)
    mesh = get_abstract_mesh()
    use_ep = bool(ep_axes) and "model" in (mesh.axis_names or ())
    if use_ep and B * S <= 4096:
        # decode-scale token counts: move the (tiny) tokens, not the (huge)
        # FSDP'd expert weights — §Perf hillclimb A in EXPERIMENTS.md
        y, aux = _moe_ep_tokengather(params, cfg, x_flat, ep_axes)
    elif use_ep:
        y, aux = _moe_ep(params, cfg, x_flat, ep_axes)
    else:
        top_ids, top_w, aux = _route(params["router"], x_flat, m.num_experts,
                                     m.top_k)
        capacity = int(m.capacity_factor * (B * S * m.top_k) / m.num_experts)
        capacity = max(capacity, 4)
        y = moe_dispatch_combine(params["experts"], x_flat, top_ids, top_w,
                                 m.num_experts, capacity, cfg.ffn_activation)
    if "shared" in params:
        y = y + apply_ffn(params["shared"], x_flat, cfg.ffn_activation)
    if "dense" in params:
        y = y + apply_ffn(params["dense"], x_flat, cfg.ffn_activation)
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Expert parallelism (shard_map): DESIGN.md §5
#
# Tokens are data-sharded and TP-replicated between blocks, so every model
# rank can route the *same* local tokens (duplicated routing is negligible),
# keep only its E_loc experts' assignments, run its expert FFNs locally, and
# psum partial outputs over the model axis. No global sort, no all-to-all;
# the only collective is one (T_loc, d) all-reduce per layer — the same class
# as the TP FFN reduce. FSDP'd expert weights are all-gathered over the data
# axes inside the region (one gather per layer, overlappable).
# ---------------------------------------------------------------------------
def _moe_ep(params, cfg, x_flat, ep_axes):
    import jax
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    mesh = get_abstract_mesh()
    dp = tuple(a for a in ep_axes if a in mesh.axis_names)
    n_model = mesh.shape.get("model", 1)
    T, d = x_flat.shape
    T_loc = T // int(np.prod([mesh.shape[a] for a in dp])) if dp else T
    capacity = max(int(m.capacity_factor * (T_loc * m.top_k)
                       / m.num_experts), 4)
    E_loc = m.num_experts // n_model
    act = cfg.ffn_activation

    def local(x_loc, router_w, w_gate, w_up, w_down):
        # x_loc: (T_loc, d) — replicated over model; weights: this rank's
        # E_loc experts, hidden dim FSDP-sharded over dp
        rank = jax.lax.axis_index("model")
        if dp:
            w_gate = jax.lax.all_gather(w_gate, dp, axis=2, tiled=True)
            w_up = jax.lax.all_gather(w_up, dp, axis=2, tiled=True)
            w_down = jax.lax.all_gather(w_down, dp, axis=1, tiled=True)
        top_ids, top_w, aux = _route(router_w, x_loc, m.num_experts, m.top_k)
        k = m.top_k
        flat_e = top_ids.reshape(-1)
        sort_idx = jnp.argsort(flat_e)
        sorted_e = flat_e[sort_idx]
        counts = jnp.zeros((m.num_experts,), jnp.int32).at[sorted_e].add(1)
        starts = jnp.cumsum(counts) - counts
        pos_in_e = (jnp.arange(T_loc * k, dtype=jnp.int32)
                    - starts[sorted_e])
        eid_local = sorted_e - rank * E_loc
        valid = ((pos_in_e < capacity) & (eid_local >= 0)
                 & (eid_local < E_loc))
        eid_c = jnp.clip(eid_local, 0, E_loc - 1)
        slot = jnp.where(valid, pos_in_e, capacity)       # OOB → dropped
        tok_idx = sort_idx // k

        xbuf = jnp.zeros((E_loc, capacity, d), x_loc.dtype)
        xbuf = xbuf.at[eid_c, slot].set(x_loc[tok_idx], mode="drop")
        h = (_act(act, jnp.einsum("ecd,edf->ecf", xbuf, w_gate))
             * jnp.einsum("ecd,edf->ecf", xbuf, w_up))
        ybuf = jnp.einsum("ecf,efd->ecd", h, w_down)
        gathered = ybuf.at[eid_c, slot].get(mode="fill", fill_value=0)
        w_sorted = top_w.reshape(-1)[sort_idx].astype(gathered.dtype)
        contrib = gathered * (w_sorted * valid.astype(gathered.dtype))[:, None]
        y = jnp.zeros((T_loc, d), x_loc.dtype).at[tok_idx].add(
            contrib.astype(x_loc.dtype))
        y = jax.lax.psum(y, "model")
        return y, aux[None]

    e_specs = {
        "w_gate": P("model", None, dp if dp else None),
        "w_up": P("model", None, dp if dp else None),
        "w_down": P("model", dp if dp else None, None),
    }
    y, aux_arr = shard_map(
        local, mesh=mesh,
        in_specs=(P(dp if dp else None, None), P(None, None),
                  e_specs["w_gate"], e_specs["w_up"], e_specs["w_down"]),
        out_specs=(P(dp if dp else None, None), P(dp if dp else None)),
    )(x_flat, params["router"], params["experts"]["w_gate"],
      params["experts"]["w_up"], params["experts"]["w_down"])
    return y, jnp.mean(aux_arr)


def _moe_ep_tokengather(params, cfg, x_flat, ep_axes):
    """EP for decode-scale batches: weights never move.

    Baseline (`_moe_ep`) all-gathers the FSDP'd expert hidden dim over the
    data axes — ~hundreds of MB *per layer per token step* at decode. Here
    each device instead all-gathers the tokens (KBs), computes its
    (E_loc experts × f_loc hidden slice) partial GLU — exact, since the
    hidden dim is elementwise through the gate — and one psum over
    (data, model) completes both the expert reduction and the hidden-shard
    reduction. Wire bytes drop from O(expert weights) to O(tokens·d).
    """
    import jax
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    mesh = get_abstract_mesh()
    dp = tuple(a for a in ep_axes if a in mesh.axis_names)
    n_model = mesh.shape.get("model", 1)
    T, d = x_flat.shape
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    T_loc = T // dp_size if (dp and T % dp_size == 0) else T
    tokens_sharded = dp and T % dp_size == 0
    capacity = max(int(m.capacity_factor * (T * m.top_k)
                       / m.num_experts), 4)
    E_loc = m.num_experts // n_model
    act = cfg.ffn_activation

    def local(x_loc, router_w, w_gate, w_up, w_down):
        rank = jax.lax.axis_index("model")
        if tokens_sharded:
            x_all = jax.lax.all_gather(x_loc, dp, axis=0, tiled=True)
        else:
            x_all = x_loc
        top_ids, top_w, aux = _route(router_w, x_all, m.num_experts, m.top_k)
        k = m.top_k
        flat_e = top_ids.reshape(-1)
        sort_idx = jnp.argsort(flat_e)
        sorted_e = flat_e[sort_idx]
        counts = jnp.zeros((m.num_experts,), jnp.int32).at[sorted_e].add(1)
        starts = jnp.cumsum(counts) - counts
        pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_e]
        eid_local = sorted_e - rank * E_loc
        valid = ((pos_in_e < capacity) & (eid_local >= 0)
                 & (eid_local < E_loc))
        eid_c = jnp.clip(eid_local, 0, E_loc - 1)
        slot = jnp.where(valid, pos_in_e, capacity)
        tok_idx = sort_idx // k

        xbuf = jnp.zeros((E_loc, capacity, d), x_all.dtype)
        xbuf = xbuf.at[eid_c, slot].set(x_all[tok_idx], mode="drop")
        # partial hidden slice: exact through the elementwise gate
        h = (_act(act, jnp.einsum("ecd,edf->ecf", xbuf, w_gate))
             * jnp.einsum("ecd,edf->ecf", xbuf, w_up))
        ybuf = jnp.einsum("ecf,efd->ecd", h, w_down)      # partial over f
        gathered = ybuf.at[eid_c, slot].get(mode="fill", fill_value=0)
        w_sorted = top_w.reshape(-1)[sort_idx].astype(gathered.dtype)
        contrib = gathered * (w_sorted * valid.astype(gathered.dtype))[:, None]
        y_all = jnp.zeros((T, d), x_all.dtype).at[tok_idx].add(
            contrib.astype(x_all.dtype))
        y_all = jax.lax.psum(y_all, dp + ("model",) if dp else ("model",))
        if tokens_sharded:
            idx = jnp.zeros((), jnp.int32)
            mult = 1
            for a in reversed(dp):
                idx = idx + jax.lax.axis_index(a) * mult
                mult *= mesh.shape[a]
            y_loc = jax.lax.dynamic_slice_in_dim(y_all, idx * T_loc, T_loc, 0)
        else:
            y_loc = y_all
        return y_loc, aux[None]

    tok_spec = P(dp if tokens_sharded else None, None)
    y, aux_arr = shard_map(
        local, mesh=mesh,
        in_specs=(tok_spec, P(None, None),
                  P("model", None, dp if dp else None),
                  P("model", None, dp if dp else None),
                  P("model", dp if dp else None, None)),
        out_specs=(tok_spec, P(dp if dp else None)),
    )(x_flat, params["router"], params["experts"]["w_gate"],
      params["experts"]["w_up"], params["experts"]["w_down"])
    return y, jnp.mean(aux_arr)
