"""Shared primitive layers: norms, RoPE, FFNs, embeddings, inits.

Pure-functional style: ``init_*`` build param pytrees, ``apply`` functions take
(params, inputs). Matmuls run in ``compute_dtype`` (bf16 on target), norm/
softmax statistics in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal_init(key, shape, scale, dtype):
    fan_in = shape[0] if len(shape) > 1 else 1
    std = scale / np.sqrt(fan_in)
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def dense_init(key, d_in, d_out, dtype, scale=1.0):
    return truncated_normal_init(key, (d_in, d_out), scale, dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    exponents = np.arange(0, head_dim, 2, dtype=np.float32) / head_dim
    return jnp.asarray(1.0 / (theta ** exponents))  # (head_dim/2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                   # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                          # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# FFN (dense; gated and plain)
# ---------------------------------------------------------------------------
def init_ffn(key, d_model, d_ff, activation, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    if activation in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(k1, d_model, d_ff, dtype),
            "w_up": dense_init(k2, d_model, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, d_model, dtype),
        }
    return {
        "w_up": dense_init(k1, d_model, d_ff, dtype),
        "w_down": dense_init(k2, d_ff, d_model, dtype),
    }


def _act(name, x):
    if name == "swiglu":
        return jax.nn.silu(x)
    if name == "geglu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.gelu(x, approximate=True)


def apply_ffn(params, x, activation):
    if "w_gate" in params:
        h = _act(activation, x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = _act(activation, x @ params["w_up"])
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------
def init_embedding(key, vocab, d_model, dtype):
    return {"table": truncated_normal_init(key, (vocab, d_model), 1.0, dtype)}


def embed(params, tokens, scale=1.0):
    out = jnp.take(params["table"], tokens, axis=0)
    if scale != 1.0:
        out = out * jnp.asarray(scale, out.dtype)
    return out


def lm_logits(embed_params, head_params, h, tie: bool, logit_scale=1.0,
              soft_cap=0.0, vocab_size: int | None = None):
    """Logits over the (possibly padded) vocab; padded columns masked to
    -1e30 so softmax/argmax ignore them."""
    table = embed_params["table"] if tie else head_params["table"]
    logits = jnp.einsum("...d,vd->...v", h, table).astype(jnp.float32)
    if logit_scale != 1.0:
        logits = logits * logit_scale
    if soft_cap > 0.0:
        logits = soft_cap * jnp.tanh(logits / soft_cap)
    if vocab_size is not None and vocab_size < table.shape[0]:
        pad_mask = jnp.arange(table.shape[0]) < vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return logits


def cross_entropy_loss(logits, labels, mask=None):
    """logits fp32 (..., V); labels int (...). Returns mean NLL over mask."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
