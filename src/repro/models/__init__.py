"""Model zoo: unified LM interface over dense/GQA/MLA/MoE/SSM/hybrid/enc-dec/VLM."""
from repro.models.model import build_model, LM

__all__ = ["build_model", "LM"]
