"""Distribution layer: mesh axes, logical sharding rules, ZeRO-1 state
sharding, gradient compression. See DESIGN.md §5."""
from repro.distributed.sharding import (
    batch_specs, cache_specs, data_axes, param_specs, zero1_specs)

__all__ = ["param_specs", "batch_specs", "cache_specs", "zero1_specs",
           "data_axes"]
