"""Logical→physical sharding rules for every architecture family.

Axis roles (DESIGN.md §5):

* ``pod`` + ``data``  — gradient/data parallel (batch), ZeRO-1 optimizer state
* ``model``           — TP (attention heads / ffn hidden / vocab), EP (MoE
                        experts), and KV-sequence parallelism for decode caches

SSM mixer weights are replicated on ``model`` (Mamba TP via head-sharded
in_proj splits is a recorded future hillclimb; the mixers are ≤2.6 GB in bf16,
see DESIGN.md §Arch-applicability); SSM decode *states* shard heads over
``model``.

Vocab dims that are not divisible by the axis size (minicpm 122753, seamless
256206) rely on GSPMD's uneven-sharding padding.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import AbstractMesh, Mesh, NamedSharding, PartitionSpec as P

MODEL_AXIS = "model"


# --------------------------------------------------------------------------
# jax version tolerance. The sharding surface moved between jax releases:
# ``AbstractMesh`` flipped from ``((name, size), ...)`` pairs to positional
# ``(sizes, names)``; ``jax.sharding.get_abstract_mesh`` / ``jax.set_mesh`` /
# ``jax.shard_map`` / ``AxisType`` only exist on newer jax. Everything in
# this repo goes through these helpers instead of calling jax directly.
# --------------------------------------------------------------------------

def make_abstract_mesh(shape, axes) -> AbstractMesh:
    """Build an ``AbstractMesh`` from ``shape``/``axes`` on any jax version.

    Newer jax takes ``AbstractMesh(axis_sizes, axis_names)``; older jax takes
    one ``shape_tuple`` of ``(name, size)`` pairs.
    """
    shape = tuple(shape)
    axes = tuple(axes)
    try:
        return AbstractMesh(shape, axes)
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


def make_mesh(shape, axes) -> Mesh:
    """``jax.make_mesh`` with Auto axis types where the API has them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def get_abstract_mesh():
    """The mesh of the current context (set via :func:`use_mesh`).

    Newer jax exposes ``jax.sharding.get_abstract_mesh``; older jax tracks
    the physical mesh in thread-local state — the physical ``Mesh`` carries
    the same ``axis_names`` / ``shape`` mapping, so callers can treat the
    two uniformly (and pass either to :func:`shard_map`).
    """
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        return getter()
    from jax._src import mesh as mesh_lib
    return mesh_lib.thread_resources.env.physical_mesh


def use_mesh(mesh: Mesh):
    """Context manager activating ``mesh`` (``jax.set_mesh`` on newer jax,
    the legacy ``with mesh:`` resource context otherwise)."""
    setter = getattr(jax, "set_mesh", None)
    if setter is None:
        setter = getattr(jax.sharding, "use_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh          # old jax: Mesh is its own context manager


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` where it exists, the experimental one otherwise."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as legacy
    return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def data_axes(mesh: Mesh) -> tuple:
    """The gradient-parallel axes present in this mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(f"[{p.idx}]")
        else:
            out.append(str(p))
    return out


# parameter tensors whose *last* dim is a TP output (columns sharded)
_COL_SHARDED = {"wq", "wk", "wv", "w_gate", "w_up", "w_uq", "w_dq"}
# tensors whose second-to-last dim is a TP input (rows sharded)
_ROW_SHARDED = {"wo", "w_down"}
_REPLICATED = {"router", "scale", "conv_w", "conv_b", "a_log", "dt_bias",
               "d_skip", "norm_scale", "in_proj", "out_proj", "w_kr", "a", "b"}


def _param_spec(names: list[str], leaf, dp=()) -> P:
    ndim = np.ndim(leaf)
    name = names[-1]
    in_experts = "experts" in names
    if name in _REPLICATED:
        return P()
    if in_experts and name in ("w_gate", "w_up", "w_down"):
        # (L?, E, d, f): experts (dim -3) over model; FSDP-shard the expert
        # hidden dim over the data axes — 100B+ MoE weights cannot fit
        # model-parallel-only (EXPERIMENTS.md §Dry-run memory math)
        spec = [None] * ndim
        spec[ndim - 3] = MODEL_AXIS
        if dp:
            f_dim = ndim - 1 if name in ("w_gate", "w_up") else ndim - 2
            spec[f_dim] = dp
        return P(*spec)
    if name == "table":
        # embedding/lm-head (V, d): shard vocab
        spec = [None] * ndim
        spec[ndim - 2] = MODEL_AXIS
        return P(*spec)
    if name in ("w_uk", "w_uv"):
        # (L?, dc, H, dn): shard heads
        spec = [None] * ndim
        spec[ndim - 2] = MODEL_AXIS
        return P(*spec)
    if name in _COL_SHARDED:
        spec = [None] * ndim
        spec[ndim - 1] = MODEL_AXIS
        return P(*spec)
    if name in _ROW_SHARDED:
        spec = [None] * ndim
        spec[ndim - 2] = MODEL_AXIS
        return P(*spec)
    if "projector" in names and ndim >= 2:
        spec = [None] * ndim
        spec[ndim - 1] = MODEL_AXIS
        return P(*spec)
    return P()


def _axes_of(entry) -> tuple:
    if entry is None:
        return ()
    return tuple(entry) if isinstance(entry, (tuple, list)) else (entry,)


def _drop_indivisible(spec: P, shape, mesh: Mesh) -> P:
    """Replace spec entries whose mesh-axis product does not divide the dim
    (uneven GSPMD shardings are rejected for jit outputs) with None."""
    if mesh is None:
        return spec
    out = []
    for i, entry in enumerate(list(spec) + [None] * (len(shape) - len(spec))):
        axes = _axes_of(entry)
        if not axes:
            out.append(None)
            continue
        size = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(entry if shape[i] % size == 0 else None)
    return P(*out)


def param_specs(params_shape: Any, cfg, mesh: Mesh = None) -> Any:
    """PartitionSpec pytree for a param pytree (shapes or arrays)."""
    dp = data_axes(mesh) if mesh is not None else ()

    def fn(path, leaf):
        spec = _param_spec(_path_names(path), leaf, dp)
        shape = getattr(leaf, "shape", ())
        return _drop_indivisible(spec, shape, mesh) if shape else spec
    return jax.tree_util.tree_map_with_path(fn, params_shape)


def zero1_specs(params_shape: Any, cfg, mesh: Mesh) -> Any:
    """ZeRO-1: optimizer-state spec = param spec + data axes on the first
    evenly-divisible unsharded dim (falls back to the param spec)."""
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def fn(path, leaf):
        base = _param_spec(_path_names(path), leaf, dp)
        shape = leaf.shape if hasattr(leaf, "shape") else ()
        base = _drop_indivisible(base, shape, mesh) if shape else base
        if dp_size <= 1 or not shape:
            return base
        spec = list(base) + [None] * (len(shape) - len(base))
        used = {a for s in spec for a in _axes_of(s)}
        if used & set(dp):
            return P(*spec)      # FSDP'd tensors are already dp-sharded
        for i, dim in enumerate(shape):
            if spec[i] is None and dim % dp_size == 0:
                spec[i] = dp
                return P(*spec)
        return P(*spec)
    return jax.tree_util.tree_map_with_path(fn, params_shape)


def batch_specs(batch_shape: Any, mesh: Mesh,
                microbatched: bool = False, dp_override=None) -> Any:
    """Shard the global-batch dim of every batch leaf over dp (dim 0, or
    dim 1 when the pipeline delivers microbatched (mb, B/mb, ...) leaves).

    ``dp_override`` widens the batch axes — SSM/hybrid train cells fold the
    otherwise-idle ``model`` axis into data parallelism (DESIGN.md §5)."""
    dp = dp_override if dp_override is not None else data_axes(mesh)

    def fn(leaf):
        shape = getattr(leaf, "shape", ())
        nd = len(shape)
        if not nd:
            return P()
        if microbatched:
            spec = P(None, dp, *([None] * (nd - 2)))
        else:
            spec = P(dp, *([None] * (nd - 1)))
        return _drop_indivisible(spec, shape, mesh)
    return jax.tree_util.tree_map(fn, batch_shape)


def cache_specs(cache_shape: Any, cfg, mesh: Mesh) -> Any:
    """Decode-cache shardings: batch over dp; KV sequence over ``model``
    (flash-decoding LSE merge); SSM state heads over ``model``."""
    dp = data_axes(mesh)
    mo = MODEL_AXIS

    def fn(path, leaf):
        names = _path_names(path)
        name = names[-1]
        nd = len(leaf.shape)
        if name == "pos":
            spec = P(dp)
        elif name in ("k", "v", "shared_k", "shared_v", "ek", "ev"):
            # (L, B, S, K, D) — sequence-shard over model
            spec = P(None, dp, mo, None, None)
        elif name in ("k_scale", "v_scale"):
            # (L, B, S, K)
            spec = P(None, dp, mo, None)
        elif name in ("c", "kr"):
            # MLA latents (L, B, S, dc)
            spec = P(None, dp, mo, None)
        elif name in ("ssm", "seg_ssm", "tail_ssm"):
            # (..., B, H, P, N): shard heads over model
            s = [None] * nd
            s[nd - 4] = dp
            s[nd - 3] = mo
            spec = P(*s)
        elif name in ("conv", "seg_conv", "tail_conv"):
            # (..., B, d_conv-1, conv_dim)
            s = [None] * nd
            s[nd - 3] = dp
            spec = P(*s)
        else:
            spec = P()
        return _drop_indivisible(spec, leaf.shape, mesh)
    return jax.tree_util.tree_map_with_path(fn, cache_shape)


def to_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
