"""Gradient compression with error feedback (distributed-optimization trick).

int8 per-tensor-block quantization of gradients before the cross-pod
all-reduce, with local error-feedback accumulation [1-bit Adam / EF-SGD
lineage]. On a (pod, data, model) mesh the pod axis crosses DCN, where wire
bytes dominate — compressing grads 4× there is the standard lever.

Pure-JAX: quantize → (dequantize for the update) happens inside the jitted
step; the all-reduce then moves int8 + fp32 scales. Error feedback keeps the
quantization noise from biasing convergence (tested property: compressed SGD
on a quadratic converges to the same point).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def quantize_int8(x: jax.Array, block: int = 256):
    """Per-block symmetric int8. Returns (q, scales)."""
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def make_error_feedback_compressor(params_like: PyTree):
    """Returns (init_state, compress) where compress(grads, state) →
    (decompressed_grads, new_state); quantization error is fed back."""

    def init_state():
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params_like)

    def compress(grads: PyTree, err: PyTree):
        def one(g, e):
            g32 = g.astype(jnp.float32) + e
            q, s = quantize_int8(g32)
            deq = dequantize_int8(q, s, g32.shape)
            return deq.astype(g.dtype), g32 - deq
        pairs = jax.tree.map(one, grads, err)
        deq = jax.tree.map(lambda t: t[0], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda t: t[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
        return deq, new_err

    return init_state, compress
