"""Deterministic synthetic LM data.

Markov-chain token streams with a fixed transition structure so a ~100M model
shows a real, reproducible loss curve (the chain's conditional entropy is the
loss floor). Batches are a pure function of (seed, step, shard) — the
straggler/elastic property the framework needs: any host can regenerate any
shard after a restart or re-balance with no data reshuffle (DESIGN.md §5).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass
class SyntheticLMDataset:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 4          # candidate successors per token (entropy knob)
    num_shards: int = 1
    shard: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V, B = self.vocab_size, self.branching
        self.successors = rng.integers(0, V, size=(V, B), dtype=np.int32)
        probs = rng.dirichlet(np.ones(B) * 2.0, size=V).astype(np.float32)
        self.cum_probs = np.cumsum(probs, axis=-1)

    @property
    def entropy_floor(self) -> float:
        """Mean conditional entropy (nats) — the achievable loss floor."""
        p = np.diff(np.concatenate(
            [np.zeros((self.vocab_size, 1), np.float32), self.cum_probs], 1))
        p = np.clip(p, 1e-9, 1.0)
        return float(-(p * np.log(p)).sum(-1).mean())

    def _walk(self, rng: np.random.Generator, n: int) -> np.ndarray:
        out = np.empty(n + 1, np.int32)
        out[0] = rng.integers(0, self.vocab_size)
        u = rng.random(n).astype(np.float32)
        for t in range(n):
            row = out[t]
            b = int(np.searchsorted(self.cum_probs[row], u[t]))
            b = min(b, self.branching - 1)
            out[t + 1] = self.successors[row, b]
        return out

    def batch(self, step: int) -> dict:
        """Batch for ``step`` on this shard: {tokens, labels} (B_shard, S)."""
        assert self.global_batch % self.num_shards == 0
        b_shard = self.global_batch // self.num_shards
        toks = np.empty((b_shard, self.seq_len), np.int32)
        labs = np.empty((b_shard, self.seq_len), np.int32)
        for i in range(b_shard):
            seq_id = step * self.global_batch + self.shard * b_shard + i
            rng = np.random.default_rng((self.seed, seq_id))
            walk = self._walk(rng, self.seq_len)
            toks[i] = walk[:-1]
            labs[i] = walk[1:]
        return {"tokens": toks, "labels": labs}


def make_batch_iterator(ds: SyntheticLMDataset, start_step: int = 0,
                        microbatches: int = 1) -> Iterator[dict]:
    """Restart-stable iterator; with microbatches>1 leaves are
    (mb, B/mb, ...) matching the trainer layout."""
    step = start_step
    while True:
        b = ds.batch(step)
        if microbatches > 1:
            b = {k: v.reshape(microbatches, -1, *v.shape[1:])
                 for k, v in b.items()}
        yield b
        step += 1
