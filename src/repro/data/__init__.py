"""Deterministic synthetic LM data pipeline (sharded, restart-stable)."""
from repro.data.synthetic import SyntheticLMDataset, make_batch_iterator

__all__ = ["SyntheticLMDataset", "make_batch_iterator"]
