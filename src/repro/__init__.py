"""repro — a tiered-persistence JAX training/serving framework.

Implements "NVMM cache design: Logging vs. Paging" (Dulong et al., 2023) as a
first-class subsystem of a multi-pod JAX LM framework: both of the paper's
cache designs (NVPages / NVLog) back the framework's KV-cache offload and
checkpoint/restart paths, and the paper's FIO study is reproduced in
benchmarks/fio_bench.py.
"""
__version__ = "1.0.0"
