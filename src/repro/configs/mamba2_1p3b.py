"""Mamba2-1.3B [arXiv:2405.21060; hf:state-spaces/mamba2-1.3b].

Pure SSD (state-space duality) stack: 48 layers, d_model=2048, expand=2
(d_inner=4096), head_dim=64 (64 heads), d_state=128, conv width 4, no
attention, no FFN. Decode state is O(1): attention-free ⇒ long_500k runs.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    head_dim=0,
    ffn_activation="swiglu",
    norm_eps=1e-5,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    subquadratic=True,
    has_kv_cache=False,
)
