"""Zamba2-1.2B [arXiv:2411.15242; hf:Zyphra/Zamba2-1.2B].

Hybrid: Mamba2 backbone (38 layers, d_model=2048, d_state=64) + shared
attention+MLP block(s) invoked periodically with per-invocation LoRA
projections (the Zamba2 trick: one set of shared transformer weights, cheap
LoRA specialization at each call site). Attention: 32 heads MHA over
2*d_model concat input in the real model; we use d_model with 32 heads
(head_dim 64), d_ff=8192 for the shared MLP.
Sub-quadratic backbone ⇒ long_500k runs (shared-attn KV is the only cache).
"""
from repro.configs.base import ModelConfig, SSMConfig, HybridConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    ffn_activation="geglu",
    norm_eps=1e-5,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    hybrid=HybridConfig(shared_block_period=6, num_shared_blocks=2, lora_rank=8),
    subquadratic=True,
)
