"""StarCoder2-15B [arXiv:2402.19173; hf:bigcode/starcoder2-15b].

Dense decoder, GQA (4 KV heads), RoPE, GELU (non-gated) FFN per the paper's
"FFN with pre-activation" — StarCoder2 uses plain GELU MLP with d_ff=4*d.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="attn_dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    ffn_activation="gelu",
    rope_theta=100_000.0,
    norm_eps=1e-5,
    subquadratic=False,
)
