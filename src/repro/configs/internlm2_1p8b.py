"""InternLM2-1.8B [arXiv:2403.17297; hf:internlm/internlm2-1_8b].

Llama-like dense decoder: GQA with 8 KV heads, SwiGLU FFN, RMSNorm, RoPE.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    family="attn_dense",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92544,
    head_dim=128,
    ffn_activation="swiglu",
    rope_theta=1_000_000.0,
    norm_eps=1e-5,
    subquadratic=False,
)
