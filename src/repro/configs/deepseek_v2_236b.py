"""DeepSeek-V2 (236B, 21B active) [arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2].

MLA attention: KV compressed to a 512-dim latent (the KV cache stores only the
latent + 64-dim decoupled RoPE key). 128 heads, qk_nope 128 + qk_rope 64,
v_head 128, q_lora_rank 1536. MoE: 2 shared + 160 routed experts, top-6,
d_expert=1536; first layer dense (d_ff=12288).
"""
from repro.configs.base import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,                # MLA: logical kv heads == q heads
    d_ff=12288,                      # dense layers' FFN hidden
    vocab_size=102400,
    head_dim=128,                    # v head dim (qk uses nope+rope split)
    ffn_activation="swiglu",
    rope_theta=10_000.0,
    norm_eps=1e-6,
    moe=MoEConfig(
        num_experts=160,
        top_k=6,
        d_expert=1536,
        num_shared_experts=2,
        first_k_dense=1,
        capacity_factor=1.25,
    ),
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    subquadratic=False,
)
