"""Config dataclasses for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig`. Configs are
plain frozen dataclasses (hashable, usable as jit static args). The input-shape
pool (train_4k / prefill_32k / decode_32k / long_500k) is shared by all LM
archs; each arch declares which cells apply (e.g. long_500k only for
sub-quadratic backbones).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


# ---------------------------------------------------------------------------
# Block kinds
# ---------------------------------------------------------------------------
ATTN_DENSE = "attn_dense"      # GQA/MQA/MHA + (G)LU FFN
ATTN_MLA = "attn_mla"          # DeepSeek multi-head latent attention
MOE = "moe"                    # attention + routed MoE FFN
SSM = "ssm"                    # Mamba-2 SSD block (no attention, no FFN)
HYBRID = "hybrid"              # SSM backbone + shared attention blocks
ENCDEC = "encdec"              # encoder-decoder transformer
VLM = "vlm"                    # decoder LM + stub vision frontend


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 2
    d_expert: int = 0              # per-expert FFN hidden dim
    num_shared_experts: int = 0    # DeepSeek-style always-on experts
    dense_residual: bool = False   # Arctic-style dense FFN in parallel
    d_dense_residual: int = 0      # hidden dim of the parallel dense FFN
    first_k_dense: int = 0         # leading layers use dense FFN instead of MoE
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0           # 0 = full-rank q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    ngroups: int = 1
    chunk_size: int = 256


@dataclass(frozen=True)
class HybridConfig:
    shared_block_period: int = 6    # a shared attention block every N ssm layers
    num_shared_blocks: int = 2      # distinct shared blocks, used round-robin
    lora_rank: int = 8              # per-invocation LoRA on the shared block


@dataclass(frozen=True)
class FrontendConfig:
    """Stub modality frontend: input_specs() provides precomputed embeddings."""
    kind: str = "none"              # "audio" | "vision" | "none"
    num_tokens: int = 0             # frontend tokens prepended to the text stream
    d_frontend: int = 0             # embedding dim delivered by the stub
    projector_layers: int = 2       # MLP projector depth (vision)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # one of the block kinds above
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 → d_model // num_heads
    max_seq_len: int = 524_288
    # FFN activation: "swiglu" | "geglu" | "gelu"
    ffn_activation: str = "swiglu"
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # minicpm-style residual/embedding scaling (mup-ish)
    residual_scale: float = 1.0
    embedding_scale: float = 1.0
    logit_scale: float = 1.0
    logit_soft_cap: float = 0.0
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    frontend: FrontendConfig = field(default_factory=FrontendConfig)
    # encoder-decoder
    num_encoder_layers: int = 0
    # training schedule hint (minicpm WSD)
    lr_schedule: str = "cosine"    # "cosine" | "wsd"
    # attention flavour capabilities
    subquadratic: bool = False     # True → run long_500k
    has_kv_cache: bool = True      # False for pure SSM
    # embedding tables are allocated padded to this multiple so the vocab dim
    # TP-shards evenly (logits stay sharded; padded columns are masked)
    vocab_pad_multiple: int = 256

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- derived quantities -------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab_size + m - 1) // m * m

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline 6ND cross-check)."""
        from repro.roofline.params import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.roofline.params import count_active_params
        return count_active_params(self)

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized sibling of this config (same family/topology)."""
        small = dict(
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads else 0,
            d_ff=256,
            vocab_size=512,
            head_dim=32,
            max_seq_len=1024,
        )
        if self.num_encoder_layers:
            small["num_encoder_layers"] = 2
        if self.moe is not None:
            small["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 8),
                d_expert=64,
                d_dense_residual=64 if self.moe.dense_residual else 0,
                top_k=min(self.moe.top_k, 2),
            )
        if self.mla is not None:
            small["mla"] = MLAConfig(
                kv_lora_rank=32, q_lora_rank=0,
                qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32)
            small["head_dim"] = 32
        if self.ssm is not None:
            small["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk_size=64)
        if self.hybrid is not None:
            small["hybrid"] = dataclasses.replace(
                self.hybrid, shared_block_period=2, num_shared_blocks=1,
                lora_rank=4)
        if self.frontend.kind != "none":
            small["frontend"] = dataclasses.replace(
                self.frontend, num_tokens=16, d_frontend=64)
        small.update(overrides)
        return dataclasses.replace(self, name=self.name + "-smoke", **small)


# ---------------------------------------------------------------------------
# Input-shape pool (assigned)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = InputShape("train_4k", "train", 4_096, 256)
PREFILL_32K = InputShape("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = InputShape("decode_32k", "decode", 32_768, 128)
LONG_500K = InputShape("long_500k", "decode", 524_288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def applicable_shapes(cfg: ModelConfig) -> list[InputShape]:
    """The shape cells that are live for this arch (skip rules per DESIGN.md §4)."""
    shapes = [TRAIN_4K, PREFILL_32K]
    shapes.append(DECODE_32K)   # all assigned archs have a decoder
    if cfg.subquadratic:
        shapes.append(LONG_500K)
    return shapes


def skipped_shapes(cfg: ModelConfig) -> list[tuple[str, str]]:
    """(shape, reason) pairs recorded in EXPERIMENTS.md §Dry-run."""
    out = []
    if not cfg.subquadratic:
        out.append(("long_500k",
                    "pure full-attention arch: 512k-token decode reserved for "
                    "sub-quadratic backbones per shape-pool rule"))
    return out
