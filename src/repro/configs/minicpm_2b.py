"""MiniCPM-2B [arXiv:2404.06395; hf:openbmb/MiniCPM-2B-sft-bf16].

Llama-like dense decoder trained with the WSD (warmup-stable-decay) schedule.
36 query heads = 36 KV heads (MHA), head_dim 64. MiniCPM uses mup-style
depth/width scaling: residual branches scaled by 1.4/sqrt(num_layers),
embeddings scaled by 12, logits divided by (d_model/256); embeddings tied.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="attn_dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    head_dim=64,
    ffn_activation="swiglu",
    rope_theta=10_000.0,
    norm_eps=1e-5,
    tie_embeddings=True,
    residual_scale=1.4 / (40 ** 0.5),     # depth_scale per MiniCPM §4
    embedding_scale=12.0,
    logit_scale=256.0 / 2304.0,           # 1/(d_model/dim_model_base)
    lr_schedule="wsd",                     # the paper's headline schedule
    subquadratic=False,
)
