"""LLaVA-NeXT (mistral-7b) [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Mistral-7B decoder backbone: 32 layers, d_model=4096, 32 heads GQA kv=8,
SwiGLU d_ff=14336, vocab 32000. Vision tower (CLIP-ViT-L/336 + anyres tiling)
is a STUB per the assignment: input_specs() provides precomputed patch
embeddings (up to 2880 tokens = 5 tiles x 576 patches, d=1024), projected by
the standard 2-layer MLP into d_model and prepended to the text stream.
"""
from repro.configs.base import ModelConfig, FrontendConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    ffn_activation="swiglu",
    rope_theta=1_000_000.0,
    norm_eps=1e-5,
    frontend=FrontendConfig(kind="vision", num_tokens=2880, d_frontend=1024,
                            projector_layers=2),
    subquadratic=False,
)
