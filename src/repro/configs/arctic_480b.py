"""Snowflake Arctic (480B) [hf:Snowflake/snowflake-arctic-base].

Dense-MoE hybrid: every layer has a *parallel* dense FFN residual (d_ff=4864)
alongside a 128-expert top-2 MoE (d_expert=4864). GQA with 8 KV heads.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,                       # dense residual branch hidden dim
    vocab_size=32000,
    head_dim=128,
    ffn_activation="swiglu",
    rope_theta=10_000.0,
    norm_eps=1e-5,
    moe=MoEConfig(
        num_experts=128,
        top_k=2,
        d_expert=4864,
        dense_residual=True,
        d_dense_residual=4864,
        capacity_factor=1.25,
    ),
    subquadratic=False,
)
