"""SeamlessM4T-Large v2 [arXiv:2308.11596; hf:facebook/seamless-m4t-v2-large].

Encoder-decoder transformer backbone (text decoder of the multimodal system):
24 encoder + 24 decoder layers, d_model=1024, 16 heads, d_ff=8192,
vocab 256206. The speech frontend (w2v-BERT conformer stack) is a STUB per
the assignment: input_specs() provides precomputed 1024-dim frame embeddings.
"""
from repro.configs.base import ModelConfig, FrontendConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,                   # decoder layers
    num_encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    head_dim=64,
    ffn_activation="gelu",
    rope_theta=10_000.0,             # backbone uses learned pos in HF; RoPE here (see DESIGN)
    norm_eps=1e-5,
    frontend=FrontendConfig(kind="audio", num_tokens=4096, d_frontend=1024),
    subquadratic=False,
)
