"""Gemma-7B [arXiv:2403.08295; hf:google/gemma-7b].

Dense decoder: 16 heads with head_dim=256 (q_dim 4096 > d_model 3072), MHA
(kv=16; the 2B sibling uses MQA), GeGLU FFN (d_ff=24576 is the *combined*
gate+up published figure; per-branch hidden is 24576/... Gemma reports
hidden_dim=24576 as the per-branch intermediate), RMSNorm, RoPE,
embedding scaled by sqrt(d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="attn_dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    d_ff=24576,
    vocab_size=256000,
    head_dim=256,
    ffn_activation="geglu",
    rope_theta=10_000.0,
    norm_eps=1e-6,
    tie_embeddings=True,
    embedding_scale=3072 ** 0.5,
    subquadratic=False,
)
