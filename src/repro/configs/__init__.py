"""Config registry: every assigned architecture + the paper's cache configs.

Usage::

    from repro.configs import get_config, REGISTRY
    cfg = get_config("starcoder2-15b")          # full published config
    cfg = get_config("starcoder2-15b-smoke")    # reduced smoke sibling
"""
from __future__ import annotations

from repro.configs.base import (
    ModelConfig, MoEConfig, MLAConfig, SSMConfig, HybridConfig,
    FrontendConfig, InputShape,
    TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K, ALL_SHAPES, SHAPES_BY_NAME,
    applicable_shapes, skipped_shapes,
)

from repro.configs import (
    starcoder2_15b, internlm2_1p8b, minicpm_2b, gemma_7b, arctic_480b,
    deepseek_v2_236b, seamless_m4t_large_v2, mamba2_1p3b, zamba2_1p2b,
    llava_next_mistral_7b,
)

_MODULES = [
    starcoder2_15b, internlm2_1p8b, minicpm_2b, gemma_7b, arctic_480b,
    deepseek_v2_236b, seamless_m4t_large_v2, mamba2_1p3b, zamba2_1p2b,
    llava_next_mistral_7b,
]

REGISTRY: dict[str, ModelConfig] = {}
for _m in _MODULES:
    _cfg = _m.CONFIG
    REGISTRY[_cfg.name] = _cfg
    REGISTRY[_cfg.name + "-smoke"] = _cfg.reduced()

ARCH_IDS = [m.CONFIG.name for m in _MODULES]


def get_config(name: str) -> ModelConfig:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(REGISTRY)}") from None


__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "HybridConfig",
    "FrontendConfig", "InputShape", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
    "LONG_500K", "ALL_SHAPES", "SHAPES_BY_NAME", "applicable_shapes",
    "skipped_shapes", "REGISTRY", "ARCH_IDS", "get_config",
]
