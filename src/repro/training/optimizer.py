"""AdamW with fp32 master weights and WSD / cosine schedules.

Hand-rolled (no optax in this environment). State pytree:

    {"mu": f32 like params, "nu": f32 like params,
     "master": f32 like params, "count": i32 scalar}

With ZeRO-1, mu/nu/master carry data-axis shardings (distributed.zero1_specs)
so XLA emits reduce-scatter(grads) → sharded update → all-gather(params).

The WSD (warmup-stable-decay) schedule is the MiniCPM training schedule
[arXiv:2404.06395]: linear warmup → constant → short decay tail.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"      # "cosine" | "wsd" | "const"
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1       # WSD: fraction of steps in the decay tail


def lr_at(cfg: AdamWConfig, step):
    """Schedule multiplier × base lr (jnp-traceable)."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "const":
        return cfg.lr * warm
    if cfg.schedule == "wsd":
        decay_steps = max(int(cfg.total_steps * cfg.decay_frac), 1)
        decay_start = cfg.total_steps - decay_steps
        frac = jnp.clip((step - decay_start) / decay_steps, 0.0, 1.0)
        # exponential-ish decay tail to 10% (MiniCPM uses sqrt-style tails)
        tail = 0.1 ** frac
        return cfg.lr * warm * tail
    # cosine to 10 %
    prog = jnp.clip(step / max(cfg.total_steps, 1), 0.0, 1.0)
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def adamw_init(params, moment_dtype=jnp.float32):
    """moment_dtype=bf16 halves mu/nu memory — required to fit 100B+ MoE
    training in v5e HBM (EXPERIMENTS.md §Dry-run memory math)."""
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: AdamWConfig, grads, opt_state, params):
    """Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_at(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, mu, nu, master):
        g = g.astype(jnp.float32) * clip
        mdt = mu.dtype
        mu32 = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu32 = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        step = (mu32 / b1c) / (jnp.sqrt(nu32 / b2c) + cfg.eps)
        wd = cfg.weight_decay * master if master.ndim >= 2 else 0.0
        master = master - lr * (step + wd)
        return mu32.astype(mdt), nu32.astype(mdt), master

    flat = jax.tree.map(upd, grads, opt_state["mu"], opt_state["nu"],
                        opt_state["master"])
    mu = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(
        lambda m, p: m.astype(p.dtype), master, params)
    new_state = {"mu": mu, "nu": nu, "master": master, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
