"""Training substrate: optimizer (AdamW + WSD/cosine), train step builder,
gradient compression, microbatching. See DESIGN.md §5."""
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update, lr_at
from repro.training.step import TrainState, make_train_step, init_train_state

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "lr_at", "TrainState",
           "make_train_step", "init_train_state"]
