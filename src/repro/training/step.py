"""Train-step builder: loss → grads → (optionally compressed) update.

The returned step is a pure function suitable for jit/lower under a mesh;
batch sharding + ZeRO-1 state sharding drive GSPMD's collective insertion
(all-reduce/reduce-scatter of grads, all-gather of updated params).
Microbatching (grad accumulation) is a lax.scan over batch slices.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update

PyTree = Any


@jax.tree_util.register_dataclass
@dataclass
class TrainState:
    params: PyTree
    opt_state: PyTree
    step: jax.Array


def init_train_state(model, rng) -> TrainState:
    params = model.init(rng)
    if model.compute_dtype == jnp.bfloat16:
        params = jax.tree.map(
            lambda a: a.astype(jnp.bfloat16)
            if a.dtype == jnp.float32 else a, params)
    return TrainState(params=params, opt_state=adamw_init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(model, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1,
                    accum_dtype=jnp.float32,
                    compress_grads: Optional[Callable] = None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``accum_dtype=bf16`` halves the grad-accumulation buffer — used for
    100B+ models where the fp32 buffer alone exceeds HBM headroom."""

    def loss_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return grads, loss, metrics

    def accumulate(params, batch):
        """batch leaves are (microbatches, B/microbatches, ...) — shaped by
        the data pipeline, so no resharding slice is needed."""
        def body(acc, mb):
            (loss, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype), acc, grads)
            return acc, (loss, metrics)

        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, accum_dtype), params)
        acc, (losses, metricses) = jax.lax.scan(body, zero, batch)
        grads = jax.tree.map(lambda g: g / microbatches, acc)
        loss = jnp.mean(losses)
        metrics = jax.tree.map(lambda m: jnp.mean(m), metricses)
        return grads, loss, metrics

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        if microbatches > 1:
            grads, loss, metrics = accumulate(state.params, batch)
        else:
            grads, loss, metrics = single(state.params, batch)
        if compress_grads is not None:
            grads = compress_grads(grads)
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, grads, state.opt_state, state.params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return TrainState(params=params, opt_state=opt_state,
                          step=state.step + 1), metrics

    return train_step
