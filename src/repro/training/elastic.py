"""Elastic scaling + straggler mitigation (DESIGN.md §5).

On a real fleet these hooks are driven by the cluster scheduler; here they
are pure functions so the policy is testable:

* ``replan_mesh``     — choose a new (data, model) mesh after node loss,
  keeping TP intact (model axis must stay whole — it holds sharded weights)
  and shrinking/growing the data axis. Re-entry = checkpoint restore +
  re-lower on the new mesh (the dry-run proves both shapes compile).
* ``StragglerPolicy`` — per-step host heartbeats → skip/rebalance decision.
  With the deterministic sharded data pipeline (repro.data), dropping or
  reassigning a shard needs no data movement: any host can regenerate any
  shard from (seed, step, shard).
* ``CrashRecovery``   — ties the NVMM crash flag protocol (repro.core) into
  the train loop: dirty flag ⇒ restore-from-log before the first step.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MeshPlan:
    data: int
    model: int
    pod: int = 1

    @property
    def devices(self) -> int:
        return self.data * self.model * self.pod


def replan_mesh(plan: MeshPlan, healthy_devices: int,
                global_batch: int) -> MeshPlan:
    """Largest data axis that fits healthy devices with TP (model) intact.

    Keeps data a divisor of global_batch so batches reshard cleanly.
    """
    assert healthy_devices >= plan.model, "cannot keep TP group alive"
    max_data = healthy_devices // (plan.model * plan.pod)
    data = max_data
    while data > 1 and global_batch % data != 0:
        data -= 1
    return MeshPlan(data=max(data, 1), model=plan.model, pod=plan.pod)


@dataclass
class StragglerPolicy:
    """Skip-slow-replica policy over per-host step latencies (EWMA)."""
    threshold: float = 2.0          # × median EWMA ⇒ straggler
    alpha: float = 0.3
    ewma: dict = field(default_factory=dict)

    def observe(self, host: str, step_seconds: float) -> None:
        prev = self.ewma.get(host, step_seconds)
        self.ewma[host] = (1 - self.alpha) * prev + self.alpha * step_seconds

    def stragglers(self) -> list[str]:
        if len(self.ewma) < 2:
            return []
        vals = sorted(self.ewma.values())
        median = vals[len(vals) // 2]
        return [h for h, v in self.ewma.items() if v > self.threshold * median]

    def reassign_shards(self, num_shards: int, hosts: list[str]) -> dict:
        """Shard→host map excluding stragglers (deterministic round-robin).
        Because batches are pure functions of (seed, step, shard), the new
        owner resumes mid-epoch with zero data movement."""
        bad = set(self.stragglers())
        good = [h for h in hosts if h not in bad] or hosts
        return {s: good[s % len(good)] for s in range(num_shards)}
