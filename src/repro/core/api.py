"""POSIX-like shared-library surface over pluggable cache engines (paper §II).

``NVCacheFS`` provides open/pread/pwrite/preadv/pwritev/fsync/close over one
:class:`repro.core.engines.CacheEngine`, constructed by name (or from an
:class:`~repro.core.engines.EngineSpec`) through the engine registry — the
facade itself contains no engine-specific dispatch. Registered designs
(``python -m repro.core.engines --list``):

* ``nvpages``      — the paging design (engines/paging.py)
* ``nvlog``        — the logging design (engines/logging.py)
* ``psync``        — the paper's FIO reference: plain LPC, **no** persistence
* ``psync_fsync``  — psync + fsync after every pwrite (the >1 h config)
* ``nvhybrid``     — small writes to a log, large/hot pages to a page pool

A flag in NVMM is set to 1 while loaded and 0 on clean unload; re-opening
after unload re-arms it. If a crashed image is re-opened with flag==1,
``recover()`` flushes every pending modification to disk before serving IO
(paper §II). See engines/README.md for the engine protocol and how to add a
design.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Union

from repro.core.clock import SimClock
from repro.core.disk import Disk
from repro.core.engines import ENGINES, EngineSpec, create_engine

__all__ = ["NVCacheFS", "ENGINES", "EngineSpec"]

# one open file occupies a 2^36-byte offset namespace inside the cache
_FILE_SPAN_BITS = 36

# detects explicitly-passed kwargs (even ones equal to their default)
_UNSET = object()


@dataclass
class _OpenFile:
    fd: int
    path: str
    base: int          # byte offset namespace start


class NVCacheFS:
    def __init__(self, engine: Union[str, EngineSpec] = "nvlog", *,
                 nvmm_bytes=_UNSET, dram_cache_bytes=_UNSET,
                 lpc_capacity_pages=_UNSET, o_direct=_UNSET, shards=_UNSET,
                 drain_batch=_UNSET, clock: Optional[SimClock] = None):
        passed = {k: v for k, v in dict(
            nvmm_bytes=nvmm_bytes, dram_cache_bytes=dram_cache_bytes,
            lpc_capacity_pages=lpc_capacity_pages, o_direct=o_direct,
            shards=shards, drain_batch=drain_batch).items()
            if v is not _UNSET}
        if isinstance(engine, EngineSpec):
            if passed:
                raise TypeError(
                    f"pass engine parameters inside the EngineSpec, not as "
                    f"keyword arguments (got both a spec and "
                    f"{sorted(passed)})")
            spec = engine
        else:
            spec = EngineSpec(engine=engine, **passed)
        self.spec = spec
        self.engine = spec.engine
        self.clock = clock or SimClock()
        self.disk = Disk(self.clock, spec.lpc_capacity_pages)
        self.cache = create_engine(spec, self.disk, self.clock)
        # persistent NVMM mount flag (paper: 1 while loaded, 0 after unload)
        self.nvmm_flag = 1 if self.cache.uses_nvmm else 0
        self._files: dict[int, _OpenFile] = {}
        self._paths: dict[str, int] = {}
        self._next_fd = 3
        self._next_slot = 0
        self.crashed = False

    # ----------------------------------------------------------------- files
    def open(self, path: str) -> int:
        assert not self.crashed, "fs crashed; call recover()"
        if path in self._paths:
            slot = self._paths[path]
        else:
            slot = self._next_slot
            self._next_slot += 1
            self._paths[path] = slot
        fd = self._next_fd
        self._next_fd += 1
        self._files[fd] = _OpenFile(fd, path, slot << _FILE_SPAN_BITS)
        self._rearm()
        return fd

    def _rearm(self) -> None:
        """Re-set the NVMM mount flag after a clean unload. Runs on open()
        and on every write path: fds stay valid across unload(), so the
        first write to an unloaded image must re-mark it dirty or a later
        crash would skip recovery and lose the write."""
        if self.cache.uses_nvmm:
            self.nvmm_flag = 1

    def _abs(self, fd: int, offset: int, length: int = 0) -> int:
        """Translate a file-relative offset; the WHOLE range must fit the
        file's 2^36-byte span (an IO ending past it would silently spill
        into the next file's address space)."""
        f = self._files[fd]
        assert 0 <= offset and offset + length <= (1 << _FILE_SPAN_BITS), \
            "IO range out of file span"
        return f.base + offset

    # -------------------------------------------------------------------- IO
    def pwrite(self, fd: int, data: bytes, offset: int) -> int:
        assert not self.crashed, "fs crashed; call recover()"
        self._rearm()
        return self.cache.pwrite(self._abs(fd, offset, len(data)), data)

    def pread(self, fd: int, n: int, offset: int) -> bytes:
        assert not self.crashed
        return self.cache.pread(self._abs(fd, offset, n), n)

    def pwritev(self, fd: int,
                iovecs: Sequence[tuple[int, bytes]]) -> int:
        """Vectorized write: ``[(offset, data), ...]`` → total bytes.
        Same tuple order as the engine-level ``CacheEngine.pwritev``."""
        assert not self.crashed
        self._rearm()
        return self.cache.pwritev(
            [(self._abs(fd, off, len(data)), data) for off, data in iovecs])

    def preadv(self, fd: int,
               iovecs: Sequence[tuple[int, int]]) -> list[bytes]:
        """Vectorized read: ``[(offset, n), ...]`` → list of blobs.
        Same tuple order as the engine-level ``CacheEngine.preadv``."""
        assert not self.crashed
        return self.cache.preadv(
            [(self._abs(fd, off, n), n) for off, n in iovecs])

    def fsync(self, fd: int) -> None:
        """Per-file durability (POSIX fsync syncs one file, not the whole
        cache): only the fd's 2^36-byte span is flushed."""
        assert not self.crashed
        f = self._files[fd]
        self.cache.fsync_range(f.base, 1 << _FILE_SPAN_BITS)

    def close(self, fd: int) -> None:
        """Drop the descriptor; the last close of a path flushes that
        path's dirty state (close-to-open consistency: closed files survive
        a crash even on the psync baseline, without making other files'
        un-synced data durable as a side effect)."""
        f = self._files.pop(fd, None)
        if f is None or self.crashed:
            return
        if not any(g.path == f.path for g in self._files.values()):
            self.cache.fsync_range(f.base, 1 << _FILE_SPAN_BITS)

    def unload(self) -> None:
        """Clean shutdown: drain/flush everything, clear the NVMM flag."""
        self.cache.flush_all()
        self.nvmm_flag = 0

    # -------------------------------------------------------- crash / recovery
    def crash(self) -> None:
        """Simulated power loss. Volatile state is dropped; NVMM + SSD
        survive. The NVMM flag stays as-is → recovery required if 1."""
        self.crashed = True
        self.cache.crash()

    def recover(self) -> float:
        """Run the paper's recovery procedure; returns simulated seconds.

        flag==1 (crashed while loaded) → full recovery: replay/flush every
        pending modification. flag==0 (clean image) → nothing pending, but
        the volatile indices still died with the power, so the engine
        remounts (metadata scan only)."""
        t0 = self.clock.now
        if self.nvmm_flag == 1:
            self.cache.recover()
        else:
            self.cache.remount()
        self.nvmm_flag = 1 if self.cache.uses_nvmm else 0
        self.crashed = False
        return self.clock.now - t0

    # ------------------------------------------------------------------ stats
    @property
    def simulated_time(self) -> float:
        return self.clock.now

    def stats(self) -> dict:
        s = {"engine": self.engine, "sim_time_s": self.clock.now,
             "tallies": dict(self.clock.tallies),
             "nvmm_capacity_bytes": self.cache.nvmm_capacity_bytes(),
             "nvmm_used_bytes": self.cache.nvmm_used_bytes()}
        s.update(self.cache.stats)
        return s
