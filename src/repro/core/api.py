"""POSIX-like shared-library surface of both caches (paper §II).

``NVCacheFS`` provides open/pread/pwrite/fsync/close over one of four
engines:

* ``nvpages``      — the paging design (repro.core.nvpages)
* ``nvlog``        — the logging design (repro.core.nvlog)
* ``psync``        — the paper's FIO reference: plain LPC, **no** persistence
* ``psync_fsync``  — psync + fsync after every pwrite (the >1 h configuration)

A flag in NVMM is set to 1 on load and 0 on clean unload; if a crashed image
is re-opened with flag==1, ``recover()`` flushes every pending modification
to disk before serving IO (paper §II).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.clock import SimClock
from repro.core.disk import Disk, PAGE_SIZE
from repro.core.nvlog import NVLog
from repro.core.nvpages import NVPages

ENGINES = ("nvpages", "nvlog", "psync", "psync_fsync")

# one open file occupies a 2^36-byte offset namespace inside the cache
_FILE_SPAN_BITS = 36


@dataclass
class _OpenFile:
    fd: int
    path: str
    base: int          # byte offset namespace start


class NVCacheFS:
    def __init__(self, engine: str = "nvlog", *, nvmm_bytes: int = 2 << 30,
                 dram_cache_bytes: int = 2 << 30,
                 lpc_capacity_pages: Optional[int] = None,
                 o_direct: bool = False, shards: int = 1,
                 drain_batch: int = 64, clock: Optional[SimClock] = None):
        assert engine in ENGINES, engine
        self.engine = engine
        self.clock = clock or SimClock()
        self.disk = Disk(self.clock, lpc_capacity_pages)
        self.cache: Optional[object] = None
        if engine == "nvpages":
            self.cache = NVPages(nvmm_bytes, self.disk, self.clock,
                                 o_direct=o_direct, shards=shards)
        elif engine == "nvlog":
            self.cache = NVLog(nvmm_bytes, self.disk, self.clock,
                               dram_cache_bytes=dram_cache_bytes,
                               drain_batch=drain_batch, log_shards=shards)
        # persistent NVMM mount flag (paper: 1 while loaded, 0 after unload)
        self.nvmm_flag = 1 if self.cache is not None else 0
        self._files: dict[int, _OpenFile] = {}
        self._paths: dict[str, int] = {}
        self._next_fd = 3
        self._next_slot = 0
        self.crashed = False

    # ----------------------------------------------------------------- files
    def open(self, path: str) -> int:
        if path in self._paths:
            slot = self._paths[path]
        else:
            slot = self._next_slot
            self._next_slot += 1
            self._paths[path] = slot
        fd = self._next_fd
        self._next_fd += 1
        self._files[fd] = _OpenFile(fd, path, slot << _FILE_SPAN_BITS)
        return fd

    def _abs(self, fd: int, offset: int) -> int:
        f = self._files[fd]
        assert 0 <= offset < (1 << _FILE_SPAN_BITS), "offset out of file span"
        return f.base + offset

    # -------------------------------------------------------------------- IO
    def pwrite(self, fd: int, data: bytes, offset: int) -> int:
        assert not self.crashed, "fs crashed; call recover_image()"
        pos = self._abs(fd, offset)
        if self.cache is not None:
            return self.cache.pwrite(pos, data)
        # psync engines: through the LPC
        done = 0
        while done < len(data):
            pno = (pos + done) // PAGE_SIZE
            in_page = (pos + done) % PAGE_SIZE
            n = min(PAGE_SIZE - in_page, len(data) - done)
            if in_page == 0 and n == PAGE_SIZE:
                self.disk.write_page_lpc(pno, data[done:done + n])
            else:
                page = bytearray(self.disk.read_page(pno))
                page[in_page:in_page + n] = data[done:done + n]
                self.disk.write_page_lpc(pno, bytes(page))
            done += n
        if self.engine == "psync_fsync":
            self.disk.fsync()
        return len(data)

    def pread(self, fd: int, n: int, offset: int) -> bytes:
        assert not self.crashed
        pos = self._abs(fd, offset)
        if self.cache is not None:
            return self.cache.pread(pos, n)
        out = bytearray()
        done = 0
        while done < n:
            pno = (pos + done) // PAGE_SIZE
            in_page = (pos + done) % PAGE_SIZE
            take = min(PAGE_SIZE - in_page, n - done)
            out += self.disk.read_page(pno)[in_page:in_page + take]
            done += take
        return bytes(out)

    def fsync(self, fd: int) -> None:
        assert not self.crashed
        if self.cache is not None:
            self.cache.fsync()          # no-op: already durable (paper §III)
        else:
            self.disk.fsync()

    def close(self, fd: int) -> None:
        self._files.pop(fd, None)

    def unload(self) -> None:
        """Clean shutdown: drain/flush everything, clear the NVMM flag."""
        if isinstance(self.cache, NVLog):
            self.cache.drain_all()
        elif isinstance(self.cache, NVPages):
            self.cache.flush_all()
        else:
            self.disk.fsync()
        self.nvmm_flag = 0

    # -------------------------------------------------------- crash / recovery
    def crash(self) -> None:
        """Simulated power loss. Volatile state is dropped; NVMM + SSD
        survive. The NVMM flag stays 1 → recovery required."""
        self.crashed = True
        if self.cache is not None:
            self.cache.crash()
        else:
            self.disk.crash()

    def recover(self) -> float:
        """Run the paper's recovery procedure; returns simulated seconds."""
        t0 = self.clock.now
        if self.nvmm_flag == 1 and self.cache is not None:
            self.cache.recover()
        self.nvmm_flag = 1
        self.crashed = False
        return self.clock.now - t0

    # ------------------------------------------------------------------ stats
    @property
    def simulated_time(self) -> float:
        return self.clock.now

    def stats(self) -> dict:
        s = {"engine": self.engine, "sim_time_s": self.clock.now,
             "tallies": dict(self.clock.tallies)}
        if self.cache is not None:
            s.update(self.cache.stats)
        return s
