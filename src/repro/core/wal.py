"""Write-ahead log with CRC32-framed records over a persistent byte region.

Replaces the paper's 8-byte-atomic Optane persist with torn-write detection:
a record is durable iff its CRC verifies on recovery scan (DESIGN.md §2,
assumption 1). The log is circular; space is reclaimed when the drainer (or
page-flush, for NVPages' redo log) confirms entries applied.
"""
from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass
from typing import Iterator, Optional

_MAGIC = 0x4E564C47  # 'NVLG'
_HEADER = struct.Struct("<IQQIII")  # magic, seqno, offset, length, crc, _pad
HEADER_SIZE = _HEADER.size


@dataclass
class LogRecord:
    seqno: int
    offset: int          # byte offset in the backing file
    payload: bytes

    @property
    def size(self) -> int:
        return HEADER_SIZE + len(self.payload)


class CircularWAL:
    """A circular write-ahead log in a persistent byte region.

    The region itself (a bytearray) survives "crashes" (the harness keeps it);
    head/tail indices are volatile and reconstructed by ``recover_scan``.
    """

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.buf = bytearray(capacity)
        self.head = 0            # next write position (logical, monotonic)
        self.tail = 0            # oldest un-reclaimed byte (logical)
        self.next_seqno = 1
        # persistent superblock mirror (kept alongside the region)
        self._persist_tail = 0
        self._persist_tail_seq = 1   # seqno of the first un-reclaimed record

    # -- geometry -----------------------------------------------------------
    @property
    def used(self) -> int:
        return self.head - self.tail

    @property
    def free(self) -> int:
        return self.capacity - self.used

    def _write_at(self, logical: int, data: bytes) -> None:
        pos = logical % self.capacity
        end = pos + len(data)
        if end <= self.capacity:
            self.buf[pos:end] = data
        else:
            first = self.capacity - pos
            self.buf[pos:] = data[:first]
            self.buf[:end - self.capacity] = data[first:]

    def _read_at(self, logical: int, n: int) -> bytes:
        pos = logical % self.capacity
        end = pos + n
        if end <= self.capacity:
            return bytes(self.buf[pos:end])
        first = self.capacity - pos
        return bytes(self.buf[pos:]) + bytes(self.buf[:end - self.capacity])

    # -- append / reclaim ----------------------------------------------------
    def record_size(self, payload_len: int) -> int:
        return HEADER_SIZE + payload_len

    def append(self, offset: int, payload: bytes) -> LogRecord:
        size = self.record_size(len(payload))
        if size > self.free:
            raise BufferError("log full")
        seqno = self.next_seqno
        crc = zlib.crc32(payload)
        hdr = _HEADER.pack(_MAGIC, seqno, offset, len(payload), crc, 0)
        self._write_at(self.head, hdr + payload)
        self.head += size
        self.next_seqno += 1
        return LogRecord(seqno, offset, payload)

    def reclaim_to(self, logical: int, next_seqno: int) -> None:
        """Mark everything before ``logical`` as drained/applied.

        ``next_seqno`` is the seqno of the first record at/after ``logical``
        (guards recovery against stale same-CRC records from previous laps).
        """
        assert self.tail <= logical <= self.head
        self.tail = logical
        self._persist_tail = logical
        self._persist_tail_seq = next_seqno

    # -- iteration / recovery -------------------------------------------------
    def iter_from(self, logical: int) -> Iterator[tuple[int, LogRecord]]:
        """Yield (record_start_logical, record) from ``logical`` to head."""
        pos = logical
        while pos < self.head:
            hdr = self._read_at(pos, HEADER_SIZE)
            magic, seqno, offset, length, crc, _ = _HEADER.unpack(hdr)
            if magic != _MAGIC:
                return
            payload = self._read_at(pos + HEADER_SIZE, length)
            if zlib.crc32(payload) != crc:
                return                      # torn write — stop
            yield pos, LogRecord(seqno, offset, payload)
            pos += HEADER_SIZE + length

    def recover_scan(self) -> list[LogRecord]:
        """Post-crash: rebuild head from the persistent tail, return records.

        Walks records from the last persisted tail; stops at the first corrupt
        or out-of-sequence header (torn tail). Restores head/next_seqno.
        """
        self.tail = self._persist_tail
        records = []
        pos = self.tail
        last_seq = None
        while True:
            if pos + HEADER_SIZE > self.tail + self.capacity:
                break
            hdr = self._read_at(pos, HEADER_SIZE)
            magic, seqno, offset, length, crc, _ = _HEADER.unpack(hdr)
            if magic != _MAGIC or length > self.capacity:
                break
            expect = self._persist_tail_seq if last_seq is None else last_seq + 1
            if seqno != expect:
                break
            payload = self._read_at(pos + HEADER_SIZE, length)
            if zlib.crc32(payload) != crc:
                break
            records.append(LogRecord(seqno, offset, payload))
            last_seq = seqno
            pos += HEADER_SIZE + length
        self.head = pos
        self.next_seqno = (last_seq + 1) if last_seq is not None \
            else self._persist_tail_seq
        return records
