"""O(1) LRU list (doubly-linked) for page-frame eviction policies."""
from __future__ import annotations

from typing import Any, Iterator, Optional


class _Node:
    __slots__ = ("key", "prev", "next")

    def __init__(self, key):
        self.key = key
        self.prev: Optional["_Node"] = None
        self.next: Optional["_Node"] = None


class LRUList:
    """Tracks recency. ``touch`` moves to MRU; ``pop_lru`` evicts the LRU key."""

    def __init__(self):
        self._map: dict[Any, _Node] = {}
        self._head: Optional[_Node] = None   # MRU
        self._tail: Optional[_Node] = None   # LRU

    def __len__(self):
        return len(self._map)

    def __contains__(self, key):
        return key in self._map

    def _unlink(self, node: _Node):
        if node.prev:
            node.prev.next = node.next
        else:
            self._head = node.next
        if node.next:
            node.next.prev = node.prev
        else:
            self._tail = node.prev
        node.prev = node.next = None

    def _push_front(self, node: _Node):
        node.next = self._head
        if self._head:
            self._head.prev = node
        self._head = node
        if self._tail is None:
            self._tail = node

    def touch(self, key) -> None:
        node = self._map.get(key)
        if node is None:
            node = _Node(key)
            self._map[key] = node
        else:
            self._unlink(node)
        self._push_front(node)

    def remove(self, key) -> None:
        node = self._map.pop(key, None)
        if node is not None:
            self._unlink(node)

    def pop_lru(self):
        if self._tail is None:
            return None
        key = self._tail.key
        self.remove(key)
        return key

    def lru_order(self) -> Iterator[Any]:
        node = self._tail
        while node is not None:
            yield node.key
            node = node.prev
