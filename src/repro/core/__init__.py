"""The paper's primary contribution: NVMM cache designs (paging, logging,
and their hybrid) as one library behind a pluggable engine registry, plus
their framework adapters (KV-cache tiering and checkpoint backends). See
DESIGN.md §1-2 and repro/core/engines/README.md."""
from repro.core.api import NVCacheFS, ENGINES
from repro.core.clock import SimClock
from repro.core.disk import Disk, PAGE_SIZE
from repro.core.engines import (CacheEngine, EngineSpec, KVCacheEngine,
                                create_engine, create_kv_engine,
                                list_kv_engines, register_engine,
                                register_kv_engine)
from repro.core.nvlog import NVLog
from repro.core.nvpages import NVPages

__all__ = ["NVCacheFS", "ENGINES", "SimClock", "Disk", "PAGE_SIZE", "NVLog",
           "NVPages", "CacheEngine", "EngineSpec", "create_engine",
           "register_engine", "KVCacheEngine", "create_kv_engine",
           "list_kv_engines", "register_kv_engine"]
