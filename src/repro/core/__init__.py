"""The paper's primary contribution: two NVMM cache designs (paging vs
logging) as one library, plus their framework adapters (KV-cache tiering and
checkpoint backends). See DESIGN.md §1-2."""
from repro.core.api import NVCacheFS, ENGINES
from repro.core.clock import SimClock
from repro.core.disk import Disk, PAGE_SIZE
from repro.core.nvlog import NVLog
from repro.core.nvpages import NVPages

__all__ = ["NVCacheFS", "ENGINES", "SimClock", "Disk", "PAGE_SIZE", "NVLog",
           "NVPages"]
