"""Checkpoint backends over the paper's two cache designs (DESIGN.md §2b).

Both write through the same :class:`repro.core.NVCacheFS` surface — the
design switch is literally the engine choice, as in the paper:

* ``PagedCheckpointBackend``  (engine=nvpages): full-snapshot, page-granular.
  Every ``save`` writes the complete state at fixed offsets.
* ``LogCheckpointBackend``    (engine=nvlog): incremental. Each ``save``
  appends only the shards that changed (delta records); a full snapshot is
  cut every ``snapshot_every`` saves; restore = snapshot + replay.

The manifest (name → offset/size/step) is persisted as a JSON header page so
restore works from a recovered image.
"""
from __future__ import annotations

import json
from typing import Optional

from repro.core.api import NVCacheFS
from repro.core.disk import PAGE_SIZE

_HEADER_BYTES = 1 << 20           # manifest region
_ALIGN = PAGE_SIZE


def _align(n: int) -> int:
    return (n + _ALIGN - 1) // _ALIGN * _ALIGN


class _Base:
    def __init__(self, fs: NVCacheFS, path: str = "/ckpt/state"):
        self.fs = fs
        self.fd = fs.open(path)
        self.manifest: dict = {"entries": {}, "step": -1, "next_off": _HEADER_BYTES}

    # -- manifest persistence -------------------------------------------------
    def _write_manifest(self) -> None:
        blob = json.dumps(self.manifest).encode()
        assert len(blob) + 8 <= _HEADER_BYTES, "manifest overflow"
        self.fs.pwrite(self.fd, len(blob).to_bytes(8, "little") + blob, 0)

    def _read_manifest(self) -> dict:
        n = int.from_bytes(self.fs.pread(self.fd, 8, 0), "little")
        if n == 0:
            return {"entries": {}, "step": -1, "next_off": _HEADER_BYTES}
        return json.loads(self.fs.pread(self.fd, n, 8))

    def _alloc(self, name: str, size: int) -> int:
        ent = self.manifest["entries"].get(name)
        if ent is not None and ent["size"] >= size:
            return ent["off"]
        off = self.manifest["next_off"]
        self.manifest["next_off"] = off + _align(size)
        return off


class PagedCheckpointBackend(_Base):
    """Full snapshot every save (the paging design's natural mode)."""

    def save(self, step: int, state: dict[str, bytes]) -> float:
        t0 = self.fs.clock.now
        iov = []
        for name, blob in state.items():
            off = self._alloc(name, len(blob))
            iov.append((off, blob))
            self.manifest["entries"][name] = {
                "off": off, "size": len(blob), "step": step}
        self.fs.pwritev(self.fd, iov)
        self.manifest["step"] = step
        self._write_manifest()
        self.fs.fsync(self.fd)
        return self.fs.clock.now - t0

    def restore(self) -> tuple[int, dict[str, bytes]]:
        self.manifest = self._read_manifest()
        names = list(self.manifest["entries"])
        blobs = self.fs.preadv(self.fd, [
            (self.manifest["entries"][n]["off"],
             self.manifest["entries"][n]["size"]) for n in names])
        return self.manifest["step"], dict(zip(names, blobs))


class LogCheckpointBackend(_Base):
    """Incremental deltas + periodic snapshot (the logging design)."""

    def __init__(self, fs: NVCacheFS, path: str = "/ckpt/state",
                 snapshot_every: int = 8):
        super().__init__(fs, path)
        self.snapshot_every = snapshot_every
        self.manifest["deltas"] = []       # [(step, {name: [off, size]})]
        self._saves = 0

    def save(self, step: int, state: dict[str, bytes],
             changed: Optional[set] = None) -> float:
        """``changed``: names modified since last save (None = all)."""
        t0 = self.fs.clock.now
        self._saves += 1
        if self._saves % self.snapshot_every == 1 or "deltas" not in self.manifest:
            # cut a full snapshot; log restarts from here
            iov = []
            for name, blob in state.items():
                off = self._alloc(name, len(blob))
                iov.append((off, blob))
                self.manifest["entries"][name] = {
                    "off": off, "size": len(blob), "step": step}
            self.fs.pwritev(self.fd, iov)
            self.manifest["deltas"] = []
        else:
            names = changed if changed is not None else set(state)
            iov = []
            delta = {}
            for name in sorted(names):
                blob = state[name]
                off = self.manifest["next_off"]
                self.manifest["next_off"] = off + _align(len(blob))
                iov.append((off, blob))
                delta[name] = [off, len(blob)]
            self.fs.pwritev(self.fd, iov)
            self.manifest["deltas"].append([step, delta])
        self.manifest["step"] = step
        self._write_manifest()
        self.fs.fsync(self.fd)
        return self.fs.clock.now - t0

    def restore(self) -> tuple[int, dict[str, bytes]]:
        self.manifest = self._read_manifest()
        names = list(self.manifest["entries"])
        blobs = self.fs.preadv(self.fd, [
            (self.manifest["entries"][n]["off"],
             self.manifest["entries"][n]["size"]) for n in names])
        out = dict(zip(names, blobs))
        for step, delta in self.manifest.get("deltas", []):
            items = list(delta.items())
            blobs = self.fs.preadv(self.fd, [(off, size)
                                             for _, (off, size) in items])
            out.update({name: blob
                        for (name, _), blob in zip(items, blobs)})
        return self.manifest["step"], out
