"""Backing store: SSD contents + a Linux-page-cache (LPC) model in DRAM.

Functionally real: SSD content is a dict of 4 KiB pages; the LPC is a
write-back DRAM cache over it. The psync FIO baseline in the paper *is* the
LPC — no persistence until fsync. ``crash()`` drops the LPC (volatile),
keeping only fsync'd SSD content.
"""
from __future__ import annotations

from typing import Optional

from repro.core.clock import SimClock
from repro.core.lru import LRUList
from repro.roofline.hw import DRAM, SSD, SSD_FSYNC_LATENCY

PAGE_SIZE = 4096
_ZERO_PAGE = bytes(PAGE_SIZE)


def iter_page_chunks(offset: int, length: int):
    """Yield ``(pos, pno, in_page, n)`` page-granular chunks covering the
    byte range ``[offset, offset+length)`` — the splitting every engine and
    the LPC helpers share: ``pos`` is the chunk start relative to the range,
    ``pno`` the page number, ``in_page`` the offset within it, ``n`` the
    chunk length (a full page iff ``in_page == 0 and n == PAGE_SIZE``)."""
    pos = 0
    while pos < length:
        pno, in_page = divmod(offset + pos, PAGE_SIZE)
        n = min(PAGE_SIZE - in_page, length - pos)
        yield pos, pno, in_page, n
        pos += n


class Disk:
    def __init__(self, clock: SimClock, lpc_capacity_pages: Optional[int] = None):
        self.clock = clock
        self.ssd: dict[int, bytes] = {}
        self.lpc: dict[int, bytearray] = {}
        self.lpc_dirty: set[int] = set()
        self.lpc_lru = LRUList()
        self.lpc_capacity = lpc_capacity_pages   # None = unbounded

    # -- internals ------------------------------------------------------------
    def _lpc_insert(self, pno: int, data: bytearray, dirty: bool) -> None:
        if (self.lpc_capacity is not None and pno not in self.lpc
                and len(self.lpc) >= self.lpc_capacity):
            victim = None
            for cand in self.lpc_lru.lru_order():
                victim = cand
                break
            if victim is not None:
                if victim in self.lpc_dirty:
                    self._writeback(victim)
                self.lpc.pop(victim, None)
                self.lpc_lru.remove(victim)
        self.lpc[pno] = data
        self.lpc_lru.touch(pno)
        if dirty:
            self.lpc_dirty.add(pno)

    def _writeback(self, pno: int) -> None:
        self.clock.charge(SSD, "write", PAGE_SIZE, random_access=True)
        self.ssd[pno] = bytes(self.lpc[pno])
        self.lpc_dirty.discard(pno)

    # -- public ----------------------------------------------------------------
    def read_page(self, pno: int, bypass_lpc: bool = False) -> bytes:
        """Read a page; charges DRAM (LPC hit) or SSD (miss) time."""
        if not bypass_lpc and pno in self.lpc:
            self.clock.charge(DRAM, "read", PAGE_SIZE)
            self.lpc_lru.touch(pno)
            return bytes(self.lpc[pno])
        self.clock.charge(SSD, "read", PAGE_SIZE, random_access=True)
        data = self.ssd.get(pno, _ZERO_PAGE)
        if not bypass_lpc:
            self.clock.charge(DRAM, "write", PAGE_SIZE)
            self._lpc_insert(pno, bytearray(data), dirty=False)
        return bytes(data)

    def write_page_lpc(self, pno: int, data: bytes) -> None:
        """Buffered write into the LPC (no persistence until fsync)."""
        self.clock.charge(DRAM, "write", len(data))
        page = self.lpc.get(pno)
        if page is None:
            if len(data) < PAGE_SIZE and pno in self.ssd:
                # read-modify-write of a partially-overwritten page
                self.clock.charge(SSD, "read", PAGE_SIZE, random_access=True)
                page = bytearray(self.ssd[pno])
            else:
                page = bytearray(PAGE_SIZE)
            self._lpc_insert(pno, page, dirty=True)
        else:
            self.lpc_lru.touch(pno)
            self.lpc_dirty.add(pno)
        page[:len(data)] = data

    def write_page_through(self, pno: int, data: bytes) -> None:
        """Durable writeback that keeps a clean LPC copy (cache eviction
        path: the page must be durable before its NVMM copy is dropped, but
        readers should still find it at DRAM speed)."""
        assert len(data) == PAGE_SIZE
        self.clock.charge(SSD, "write", PAGE_SIZE, random_access=True)
        self.ssd[pno] = bytes(data)
        self.clock.charge(DRAM, "write", PAGE_SIZE)
        self._lpc_insert(pno, bytearray(data), dirty=False)

    def write_page_direct(self, pno: int, data: bytes) -> None:
        """O_DIRECT-style write: straight to SSD, invalidating the LPC copy."""
        assert len(data) == PAGE_SIZE
        self.clock.charge(SSD, "write", PAGE_SIZE, random_access=True)
        self.ssd[pno] = bytes(data)
        self.lpc.pop(pno, None)
        self.lpc_dirty.discard(pno)
        self.lpc_lru.remove(pno)

    def write_bytes(self, offset: int, data: bytes) -> int:
        """Byte-granular buffered write through the LPC.

        The page-granular read-modify-write loop shared by every
        LPC-backed write path (the paper's psync reference): full-page
        aligned chunks go straight in; partial chunks patch the page.
        """
        for pos, pno, in_page, n in iter_page_chunks(offset, len(data)):
            if in_page == 0 and n == PAGE_SIZE:
                self.write_page_lpc(pno, data[pos:pos + n])
            else:
                page = bytearray(self.read_page(pno))
                page[in_page:in_page + n] = data[pos:pos + n]
                self.write_page_lpc(pno, bytes(page))
        return len(data)

    def read_bytes(self, offset: int, n: int) -> bytes:
        """Byte-granular read through the LPC (page-chunked)."""
        out = bytearray()
        for _, pno, in_page, take in iter_page_chunks(offset, n):
            out += self.read_page(pno)[in_page:in_page + take]
        return bytes(out)

    def _flush_dirty(self, pnos: list[int]) -> None:
        """Write back the given dirty pages + one fsync barrier."""
        for pno in pnos:
            self.clock.charge(SSD, "write", PAGE_SIZE, random_access=True)
            self.ssd[pno] = bytes(self.lpc[pno])
            self.lpc_dirty.discard(pno)
        self.clock.advance(SSD_FSYNC_LATENCY)

    def fsync(self) -> None:
        """Flush all dirty LPC pages to SSD + barrier latency."""
        self._flush_dirty(sorted(self.lpc_dirty))

    def fsync_range(self, lo_pno: int, hi_pno: int) -> None:
        """Flush only dirty LPC pages with ``lo_pno <= pno < hi_pno``
        (per-file sync: other files' un-synced pages stay volatile). A
        clean range is free — closing a read-only file must not charge a
        barrier (full ``fsync()`` keeps the seed's always-barrier model)."""
        pnos = sorted(p for p in self.lpc_dirty if lo_pno <= p < hi_pno)
        if pnos:
            self._flush_dirty(pnos)

    # -- crash semantics ---------------------------------------------------------
    def crash(self) -> None:
        """Power loss: the LPC (volatile DRAM) is gone; SSD content survives."""
        self.lpc.clear()
        self.lpc_dirty.clear()
        self.lpc_lru = LRUList()

    # -- silent ops (background drainer: time is charged analytically) -----------
    def apply_silent(self, pno: int, offset_in_page: int, payload: bytes) -> None:
        page = bytearray(self.ssd.get(pno, _ZERO_PAGE))
        page[offset_in_page:offset_in_page + len(payload)] = payload
        self.ssd[pno] = bytes(page)
        # the drainer writes *through the LPC* (paper §II: NVLog uses the LPC
        # as a read extension of its DRAM cache) — land a clean copy there
        lpc_page = self.lpc.get(pno)
        if lpc_page is not None:
            lpc_page[offset_in_page:offset_in_page + len(payload)] = payload
        else:
            self._lpc_insert(pno, bytearray(page), dirty=False)
