"""Backing store: SSD contents + a Linux-page-cache (LPC) model in DRAM.

Functionally real: SSD content is a dict of 4 KiB pages; the LPC is a
write-back DRAM cache over it. The psync FIO baseline in the paper *is* the
LPC — no persistence until fsync. ``crash()`` drops the LPC (volatile),
keeping only fsync'd SSD content.
"""
from __future__ import annotations

from typing import Optional

from repro.core.clock import SimClock
from repro.core.lru import LRUList
from repro.roofline.hw import DRAM, SSD, SSD_FSYNC_LATENCY

PAGE_SIZE = 4096
_ZERO_PAGE = bytes(PAGE_SIZE)


class Disk:
    def __init__(self, clock: SimClock, lpc_capacity_pages: Optional[int] = None):
        self.clock = clock
        self.ssd: dict[int, bytes] = {}
        self.lpc: dict[int, bytearray] = {}
        self.lpc_dirty: set[int] = set()
        self.lpc_lru = LRUList()
        self.lpc_capacity = lpc_capacity_pages   # None = unbounded

    # -- internals ------------------------------------------------------------
    def _lpc_insert(self, pno: int, data: bytearray, dirty: bool) -> None:
        if (self.lpc_capacity is not None and pno not in self.lpc
                and len(self.lpc) >= self.lpc_capacity):
            victim = None
            for cand in self.lpc_lru.lru_order():
                victim = cand
                break
            if victim is not None:
                if victim in self.lpc_dirty:
                    self._writeback(victim)
                self.lpc.pop(victim, None)
                self.lpc_lru.remove(victim)
        self.lpc[pno] = data
        self.lpc_lru.touch(pno)
        if dirty:
            self.lpc_dirty.add(pno)

    def _writeback(self, pno: int) -> None:
        self.clock.charge(SSD, "write", PAGE_SIZE, random_access=True)
        self.ssd[pno] = bytes(self.lpc[pno])
        self.lpc_dirty.discard(pno)

    # -- public ----------------------------------------------------------------
    def read_page(self, pno: int, bypass_lpc: bool = False) -> bytes:
        """Read a page; charges DRAM (LPC hit) or SSD (miss) time."""
        if not bypass_lpc and pno in self.lpc:
            self.clock.charge(DRAM, "read", PAGE_SIZE)
            self.lpc_lru.touch(pno)
            return bytes(self.lpc[pno])
        self.clock.charge(SSD, "read", PAGE_SIZE, random_access=True)
        data = self.ssd.get(pno, _ZERO_PAGE)
        if not bypass_lpc:
            self.clock.charge(DRAM, "write", PAGE_SIZE)
            self._lpc_insert(pno, bytearray(data), dirty=False)
        return bytes(data)

    def write_page_lpc(self, pno: int, data: bytes) -> None:
        """Buffered write into the LPC (no persistence until fsync)."""
        self.clock.charge(DRAM, "write", len(data))
        page = self.lpc.get(pno)
        if page is None:
            if len(data) < PAGE_SIZE and pno in self.ssd:
                # read-modify-write of a partially-overwritten page
                self.clock.charge(SSD, "read", PAGE_SIZE, random_access=True)
                page = bytearray(self.ssd[pno])
            else:
                page = bytearray(PAGE_SIZE)
            self._lpc_insert(pno, page, dirty=True)
        else:
            self.lpc_lru.touch(pno)
            self.lpc_dirty.add(pno)
        page[:len(data)] = data

    def write_page_through(self, pno: int, data: bytes) -> None:
        """Durable writeback that keeps a clean LPC copy (cache eviction
        path: the page must be durable before its NVMM copy is dropped, but
        readers should still find it at DRAM speed)."""
        assert len(data) == PAGE_SIZE
        self.clock.charge(SSD, "write", PAGE_SIZE, random_access=True)
        self.ssd[pno] = bytes(data)
        self.clock.charge(DRAM, "write", PAGE_SIZE)
        self._lpc_insert(pno, bytearray(data), dirty=False)

    def write_page_direct(self, pno: int, data: bytes) -> None:
        """O_DIRECT-style write: straight to SSD, invalidating the LPC copy."""
        assert len(data) == PAGE_SIZE
        self.clock.charge(SSD, "write", PAGE_SIZE, random_access=True)
        self.ssd[pno] = bytes(data)
        self.lpc.pop(pno, None)
        self.lpc_dirty.discard(pno)
        self.lpc_lru.remove(pno)

    def fsync(self) -> None:
        """Flush all dirty LPC pages to SSD + barrier latency."""
        for pno in sorted(self.lpc_dirty):
            self.clock.charge(SSD, "write", PAGE_SIZE, random_access=True)
            self.ssd[pno] = bytes(self.lpc[pno])
        self.lpc_dirty.clear()
        self.clock.advance(SSD_FSYNC_LATENCY)

    # -- crash semantics ---------------------------------------------------------
    def crash(self) -> None:
        """Power loss: the LPC (volatile DRAM) is gone; SSD content survives."""
        self.lpc.clear()
        self.lpc_dirty.clear()
        self.lpc_lru = LRUList()

    # -- silent ops (background drainer: time is charged analytically) -----------
    def apply_silent(self, pno: int, offset_in_page: int, payload: bytes) -> None:
        page = bytearray(self.ssd.get(pno, _ZERO_PAGE))
        page[offset_in_page:offset_in_page + len(payload)] = payload
        self.ssd[pno] = bytes(page)
        # the drainer writes *through the LPC* (paper §II: NVLog uses the LPC
        # as a read extension of its DRAM cache) — land a clean copy there
        lpc_page = self.lpc.get(pno)
        if lpc_page is not None:
            lpc_page[offset_in_page:offset_in_page + len(payload)] = payload
        else:
            self._lpc_insert(pno, bytearray(page), dirty=False)
