"""Simulated-time accounting for the tiered cache (DESIGN.md §2).

All data movement in ``repro.core`` is functionally real (real bytes move);
*time* is modeled, because the container has neither Optane nor a TPU host
fabric. Costs come from the calibrated tier specs in ``repro.roofline.hw``.

Two actors share the simulation: the foreground application thread and the
background drainer. The drainer is modeled as a single-server queue whose
entry finish-times are computed analytically (arrival/service), so foreground
stalls (log full) and crash cut-offs (which entries are durable at time t)
are exact functions of simulated time.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.roofline.hw import TierSpec


@dataclass
class SimClock:
    now: float = 0.0
    # accounting by (tier, op) for reporting read/write amplification
    tallies: dict = field(default_factory=dict)

    def charge(self, tier: TierSpec, op: str, nbytes: int,
               random_access: bool = True, advance: bool = True) -> float:
        """Account one IO. Returns the cost in seconds."""
        if op == "read":
            bw = tier.rand_read_bw if random_access else tier.read_bw
            lat = tier.read_latency
        else:
            bw = tier.rand_write_bw if random_access else tier.write_bw
            lat = tier.write_latency
        cost = lat + nbytes / bw
        key = (tier.name, op)
        cnt, tot = self.tallies.get(key, (0, 0))
        self.tallies[key] = (cnt + 1, tot + nbytes)
        if advance:
            self.now += cost
        return cost

    def advance(self, seconds: float) -> None:
        self.now += seconds

    def wait_until(self, t: float) -> None:
        if t > self.now:
            self.now = t

    def bytes_moved(self, tier_name: str, op: str) -> int:
        return self.tallies.get((tier_name, op), (0, 0))[1]


@dataclass
class DrainQueue:
    """Analytic single-server queue for the background drainer.

    ``push`` registers a unit of drain work arriving at time ``t`` with
    service time ``svc``; returns the finish time. Entries finish in FIFO
    order: finish_i = max(arrival_i, finish_{i-1}) + svc_i.

    A push may carry a ``token`` naming its reservation, which makes the
    entry *cancellable*: :meth:`cancel` removes a tokened reservation and
    replays the remaining pending entries over the freed server time, so
    ``backlog`` stops counting work that will never run (a released
    sequence's queued transfers). Service the server already performed is
    history — a reservation that finished (or the served part of one in
    mid-service) is never refunded.
    """
    last_finish: float = 0.0

    def __post_init__(self):
        # token → (arrival, service, finish); only tokened pushes are
        # cancellable. _base is the completed-work watermark: server time
        # owed to untracked/settled/served entries that replay must respect.
        self._resv: dict = {}
        self._base: float = 0.0

    def push(self, arrival: float, service: float, token=None) -> float:
        start = max(arrival, self.last_finish)
        self.last_finish = start + service
        if token is not None:
            self._resv[token] = (arrival, service, self.last_finish)
        else:
            self._base = max(self._base, self.last_finish)
        return self.last_finish

    def finish_of(self, token) -> Optional[float]:
        """Current finish time of a tracked reservation (may be earlier
        than the value ``push`` returned if a cancel compacted the queue)."""
        r = self._resv.get(token)
        return None if r is None else r[2]

    def settle(self, token) -> Optional[float]:
        """Retire a tracked reservation (its caller barriered on it): its
        finish joins the completed-work watermark. Returns the finish."""
        r = self._resv.pop(token, None)
        if r is None:
            return None
        self._base = max(self._base, r[2])
        return r[2]

    def cancel(self, token, now: float) -> float:
        """Remove a tracked reservation and reclaim its *unserved* time.

        Entries fully served by ``now`` are history (no refund); the served
        part of a mid-service entry stays on the books. Remaining pending
        entries replay FIFO over the freed timeline — an entry that had
        already started keeps its start (the server cannot un-serve), the
        rest close up behind it. Returns the seconds reclaimed from
        ``last_finish``.
        """
        entry = self._resv.pop(token, None)
        if entry is None:
            return 0.0
        # fold anything fully served into the watermark first
        for tok in [t for t, r in self._resv.items() if r[2] <= now]:
            self._base = max(self._base, self._resv.pop(tok)[2])
        if entry[2] <= now:
            self._base = max(self._base, entry[2])
            return 0.0                      # already drained: no refund
        old = self.last_finish
        _, svc, fin = entry
        # a cancelled mid-service entry occupied the server until `now`
        t = max(self._base, now if fin - svc < now else self._base)
        for tok in sorted(self._resv, key=lambda k: self._resv[k][2]):
            a, s, f = self._resv[tok]
            start = (f - s) if f - s < now else max(a, t)   # started: fixed
            f2 = start + s
            self._resv[tok] = (a, s, f2)
            t = max(t, f2)
        self.last_finish = max(t, self._base)
        return max(0.0, old - self.last_finish)

    def backlog(self, now: float) -> float:
        """Seconds of queued work still draining at time ``now`` (0 when the
        server is idle) — the channel-occupancy gauge the async transfer
        pipeline reports."""
        return max(0.0, self.last_finish - now)


class ShardedDrainer:
    """N independent :class:`DrainQueue` servers sharing one SimClock.

    The per-shard drainer both cache tiers use (``NVLog`` shards its WAL by
    page number, the hybrid KV cache shards its token log by sequence):
    ``shard_of(key)`` hashes a key onto a shard, and each shard drains as an
    independent FIFO server — backlog on one shard never delays another.
    Within a shard, FIFO finish order is what the force-drain coherence rule
    relies on: waiting for a page's (or sequence's) newest entry implies
    every earlier entry of that shard has drained too.
    """

    def __init__(self, shards: int = 1):
        assert shards >= 1, shards
        self.queues = [DrainQueue() for _ in range(shards)]

    @property
    def num_shards(self) -> int:
        return len(self.queues)

    def shard_of(self, key) -> int:
        return hash(key) % len(self.queues)

    def push(self, shard: int, arrival: float, service: float,
             token=None) -> float:
        """Enqueue one unit of drain work on ``shard``; returns finish time."""
        return self.queues[shard].push(arrival, service, token=token)

    def last_finish(self, shard: int) -> float:
        return self.queues[shard].last_finish

    def idle_time(self) -> float:
        """Time by which every shard's backlog has fully drained."""
        return max(q.last_finish for q in self.queues)

    def reset(self) -> None:
        """Drop all queue state (crash: the drainer's backlog is volatile)."""
        for q in self.queues:
            q.last_finish = 0.0
            q._resv.clear()
            q._base = 0.0
