"""Tiered KV-cache for long-context serving: paged vs log (DESIGN.md §2a).

The TPU translation of the paper's question. Tiers: HBM (fast, small) ↔ host
DRAM over PCIe (big, bandwidth-asymmetric) ↔ disk (preempted sequences).

* ``PagedKVCache``  (NVPages): fixed-size token pages live in a host pool; a
  block table maps (seq, logical page) → physical page; an HBM LRU holds the
  working set; appends go through a redo buffer then into the page (2×
  write); misses DMA whole pages up. Attention over resident pages uses the
  ``paged_attention`` Pallas kernel's block-table layout.
* ``LogKVCache``  (NVLog): appends go to one sequential host log (1× write);
  a per-sequence HBM hot-window holds the most recent tokens (the paper's
  small DRAM cache); a background drainer compacts log segments into host
  pages; cold reads patch pages from the log (``log_patch`` kernel layout).

Data movement is real (numpy); PCIe/HBM timing is modeled via SimClock.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.clock import DrainQueue, SimClock
from repro.core.lru import LRUList
from repro.roofline.hw import TierSpec

# PCIe gen4 x16-ish host link as seen from the device, and HBM for reference
HOST_LINK = TierSpec("host", read_bw=16e9, write_bw=16e9,
                     rand_read_bw=4e9, rand_write_bw=4e9,
                     read_latency=5e-6, write_latency=5e-6)
HBM = TierSpec("hbm", read_bw=819e9, write_bw=819e9,
               rand_read_bw=400e9, rand_write_bw=400e9,
               read_latency=1e-6, write_latency=1e-6)


@dataclass
class KVSpec:
    num_layers: int
    kv_heads: int
    head_dim: int
    page_tokens: int = 16
    dtype: np.dtype = np.dtype(np.float16)

    @property
    def token_bytes(self) -> int:          # K+V for one token, one layer
        return 2 * self.kv_heads * self.head_dim * self.dtype.itemsize

    @property
    def page_bytes(self) -> int:
        return self.page_tokens * self.token_bytes

    def empty_page(self) -> np.ndarray:
        return np.zeros((2, self.page_tokens, self.kv_heads, self.head_dim),
                        self.dtype)


class PagedKVCache:
    """NVPages design over (layer, seq) KV pages."""

    def __init__(self, spec: KVSpec, clock: SimClock, *,
                 hbm_budget_bytes: int):
        self.spec = spec
        self.clock = clock
        self.pool: dict[tuple, np.ndarray] = {}      # (layer, phys) → page
        self.block_table: dict[int, list[int]] = {}  # seq → [phys per logical]
        self.seq_len: dict[int, int] = {}
        self.hbm_lru = LRUList()                     # (layer, phys) resident
        self.hbm_capacity = max(hbm_budget_bytes // spec.page_bytes, 1)
        self.next_phys = 0
        self.stats = {"hbm_hits": 0, "hbm_misses": 0, "dma_up_bytes": 0,
                      "host_writes": 0, "redo_bytes": 0}

    def _ensure_resident(self, layer: int, phys: int) -> None:
        key = (layer, phys)
        if key in self.hbm_lru:
            self.stats["hbm_hits"] += 1
            self.hbm_lru.touch(key)
            return
        self.stats["hbm_misses"] += 1
        if len(self.hbm_lru) >= self.hbm_capacity:
            self.hbm_lru.pop_lru()                   # clean: host copy is truth
        # DMA whole page up — the paper's miss-copy cost
        self.clock.charge(HOST_LINK, "read", self.spec.page_bytes,
                          random_access=True)
        self.stats["dma_up_bytes"] += self.spec.page_bytes
        self.hbm_lru.touch(key)

    def append(self, seq: int, kv_token: np.ndarray) -> None:
        """kv_token: (layers, 2, kv_heads, head_dim) — one decoded token."""
        spec = self.spec
        pos = self.seq_len.get(seq, 0)
        logical = pos // spec.page_tokens
        slot = pos % spec.page_tokens
        table = self.block_table.setdefault(seq, [])
        if logical >= len(table):
            table.append(self.next_phys)
            self.next_phys += 1
            for layer in range(spec.num_layers):
                self.pool[(layer, table[logical])] = spec.empty_page()
        phys = table[logical]
        for layer in range(spec.num_layers):
            # redo-buffer write then page write: the paging design's 2× write
            self.clock.charge(HOST_LINK, "write", spec.token_bytes,
                              random_access=False)           # redo append
            self.stats["redo_bytes"] += spec.token_bytes
            self.clock.charge(HOST_LINK, "write", spec.token_bytes,
                              random_access=True)            # into the page
            self.stats["host_writes"] += 1
            self.pool[(layer, phys)][:, slot] = kv_token[layer]
        self.seq_len[seq] = pos + 1

    def gather(self, seq: int, layer: int) -> np.ndarray:
        """Materialize (2, T, kv_heads, head_dim) for attention; pages are
        DMA'd to HBM on miss (block-table indirection)."""
        spec = self.spec
        T = self.seq_len.get(seq, 0)
        out = np.zeros((2, T, spec.kv_heads, spec.head_dim), spec.dtype)
        for logical, phys in enumerate(self.block_table.get(seq, [])):
            self._ensure_resident(layer, phys)
            lo = logical * spec.page_tokens
            hi = min(lo + spec.page_tokens, T)
            if lo >= T:
                break
            page = self.pool[(layer, phys)]
            out[:, lo:hi] = page[:, :hi - lo]
            self.clock.charge(HBM, "read", (hi - lo) * spec.token_bytes)
        return out


class LogKVCache:
    """NVLog design: sequential host log + HBM hot window + drain/compact."""

    def __init__(self, spec: KVSpec, clock: SimClock, *,
                 hot_window_tokens: int = 256, drain_batch: int = 32):
        self.spec = spec
        self.clock = clock
        self.hot_window = hot_window_tokens
        self.drain_batch = drain_batch
        self.queue = DrainQueue()
        # the sequential log: list of (seq, pos, kv_token) + drain finish time
        self.log: deque = deque()
        # compacted host pages: (seq, layer, logical) → page
        self.pages: dict[tuple, np.ndarray] = {}
        # per-sequence HBM hot window (most recent tokens, all layers)
        self.hot: dict[int, deque] = {}
        self.seq_len: dict[int, int] = {}
        self.stats = {"log_appends": 0, "patches": 0, "hot_hits": 0,
                      "host_reads": 0, "drained": 0}

    def _drain_service(self) -> float:
        b = self.spec.token_bytes * self.spec.num_layers
        return HOST_LINK.write_latency / self.drain_batch + b / HOST_LINK.write_bw

    def _advance(self, now: float) -> None:
        spec = self.spec
        while self.log and self.log[0][3] <= now:
            seq, pos, kv_token, _ = self.log.popleft()
            logical, slot = divmod(pos, spec.page_tokens)
            for layer in range(spec.num_layers):
                key = (seq, layer, logical)
                page = self.pages.get(key)
                if page is None:
                    page = spec.empty_page()
                    self.pages[key] = page
                page[:, slot] = kv_token[layer]
            self.stats["drained"] += 1

    def append(self, seq: int, kv_token: np.ndarray) -> None:
        spec = self.spec
        pos = self.seq_len.get(seq, 0)
        nbytes = spec.token_bytes * spec.num_layers
        # one sequential log write — the logging design's 1× write
        self.clock.charge(HOST_LINK, "write", nbytes, random_access=False)
        finish = self.queue.push(self.clock.now, self._drain_service())
        self.log.append((seq, pos, kv_token.copy(), finish))
        self.stats["log_appends"] += 1
        hot = self.hot.setdefault(seq, deque(maxlen=self.hot_window))
        hot.append((pos, kv_token.copy()))
        self.seq_len[seq] = pos + 1
        self._advance(self.clock.now)

    def gather(self, seq: int, layer: int) -> np.ndarray:
        """(2, T, kv_heads, head_dim): hot window from HBM; cold history from
        compacted pages, patched from the log where the drainer hasn't
        caught up (the log_patch kernel's layout)."""
        spec = self.spec
        self._advance(self.clock.now)
        T = self.seq_len.get(seq, 0)
        out = np.zeros((2, T, spec.kv_heads, spec.head_dim), spec.dtype)
        hot = self.hot.get(seq, ())
        hot_positions = set()
        for pos, kv_token in hot:
            out[:, pos] = kv_token[layer]
            hot_positions.add(pos)
        if hot_positions:
            self.stats["hot_hits"] += len(hot_positions)
            self.clock.charge(
                HBM, "read", len(hot_positions) * spec.token_bytes)
        cold_T = min(T, min(hot_positions) if hot_positions else T)
        npages = -(-cold_T // spec.page_tokens) if cold_T else 0
        for logical in range(npages):
            lo = logical * spec.page_tokens
            hi = min(lo + spec.page_tokens, cold_T)
            page = self.pages.get((seq, layer, logical))
            if page is not None:
                out[:, lo:hi] = page[:, :hi - lo]
            self.clock.charge(HOST_LINK, "read",
                              (hi - lo) * spec.token_bytes,
                              random_access=False)
            self.stats["host_reads"] += 1
        # patch undrained entries overlapping the cold range
        for seq_i, pos, kv_token, _ in self.log:
            if seq_i == seq and pos < cold_T and pos not in hot_positions:
                out[:, pos] = kv_token[layer]
                self.clock.charge(HOST_LINK, "read", spec.token_bytes,
                                  random_access=True)
                self.stats["patches"] += 1
        return out
