"""Tiered KV-cache for long-context serving: paged vs log vs hybrid
(DESIGN.md §2a).

The TPU translation of the paper's question. Tiers: HBM (fast, small) ↔ host
DRAM over PCIe (big, bandwidth-asymmetric) ↔ disk (preempted sequences).
Every design is a :class:`repro.core.engines.kv.KVCacheEngine` plugin,
constructed from the same :class:`~repro.core.engines.EngineSpec` the FS
registry uses (``create_kv_engine(spec, kvspec, clock)``):

* ``paged``  (:class:`PagedKVCache`, NVPages): fixed-size token pages live
  in a host pool; a block table maps (seq, logical page) → physical page; an
  HBM LRU holds the working set; appends go through a redo buffer then into
  the page (2× write); misses DMA whole pages up. Attention over resident
  pages uses the ``paged_attention`` Pallas kernel's block-table layout.
* ``log``  (:class:`LogKVCache`, NVLog): appends go to one sequential host
  log (1× write); a per-sequence HBM hot-window holds the most recent tokens
  (the paper's small DRAM cache); a background drainer compacts log segments
  into host pages; cold reads patch pages from the log (``log_patch`` kernel
  layout).
* ``kvhybrid``  (:class:`HybridKVCache`): the serving twin of the FS
  ``nvhybrid`` engine. Appends route adaptively — small appends (decode
  tokens of hot sequences) take the log hot-window path, large appends
  (prefill bursts, restores of long cold sequences) go straight to pages —
  with the threshold learned online from the observed append-size/reuse
  histogram (:class:`AdaptiveRouter`). The log drains through per-shard
  parallel drainers (hash(seq) → shard, each shard an independent FIFO
  server on the shared ``SimClock``), and a shard force-drains before the
  page side takes ownership of a page — the same log-before-pages ordering
  as ``nvhybrid``.

Data movement is real (numpy); PCIe/HBM/disk timing is modeled via SimClock.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Iterable, Optional, Sequence

import numpy as np

from repro.core.clock import ShardedDrainer, SimClock
from repro.core.engines.base import EngineSpec
from repro.core.engines.desc import (CacheDescriptor, PLANE_STAT_NAMES,
                                     dense_descriptor)
from repro.core.engines.kv import KVCacheEngine, register_kv_engine
from repro.core.lru import LRUList
from repro.roofline.hw import SSD, TierSpec

# PCIe gen4 x16-ish host link as seen from the device, and HBM for reference
HOST_LINK = TierSpec("host", read_bw=16e9, write_bw=16e9,
                     rand_read_bw=4e9, rand_write_bw=4e9,
                     read_latency=5e-6, write_latency=5e-6)
HBM = TierSpec("hbm", read_bw=819e9, write_bw=819e9,
               rand_read_bw=400e9, rand_write_bw=400e9,
               read_latency=1e-6, write_latency=1e-6)


@dataclass
class KVSpec:
    num_layers: int
    kv_heads: int
    head_dim: int
    page_tokens: int = 16
    dtype: np.dtype = np.dtype(np.float16)
    #: optional cache descriptor (repro.core.engines.desc) naming the pool's
    #: planes; None resolves to the legacy dense (k, v) layout, so every
    #: mirror engine's byte math below is unchanged
    desc: Optional[CacheDescriptor] = None

    def descriptor(self) -> CacheDescriptor:
        if self.desc is not None:
            return self.desc
        return dense_descriptor(self.num_layers, self.kv_heads,
                                self.head_dim, self.page_tokens,
                                dtype=np.dtype(self.dtype).name)

    @property
    def token_bytes(self) -> int:          # K+V for one token, one layer
        return 2 * self.kv_heads * self.head_dim * self.dtype.itemsize

    @property
    def page_bytes(self) -> int:
        return self.page_tokens * self.token_bytes

    def empty_page(self) -> np.ndarray:
        return np.zeros((2, self.page_tokens, self.kv_heads, self.head_dim),
                        self.dtype)


class _TieredKV(KVCacheEngine):
    """Shared engine plumbing: batched appends, preempt/restore via the disk
    tier, and the preempted-sequence guard. Engines implement
    ``_append_tokens`` / ``_read`` / ``_drop_seq``."""

    def __init__(self, spec: KVSpec, clock: SimClock):
        self.spec = spec
        self.clock = clock
        self.seq_len: dict[int, int] = {}
        self._preempted: dict[int, np.ndarray] = {}   # seq → (L, 2, T, K, D)
        self.stats: dict = {"preempts": 0, "restores": 0, "releases": 0,
                            "preempt_out_bytes": 0, "restore_in_bytes": 0,
                            # prefix-sharing counters (ISSUE 6) — zero on
                            # engines without sharing so the stats key set
                            # stays identical across every registered engine
                            "prefix_hits": 0, "prefix_tokens_reused": 0,
                            "cow_copies": 0, "shared_pages": 0,
                            # async-tiering counters (ISSUE 8) — zero on
                            # engines without a transfer pipeline, same rule
                            "async_spills": 0, "prefetch_hits": 0,
                            "stall_ticks_saved": 0,
                            # fault-tolerance counters (ISSUE 10) — zero on
                            # engines without a pipeline or when no injector
                            # is attached, so the key set stays uniform
                            "transfer_retries": 0, "transfer_failures": 0,
                            "retried_faults": 0, "host_pages_lost": 0,
                            "shard_stalls": 0, "tiering_degraded": 0}
        # per-plane pool traffic (ISSUE 9) — one counter pair per plane in
        # the descriptor universe, zero on engines without a pool, so the
        # stats key set stays identical across every registered engine.
        # Paged-plane spills satisfy the exactness invariant per plane:
        # pool_d2h_bytes_<p> == pool_page_spills × plane_page_bytes(p).
        for plane in PLANE_STAT_NAMES:
            self.stats[f"pool_d2h_bytes_{plane}"] = 0
            self.stats[f"pool_h2d_bytes_{plane}"] = 0

    # hooks -----------------------------------------------------------------
    def _append_tokens(self, seq: int, toks: list[np.ndarray]) -> None:
        raise NotImplementedError

    def _read(self, seq: int, layer: int) -> np.ndarray:
        raise NotImplementedError

    def _drop_seq(self, seq: int) -> None:
        raise NotImplementedError

    def _spill(self, seq: int) -> np.ndarray:
        """Materialize ``(L, 2, T, K, D)`` for preemption WITHOUT the read
        path's side effects (no HBM LRU touches, DMA faults, or router
        reuse feedback) — preempting must not pollute what stays resident."""
        raise NotImplementedError

    # protocol --------------------------------------------------------------
    def _check_active(self, seq: int) -> None:
        if seq in self._preempted:
            raise RuntimeError(
                f"sequence {seq} is preempted to disk; restore() it first")

    def append(self, seq: int, kv_tokens: np.ndarray) -> None:
        self._check_active(seq)
        kv_tokens = np.asarray(kv_tokens)
        if kv_tokens.ndim == 4:            # (L, 2, K, D): one decoded token
            toks = [kv_tokens]
        elif kv_tokens.ndim == 5:          # (L, 2, T, K, D): prefill burst
            toks = [kv_tokens[:, :, t] for t in range(kv_tokens.shape[2])]
        else:
            raise ValueError(
                f"kv_tokens must be (L, 2, K, D) or (L, 2, T, K, D); got "
                f"shape {kv_tokens.shape}")
        if toks:
            self._append_tokens(seq, toks)

    def read(self, seq: int, layer: int) -> np.ndarray:
        self._check_active(seq)
        return self._read(seq, layer)

    def preempt(self, seq: int) -> None:
        self._check_active(seq)
        blob = self._spill(seq)
        # sequential drain of the whole sequence out of the host tier and
        # onto the disk tier (one streamed copy, no random faults)
        self.clock.charge(HOST_LINK, "read", blob.nbytes, random_access=False)
        self.clock.charge(SSD, "write", blob.nbytes, random_access=False)
        self._drop_seq(seq)
        self.seq_len.pop(seq, None)
        self._preempted[seq] = blob
        self.stats["preempts"] += 1
        self.stats["preempt_out_bytes"] += blob.nbytes

    def restore(self, seq: int) -> None:
        blob = self._preempted.pop(seq, None)
        if blob is None:
            raise RuntimeError(f"sequence {seq} is not preempted")
        self.clock.charge(SSD, "read", blob.nbytes, random_access=False)
        self.stats["restores"] += 1
        self.stats["restore_in_bytes"] += blob.nbytes
        toks = [blob[:, :, t] for t in range(blob.shape[2])]
        if toks:
            # restore re-enters through the append path: one large batch —
            # under kvhybrid a long cold sequence lands on the page side
            self._append_tokens(seq, toks)

    def _on_release(self, seq: int) -> None:
        """Hook: per-sequence policy-state cleanup on release (adaptive
        routers forget their reuse histograms here). Runs on BOTH release
        branches — active and preempted — so every engine forgets
        consistently (the kvhybrid-only forget was a leak)."""

    def release(self, seq: int) -> None:
        """Finished request: drop the sequence from every tier. A preempted
        sequence just drops its disk blob; an active one drops host/HBM
        state through the engine's ``_drop_seq``."""
        if self._preempted.pop(seq, None) is None:
            self._drop_seq(seq)
            self.seq_len.pop(seq, None)
        self.stats["releases"] += 1
        self._on_release(seq)


@register_kv_engine("paged")
class PagedKVCache(_TieredKV):
    """NVPages design over (layer, seq) KV pages.

    Two modes share the block table and the (seq → [phys]) indirection:

    * **host mode** (default, the original design): pages live in a host
      numpy pool, an HBM LRU models the device working set, appends pay the
      2× redo+page host write, misses DMA whole pages up.
    * **pooled mode** (:meth:`init_pool`, the mirror-free serving path):
      pages live in device-resident ``(L, P, T, K, D)`` arrays the
      paged_attention kernel reads directly. Page alloc/free is tied to the
      same LRU accounting — when the fixed pool fills, the least-recently
      -used page of a non-pinned sequence is *spilled to the host tier at
      page granularity* (D2H one page) and faulted back on demand (H2D),
      so HBM-pressure spills evict pool pages, never dense per-sequence
      mirrors. Decode appends are device-born (the model scatters them in
      place) and cost HBM writes only — zero device→host mirror traffic.
    """

    def __init__(self, spec: KVSpec, clock: SimClock, *,
                 hbm_budget_bytes: int, async_tiering: bool = False,
                 transfer_max_retries: int = 3,
                 transfer_backoff_s: float = 1e-4):
        super().__init__(spec, clock)
        self.pool: dict[tuple, np.ndarray] = {}      # (layer, phys) → page
        self.block_table: dict[int, list[int]] = {}  # seq → [phys per logical]
        self.hbm_lru = LRUList()                     # (layer, phys) resident
        self.hbm_budget_bytes = hbm_budget_bytes
        self.hbm_capacity = max(hbm_budget_bytes // spec.page_bytes, 1)
        self.next_phys = 0
        self._pooled = False
        self._share_index = None       # prefix index (set_share_index)
        self.async_tiering = bool(async_tiering)
        self._pipeline = None          # TransferPipeline once pooled + async
        self._injector = None          # FaultInjector (set_fault_injector)
        self._xfer_retries = transfer_max_retries
        self._xfer_backoff = transfer_backoff_s
        self.stats.update({"hbm_hits": 0, "hbm_misses": 0, "dma_up_bytes": 0,
                           "host_writes": 0, "redo_bytes": 0})

    @classmethod
    def from_spec(cls, spec: EngineSpec, kvspec: KVSpec,
                  clock: SimClock) -> "PagedKVCache":
        return cls(kvspec, clock, hbm_budget_bytes=spec.kv_hbm_bytes,
                   async_tiering=spec.async_tiering,
                   transfer_max_retries=spec.transfer_max_retries,
                   transfer_backoff_s=spec.transfer_backoff_s)

    # ------------------------------------------------------ device page pool
    def supports_pool(self) -> bool:
        return True

    @property
    def pooled(self) -> bool:
        return self._pooled

    def init_pool(self, dtype=None, pages: Optional[int] = None) -> None:
        import jax.numpy as jnp
        if self._pooled:
            raise RuntimeError("init_pool() called twice")
        if self.seq_len or self.pool or self._preempted:
            raise RuntimeError("init_pool() must run before any append")
        spec = self.spec
        desc = spec.descriptor()
        if dtype is not None:
            desc = desc.with_kv_dtype(dtype)
        if desc.page_tokens != spec.page_tokens:
            raise ValueError(
                f"descriptor page_tokens={desc.page_tokens} disagrees with "
                f"KVSpec page_tokens={spec.page_tokens}")
        self.desc = desc
        self._plane_names = tuple(p.name for p in desc.paged_planes)
        self._state_only = not desc.has_pages
        kv_planes = [p for p in desc.paged_planes if p.kind == "kv"]
        self.pool_dtype = (kv_planes[0].np_dtype if kv_planes
                           else np.dtype(np.float32))
        # one physical page spans every layer and every plane (the block
        # table is shared by the whole stack), so a page group costs L
        # per-layer pages of HBM summed across the descriptor's planes
        self._group_bytes = desc.page_group_bytes
        self.dev_planes: dict = {}
        if desc.has_pages:
            self.pool_pages = (pages if pages is not None else
                               max(self.hbm_budget_bytes
                                   // self._group_bytes, 1))
            for p in desc.paged_planes:
                shape = ((spec.num_layers, self.pool_pages, spec.page_tokens)
                         + tuple(p.shape))
                self.dev_planes[p.name] = jnp.zeros(shape, p.np_dtype)
        else:
            # state-only layout (SSM): zero paged planes — per-seq state
            # rows ride alongside the (empty) page tables instead, spilled
            # and restored whole with the row
            self.pool_pages = 0
            self._state_capacity = max(
                self.hbm_budget_bytes // max(desc.seq_state_bytes, 1), 1)
        self.seq_state: dict[int, dict] = {}     # seq → plane → (L, *shape)
        self.free_pages: list[int] = list(range(self.pool_pages - 1, -1, -1))
        self.pool_lru = LRUList()                    # resident phys pages
        # refcounted page users: phys → {seq: logical}. A page may appear in
        # several sequences' block tables at once (prefix sharing); it is
        # freed only when its user dict empties AND no index pin remains.
        self.page_users: dict[int, dict[int, int]] = {}
        self.trie_refs: set[int] = set()             # index-pinned pages
        # spilled pages: (seq, logical) → {plane → (L, T, *shape)}
        self.host_pages: dict[tuple[int, int], dict] = {}
        self._pooled = True
        # async tiering (ISSUE 8): spills/faults drain through a background
        # pipeline; the hot/cold victim model runs in BOTH modes so spill
        # decisions (and therefore tokens) are identical sync vs async.
        # Lazy import: serving owns the pipeline, importing it at module
        # scope would cycle through the serving package (same rule as
        # _cow_page's batching import).
        from repro.serving.tiering import PageHeat, TransferPipeline
        if self.async_tiering:
            self._pipeline = TransferPipeline(
                self.clock, stats=self.stats, injector=self._injector,
                max_retries=self._xfer_retries,
                backoff_s=self._xfer_backoff)
        self._heat = PageHeat()
        self._alloc_seq = 0            # allocation counter (logical time)
        self._fault_mark: dict[int, int] = {}   # phys → _alloc_seq at fault
        self.stats.update({"pool_appends": 0, "pool_hits": 0,
                           "pool_faults": 0, "pool_page_spills": 0,
                           "pool_d2h_bytes": 0, "pool_h2d_bytes": 0})

    def pool_views(self):
        """Device pool planes in descriptor order — dense descriptors
        return the classic ``(pool_k, pool_v)`` pair."""
        if not self._pooled:
            return super().pool_views()      # the loud "no pool" error
        return tuple(self.dev_planes[n] for n in self._plane_names)

    def _token_group_bytes(self) -> int:
        """One pooled token across all layers and planes."""
        return self.desc.token_group_bytes

    def _page_planes_np(self, phys: int) -> dict:
        """Materialize device page ``phys`` as host arrays, one
        ``(L, T, *shape)`` per plane."""
        return {n: np.asarray(self.dev_planes[n][:, phys])
                for n in self._plane_names}

    def _count_plane_bytes(self, counter: str, page: dict) -> None:
        """Charge a page/blob's bytes to the per-plane traffic counters."""
        for name, arr in page.items():
            self.stats[f"{counter}_{name}"] += arr.nbytes

    def _touch_page(self, phys: int) -> None:
        """One page access: LRU recency + the hot/cold model's EMA."""
        self.pool_lru.touch(phys)
        self._heat.touch(phys)

    def _recently_faulted(self, phys: int) -> bool:
        """Was ``phys`` faulted within the last pool-size allocations?
        Such pages spill only as a last resort (ISSUE 8 thrash guard): a
        page that just paid an H2D round-trips straight back out otherwise.
        Allocation count, not wall time, so sync/async rank identically."""
        return (self._alloc_seq - self._fault_mark.get(phys, -self.pool_pages)
                <= self.pool_pages)

    def _spill_lru_page(self, pinned: set) -> int:
        """Evict one spillable resident page to the host tier (page-granular
        spill); returns the freed physical index.

        Refcount-aware (ISSUE 6): only a page with exactly ONE live user —
        and that user outside the pinned batch — can spill coherently;
        pages aliased by several sequences never spill (the scheduler
        preempts whole sequences instead). A single-user page the prefix
        index also pins is forgotten from the index first: the cache
        re-prefills on a future miss, no sequence loses data. A pin with NO
        index object behind it (raw ``pin_page`` use) is dropped instead of
        skipped — skipping made that page headroom the pressure surface
        promised but eviction could never deliver (ISSUE 8).

        Victim choice is no longer pure LRU (ISSUE 8): eligible candidates
        rank by ``(recently_faulted, hotness, LRU rank)`` — coldest page by
        the :class:`~repro.serving.tiering.PageHeat` re-reference model
        first, LRU order breaking ties, and just-faulted pages last so a
        multi-page fault burst cannot evict its own pages (thrash). Every
        page costs the same one-page H2D to miss on, so min re-reference
        probability IS min expected miss cost.

        Async mode submits the D2H to the background pipeline — the numpy
        copy below is the staging buffer, the link time drains beside the
        foreground, and only a reader of the host copy barriers on it."""
        best = None
        for rank, phys in enumerate(self.pool_lru.lru_order()):
            users = self.page_users.get(phys)
            if not users or len(users) > 1:
                continue               # index-only (reclaimed, not spilled)
                                       # or shared between live sequences
            (seq, logical), = users.items()
            if seq in pinned:
                continue
            # index-pinned single-user pages stay eligible: a live index
            # forgets them first, a stale pin (no index) just drops
            key = (self._recently_faulted(phys), self._heat.hotness(phys),
                   rank)
            if best is None or key < best[0]:
                best = (key, phys, seq, logical)
        if best is None:
            raise RuntimeError(
                "paged pool exhausted: every resident page is pinned, "
                "shared, or index-held — the HBM budget is too small for "
                "the running batch")
        _, phys, seq, logical = best
        if phys in self.trie_refs:
            if self._share_index is not None:
                self._share_index.forget_phys(phys)
            else:
                self.trie_refs.discard(phys)
        page = self._page_planes_np(phys)
        nbytes = sum(a.nbytes for a in page.values())
        self.host_pages[(seq, logical)] = page
        self.block_table[seq][logical] = -1
        self.page_users.pop(phys)
        self.pool_lru.remove(phys)
        if self._pipeline is not None and not self._pipeline.degraded:
            self._pipeline.submit(self._pipeline.D2H, ("d2h", seq, logical),
                                  HOST_LINK, "write", nbytes)
            self.stats["async_spills"] += 1
            self.stats["stall_ticks_saved"] += 1   # sync stalls right here
        else:
            # no pipeline, or terminal transfer faults flipped it to
            # degraded: synchronous tiering on the foreground clock
            self.clock.charge(HOST_LINK, "write", nbytes,
                              random_access=True)          # D2H page out
        self.stats["pool_page_spills"] += 1
        self.stats["pool_d2h_bytes"] += nbytes
        self._count_plane_bytes("pool_d2h_bytes", page)
        return phys

    def _alloc_page(self, pinned: set) -> int:
        self._alloc_seq += 1
        if self.free_pages:
            return self.free_pages.pop()
        # reclaim before spilling: an idle index-held page (no live user)
        # frees without any D2H traffic — dropping cached prefix KV is
        # cheaper than spilling a live sequence's page
        if self._share_index is not None:
            if self._share_index.reclaim_one() is not None:
                return self.free_pages.pop()
        else:
            # pins without an index object cannot reclaim through the index;
            # free an idle one directly so the headroom the pressure surface
            # counted actually exists at allocation time (ISSUE 8)
            idle = next((p for p in sorted(self.trie_refs)
                         if not self.page_users.get(p)), None)
            if idle is not None:
                self.trie_refs.discard(idle)
                self.page_users.pop(idle, None)
                if idle in self.pool_lru:
                    self.pool_lru.remove(idle)
                return idle
        return self._spill_lru_page(pinned)

    def _extend_table(self, seq: int, pinned: set) -> None:
        table = self.block_table.setdefault(seq, [])
        phys = self._alloc_page(pinned)
        self.page_users[phys] = {seq: len(table)}
        table.append(phys)
        self._heat.assign(phys)
        self._touch_page(phys)

    def _fault_page(self, seq: int, logical: int, pinned: set) -> None:
        import jax.numpy as jnp
        if self._injector is not None \
                and self._injector.page_lost(seq, logical):
            # the spilled host copy is gone (ISSUE 10): surface the loss
            # BEFORE any allocation side effect so there is nothing to
            # unwind — the scheduler sheds this row back to waiting and
            # re-prefills it (degradation, never token divergence)
            from repro.serving.faults import LostPageError
            if self._pipeline is not None:
                self._pipeline.cancel(("d2h", seq, logical), reclaim=True)
                self._pipeline.cancel(("h2d", seq, logical), reclaim=True)
            self.host_pages.pop((seq, logical), None)
            self.stats["host_pages_lost"] += 1
            raise LostPageError(seq, logical)
        phys = self._alloc_page(pinned)
        prefetched = False
        retried = False
        pipe = self._pipeline
        use_async = pipe is not None and not pipe.degraded
        if pipe is not None:
            # coherence: the H2D reads the host staging copy, so it chains
            # after the page's own D2H finish when that is still in flight
            d2h_key = ("d2h", seq, logical)
            after = pipe.finish_of(d2h_key) or 0.0
            h2d_key = ("h2d", seq, logical)
            prefetched = pipe.finish_of(h2d_key) is not None
            if use_async:
                pipe.cancel(d2h_key)      # the h2d chains after= instead
                if not prefetched:
                    pipe.submit(pipe.H2D, h2d_key, HOST_LINK,
                                "read", self._group_bytes, after=after)
                # drain barrier before the kernel may read this page — the
                # one foreground wait; a prefetched page usually finished
                if pipe.barrier(h2d_key) == 0.0:
                    self.stats["stall_ticks_saved"] += 1
                retried = pipe.took_retries(h2d_key)
            else:
                # degraded: the foreground reads the staging copy directly,
                # so it must wait out any straggler from before the flip
                pipe.barrier(d2h_key)
                pipe.barrier(h2d_key)
        page = self.host_pages.pop((seq, logical))   # plane → (L, T, *shape)
        nbytes = sum(a.nbytes for a in page.values())
        for name in self._plane_names:
            self.dev_planes[name] = self.dev_planes[name].at[:, phys].set(
                jnp.asarray(page[name], self.dev_planes[name].dtype))
        self.block_table[seq][logical] = phys
        self.page_users[phys] = {seq: logical}
        self._heat.assign(phys)
        self._touch_page(phys)
        self._fault_mark[phys] = self._alloc_seq
        if pipe is None or (not use_async and not prefetched):
            self.clock.charge(HOST_LINK, "read", nbytes,
                              random_access=True)        # H2D fault-in
        if prefetched:
            # the scheduler's lookahead had this page's transfer in flight:
            # the demand fault becomes a (mostly) free pickup
            self.stats["prefetch_hits"] += 1
        elif retried:
            # demand fault whose H2D needed ≥1 retry: counted apart so the
            # chaos conservation law stays exact —
            # prefetch_hits + pool_faults + retried_faults == sync faults
            self.stats["retried_faults"] += 1
        else:
            self.stats["pool_faults"] += 1
        self.stats["pool_h2d_bytes"] += nbytes
        self._count_plane_bytes("pool_h2d_bytes", page)

    def _ensure_seq_resident(self, seq: int, pinned: set) -> None:
        faulted = []
        for logical, phys in enumerate(self.block_table.get(seq, [])):
            if phys < 0:
                self._fault_page(seq, logical, pinned)
                faulted.append(self.block_table[seq][logical])
            else:
                self._touch_page(phys)
                self.stats["pool_hits"] += 1
        # recency fix (ISSUE 8): the logical-order walk touches the
        # sequence's later RESIDENT pages after its early faulted ones, so
        # after a multi-page fault burst the pages that just paid an H2D sat
        # coldest in the LRU — the next allocation's first victims (thrash).
        # Re-touch the burst at the end: the whole sequence was accessed at
        # once, so its pages share one recency class and the freshly faulted
        # ones must not rank behind it.
        for phys in faulted:
            self.pool_lru.touch(phys)

    def prepare_step(self, seqs: Sequence[int], n_tokens: Sequence[int],
                     max_pages: int):
        """Multi-token step preparation (fused mixed-batch ticks): every
        batch sequence's pages are pinned — a later allocation must never
        spill a page the kernel is about to read — and each sequence gets
        pages covering its whole chunk."""
        if self._pooled and self._state_only:
            raise RuntimeError(
                "state-only descriptor has no pages; drive steps through "
                "state_views()/commit_state()")
        pinned = set(seqs)
        T = self.spec.page_tokens
        for seq, n in zip(seqs, n_tokens):
            self._check_active(seq)
            self._ensure_seq_resident(seq, pinned)
            # the kernel is about to scatter this row's tokens: if the
            # boundary page is aliased by other sequences, give this writer
            # its own copy first (copy-on-write divergence)
            self._maybe_cow_boundary(seq, pinned)
            table = self.block_table.setdefault(seq, [])
            end = self.seq_len.get(seq, 0) + max(int(n), 1)
            for _ in range(-(-end // T) - len(table)):
                self._extend_table(seq, pinned)
        tbl = np.zeros((len(seqs), max_pages), np.int32)
        lens = np.zeros(len(seqs), np.int32)
        for i, seq in enumerate(seqs):
            row = self.block_table.get(seq, [])
            if len(row) > max_pages:
                raise ValueError(
                    f"sequence {seq} spans {len(row)} pages > max_pages="
                    f"{max_pages}")
            tbl[i, :len(row)] = row
            lens[i] = self.seq_len.get(seq, 0)
        return tbl, lens

    def commit_step(self, pool_k, pool_v, seqs: Sequence[int],
                    n_tokens: Sequence[int],
                    prepared: Optional[Sequence[int]] = None) -> None:
        """Dense ``(k, v)`` special case of :meth:`commit_step_planes`."""
        return self.commit_step_planes((pool_k, pool_v), seqs, n_tokens,
                                       prepared=prepared)

    def commit_step_planes(self, planes, seqs: Sequence[int],
                           n_tokens: Sequence[int],
                           prepared: Optional[Sequence[int]] = None) -> None:
        """Commit ``n_tokens[i]`` tokens per sequence, accepting updated
        pool planes in descriptor order. With speculative decode,
        ``n_tokens[i]`` may be SMALLER than the ``prepared[i]`` count
        :meth:`prepare_step` was sized for: the rejected tail's KV was
        physically scattered (the HBM write is charged for every prepared
        slot) but never becomes visible — ``seq_len`` advances by the
        accepted count only, pages allocated solely for the tail go back
        to the free list, and stale KV inside retained pages is masked by
        the kernels (slots at or past ``lengths``) until the next
        committed tokens overwrite it in place."""
        if len(planes) != len(self._plane_names):
            raise ValueError(
                f"expected {len(self._plane_names)} pool planes "
                f"{self._plane_names}, got {len(planes)}")
        for name, arr in zip(self._plane_names, planes):
            self.dev_planes[name] = arr
        per_tok = self._token_group_bytes()
        T = self.spec.page_tokens
        for i, (seq, n) in enumerate(zip(seqs, n_tokens)):
            n = int(n)
            prep = n if prepared is None else int(prepared[i])
            pos = self.seq_len.get(seq, 0)
            self.seq_len[seq] = pos + n
            # a prepared page can be spilled mid-tick by an out-of-batch
            # allocation once the prepare pin is released — its -1 marker
            # must never enter the LRU/heat maps
            for logical in range(pos // T, -(-(pos + n) // T)):
                phys = self.block_table[seq][logical]
                if phys >= 0:
                    self._touch_page(phys)
            self.clock.charge(HBM, "write", max(prep, n) * per_tok)
            self.stats["pool_appends"] += n
            if prep > n:
                self._rewind_step_pages(seq)

    def _rewind_step_pages(self, seq: int) -> None:
        """Speculative rollback: drop trailing block-table pages past the
        committed length. Such pages are this step's fresh allocations —
        sole-user, unpinned (``_extend_table`` never hands out a shared or
        index-held page) — so they return straight to the free list; the
        guard stops at anything that doesn't match that shape.

        A trailing page may have been SPILLED between prepare and commit
        (an out-of-batch allocation can evict a prepared page once the
        batch pin is gone): its host copy holds only rejected KV. Breaking
        there — the old behavior — leaked that stale staging copy forever
        AND stranded every rolled-back page behind it (ISSUE 8). The fix
        drops the dead copy (cancelling its in-flight transfers) and keeps
        rewinding. The D2H byte counters are NOT rewound: the spill moved
        real bytes, so ``pool_d2h_bytes == pool_page_spills × page_bytes``
        stays the monotone bytes-moved invariant either way."""
        T = self.spec.page_tokens
        keep = max(-(-self.seq_len.get(seq, 0) // T), 0)
        table = self.block_table.get(seq, [])
        while len(table) > keep:
            phys = table[-1]
            if phys < 0:
                table.pop()
                logical = len(table)
                self.host_pages.pop((seq, logical), None)
                if self._pipeline is not None:
                    # rolled-back pages' transfers never need to land:
                    # reclaim their unserved channel reservations
                    self._pipeline.cancel(("d2h", seq, logical),
                                          reclaim=True)
                    self._pipeline.cancel(("h2d", seq, logical),
                                          reclaim=True)
                continue
            users = self.page_users.get(phys, {})
            if phys in self.trie_refs or users.keys() - {seq}:
                break
            table.pop()
            users.pop(seq, None)
            if not users:
                self.page_users.pop(phys, None)
                self.pool_lru.remove(phys)
                self.free_pages.append(phys)

    def alloc_prefill(self, seq: int, n_tokens: int):
        pinned = {seq}
        self._check_active(seq)
        self._ensure_seq_resident(seq, pinned)
        if n_tokens > 0:
            self._maybe_cow_boundary(seq, pinned)
        table = self.block_table.setdefault(seq, [])
        end = self.seq_len.get(seq, 0) + n_tokens
        need = -(-end // self.spec.page_tokens) - len(table)
        for _ in range(max(need, 0)):
            self._extend_table(seq, pinned)
        return np.asarray(table, np.int32)

    def commit_prefill(self, pool_k, pool_v, seq: int,
                       n_tokens: int) -> None:
        """Dense ``(k, v)`` special case of :meth:`commit_prefill_planes`."""
        return self.commit_prefill_planes((pool_k, pool_v), seq, n_tokens)

    def commit_prefill_planes(self, planes, seq: int, n_tokens: int) -> None:
        if len(planes) != len(self._plane_names):
            raise ValueError(
                f"expected {len(self._plane_names)} pool planes "
                f"{self._plane_names}, got {len(planes)}")
        for name, arr in zip(self._plane_names, planes):
            self.dev_planes[name] = arr
        self.seq_len[seq] = self.seq_len.get(seq, 0) + n_tokens
        for phys in self.block_table.get(seq, []):
            if phys >= 0:
                self._touch_page(phys)
        self.clock.charge(HBM, "write", n_tokens * self._token_group_bytes())
        self.stats["pool_appends"] += n_tokens

    def _idle_index_pages(self) -> int:
        """Index-pinned pages with no live user that allocation can ACTUALLY
        free on demand — the pressure surface must only promise headroom
        eviction can deliver (ISSUE 8). With an index registered, an idle
        pin reclaims through ``reclaim_one`` only while its trie node is
        unreferenced, so the count caps at the index's own reclaimable
        total (an idle page whose node other sequences still hold is NOT
        headroom — the old uncapped count admitted work the allocator then
        crashed on). With no index object, idle pins free directly in
        ``_alloc_page``, so the raw count stands."""
        idle = sum(1 for p in self.trie_refs if not self.page_users.get(p))
        if idle == 0 or self._share_index is None:
            return idle
        cap = getattr(self._share_index, "reclaimable_pages", None)
        return idle if cap is None else min(idle, cap())

    def can_admit_tokens(self, n_tokens: int) -> bool:
        if not self._pooled:
            return True
        if self._state_only:
            # state rows are fixed-size: admission is a row-count check
            return len(self.seq_state) < self._state_capacity
        pages_needed = -(-n_tokens // self.spec.page_tokens)
        return (pages_needed + self._reserve_pages()
                <= len(self.free_pages) + self._idle_index_pages())

    def can_place_step(self, seqs: Sequence[int],
                       n_tokens: Sequence[int]) -> bool:
        """Conservative placement check for one fused step: every page the
        batch will hold afterwards (chunk growth + faulting back any
        spilled page of a batch sequence, plus a possible boundary COW per
        row) must be coverable by free pages plus pages spillable from
        sequences OUTSIDE the batch — because ``prepare_step`` pins the
        whole batch while allocating. Shared pages (several live users)
        never spill, so they don't count; idle index-held pages reclaim
        for free, so they do."""
        if not self._pooled or self._state_only:
            return True
        T = self.spec.page_tokens
        batch = set(seqs)
        needed = 0
        for seq, n in zip(seqs, n_tokens):
            table = self.block_table.get(seq, [])
            resident = sum(1 for p in table if p >= 0)
            target = -(-(self.seq_len.get(seq, 0) + max(int(n), 1)) // T)
            needed += max(target, len(table)) - resident
            pos = self.seq_len.get(seq, 0)
            if pos % T:
                logical = pos // T
                if logical < len(table) and \
                        len(self.page_users.get(table[logical], ())) > 1:
                    needed += 1        # boundary copy-on-write page
        spillable = sum(
            1 for phys, users in self.page_users.items()
            if len(users) == 1 and next(iter(users)) not in batch)
        return needed <= (len(self.free_pages) + self._idle_index_pages()
                          + spillable)

    def _reserve_pages(self) -> int:
        """Pages the next decode step will claim: one per active sequence
        whose next token starts a fresh page."""
        if self._pooled and self._state_only:
            return 0
        T = self.spec.page_tokens
        return sum(1 for seq, n in self.seq_len.items()
                   if seq not in self._preempted
                   and n >= T * len(self.block_table.get(seq, ())))

    # ------------------------------------------------- async tier transfers
    def prefetch(self, seqs: Sequence[int],
                 n_tokens: Optional[Sequence[int]] = None) -> int:
        """Schedule background H2D fault-ins for every spilled page of next
        tick's planned batch (ISSUE 8). Timing-only: the host staging copy
        stays where it is and no page is allocated — the later demand fault
        in ``_fault_page`` materializes the page and, finding the transfer
        already in flight, pays only the residual wait (usually zero). That
        keeps allocation state bit-identical to a synchronous run, which is
        what makes ``prefetch_hits + pool_faults == sync pool_faults`` an
        exact invariant rather than an approximation."""
        if not self._pooled or self._pipeline is None \
                or self._pipeline.degraded:
            return 0
        n = 0
        for seq in seqs:
            if seq in self._preempted:
                continue
            for logical, phys in enumerate(self.block_table.get(seq, ())):
                if phys >= 0:
                    continue
                key = ("h2d", seq, logical)
                if self._pipeline.finish_of(key) is not None:
                    continue           # already in flight from a prior tick
                after = self._pipeline.finish_of(("d2h", seq, logical)) or 0.0
                self._pipeline.submit(self._pipeline.H2D, key, HOST_LINK,
                                      "read", self._group_bytes, after=after)
                n += 1
        return n

    def flush_transfers(self) -> None:
        if self._pooled and self._pipeline is not None:
            self._pipeline.flush()

    # ------------------------------------------------- faults & recovery
    def set_fault_injector(self, injector) -> None:
        """Attach the serving tier's deterministic injector (ISSUE 10).
        Transfer fail/delay decisions live in the pipeline; the spilled
        host-page loss check lives in ``_fault_page``. Placement never
        consults the injector, so transfer faults stay timing-only."""
        self._injector = injector
        if self._pipeline is not None:
            self._pipeline.injector = injector

    def abort_step(self, seqs: Sequence[int]) -> None:
        """Roll back a prepared-but-uncommitted step (exception between
        ``prepare_step`` and ``commit_step``): ``seq_len`` never advanced,
        so rewinding each row to its committed length returns exactly this
        tick's fresh allocations to the free list — a poisoned tick leaks
        no pool pages. Pages that faulted back in during prepare hold
        committed KV and stay resident."""
        if not self._pooled or self._state_only:
            return
        for seq in seqs:
            if seq in self.block_table:
                self._rewind_step_pages(seq)

    def stall_transfers(self, direction: int, seconds: float) -> None:
        if self._pooled and self._pipeline is not None:
            self._pipeline.stall_channel(direction, seconds)

    # ------------------------------------------------------- prefix sharing
    def supports_sharing(self) -> bool:
        return self._pooled and not self._state_only

    def set_share_index(self, index) -> None:
        if not self._pooled:
            raise RuntimeError("prefix sharing requires pooled mode; call "
                               "init_pool() first")
        self._share_index = index

    def page_refs(self, phys: int) -> int:
        if not self._pooled:
            return 0
        return (len(self.page_users.get(phys, ()))
                + (1 if phys in self.trie_refs else 0))

    def adopt_pages(self, seq: int, pages: Sequence[int],
                    covered_tokens: int) -> None:
        """Splice-on-admit: alias ``seq``'s block table onto shared pool
        pages covering its first ``covered_tokens`` prompt tokens. Pure
        metadata — page refcounts go up, zero KV moves, zero compute."""
        if not self._pooled:
            raise RuntimeError("adopt_pages() requires pooled mode")
        self._check_active(seq)
        if self.block_table.get(seq) or self.seq_len.get(seq):
            raise RuntimeError(
                f"sequence {seq} already holds pages; prefix splice is "
                f"admission-only")
        if len(pages) != -(-covered_tokens // self.spec.page_tokens):
            raise ValueError(
                f"{len(pages)} pages cannot cover {covered_tokens} tokens "
                f"at {self.spec.page_tokens} tokens/page")
        table = self.block_table[seq] = []
        for logical, phys in enumerate(pages):
            users = self.page_users.setdefault(phys, {})
            if len(users) == 1:
                self.stats["shared_pages"] += 1   # gained a 2nd live user
            users[seq] = logical
            table.append(phys)
            self._touch_page(phys)
        self.seq_len[seq] = covered_tokens
        self.stats["prefix_hits"] += 1
        self.stats["prefix_tokens_reused"] += covered_tokens

    def pin_page(self, phys: int) -> None:
        if phys in self.trie_refs:
            return
        if self.page_users.get(phys):
            self.stats["shared_pages"] += 1       # index + live user(s)
        self.trie_refs.add(phys)

    def unpin_page(self, phys: int) -> None:
        self.trie_refs.discard(phys)
        if not self.page_users.get(phys):
            # the index was the last referent: free the page
            self.page_users.pop(phys, None)
            if phys in self.pool_lru:
                self.pool_lru.remove(phys)
                self.free_pages.append(phys)

    def _maybe_cow_boundary(self, seq: int, pinned: set) -> None:
        """Copy-on-write before a write lands mid-page: the next token slot
        of ``seq`` falls inside an existing page — if that page is aliased
        by OTHER live sequences, the writer gets a private copy first and
        readers keep the original. A page whose only other referent is the
        prefix index needs no copy: splicers trust only the first
        ``covered`` slots (the kernel masks beyond each row's length), and
        those slots are never rewritten with different values."""
        T = self.spec.page_tokens
        pos = self.seq_len.get(seq, 0)
        if pos % T == 0:
            return                     # next write starts a fresh page
        logical = pos // T
        table = self.block_table.get(seq, ())
        if logical >= len(table):
            return
        phys = table[logical]
        if phys < 0 or len(self.page_users.get(phys, ())) <= 1:
            return
        self._cow_page(seq, logical, pinned)

    def _cow_page(self, seq: int, logical: int, pinned: set) -> None:
        """Duplicate ``seq``'s view of a shared page into a fresh physical
        page (one on-device page copy) and retarget its block table; every
        other referent — sequences and the prefix index — keeps the
        original."""
        # lazy import: repro.serving.batching owns the device-pool helpers
        # and importing it at module scope would cycle through the serving
        # package
        from repro.serving.batching import copy_pool_page_planes
        phys = self.block_table[seq][logical]
        new = self._alloc_page(set(pinned) | {seq})
        copied = copy_pool_page_planes(
            tuple(self.dev_planes[n] for n in self._plane_names), phys, new)
        for name, arr in zip(self._plane_names, copied):
            self.dev_planes[name] = arr
        self.page_users[phys].pop(seq, None)
        self.page_users[new] = {seq: logical}
        self.block_table[seq][logical] = new
        self._heat.assign(new)
        self._touch_page(new)
        self.clock.charge(HBM, "read", self._group_bytes)
        self.clock.charge(HBM, "write", self._group_bytes)
        self.stats["cow_copies"] += 1
        if self._share_index is not None:
            self._share_index.on_cow(seq, phys)

    # ------------------------------------------------------ per-seq state rows
    # SSM configs pool ZERO paged planes: their cache is a fixed-size state
    # row per sequence (descriptor seq_planes) that rides alongside the
    # block tables — committed with the row each step, spilled/preempted/
    # restored whole, and rolled back by committing an earlier slot's state.
    def state_views(self, seqs: Sequence[int]):
        """Batched state rows for one step: one ``(L, B, *shape)`` array
        per seq plane in descriptor order. Sequences without committed
        state yet (fresh admissions) read zero-initialized rows."""
        import jax.numpy as jnp
        if not self._pooled or not self.desc.has_state:
            raise RuntimeError("state_views() requires a pooled engine with "
                               "a state-bearing descriptor")
        out = []
        for p in self.desc.seq_planes:
            zero = None
            rows = []
            for seq in seqs:
                arr = self.seq_state.get(seq, {}).get(p.name)
                if arr is None:
                    if zero is None:
                        zero = jnp.zeros(
                            (self.spec.num_layers,) + tuple(p.shape),
                            p.np_dtype)
                    arr = zero
                rows.append(arr)
            out.append(jnp.stack(rows, axis=1))
        return tuple(out)

    def commit_state(self, seqs: Sequence[int], n_tokens: Sequence[int],
                     states) -> None:
        """Commit one step's updated state rows. ``states``: one
        ``(L, B, *shape)`` per seq plane (descriptor order); row ``i``
        becomes ``seqs[i]``'s new state and ``seq_len`` advances by
        ``n_tokens[i]``. Rows with ``n_tokens[i] == 0`` (batch padding,
        fully-rejected speculative rows) commit NOTHING — their stored
        state is untouched, which is the state-row form of the paged
        rewind rule."""
        if not self._pooled or not self.desc.has_state:
            raise RuntimeError("commit_state() requires a pooled engine "
                               "with a state-bearing descriptor")
        live = 0
        for i, (seq, n) in enumerate(zip(seqs, n_tokens)):
            n = int(n)
            if n <= 0:
                continue
            self._check_active(seq)
            live += 1
            row = self.seq_state.setdefault(seq, {})
            for p, arr in zip(self.desc.seq_planes, states):
                row[p.name] = arr[:, i]
            self.seq_len[seq] = self.seq_len.get(seq, 0) + n
            self.stats["pool_appends"] += n
        self.clock.charge(HBM, "write", live * self.desc.seq_state_bytes)

    def _spill_state_planes(self, seq: int) -> dict:
        """Preemption blobs for a state-only sequence: the device state
        rows come down over the link (D2H), one array per seq plane."""
        blobs = {}
        for p in self.desc.seq_planes:
            arr = self.seq_state.get(seq, {}).get(p.name)
            if arr is None:
                arr = np.zeros((self.spec.num_layers,) + tuple(p.shape),
                               p.np_dtype)
            blobs[p.name] = np.asarray(arr)
        nbytes = sum(a.nbytes for a in blobs.values())
        self.clock.charge(HOST_LINK, "write", nbytes, random_access=False)
        self.stats["pool_d2h_bytes"] += nbytes
        self._count_plane_bytes("pool_d2h_bytes", blobs)
        return blobs

    def _restore_state_planes(self, seq: int, length: int,
                              blobs: dict) -> None:
        import jax.numpy as jnp
        self.seq_state[seq] = {n: jnp.asarray(a) for n, a in blobs.items()}
        nbytes = sum(a.nbytes for a in blobs.values())
        self.clock.charge(HOST_LINK, "read", nbytes, random_access=False)
        self.clock.charge(HBM, "write", nbytes)
        self.stats["pool_h2d_bytes"] += nbytes
        self._count_plane_bytes("pool_h2d_bytes", blobs)
        self.seq_len[seq] = length

    # --------------------------------------------- pooled preempt / restore
    def preempt(self, seq: int) -> None:
        """Pooled preemption spills PLANE blobs (one token-exact array per
        paged plane, or the state rows) rather than the host engines'
        dense ``(L, 2, T, K, D)`` blob — the layout leaves the pool the
        same way it lives in it."""
        if not self._pooled:
            return super().preempt(seq)
        self._check_active(seq)
        length = self.seq_len.get(seq, 0)
        blobs = (self._spill_state_planes(seq) if self._state_only
                 else self._spill_pooled_planes(seq))
        nbytes = sum(a.nbytes for a in blobs.values())
        # sequential drain of the whole sequence out of the host tier and
        # onto the disk tier (one streamed copy, no random faults)
        self.clock.charge(HOST_LINK, "read", nbytes, random_access=False)
        self.clock.charge(SSD, "write", nbytes, random_access=False)
        self._drop_seq(seq)
        self.seq_len.pop(seq, None)
        self._preempted[seq] = (length, blobs)
        self.stats["preempts"] += 1
        self.stats["preempt_out_bytes"] += nbytes

    def restore(self, seq: int) -> None:
        if not self._pooled:
            return super().restore(seq)
        item = self._preempted.pop(seq, None)
        if item is None:
            raise RuntimeError(f"sequence {seq} is not preempted")
        length, blobs = item
        nbytes = sum(a.nbytes for a in blobs.values())
        self.clock.charge(SSD, "read", nbytes, random_access=False)
        self.stats["restores"] += 1
        self.stats["restore_in_bytes"] += nbytes
        if self._state_only:
            self._restore_state_planes(seq, length, blobs)
        else:
            self._restore_pooled_planes(seq, length, blobs)

    def _restore_pooled_planes(self, seq: int, length: int,
                               blobs: dict) -> None:
        """Scatter a preempted sequence's plane blobs into fresh pool
        pages: disk → host (charged by :meth:`restore`) → device (PCIe
        upload + HBM write). Pages come from the same allocator as any
        append, so a tight pool may spill other sequences to make room."""
        import jax.numpy as jnp
        spec = self.spec
        pinned = {seq}
        table = self.block_table.setdefault(seq, [])
        npages = -(-length // spec.page_tokens)
        for _ in range(npages - len(table)):
            self._extend_table(seq, pinned)
        for logical in range(npages):
            lo = logical * spec.page_tokens
            hi = min(lo + spec.page_tokens, length)
            phys = table[logical]
            for name in self._plane_names:
                plane = self.dev_planes[name]
                chunk = jnp.asarray(blobs[name][:, lo:hi], plane.dtype)
                self.dev_planes[name] = \
                    plane.at[:, phys, :hi - lo].set(chunk)
            self._touch_page(phys)
        nbytes = sum(a.nbytes for a in blobs.values())
        self.clock.charge(HOST_LINK, "read", nbytes, random_access=False)
        self.clock.charge(HBM, "write", nbytes)
        self.stats["pool_h2d_bytes"] += nbytes
        self._count_plane_bytes("pool_h2d_bytes", blobs)
        self.stats["pool_appends"] += length
        self.seq_len[seq] = length

    # pooled data paths ------------------------------------------------------
    def _append_tokens_pooled(self, seq: int, toks: list[np.ndarray]) -> None:
        """Host-facing append in pooled mode (benchmarks and the sequential
        mirror): scatter into the device pool. Decode-shaped appends model
        device-born tokens (HBM write only). Dense ``(k, v)`` layouts only
        — other families' hosts-side callers have no dense token format."""
        import jax.numpy as jnp
        if self.desc.kernel != "dense":
            raise NotImplementedError(
                f"host-facing appends are dense-only; {self.desc.family!r} "
                f"pools are fed on device via commit_step_planes/"
                f"commit_prefill_planes")
        spec = self.spec
        pinned = {seq}
        self._ensure_seq_resident(seq, pinned)
        if toks:
            self._maybe_cow_boundary(seq, pinned)
        table = self.block_table.setdefault(seq, [])
        start = self.seq_len.get(seq, 0)
        end = start + len(toks)
        for _ in range(-(-end // spec.page_tokens) - len(table)):
            self._extend_table(seq, pinned)
        arr = np.stack(toks)                      # (n, L, 2, K, D)
        for logical in range(start // spec.page_tokens,
                             -(-end // spec.page_tokens)):
            lo = max(start, logical * spec.page_tokens)
            hi = min(end, (logical + 1) * spec.page_tokens)
            sl = slice(lo - logical * spec.page_tokens,
                       hi - logical * spec.page_tokens)
            chunk = arr[lo - start:hi - start]    # (m, L, 2, K, D)
            phys = table[logical]
            self.dev_planes["k"] = self.dev_planes["k"].at[:, phys, sl].set(
                jnp.asarray(chunk[:, :, 0].transpose(1, 0, 2, 3),
                            self.pool_dtype))
            self.dev_planes["v"] = self.dev_planes["v"].at[:, phys, sl].set(
                jnp.asarray(chunk[:, :, 1].transpose(1, 0, 2, 3),
                            self.pool_dtype))
            self._touch_page(phys)
        nbytes = len(toks) * self._token_group_bytes()
        self.clock.charge(HBM, "write", nbytes)
        self.stats["pool_appends"] += len(toks)
        self.seq_len[seq] = end

    def _read_pooled(self, seq: int, layer: int) -> np.ndarray:
        spec = self.spec
        if self.desc.kernel != "dense":
            raise NotImplementedError(
                f"host-facing reads are dense-only; {self.desc.family!r} "
                f"pools are consumed on device through pool_views()")
        self._ensure_seq_resident(seq, {seq})
        T = self.seq_len.get(seq, 0)
        out = np.zeros((2, T, spec.kv_heads, spec.head_dim), spec.dtype)
        dev_k, dev_v = self.dev_planes["k"], self.dev_planes["v"]
        for logical, phys in enumerate(self.block_table.get(seq, [])):
            lo = logical * spec.page_tokens
            hi = min(lo + spec.page_tokens, T)
            if lo >= T:
                break
            out[0, lo:hi] = np.asarray(
                dev_k[layer, phys, :hi - lo]).astype(spec.dtype)
            out[1, lo:hi] = np.asarray(
                dev_v[layer, phys, :hi - lo]).astype(spec.dtype)
            self._touch_page(phys)
            self.clock.charge(HBM, "read", (hi - lo) * spec.token_bytes)
        return out

    def _spill_pooled_planes(self, seq: int) -> dict:
        """Whole-sequence preemption blobs — one token-exact
        ``(L, T, *shape)`` array per paged plane — gathered page by page:
        resident pages pay a D2H transfer each, already-spilled pages are
        host-side copies (no device traffic)."""
        spec = self.spec
        T = self.seq_len.get(seq, 0)
        blobs = {p.name: np.zeros((spec.num_layers, T) + tuple(p.shape),
                                  p.np_dtype)
                 for p in self.desc.paged_planes}
        for logical, phys in enumerate(self.block_table.get(seq, [])):
            lo = logical * spec.page_tokens
            hi = min(lo + spec.page_tokens, T)
            if lo >= T:
                break
            if phys < 0:
                if self._pipeline is not None:
                    # coherence barrier: the staging copy may still be in
                    # flight to the host — never read an in-flight page
                    self._pipeline.barrier(("d2h", seq, logical))
                page = self.host_pages[(seq, logical)]
            else:
                page = self._page_planes_np(phys)
                nbytes = sum(a.nbytes for a in page.values())
                self.clock.charge(HOST_LINK, "write", nbytes,
                                  random_access=True)      # D2H page out
                self.stats["pool_d2h_bytes"] += nbytes
                self.stats["pool_page_spills"] += 1
                self._count_plane_bytes("pool_d2h_bytes", page)
            for name, arr in page.items():
                blobs[name][:, lo:hi] = arr[:, :hi - lo]
        return blobs

    def _drop_seq_pooled(self, seq: int) -> None:
        """Release ``seq``'s pages (and any state rows): shared pages only
        lose this sequence's refcount; a page returns to the free list
        when its last live user leaves AND the prefix index does not pin
        it."""
        self.seq_state.pop(seq, None)
        for logical, phys in enumerate(self.block_table.pop(seq, [])):
            if phys >= 0:
                users = self.page_users.get(phys, {})
                users.pop(seq, None)
                if not users:
                    self.page_users.pop(phys, None)
                    if phys not in self.trie_refs:
                        self.pool_lru.remove(phys)
                        self.free_pages.append(phys)
            else:
                self.host_pages.pop((seq, logical), None)
        if self._pipeline is not None:
            # a later sequence may reuse this id: its (dir, seq, logical)
            # keys must not inherit this sequence's in-flight transfers
            self._pipeline.cancel_seq(seq)
        if self._share_index is not None:
            self._share_index.on_seq_dropped(seq)

    def _ensure_resident(self, layer: int, phys: int) -> None:
        key = (layer, phys)
        if key in self.hbm_lru:
            self.stats["hbm_hits"] += 1
            self.hbm_lru.touch(key)
            return
        self.stats["hbm_misses"] += 1
        if len(self.hbm_lru) >= self.hbm_capacity:
            self.hbm_lru.pop_lru()                   # clean: host copy is truth
        # DMA whole page up — the paper's miss-copy cost
        self.clock.charge(HOST_LINK, "read", self.spec.page_bytes,
                          random_access=True)
        self.stats["dma_up_bytes"] += self.spec.page_bytes
        self.hbm_lru.touch(key)

    def _touch_resident(self, layer: int, phys: int) -> None:
        """Mark the page being appended to as HBM-resident. The token just
        came out of the device, so the page is in the working set by
        construction — no DMA and no hit/miss accounting (those are
        read-path stats)."""
        if len(self.hbm_lru) >= self.hbm_capacity and \
                (layer, phys) not in self.hbm_lru:
            self.hbm_lru.pop_lru()
        self.hbm_lru.touch((layer, phys))

    def _append_tokens(self, seq: int, toks: list[np.ndarray]) -> None:
        if self._pooled:
            return self._append_tokens_pooled(seq, toks)
        spec = self.spec
        for kv_token in toks:
            pos = self.seq_len.get(seq, 0)
            logical = pos // spec.page_tokens
            slot = pos % spec.page_tokens
            table = self.block_table.setdefault(seq, [])
            if logical >= len(table):
                table.append(self.next_phys)
                self.next_phys += 1
                for layer in range(spec.num_layers):
                    self.pool[(layer, table[logical])] = spec.empty_page()
            phys = table[logical]
            for layer in range(spec.num_layers):
                # redo-buffer write then page write: the paging design's 2×
                self.clock.charge(HOST_LINK, "write", spec.token_bytes,
                                  random_access=False)       # redo append
                self.stats["redo_bytes"] += spec.token_bytes
                self.clock.charge(HOST_LINK, "write", spec.token_bytes,
                                  random_access=True)        # into the page
                self.stats["host_writes"] += 1
                self.pool[(layer, phys)][:, slot] = kv_token[layer]
                self._touch_resident(layer, phys)
            self.seq_len[seq] = pos + 1

    def _read(self, seq: int, layer: int) -> np.ndarray:
        """Materialize (2, T, kv_heads, head_dim) for attention; pages are
        DMA'd to HBM on miss (block-table indirection)."""
        if self._pooled:
            return self._read_pooled(seq, layer)
        spec = self.spec
        T = self.seq_len.get(seq, 0)
        out = np.zeros((2, T, spec.kv_heads, spec.head_dim), spec.dtype)
        for logical, phys in enumerate(self.block_table.get(seq, [])):
            self._ensure_resident(layer, phys)
            lo = logical * spec.page_tokens
            hi = min(lo + spec.page_tokens, T)
            if lo >= T:
                break
            page = self.pool[(layer, phys)]
            out[:, lo:hi] = page[:, :hi - lo]
            self.clock.charge(HBM, "read", (hi - lo) * spec.token_bytes)
        return out

    def _spill(self, seq: int) -> np.ndarray:
        if self._pooled:
            raise RuntimeError(
                "pooled preemption goes through plane blobs, not the dense "
                "host spill hook")
        spec = self.spec
        T = self.seq_len.get(seq, 0)
        blob = np.zeros((spec.num_layers, 2, T, spec.kv_heads,
                         spec.head_dim), spec.dtype)
        for logical, phys in enumerate(self.block_table.get(seq, [])):
            lo = logical * spec.page_tokens
            hi = min(lo + spec.page_tokens, T)
            if lo >= T:
                break
            for layer in range(spec.num_layers):
                blob[layer, :, lo:hi] = self.pool[(layer, phys)][:, :hi - lo]
        return blob

    def _drop_seq(self, seq: int) -> None:
        if self._pooled:
            return self._drop_seq_pooled(seq)
        for phys in self.block_table.pop(seq, []):
            for layer in range(self.spec.num_layers):
                self.pool.pop((layer, phys), None)
                self.hbm_lru.remove((layer, phys))

    # -------------------------------------------------------------- pressure
    def hbm_used_bytes(self) -> int:
        if self._pooled:
            if self._state_only:
                return len(self.seq_state) * self.desc.seq_state_bytes
            return ((self.pool_pages - len(self.free_pages))
                    * self._group_bytes)
        return len(self.hbm_lru) * self.spec.page_bytes

    def hbm_limit_bytes(self) -> Optional[int]:
        if self._pooled:
            if self._state_only:
                return self._state_capacity * self.desc.seq_state_bytes
            return self.pool_pages * self._group_bytes
        return self.hbm_capacity * self.spec.page_bytes

    def pressure(self) -> float:
        if not self._pooled:
            return super().pressure()
        if self._state_only:
            return min(len(self.seq_state) / self._state_capacity, 1.0)
        # count the pages the NEXT decode step will claim, so the scheduler
        # preempts one tick before allocation would have to spill pages of
        # the running batch itself (page-granular early warning); pages held
        # only by the prefix index are reclaimable on demand, so they count
        # as headroom rather than load
        used = (self.pool_pages - len(self.free_pages)
                - self._idle_index_pages() + self._reserve_pages())
        return min(used / self.pool_pages, 1.0)

    def resident_bytes(self, seq: int) -> int:
        if self._pooled:
            if self._state_only:
                return (self.desc.seq_state_bytes
                        if seq in self.seq_state else 0)
            n = sum(1 for phys in self.block_table.get(seq, ()) if phys >= 0)
            return n * self._group_bytes
        n = sum(1 for phys in self.block_table.get(seq, ())
                for layer in range(self.spec.num_layers)
                if (layer, phys) in self.hbm_lru)
        return n * self.spec.page_bytes

    def victim_hint(self, candidates: Iterable[int]) -> Optional[int]:
        """Pooled mode answers at page granularity: preempt the candidate
        whose eviction actually FREES the most device pool pages — a page
        this sequence shares with other rows (or that the prefix index
        pins) stays resident after the preempt, so only sole-user unpinned
        pages count. Ties rank by the hot/cold model (ISSUE 8): prefer the
        candidate whose freeable pages carry the least re-reference mass
        (``PageHeat.hotness`` summed — evicting them forfeits the fewest
        expected future hits), then by LRU coldness. Host mode keeps the
        LRU fallback."""
        if not self._pooled or self._state_only:
            return None
        cands = list(candidates)
        if not cands:
            return None
        order = {phys: i for i, phys in enumerate(self.pool_lru.lru_order())}

        def key(seq):
            pages = [p for p in self.block_table.get(seq, ()) if p >= 0]
            freeable = [p for p in pages
                        if len(self.page_users.get(p, ())) == 1
                        and p not in self.trie_refs]
            heat = sum(self._heat.hotness(p) for p in freeable)
            coldest = min((order.get(p, len(order)) for p in pages),
                          default=len(order))
            return (-len(freeable), heat, coldest)
        return min(cands, key=key)


class _DrainingKV(_TieredKV):
    """Shared log/drain machinery for the log-structured designs.

    Appends go to a sequential host log (1× write) whose entries drain into
    compacted host pages through :class:`ShardedDrainer` — per-shard pending
    queues (``hash(seq) → shard``), each an independent FIFO server, so
    backlog on one shard never delays another. A per-sequence HBM hot
    window serves recent tokens; cold reads come from the compacted pages,
    patched from undrained log entries (the ``log_patch`` kernel's layout).
    """

    def __init__(self, spec: KVSpec, clock: SimClock, *,
                 hot_window_tokens: int, drain_batch: int, drain_shards: int,
                 hbm_budget_bytes: Optional[int] = None):
        super().__init__(spec, clock)
        self.hot_window = hot_window_tokens
        # the hot windows are the engine's HBM use: bound their TOTAL across
        # sequences to the budget (None = unbounded, the legacy behavior of
        # the direct constructors)
        per_token = spec.token_bytes * spec.num_layers
        self._hot_budget_tokens = (None if hbm_budget_bytes is None
                                   else max(hbm_budget_bytes // per_token, 1))
        self._hot_total = 0
        self._batch_depth = 0      # >0 inside append_many: advance once
        self.drain_batch = drain_batch
        self.drainer = ShardedDrainer(drain_shards)
        # per-shard pending log entries: (seq, pos, kv_token, finish)
        self.shard_log: list[deque] = [deque() for _ in range(drain_shards)]
        self._seq_pending: dict[int, int] = {}   # seq → undrained entries
        # compacted host pages, indexed per sequence so preempting one
        # sequence never scans the others: seq → (layer, logical) → page
        self.pages: dict[int, dict[tuple, np.ndarray]] = {}
        # per-sequence HBM hot window (most recent tokens, all layers)
        self.hot: dict[int, deque] = {}
        self.stats.update({"log_appends": 0, "patches": 0, "hot_hits": 0,
                           "host_reads": 0, "host_writes": 0, "drained": 0,
                           "stall_time": 0.0})

    def pending_for(self, seq: int) -> int:
        """Undrained log entries for ``seq`` (0 after a force-drain)."""
        return self._seq_pending.get(seq, 0)

    # ---------------------------------------------------------------- drain
    def _drain_service(self) -> float:
        b = self.spec.token_bytes * self.spec.num_layers
        return HOST_LINK.write_latency / self.drain_batch + b / HOST_LINK.write_bw

    def _apply(self, seq: int, pos: int, kv_token: np.ndarray) -> None:
        spec = self.spec
        logical, slot = divmod(pos, spec.page_tokens)
        seq_pages = self.pages.setdefault(seq, {})
        for layer in range(spec.num_layers):
            page = seq_pages.get((layer, logical))
            if page is None:
                page = spec.empty_page()
                seq_pages[(layer, logical)] = page
            page[:, slot] = kv_token[layer]

    def _advance(self, now: float) -> None:
        """Functionally apply every entry whose drain finished by ``now``."""
        for pending in self.shard_log:
            while pending and pending[0][3] <= now:
                seq, pos, kv_token, _ = pending.popleft()
                self._apply(seq, pos, kv_token)
                self._seq_pending[seq] -= 1
                if not self._seq_pending[seq]:
                    del self._seq_pending[seq]
                self.stats["drained"] += 1

    def _force_drain_seq(self, seq: int) -> None:
        """Stall until every pending entry of ``seq`` has drained. FIFO
        shard order means waiting for the sequence's newest entry drains
        everything it appended earlier too; other shards keep their own
        schedule."""
        if not self._seq_pending.get(seq, 0):
            return
        pending = self.shard_log[self.drainer.shard_of(seq)]
        finish = max(e[3] for e in pending if e[0] == seq)
        stall = max(0.0, finish - self.clock.now)
        if stall:
            self.stats["stall_time"] += stall
        self.clock.wait_until(finish)
        self._advance(self.clock.now)

    # --------------------------------------------------------------- append
    def _hot_push(self, seq: int, pos: int, kv_token: np.ndarray) -> None:
        hot = self.hot.setdefault(seq, deque())
        hot.append((pos, kv_token.copy()))
        self._hot_total += 1
        if len(hot) > self.hot_window:       # per-sequence recency window
            hot.popleft()
            self._hot_total -= 1
        while (self._hot_budget_tokens is not None
               and self._hot_total > self._hot_budget_tokens):
            # global HBM budget: shrink the largest window first (evicted
            # tokens stay readable through the cold pages/patch path)
            victim = max(self.hot.values(), key=len)
            victim.popleft()
            self._hot_total -= 1

    def _log_takes_page(self, seq: int, logical: int) -> None:
        """Hook: the log (re)gains responsibility for a page (kvhybrid's
        ownership bookkeeping)."""

    def _log_owns(self, seq: int, logical: int) -> bool:
        """Hook: may the log patch this page on read? Always true for the
        pure log design; kvhybrid answers false for page-side-owned pages
        (reads trust the page side once ownership transferred)."""
        return True

    def _append_log(self, seq: int, toks: list[np.ndarray]) -> None:
        spec = self.spec
        shard = self.drainer.shard_of(seq)
        pending = self.shard_log[shard]
        for kv_token in toks:
            pos = self.seq_len.get(seq, 0)
            nbytes = spec.token_bytes * spec.num_layers
            # one sequential log write — the logging design's 1× write
            self.clock.charge(HOST_LINK, "write", nbytes, random_access=False)
            self.stats["host_writes"] += 1
            finish = self.drainer.push(shard, self.clock.now,
                                       self._drain_service())
            pending.append((seq, pos, kv_token.copy(), finish))
            self._seq_pending[seq] = self._seq_pending.get(seq, 0) + 1
            self.stats["log_appends"] += 1
            self._log_takes_page(seq, pos // spec.page_tokens)
            self._hot_push(seq, pos, kv_token)
            self.seq_len[seq] = pos + 1

    def append_many(self, items: Sequence[tuple[int, np.ndarray]]) -> None:
        """Batched multi-sequence append with ONE drainer advance for the
        whole batch (per-append advances are suppressed while inside)."""
        self._batch_depth += 1
        try:
            for seq, kv_tokens in items:
                self.append(seq, kv_tokens)
        finally:
            self._batch_depth -= 1
        self._advance(self.clock.now)

    # ----------------------------------------------------------------- read
    def _observe_read(self, seq: int, hot_tokens: int, cold_tokens: int,
                      latency_s: float) -> None:
        """Hook: reuse + gather-latency feedback for the adaptive router
        (kvhybrid)."""

    def _read(self, seq: int, layer: int) -> np.ndarray:
        """(2, T, kv_heads, head_dim): hot window from HBM; cold history from
        compacted pages, patched from the log where the drainer hasn't
        caught up."""
        spec = self.spec
        t_read0 = self.clock.now
        self._advance(self.clock.now)
        T = self.seq_len.get(seq, 0)
        out = np.zeros((2, T, spec.kv_heads, spec.head_dim), spec.dtype)
        hot = self.hot.get(seq, ())
        hot_positions = set()
        for pos, kv_token in hot:
            out[:, pos] = kv_token[layer]
            hot_positions.add(pos)
        if hot_positions:
            self.stats["hot_hits"] += len(hot_positions)
            self.clock.charge(
                HBM, "read", len(hot_positions) * spec.token_bytes)
        cold_T = min(T, min(hot_positions) if hot_positions else T)
        npages = -(-cold_T // spec.page_tokens) if cold_T else 0
        seq_pages = self.pages.get(seq, {})
        for logical in range(npages):
            lo = logical * spec.page_tokens
            hi = min(lo + spec.page_tokens, cold_T)
            page = seq_pages.get((layer, logical))
            if page is not None:
                # only existing compacted pages cost host traffic; a still-
                # undrained page's tokens are charged by the patch loop below
                out[:, lo:hi] = page[:, :hi - lo]
                self.clock.charge(HOST_LINK, "read",
                                  (hi - lo) * spec.token_bytes,
                                  random_access=False)
                self.stats["host_reads"] += 1
        # patch undrained log entries overlapping the cold range — the
        # sequence's entries live only in its own shard (hash(seq) → shard),
        # so other shards' backlogs are never scanned
        pending = self.shard_log[self.drainer.shard_of(seq)]
        for seq_i, pos, kv_token, _ in pending:
            if (seq_i == seq and pos < cold_T and pos not in hot_positions
                    and self._log_owns(seq, pos // spec.page_tokens)):
                out[:, pos] = kv_token[layer]
                self.clock.charge(HOST_LINK, "read", spec.token_bytes,
                                  random_access=True)
                self.stats["patches"] += 1
        self._observe_read(seq, len(hot_positions), max(cold_T, 0),
                           self.clock.now - t_read0)
        return out

    def _spill(self, seq: int) -> np.ndarray:
        spec = self.spec
        T = self.seq_len.get(seq, 0)
        blob = np.zeros((spec.num_layers, 2, T, spec.kv_heads,
                         spec.head_dim), spec.dtype)
        # compacted pages first, then undrained log entries on top (FIFO) —
        # together they hold every appended token; the hot window is only a
        # cache of the same data
        for (layer, logical), page in self.pages.get(seq, {}).items():
            lo = logical * spec.page_tokens
            hi = min(lo + spec.page_tokens, T)
            if lo < T:
                blob[layer, :, lo:hi] = page[:, :hi - lo]
        for seq_i, pos, kv_token, _ in self.shard_log[
                self.drainer.shard_of(seq)]:
            if seq_i == seq:
                blob[:, :, pos] = kv_token
        return blob

    def _drop_seq(self, seq: int) -> None:
        self._hot_total -= len(self.hot.pop(seq, ()))
        self.pages.pop(seq, None)
        if self._seq_pending.pop(seq, None):
            shard = self.drainer.shard_of(seq)
            self.shard_log[shard] = deque(
                e for e in self.shard_log[shard] if e[0] != seq)

    # -------------------------------------------------------------- pressure
    def hbm_used_bytes(self) -> int:
        return self._hot_total * self.spec.token_bytes * self.spec.num_layers

    def hbm_limit_bytes(self) -> Optional[int]:
        if self._hot_budget_tokens is None:
            return None
        return (self._hot_budget_tokens * self.spec.token_bytes
                * self.spec.num_layers)

    def resident_bytes(self, seq: int) -> int:
        return (len(self.hot.get(seq, ())) * self.spec.token_bytes
                * self.spec.num_layers)


@register_kv_engine("log")
class LogKVCache(_DrainingKV):
    """NVLog design: sequential host log + HBM hot window + drain/compact."""

    def __init__(self, spec: KVSpec, clock: SimClock, *,
                 hot_window_tokens: int = 256, drain_batch: int = 32,
                 drain_shards: int = 1,
                 hbm_budget_bytes: Optional[int] = None):
        super().__init__(spec, clock, hot_window_tokens=hot_window_tokens,
                         drain_batch=drain_batch, drain_shards=drain_shards,
                         hbm_budget_bytes=hbm_budget_bytes)

    @classmethod
    def from_spec(cls, spec: EngineSpec, kvspec: KVSpec,
                  clock: SimClock) -> "LogKVCache":
        return cls(kvspec, clock, hot_window_tokens=spec.kv_hot_window,
                   drain_batch=spec.drain_batch,
                   drain_shards=spec.drain_shards,
                   hbm_budget_bytes=spec.kv_hbm_bytes)

    def _append_tokens(self, seq: int, toks: list[np.ndarray]) -> None:
        self._append_log(seq, toks)
        if not self._batch_depth:
            self._advance(self.clock.now)


class AdaptiveRouter:
    """Online log-vs-pages routing policy for :class:`HybridKVCache`.

    Keeps a log2 histogram of observed append sizes plus hot/cold read
    counters and a gather-latency EMA, and re-learns the byte threshold
    every ``update_every`` appends (appends below the threshold route to
    the log hot-window path, the rest to pages):

    * **bimodal** sizes (decode tokens vs prefill bursts): the threshold
      sits in the widest histogram valley, nudged toward the log side when
      reads are cold-heavy (pages gather long histories cheaper) and toward
      the page side when the hot window serves most reads;
    * **unimodal small** (< page granularity): everything logs — the
      threshold parks at 4× the mode, capped at one page (the paper's
      conclusion: logging wins writes below page granularity);
    * **unimodal large** (≥ one page): everything pages — full-page appends
      pay no redo write and gathers skip patching.

    **Latency feedback:** counts say where reads land; ``latency_s`` says
    what they cost. The router keeps an EMA of observed per-token gather
    latency and compares it to ``page_per_token_s`` — the modeled cost of
    serving the same token from a compacted page. When gathers run hot
    (patch-dominated reads behind a backlogged drainer), the bias shifts
    toward pages regardless of what the counts alone would say; when
    gathers are cheap the log keeps its sub-page wins.

    Per-sequence hot/cold counters (``seq_reuse``) feed
    :meth:`HybridKVCache.victim_hint`: under HBM pressure the scheduler
    preempts the sequence whose reads reuse the hot window least.
    """

    #: observed-vs-modeled gather cost ratio above which gathers count as
    #: slow (bias toward pages) / below which as cheap (keep the log)
    SLOW_GATHER_RATIO = 2.0
    FAST_GATHER_RATIO = 1.2

    def __init__(self, threshold_bytes: int, page_bytes: int, *,
                 update_every: int = 16,
                 page_per_token_s: Optional[float] = None):
        self.threshold = max(int(threshold_bytes), 1)
        self.page_bytes = page_bytes
        self.update_every = update_every
        self.page_per_token_s = page_per_token_s
        self.hist: dict[int, int] = {}    # log2 bucket → append count
        self.hot_reads = 0
        self.cold_reads = 0
        self.gather_lat_s: Optional[float] = None   # per-token EMA
        self.seq_reuse: dict[int, list[int]] = {}   # seq → [hot, cold]
        self._n = 0

    def observe_read(self, seq: int, hot_tokens: int, cold_tokens: int,
                     latency_s: float = 0.0) -> None:
        self.hot_reads += hot_tokens
        self.cold_reads += cold_tokens
        reuse = self.seq_reuse.setdefault(seq, [0, 0])
        reuse[0] += hot_tokens
        reuse[1] += cold_tokens
        tokens = hot_tokens + cold_tokens
        if tokens and latency_s > 0.0:
            per_tok = latency_s / tokens
            self.gather_lat_s = (per_tok if self.gather_lat_s is None
                                 else 0.8 * self.gather_lat_s + 0.2 * per_tok)

    def reuse_score(self, seq: int) -> Optional[float]:
        """Hot-window share of this sequence's observed reads (None = never
        read). Low score = cold sequence = cheap preemption victim."""
        reuse = self.seq_reuse.get(seq)
        if reuse is None or (reuse[0] + reuse[1]) == 0:
            return None
        return reuse[0] / (reuse[0] + reuse[1])

    def forget_seq(self, seq: int) -> None:
        """Drop per-sequence reuse state (finished request)."""
        self.seq_reuse.pop(seq, None)

    def _latency_bias(self) -> float:
        """Extra threshold bias from *observed* gather latency: slow gathers
        (≫ the modeled page-read cost) push appends toward pages, cheap
        ones keep the log attractive."""
        if self.gather_lat_s is None or not self.page_per_token_s:
            return 0.0
        ratio = self.gather_lat_s / self.page_per_token_s
        if ratio > self.SLOW_GATHER_RATIO:
            return -1.0                     # gathers hurt → favor pages
        if ratio < self.FAST_GATHER_RATIO:
            return 0.25                     # gathers cheap → keep logging
        return 0.0

    def route(self, nbytes: int) -> str:
        """Record one append of ``nbytes`` and return ``"log"``/``"pages"``."""
        self.hist[nbytes.bit_length()] = \
            self.hist.get(nbytes.bit_length(), 0) + 1
        self._n += 1
        if self._n % self.update_every == 0:
            self._relearn()
        return "log" if nbytes < self.threshold else "pages"

    def _relearn(self) -> None:
        buckets = sorted(self.hist)
        total = sum(self.hist.values())
        # drop noise buckets (<2% of mass) so a stray append can't masquerade
        # as a mode
        buckets = [b for b in buckets
                   if self.hist[b] >= max(total * 0.02, 1)] or buckets
        gap_mid, gap_w = None, 1
        for lo, hi in zip(buckets, buckets[1:]):
            if hi - lo > gap_w:
                gap_w, gap_mid = hi - lo, (lo + hi) / 2
        if gap_mid is not None:
            # bimodal: split at the valley, biased by observed reuse and by
            # the measured gather-latency-vs-page-cost ratio
            reads = self.hot_reads + self.cold_reads
            bias = 0.0
            if reads:
                if self.cold_reads > 0.75 * reads:
                    bias = -0.5        # cold-heavy reuse → favor pages
                elif self.hot_reads > 0.75 * reads:
                    bias = 0.5         # hot-window reuse → favor the log
            bias = max(-1.5, min(1.5, bias + self._latency_bias()))
            self.threshold = int(2 ** (gap_mid + bias))
            return
        mode = max(buckets, key=lambda b: self.hist[b])
        mode_size = 1 << max(mode - 1, 0)
        if mode_size >= self.page_bytes:
            self.threshold = self.page_bytes       # page-sized: route pages
        else:
            self.threshold = min(4 * mode_size, self.page_bytes)


@register_kv_engine("kvhybrid")
class HybridKVCache(_DrainingKV):
    """The combined design: adaptive log/pages routing + sharded drainers.

    Small appends take the log path (1× sequential host write, HBM hot
    window, per-shard background drain into host pages); large appends write
    host pages directly (no redo write for fully covered pages). Coherence
    follows the FS ``nvhybrid`` ownership rule: before the page side takes
    ownership of a sequence's pages, that sequence's drain shard is
    force-drained — log entries always reach the pages before page-side
    writes land on top (log-before-pages ordering).
    """

    def __init__(self, spec: KVSpec, clock: SimClock, *,
                 hbm_budget_bytes: int, hot_window_tokens: int = 256,
                 drain_batch: int = 32, drain_shards: int = 1,
                 threshold_bytes: int = 2048):
        super().__init__(spec, clock, hot_window_tokens=hot_window_tokens,
                         drain_batch=drain_batch, drain_shards=drain_shards,
                         hbm_budget_bytes=hbm_budget_bytes)
        # pages whose pending state the page side owns: seq → {logical}
        self.page_owned: dict[int, set[int]] = {}
        # modeled cost of serving one token from a compacted page — the
        # reference the router's gather-latency feedback compares against
        page_per_token = (HOST_LINK.read_latency / spec.page_tokens
                          + spec.token_bytes / HOST_LINK.read_bw)
        self.router = AdaptiveRouter(threshold_bytes, spec.page_bytes,
                                     page_per_token_s=page_per_token)
        self.stats.update({"routed_log": 0, "routed_pages": 0,
                           "page_appends": 0, "force_drains": 0,
                           "redo_bytes": 0})

    @classmethod
    def from_spec(cls, spec: EngineSpec, kvspec: KVSpec,
                  clock: SimClock) -> "HybridKVCache":
        return cls(kvspec, clock, hbm_budget_bytes=spec.kv_hbm_bytes,
                   hot_window_tokens=spec.kv_hot_window,
                   drain_batch=spec.drain_batch,
                   drain_shards=spec.drain_shards,
                   threshold_bytes=spec.hybrid_threshold)

    @property
    def threshold(self) -> int:
        """Current learned routing threshold in bytes (a gauge, not a
        counter — deliberately not part of ``stats``)."""
        return self.router.threshold

    def _log_takes_page(self, seq: int, logical: int) -> None:
        # the log side owns this page again (reads patch from the log)
        owned = self.page_owned.get(seq)
        if owned:
            owned.discard(logical)

    def _log_owns(self, seq: int, logical: int) -> bool:
        # ownership is what reads trust: once the page side took a page
        # (after the force-drain), the log never patches it again
        return logical not in self.page_owned.get(seq, ())

    def _observe_read(self, seq: int, hot_tokens: int, cold_tokens: int,
                      latency_s: float) -> None:
        self.router.observe_read(seq, hot_tokens, cold_tokens, latency_s)

    def victim_hint(self, candidates: Iterable[int]) -> Optional[int]:
        """Preemption victim from the router's per-sequence reuse histogram:
        the candidate whose reads reuse the hot window least (its history is
        served from pages/disk anyway), ties broken toward the largest HBM
        footprint. ``None`` when no candidate has been read yet — the
        scheduler then falls back to LRU."""
        scored = [(self.router.reuse_score(seq), seq) for seq in candidates]
        if all(score is None for score, _ in scored):
            return None
        # unread sequences score neutral: known-cold beats unknown
        return min(scored, key=lambda sv: (
            0.5 if sv[0] is None else sv[0],
            -self.resident_bytes(sv[1])))[1]

    def _append_pages(self, seq: int, toks: list[np.ndarray]) -> None:
        spec = self.spec
        start = self.seq_len.get(seq, 0)
        end = start + len(toks)
        # ownership handover: this sequence's log entries must reach the
        # pages before the page side writes on top of them
        self._force_drain_seq(seq)
        for i, kv_token in enumerate(toks):
            pos = start + i
            logical = pos // spec.page_tokens
            page_lo = logical * spec.page_tokens
            page_hi = page_lo + spec.page_tokens
            full_page = start <= page_lo and page_hi <= end
            nbytes = spec.token_bytes * spec.num_layers
            if full_page:
                # fully covered page: one sequential write, no redo
                self.clock.charge(HOST_LINK, "write", nbytes,
                                  random_access=False)
            else:
                # partial page: redo append + in-place page write (the
                # paging design's 2× for sub-page writes)
                self.clock.charge(HOST_LINK, "write", nbytes,
                                  random_access=False)
                self.clock.charge(HOST_LINK, "write", nbytes,
                                  random_access=True)
                self.stats["redo_bytes"] += nbytes
            self.stats["host_writes"] += 1
            self._apply(seq, pos, kv_token)
            self.page_owned.setdefault(seq, set()).add(logical)
            self.stats["page_appends"] += 1
            self._hot_push(seq, pos, kv_token)
            self.seq_len[seq] = pos + 1

    def _force_drain_seq(self, seq: int) -> None:
        if self.pending_for(seq):
            super()._force_drain_seq(seq)
            self.stats["force_drains"] += 1

    def _append_tokens(self, seq: int, toks: list[np.ndarray]) -> None:
        nbytes = len(toks) * self.spec.token_bytes * self.spec.num_layers
        route = self.router.route(nbytes)
        if route == "log":
            self.stats["routed_log"] += 1
            self._append_log(seq, toks)
        else:
            self.stats["routed_pages"] += 1
            self._append_pages(seq, toks)
        if not self._batch_depth:
            self._advance(self.clock.now)

    def _drop_seq(self, seq: int) -> None:
        super()._drop_seq(seq)
        self.page_owned.pop(seq, None)

    def _on_release(self, seq: int) -> None:
        self.router.forget_seq(seq)
