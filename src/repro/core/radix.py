"""Token-sequence radix trie: the shared-prefix index (ISSUE 6).

Generalizes the seed's 4-level page-number radix tree — the paper's "radix
tree in volatile memory [that] looks for a volatile metadata structure that
contains a pointer to the non-volatile page" — into a token-keyed prefix
trie with longest-prefix match, insert-along-path, and per-node refcounts.
The serving tier's prefix cache hangs refcounted pool pages off value
nodes; NVPages keeps the original int-keyed API through :class:`RadixTree`,
a thin wrapper that maps a page number to its 4 radix bytes (same bound
check, same lookup/insert/delete/items semantics).

Invariants the prefix cache relies on:

* a *value node* marks the end of one page-sized token chunk (the last
  chunk of a prompt may be shorter than a page — a boundary leaf);
* ``match`` walks token by token and returns every value node it passes,
  shallowest first — the longest shared prefix is the deepest one;
* refcounts live on value nodes; because a sequence that acquires a deep
  node also acquires every ancestor value node on its path (prefix
  closure), ancestor refcounts always dominate descendants', so evicting
  refcount-0 value *leaves* (``subtree_values == 1``) can never strand a
  referenced descendant.
"""
from __future__ import annotations

from typing import Any, Iterator, Optional, Sequence


class TrieNode:
    __slots__ = ("token", "parent", "children", "value", "has_value",
                 "refs", "subtree_values")

    def __init__(self, token: Any = None,
                 parent: Optional["TrieNode"] = None):
        self.token = token
        self.parent = parent
        self.children: dict = {}
        self.value: Any = None
        self.has_value = False
        self.refs = 0                 # sequences currently aliasing this node
        self.subtree_values = 0       # value nodes in this subtree (incl self)


class TokenRadixTree:
    """Prefix trie over token sequences with per-node refcounts."""

    __slots__ = ("_root", "_values")

    def __init__(self):
        self._root = TrieNode()
        self._values = 0

    # ------------------------------------------------------------- walking
    def _walk(self, tokens: Sequence) -> Optional[TrieNode]:
        node = self._root
        for t in tokens:
            node = node.children.get(t)
            if node is None:
                return None
        return node

    def match(self, tokens: Sequence) -> list[TrieNode]:
        """Longest-prefix match: every value node on the deepest walkable
        path, shallowest first (each marks one fully covered chunk)."""
        node, out = self._root, []
        for t in tokens:
            node = node.children.get(t)
            if node is None:
                break
            if node.has_value:
                out.append(node)
        return out

    def lookup(self, tokens: Sequence) -> Optional[Any]:
        """Exact-key lookup (None when no value ends exactly here)."""
        node = self._walk(tokens)
        return node.value if node is not None and node.has_value else None

    def find(self, tokens: Sequence) -> Optional[TrieNode]:
        """The value node ending exactly at ``tokens`` (None otherwise)."""
        node = self._walk(tokens)
        return node if node is not None and node.has_value else None

    # ----------------------------------------------------------- mutation
    def insert(self, tokens: Sequence, value: Any) -> TrieNode:
        """Insert along the path, set ``value`` at the final node."""
        node = self._root
        for t in tokens:
            child = node.children.get(t)
            if child is None:
                child = TrieNode(t, node)
                node.children[t] = child
            node = child
        if not node.has_value:
            node.has_value = True
            self._values += 1
            p: Optional[TrieNode] = node
            while p is not None:
                p.subtree_values += 1
                p = p.parent
        node.value = value
        return node

    def remove(self, node: TrieNode) -> None:
        """Clear the value at ``node`` and prune any now-empty chain."""
        if not node.has_value:
            return
        node.has_value = False
        node.value = None
        self._values -= 1
        p: Optional[TrieNode] = node
        while p is not None:
            p.subtree_values -= 1
            p = p.parent
        while (node.parent is not None and not node.children
               and not node.has_value):
            parent = node.parent
            del parent.children[node.token]
            node = parent

    def delete(self, tokens: Sequence) -> None:
        node = self._walk(tokens)
        if node is not None:
            self.remove(node)

    # ---------------------------------------------------------- refcounts
    def acquire(self, node: TrieNode) -> None:
        node.refs += 1

    def release(self, node: TrieNode) -> None:
        if node.refs <= 0:
            raise RuntimeError("radix node refcount underflow")
        node.refs -= 1

    def evictable(self, node: TrieNode) -> bool:
        """A value leaf no live sequence references: safe to drop. Interior
        value nodes wait for their subtrees to empty (prefix closure)."""
        return node.has_value and node.refs == 0 and node.subtree_values == 1

    # -------------------------------------------------------------- views
    def __len__(self) -> int:
        return self._values

    def items(self) -> Iterator[tuple[tuple, Any]]:
        def walk(node: TrieNode, prefix: tuple):
            if node.has_value:
                yield prefix, node.value
            for t, child in node.children.items():
                yield from walk(child, prefix + (t,))
        yield from walk(self._root, ())


# --------------------------------------------------------------------------
# NVPages' original int-keyed page index, now a wrapper over the token trie
# --------------------------------------------------------------------------

_LEVELS = 4
_FANOUT = 256
_SHIFTS = [(8 * (_LEVELS - 1 - i)) for i in range(_LEVELS)]   # 24,16,8,0
_MAX_KEY = _FANOUT ** _LEVELS


class RadixTree:
    """4-level 256-ary radix tree: page number → metadata (NVPages)."""

    __slots__ = ("_trie",)

    def __init__(self):
        self._trie = TokenRadixTree()

    def _indices(self, key: int) -> list[int]:
        if not (0 <= key < _MAX_KEY):
            raise KeyError(f"key {key} out of radix range")
        return [(key >> s) & 0xFF for s in _SHIFTS]

    def lookup(self, key: int) -> Optional[Any]:
        return self._trie.lookup(self._indices(key))

    def insert(self, key: int, value: Any) -> None:
        self._trie.insert(self._indices(key), value)

    def delete(self, key: int) -> None:
        self._trie.delete(self._indices(key))

    def __len__(self) -> int:
        return self._trie._values

    def items(self) -> Iterator[tuple[int, Any]]:
        for bytes_, value in self._trie.items():
            key = 0
            for b, s in zip(bytes_, _SHIFTS):
                key |= b << s
            yield key, value
