"""4-level 256-ary radix tree: page number → metadata (NVPages' volatile index).

Mirrors the paper's "radix tree in volatile memory [that] looks for a volatile
metadata structure that contains a pointer to the non-volatile page".
"""
from __future__ import annotations

from typing import Any, Iterator, Optional

_LEVELS = 4
_FANOUT = 256
_SHIFTS = [(8 * (_LEVELS - 1 - i)) for i in range(_LEVELS)]   # 24,16,8,0
_MAX_KEY = _FANOUT ** _LEVELS


class RadixTree:
    __slots__ = ("_root", "_count")

    def __init__(self):
        self._root: list = [None] * _FANOUT
        self._count = 0

    def _indices(self, key: int):
        if not (0 <= key < _MAX_KEY):
            raise KeyError(f"key {key} out of radix range")
        return [(key >> s) & 0xFF for s in _SHIFTS]

    def lookup(self, key: int) -> Optional[Any]:
        node = self._root
        for ix in self._indices(key):
            node = node[ix]
            if node is None:
                return None
        return node

    def insert(self, key: int, value: Any) -> None:
        idx = self._indices(key)
        node = self._root
        for ix in idx[:-1]:
            nxt = node[ix]
            if nxt is None:
                nxt = [None] * _FANOUT
                node[ix] = nxt
            node = nxt
        if node[idx[-1]] is None:
            self._count += 1
        node[idx[-1]] = value

    def delete(self, key: int) -> None:
        idx = self._indices(key)
        node = self._root
        path = []
        for ix in idx[:-1]:
            nxt = node[ix]
            if nxt is None:
                return
            path.append((node, ix))
            node = nxt
        if node[idx[-1]] is not None:
            node[idx[-1]] = None
            self._count -= 1

    def __len__(self) -> int:
        return self._count

    def items(self) -> Iterator[tuple[int, Any]]:
        def walk(node, prefix, level):
            for ix, child in enumerate(node):
                if child is None:
                    continue
                key = prefix | (ix << _SHIFTS[level])
                if level == _LEVELS - 1:
                    yield key, child
                else:
                    yield from walk(child, key, level + 1)
        yield from walk(self._root, 0, 0)
