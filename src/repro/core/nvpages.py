"""NVPages: the paper's paging design (Fig. 1).

4 KiB pages live in NVMM; a volatile radix tree maps page number → frame
metadata; ``pwrite`` goes through a redo log in NVMM *then* into the NVMM
page (the 2× write the paper calls out); eviction is LRU; cache misses copy
the missing page into NVMM (the miss cost the paper calls out). Frame
headers (page_no, dirty) are kept in NVMM so crash recovery can flush every
pending modification to disk.

Beyond-paper option (the paper's own future-work §IV): ``shards > 1`` gives
independent redo logs + frame pools per page-number shard, the design the
authors argue makes paging multithread-friendly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core.clock import SimClock
from repro.core.disk import Disk, PAGE_SIZE, _ZERO_PAGE, iter_page_chunks
from repro.core.lru import LRUList
from repro.core.radix import RadixTree
from repro.core.wal import CircularWAL
from repro.roofline.hw import NVMM


@dataclass
class Frame:
    frame_id: int
    page_no: int
    dirty: bool


class _Shard:
    def __init__(self, frames: int, redo_bytes: int):
        self.index = RadixTree()
        self.lru = LRUList()
        self.redo = CircularWAL(redo_bytes)          # NVMM-resident
        self.pool: dict[int, bytearray] = {}         # frame_id → NVMM page
        self.headers: dict[int, tuple[int, bool]] = {}  # persistent (pno, dirty)
        self.free_frames = list(range(frames - 1, -1, -1))
        self.max_frames = frames


class NVPages:
    def __init__(self, nvmm_bytes: int, disk: Disk, clock: SimClock, *,
                 redo_log_bytes: Optional[int] = None, o_direct: bool = False,
                 shards: int = 1):
        self.disk = disk
        self.clock = clock
        self.o_direct = o_direct
        self.num_shards = shards
        if redo_log_bytes is None:
            # almost all NVMM goes to pages (paper §II Discussion); the redo
            # log only needs to cover in-flight writes
            redo_log_bytes = max(min(8 << 20, nvmm_bytes // 16), 16 << 10)
        frames_total = max((nvmm_bytes - shards * redo_log_bytes)
                           // PAGE_SIZE, shards)
        self.shards = [
            _Shard(frames_total // shards, redo_log_bytes)
            for _ in range(shards)]
        # counters for the paper's write-amplification analysis
        self.stats = {"hits": 0, "misses": 0, "evictions": 0,
                      "nvmm_page_writes": 0, "redo_writes": 0}

    # ------------------------------------------------------------------ util
    def _shard(self, pno: int) -> _Shard:
        return self.shards[pno % self.num_shards]

    def is_resident(self, pno: int) -> bool:
        """True if ``pno`` currently occupies an NVMM frame."""
        return self._shard(pno).index.lookup(pno) is not None

    def nvmm_capacity_bytes(self) -> int:
        """NVMM actually provisioned: frame pools + redo logs (may round
        below the requested budget)."""
        return sum(sh.max_frames * PAGE_SIZE + sh.redo.capacity
                   for sh in self.shards)

    def nvmm_used_bytes(self) -> int:
        """Live NVMM footprint: occupied frames + un-reclaimed redo bytes."""
        return sum(len(sh.pool) * PAGE_SIZE + sh.redo.used
                   for sh in self.shards)

    def _evict_one(self, sh: _Shard) -> None:
        victim = sh.lru.pop_lru()
        assert victim is not None, "evicting from empty LRU"
        frame: Frame = sh.index.lookup(victim)
        if frame.dirty:
            data = bytes(sh.pool[frame.frame_id])
            self.clock.charge(NVMM, "read", PAGE_SIZE)   # read page out of NVMM
            if self.o_direct:
                self.disk.write_page_direct(victim, data)
            else:
                # durable writeback keeping a clean LPC copy (no per-evict
                # fsync barrier — the page is persisted by the write itself)
                self.disk.write_page_through(victim, data)
        sh.index.delete(victim)
        sh.headers.pop(frame.frame_id, None)
        sh.pool.pop(frame.frame_id, None)
        sh.free_frames.append(frame.frame_id)
        self.stats["evictions"] += 1

    def _get_frame(self, pno: int, *, load: bool) -> Frame:
        """Return the frame for pno, faulting it in (copy to NVMM) on miss."""
        sh = self._shard(pno)
        frame: Optional[Frame] = sh.index.lookup(pno)
        if frame is not None:
            self.stats["hits"] += 1
            sh.lru.touch(pno)
            return frame
        self.stats["misses"] += 1
        if not sh.free_frames:
            self._evict_one(sh)
        fid = sh.free_frames.pop()
        if load:
            data = self.disk.read_page(pno, bypass_lpc=self.o_direct)
            # the miss cost the paper highlights: copy page into NVMM
            self.clock.charge(NVMM, "write", PAGE_SIZE)
            self.stats["nvmm_page_writes"] += 1
        else:
            data = _ZERO_PAGE   # full overwrite: no copy, the write follows
        sh.pool[fid] = bytearray(data)
        frame = Frame(fid, pno, dirty=False)
        sh.headers[fid] = (pno, False)
        sh.index.insert(pno, frame)
        sh.lru.touch(pno)
        return frame

    # ------------------------------------------------------------------- IO
    def pwrite(self, offset: int, data: bytes) -> int:
        """Durable as soon as this returns (redo record persisted)."""
        for pos, pno, in_page, n in iter_page_chunks(offset, len(data)):
            chunk = data[pos:pos + n]
            sh = self._shard(pno)
            # 1. redo log append (sequential NVMM write)
            rec_size = sh.redo.record_size(n)
            if rec_size > sh.redo.free:
                # redo entries are applied immediately below, so the log can
                # always be reclaimed wholesale
                sh.redo.reclaim_to(sh.redo.head, sh.redo.next_seqno)
            sh.redo.append(offset + pos, chunk)
            self.clock.charge(NVMM, "write", rec_size, random_access=False)
            self.stats["redo_writes"] += 1
            # 2. apply into the NVMM page (second write — the 2× the paper
            #    predicts for pure-write workloads)
            full_overwrite = (in_page == 0 and n == PAGE_SIZE)
            frame = self._get_frame(pno, load=not full_overwrite)
            sh.pool[frame.frame_id][in_page:in_page + n] = chunk
            self.clock.charge(NVMM, "write", n)
            self.stats["nvmm_page_writes"] += 1
            if not frame.dirty:
                frame.dirty = True
                sh.headers[frame.frame_id] = (pno, True)
            # 3. applied → reclaim the redo record
            sh.redo.reclaim_to(sh.redo.head, sh.redo.next_seqno)
        return len(data)

    def pread(self, offset: int, n: int) -> bytes:
        out = bytearray()
        for _, pno, in_page, take in iter_page_chunks(offset, n):
            sh = self._shard(pno)
            frame: Optional[Frame] = sh.index.lookup(pno)
            if frame is None:
                frame = self._get_frame(pno, load=True)
            else:
                self.stats["hits"] += 1
                sh.lru.touch(pno)
            # reads come from NVMM — the paper's fundamental flaw: NVMM read
            # bandwidth ≪ DRAM read bandwidth
            self.clock.charge(NVMM, "read", take)
            out += sh.pool[frame.frame_id][in_page:in_page + take]
        return bytes(out)

    def fsync(self) -> None:
        """No-op: pwrite is already durable at return (paper §III)."""

    # ------------------------------------------------------- crash / recovery
    def flush_all(self) -> None:
        for sh in self.shards:
            for pno, frame in list(sh.index.items()):
                if frame.dirty:
                    data = bytes(sh.pool[frame.frame_id])
                    self.clock.charge(NVMM, "read", PAGE_SIZE)
                    self.disk.write_page_lpc(pno, data)
                    frame.dirty = False
                    sh.headers[frame.frame_id] = (pno, False)
        self.disk.fsync()

    def crash(self) -> None:
        """Volatile state (radix index, LRU) is lost; NVMM pool/headers/redo
        and the disk survive."""
        for sh in self.shards:
            sh.index = RadixTree()
            sh.lru = LRUList()
        self.disk.crash()

    def remount(self) -> None:
        """Rebuild the volatile index/LRU/free-list from the persistent
        NVMM frame headers (the cheap half of recovery: no replay, no
        flush — what a clean image still needs after power loss)."""
        for sh in self.shards:
            sh.free_frames = list(
                set(range(sh.max_frames)) - set(sh.headers.keys()))
            for fid, (pno, dirty) in sh.headers.items():
                self.clock.charge(NVMM, "read", 16)     # header scan
                sh.index.insert(pno, Frame(fid, pno, dirty))
                sh.lru.touch(pno)

    def recover(self) -> None:
        """Rebuild the index from NVMM frame headers, replay redo-log
        remnants, then flush every pending modification to disk (paper §II)."""
        self.remount()
        for sh in self.shards:
            for _, rec in sh.redo.iter_from(sh.redo.tail):
                pno = rec.offset // PAGE_SIZE
                in_page = rec.offset % PAGE_SIZE
                frame = sh.index.lookup(pno)
                if frame is None:
                    frame = self._get_frame(pno, load=True)
                self.clock.charge(NVMM, "read", rec.size)
                self.clock.charge(NVMM, "write", len(rec.payload))
                sh.pool[frame.frame_id][in_page:in_page + len(rec.payload)] = \
                    rec.payload
                frame.dirty = True
                sh.headers[frame.frame_id] = (pno, True)
            sh.redo.reclaim_to(sh.redo.head, sh.redo.next_seqno)
        self.flush_all()
