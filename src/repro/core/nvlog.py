"""NVLog: the paper's logging design (Fig. 2), NVCache [DSN'21] as a library.

``pwrite`` appends one record to a sequential NVMM log (durable at return).
A background drainer continuously applies log entries to disk *through the
LPC in batches followed by fsync* (benefiting from LPC write merging, as the
paper describes). Reads are served from a small DRAM page cache; on miss the
base page comes from the LPC/disk and pending log entries are *patched* in.
A per-page pending map tracks which pages need patching so the NVMM log is
only searched when necessary (paper §II).

The drainer is simulated as an analytic FIFO queue (repro.core.clock): entry
finish-times determine foreground stalls (log full) and the crash cut-off
(which entries were durably applied at crash time).

Beyond-paper option: ``log_shards > 1`` (per-shard logs + drainers — the
sharded-log design the paper suggests would be needed for multithreading).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Optional

from repro.core.clock import ShardedDrainer, SimClock
from repro.core.disk import Disk, PAGE_SIZE, iter_page_chunks
from repro.core.lru import LRUList
from repro.core.wal import CircularWAL, HEADER_SIZE, LogRecord
from repro.roofline.hw import DRAM, NVMM, SSD, SSD_FSYNC_LATENCY


@dataclass
class _PendingEntry:
    logical: int         # record start in the WAL
    record: LogRecord
    finish_time: float   # drain durability point (simulated)


class _LogShard:
    def __init__(self, capacity: int, merge_window: int = 256):
        self.wal = CircularWAL(capacity)
        self.pending: deque[_PendingEntry] = deque()
        # sliding window of recently logged page numbers: models the LPC
        # merging writes to the same page within the drain backlog
        # (paper §II: "merging consecutive writes on the same offset")
        self.recent_pages: deque = deque(maxlen=merge_window)


class NVLog:
    def __init__(self, nvmm_bytes: int, disk: Disk, clock: SimClock, *,
                 dram_cache_bytes: int = 2 << 30, drain_batch: int = 64,
                 log_shards: int = 1):
        self.disk = disk
        self.clock = clock
        self.drain_batch = drain_batch
        shard_bytes = nvmm_bytes // log_shards
        # every shard must be able to hold at least two max-size records
        # (one draining + one arriving), or pwrite's stall-until-drained
        # loop can never make progress
        min_shard = 2 * (HEADER_SIZE + PAGE_SIZE)
        if shard_bytes < min_shard:
            raise ValueError(
                f"log_shards={log_shards} leaves {shard_bytes} bytes of WAL "
                f"per shard; each shard needs >= {min_shard} bytes — lower "
                f"drain_shards/shards or raise nvmm_bytes")
        self.num_shards = log_shards
        self.shards = [_LogShard(shard_bytes) for _ in range(log_shards)]
        # per-shard drainers: each WAL shard is an independent FIFO server
        self.drainer = ShardedDrainer(log_shards)
        # small DRAM page cache with up-to-date pages (paper: 2 GiB)
        self.dram_capacity = max(dram_cache_bytes // PAGE_SIZE, 1)
        self.dram: dict[int, bytearray] = {}
        self.dram_lru = LRUList()
        # pages with log entries not yet applied to disk → must patch on miss
        self.needs_patch: dict[int, list[_PendingEntry]] = {}
        self.stats = {"log_appends": 0, "dram_hits": 0, "dram_misses": 0,
                      "patches_applied": 0, "stall_time": 0.0}

    # --------------------------------------------------------------- drainer
    def _stall_until(self, t: float) -> None:
        """Foreground blocks on the drainer: account the stall, jump the
        clock, apply everything that finished by then."""
        stall = max(0.0, t - self.clock.now)
        if stall:
            self.stats["stall_time"] += stall
        self.clock.wait_until(t)
        self._advance_drainer(self.clock.now)

    def _drain_service_time(self, sh: "_LogShard", pno: int) -> float:
        """Per-entry drain cost: submit to LPC + amortized batched fsync.

        The SSD portion is scaled by the unique-page ratio of the drain
        window — the LPC merges same-page writes before writeback (paper
        §II), so hot (zipf) write streams cost less disk traffic."""
        sh.recent_pages.append(pno)
        uniq = len(set(sh.recent_pages)) / len(sh.recent_pages)
        lpc_write = DRAM.write_latency + PAGE_SIZE / DRAM.write_bw
        # batched writeback: the LPC submits whole fsync batches, so the SSD
        # sees deep-queue bursts (≈ sequential bandwidth, amortized latency)
        # — unlike NVPages' synchronous one-page random evictions. This is
        # the second asymmetry the logging design exploits (paper §II).
        ssd_write = uniq * (SSD.write_latency / self.drain_batch
                            + PAGE_SIZE / SSD.write_bw)
        return lpc_write + ssd_write + SSD_FSYNC_LATENCY / self.drain_batch

    def _apply_entry(self, entry: _PendingEntry) -> None:
        rec = entry.record
        pno = rec.offset // PAGE_SIZE
        self.disk.apply_silent(pno, rec.offset % PAGE_SIZE, rec.payload)
        lst = self.needs_patch.get(pno)
        if lst:
            try:
                lst.remove(entry)
            except ValueError:
                pass
            if not lst:
                del self.needs_patch[pno]

    def _advance_drainer(self, upto_time: float) -> None:
        """Functionally apply every entry whose drain finished by ``upto_time``."""
        for sh in self.shards:
            while sh.pending and sh.pending[0].finish_time <= upto_time:
                entry = sh.pending.popleft()
                self._apply_entry(entry)
                nxt = (sh.pending[0].record.seqno if sh.pending
                       else sh.wal.next_seqno)
                end = (sh.pending[0].logical if sh.pending else sh.wal.head)
                sh.wal.reclaim_to(end, nxt)

    # ------------------------------------------------------------ DRAM cache
    def _dram_put(self, pno: int, data: bytearray) -> None:
        if pno not in self.dram and len(self.dram) >= self.dram_capacity:
            victim = self.dram_lru.pop_lru()
            if victim is not None:
                self.dram.pop(victim, None)   # clean drop: log is truth
        self.dram[pno] = data
        self.dram_lru.touch(pno)

    # -------------------------------------------------------------------- IO
    def pwrite(self, offset: int, data: bytes) -> int:
        for pos, pno, in_page, n in iter_page_chunks(offset, len(data)):
            chunk = data[pos:pos + n]
            shard_idx = pno % self.num_shards
            sh = self.shards[shard_idx]
            rec_size = sh.wal.record_size(n)
            # stall if the log is full until the drainer frees space
            while sh.wal.free < rec_size:
                assert sh.pending, "log full but nothing to drain"
                self._stall_until(sh.pending[0].finish_time)
            logical = sh.wal.head
            rec = sh.wal.append(offset + pos, chunk)
            self.clock.charge(NVMM, "write", rec_size, random_access=False)
            self.stats["log_appends"] += 1
            finish = self.drainer.push(shard_idx, self.clock.now,
                                       self._drain_service_time(sh, pno))
            entry = _PendingEntry(logical, rec, finish)
            sh.pending.append(entry)
            self.needs_patch.setdefault(pno, []).append(entry)
            # keep fresh pages in DRAM (paper §III): update-if-present, and
            # write-allocate on *full-page* writes (no base page needed);
            # partial writes to absent pages ride on the patch tracking
            page = self.dram.get(pno)
            if page is not None:
                self.clock.charge(DRAM, "write", n)
                page[in_page:in_page + n] = chunk
                self.dram_lru.touch(pno)
            elif in_page == 0 and n == PAGE_SIZE:
                self.clock.charge(DRAM, "write", n)
                self._dram_put(pno, bytearray(chunk))
        self._advance_drainer(self.clock.now)
        return len(data)

    def _materialize_page(self, pno: int) -> bytearray:
        """Base page from LPC/disk + patches from the NVMM log."""
        base = bytearray(self.disk.read_page(pno))
        entries = self.needs_patch.get(pno)
        if entries:
            for entry in list(entries):
                rec = entry.record
                self.clock.charge(NVMM, "read", rec.size)
                base[rec.offset % PAGE_SIZE:
                     rec.offset % PAGE_SIZE + len(rec.payload)] = rec.payload
                self.stats["patches_applied"] += 1
        return base

    def pread(self, offset: int, n: int) -> bytes:
        self._advance_drainer(self.clock.now)
        out = bytearray()
        for _, pno, in_page, take in iter_page_chunks(offset, n):
            page = self.dram.get(pno)
            if page is not None:
                # the paper's headline advantage: reads at DRAM bandwidth
                self.clock.charge(DRAM, "read", take)
                self.dram_lru.touch(pno)
                self.stats["dram_hits"] += 1
            else:
                self.stats["dram_misses"] += 1
                page = self._materialize_page(pno)
                self.clock.charge(DRAM, "write", PAGE_SIZE)
                self._dram_put(pno, page)
            out += page[in_page:in_page + take]
        return bytes(out)

    def fsync(self) -> None:
        """No-op: pwrite is already durable at return (data is in the log)."""

    def nvmm_capacity_bytes(self) -> int:
        """NVMM actually provisioned: the shard WALs."""
        return sum(sh.wal.capacity for sh in self.shards)

    def nvmm_used_bytes(self) -> int:
        """Live NVMM footprint: un-reclaimed WAL bytes across shards."""
        return sum(sh.wal.used for sh in self.shards)

    # ------------------------------------------------- hybrid-engine hooks
    def has_pending(self, pno: int) -> bool:
        """True if the drainer still owes disk some entries for ``pno``."""
        return pno in self.needs_patch

    def force_drain_page(self, pno: int) -> None:
        """Stall until every pending entry for ``pno`` is applied to disk.

        FIFO drain order means waiting for the page's newest entry drains
        everything appended before it too — the ordering handover the
        hybrid engine relies on (log drains before the page side takes
        ownership of a page).
        """
        entries = self.needs_patch.get(pno)
        if not entries:
            return
        self._stall_until(entries[-1].finish_time)

    def invalidate(self, pno: int) -> None:
        """Drop the DRAM-cached copy of ``pno`` (another engine component
        took ownership of the page and will serve newer data)."""
        if self.dram.pop(pno, None) is not None:
            self.dram_lru.remove(pno)

    # -------------------------------------------------------- crash / recovery
    def drain_all(self) -> None:
        """Block until the drainer is idle (clean shutdown)."""
        for sh in self.shards:
            if sh.pending:
                self.clock.wait_until(sh.pending[-1].finish_time)
        self._advance_drainer(self.clock.now)

    def crash(self) -> None:
        """DRAM cache and LPC are lost. Entries whose drain had finished by
        now are on the SSD; the rest survive only in the NVMM log."""
        self._advance_drainer(self.clock.now)
        self.dram.clear()
        self.dram_lru = LRUList()
        self.needs_patch.clear()
        for sh in self.shards:
            sh.pending.clear()
        self.drainer.reset()
        self.disk.crash()

    def recover(self, *, barrier: bool = True) -> None:
        """Replay every record still in the NVMM log to disk (paper §II:
        'flushing to disk every modification still pending in cache').

        ``barrier=False`` skips the terminal fsync — for composition (the
        hybrid engine runs one shared barrier after its page flush instead
        of paying SSD_FSYNC_LATENCY once per component)."""
        for sh in self.shards:
            records = sh.wal.recover_scan()
            for rec in records:
                self.clock.charge(NVMM, "read", rec.size)
                pno = rec.offset // PAGE_SIZE
                self.disk.write_page_lpc(pno, bytes(
                    self._patched_base_for_recovery(pno, rec)))
            sh.wal.reclaim_to(sh.wal.head, sh.wal.next_seqno)
        if barrier:
            self.disk.fsync()

    def _patched_base_for_recovery(self, pno: int, rec: LogRecord) -> bytearray:
        base = bytearray(self.disk.read_page(pno))
        off = rec.offset % PAGE_SIZE
        base[off:off + len(rec.payload)] = rec.payload
        return base
