"""Engine protocol, config object, and string-keyed registry.

``CacheEngine`` is the formal contract every cache design implements:
byte-granular ``pwrite``/``pread`` (plus vectorized ``pwritev``/``preadv``),
durability (``fsync``, ``flush_all``), the paper's crash protocol
(``crash``/``recover``), a ``stats`` mapping, and NVMM capacity accounting.

``EngineSpec`` is the one config object every construction site uses —
facade, checkpoint manager, benchmarks, examples — instead of ad-hoc kwargs.

New designs register with ``@register_engine("name")`` and are constructed
via ``create_engine(spec, disk, clock)``; unknown names raise ``ValueError``.
"""
from __future__ import annotations

import abc
from dataclasses import dataclass
from typing import Optional, Sequence

from repro.core.clock import SimClock
from repro.core.disk import Disk


@dataclass(frozen=True)
class EngineSpec:
    """Everything needed to build a cache engine (paper's table of knobs).

    ``hybrid_*`` fields only matter for the nvhybrid engine but live here so
    one spec object can describe any engine.
    """
    engine: str = "nvlog"
    nvmm_bytes: int = 2 << 30
    dram_cache_bytes: int = 2 << 30
    shards: int = 1
    drain_batch: int = 64
    o_direct: bool = False
    lpc_capacity_pages: Optional[int] = None
    # nvhybrid routing: writes smaller than the threshold go to the journal
    # (for kvhybrid this is the *initial* threshold the online policy adapts)
    hybrid_threshold: int = 2048
    # nvhybrid NVMM split: fraction given to the journal, rest to pages
    hybrid_log_fraction: float = 0.25
    # per-shard drainer parallelism: independent FIFO drain servers for the
    # log side of nvhybrid and for the log/kvhybrid KV engines
    drain_shards: int = 1
    # KV-cache tier budgets (only the KV engine registry reads these; they
    # live here so serving configs and FS configs share one object)
    kv_hbm_bytes: int = 64 << 20
    kv_hot_window: int = 128
    # cross-request prefix cache (ISSUE 6): token capacity of the radix
    # index over shared pool pages; 0 disables sharing entirely (pooled
    # engines behave exactly as before)
    prefix_cache_tokens: int = 0
    # async tiering (ISSUE 8): pooled spills/faults go through a background
    # transfer pipeline (double-buffered D2H/H2D drain queues) instead of
    # stalling the foreground; False keeps every transfer synchronous
    async_tiering: bool = False
    # fault tolerance (ISSUE 10): retry budget and base backoff for failed
    # async transfer submissions; past the budget the pipeline escalates to
    # synchronous tiering (degradation ladder in engines/README.md)
    transfer_max_retries: int = 3
    transfer_backoff_s: float = 1e-4


class CacheEngine(abc.ABC):
    """Abstract base for all cache engines behind :class:`NVCacheFS`."""

    #: registry key, filled in by ``@register_engine``
    engine_name: str = "?"
    #: True if the engine persists data in NVMM (drives the mount-flag
    #: protocol: psync engines have nothing to recover)
    uses_nvmm: bool = True
    #: per-engine counters; the facade merges this into its ``stats()``
    stats: dict

    @classmethod
    @abc.abstractmethod
    def from_spec(cls, spec: EngineSpec, disk: Disk,
                  clock: SimClock) -> "CacheEngine":
        """Construct the engine from one config object."""

    # -------------------------------------------------------------------- IO
    @abc.abstractmethod
    def pwrite(self, offset: int, data: bytes) -> int:
        """Write ``data`` at byte ``offset``; returns bytes written."""

    @abc.abstractmethod
    def pread(self, offset: int, n: int) -> bytes:
        """Read ``n`` bytes at byte ``offset``."""

    def pwritev(self, iovecs: Sequence[tuple[int, bytes]]) -> int:
        """Vectorized write: ``[(offset, data), ...]`` → total bytes.

        The default loops; engines may override to amortize per-call work
        (drainer advance, batching) across the whole vector.
        """
        return sum(self.pwrite(off, data) for off, data in iovecs)

    def preadv(self, iovecs: Sequence[tuple[int, int]]) -> list[bytes]:
        """Vectorized read: ``[(offset, n), ...]`` → list of byte blobs."""
        return [self.pread(off, n) for off, n in iovecs]

    @abc.abstractmethod
    def fsync(self) -> None:
        """Make all acked writes durable (no-op for the NVMM designs)."""

    def fsync_range(self, offset: int, length: int) -> None:
        """Make acked writes in ``[offset, offset+length)`` durable (the
        facade's per-file close path). Defaults to a full :meth:`fsync`;
        engines with a cheaper scoped flush override it."""
        self.fsync()

    # --------------------------------------------------- lifecycle / recovery
    @abc.abstractmethod
    def flush_all(self) -> None:
        """Clean shutdown: drain/flush every pending modification to disk."""

    @abc.abstractmethod
    def crash(self) -> None:
        """Simulated power loss: drop volatile state; NVMM + SSD survive."""

    @abc.abstractmethod
    def recover(self) -> None:
        """Paper §II recovery: flush every modification pending at crash.
        Implies :meth:`remount`."""

    def remount(self) -> None:
        """Rebuild volatile metadata from NVMM after a crash of a *clean*
        image (mount flag 0: nothing pending to replay or flush). Engines
        whose volatile state rebuilds lazily keep this a no-op."""

    # -------------------------------------------------- capacity accounting
    def nvmm_capacity_bytes(self) -> int:
        """NVMM the engine actually provisioned (frames, logs, redo) — may
        round below the requested ``spec.nvmm_bytes``; LPC-only engines
        report 0."""
        return 0

    def nvmm_used_bytes(self) -> int:
        return 0


_REGISTRY: dict[str, type[CacheEngine]] = {}


def register_engine(name: str, *, override: bool = False):
    """Class decorator: make an engine constructible by name.

    Re-registering an existing name raises unless ``override=True`` — a
    silent replacement of a built-in would corrupt every registry-driven
    construction site while all names still look correct.
    """
    def deco(cls: type[CacheEngine]) -> type[CacheEngine]:
        if not override and name in _REGISTRY:
            raise ValueError(
                f"engine {name!r} is already registered "
                f"({_REGISTRY[name].__name__}); pass override=True to "
                f"replace it")
        cls.engine_name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_engine(name: str) -> type[CacheEngine]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown cache engine {name!r}; registered engines: "
            f"{', '.join(sorted(_REGISTRY))}") from None


def create_engine(spec: EngineSpec, disk: Disk,
                  clock: SimClock) -> CacheEngine:
    """Build the engine named by ``spec.engine`` over ``disk``/``clock``."""
    return get_engine(spec.engine).from_spec(spec, disk, clock)


def list_engines() -> tuple[str, ...]:
    return tuple(_REGISTRY)
