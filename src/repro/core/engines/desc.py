"""Per-family cache descriptors: ONE frozen spec of a model family's cache
layout that drives the pooled mirror-free serving path end to end.

The paper's core lesson is that a cache design must match the data layout
of its medium — NVPages pays off when whole pages live in the fast tier,
NVLog when small heterogeneous writes are journaled. The serving tier used
to hard-code one layout (dense fp16 ``(k, v)`` planes), so every other
family (MLA latent caches, int8 quantized KV with scale planes, Mamba-2
SSM state) fell back to the mirrored unfused path. A
:class:`CacheDescriptor` makes the layout data, not code:

* **paged planes** — per-token arrays that live in the device page pool as
  ``(L, P, page_tokens, *shape)``; each plane carries its own dtype (int8
  KV pages ride next to bf16 scale planes) and its name matches the
  model's prefill cache key (``k``/``v``/``k_scale``/``v_scale``/``c``/
  ``kr``).
* **seq planes** — per-sequence state rows (SSM ``conv``/``ssm`` states)
  that ride alongside the page tables: committed, spilled, preempted and
  restored with the row rather than with pages.

``PagedKVCache`` sizes, allocates, spills, faults and byte-accounts the
pool from the descriptor; ``serving/batching.py`` scatters/gathers planes
generically; the ragged kernels pick their entry via
:attr:`CacheDescriptor.kernel`; and the per-plane ``pool_d2h_bytes_*`` /
``pool_h2d_bytes_*`` stats keys every engine exposes come from the plane
list — so ``supports_*`` gates reduce to "does a descriptor exist".

Registering a new family is one entry in ``_FAMILY_BUILDERS``: a predicate
on the model config and a builder returning the plane lists (see the
engines README, "Cache descriptors").
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


#: the plane-name universe across every registered family — the uniform
#: key set behind the per-plane ``pool_d2h_bytes_<plane>`` /
#: ``pool_h2d_bytes_<plane>`` counters EVERY KV engine exposes (zeroed on
#: engines without a pool) so stats stay comparable across engines.
PLANE_STAT_NAMES: tuple = ("k", "v", "k_scale", "v_scale", "c", "kr",
                           "conv", "ssm")


@dataclass(frozen=True)
class PlaneSpec:
    """One named cache plane.

    For paged planes ``shape`` is the per-token trailing shape (a page is
    ``(page_tokens, *shape)`` per layer); for seq planes it is the whole
    per-layer per-sequence state shape. ``kind`` distinguishes quantized
    payload planes (``kv``), their ``scale`` planes, and per-seq
    ``state`` planes.
    """
    name: str
    shape: tuple
    dtype: str
    kind: str = "kv"

    @property
    def np_dtype(self) -> np.dtype:
        return np.dtype(self.dtype)

    @property
    def entry_bytes(self) -> int:
        """Bytes of one entry: per token (paged) or per seq-layer (state)."""
        return int(math.prod(self.shape)) * self.np_dtype.itemsize


@dataclass(frozen=True)
class CacheDescriptor:
    """Frozen layout spec for one model family's decode cache."""
    family: str                 # cache-layout family: dense | mla | int8 | ssm
    num_layers: int
    page_tokens: int
    paged_planes: tuple = ()
    seq_planes: tuple = ()
    kernel: str = "dense"       # ragged kernel entry: dense | int8 | mla | none

    # ------------------------------------------------------------- byte math
    @property
    def token_group_bytes(self) -> int:
        """Bytes one pooled token occupies across ALL layers and planes."""
        return self.num_layers * sum(p.entry_bytes for p in self.paged_planes)

    @property
    def page_group_bytes(self) -> int:
        """Bytes one page GROUP occupies: the unit every spill/fault moves
        and every ``pool_d2h_bytes``/``pool_h2d_bytes`` counter charges."""
        return self.token_group_bytes * self.page_tokens

    def plane_page_bytes(self, plane: PlaneSpec) -> int:
        """One plane's share of a page group (all layers)."""
        return self.num_layers * self.page_tokens * plane.entry_bytes

    @property
    def seq_state_bytes(self) -> int:
        """Bytes of one sequence's state rows across layers and planes."""
        return self.num_layers * sum(p.entry_bytes for p in self.seq_planes)

    @property
    def has_pages(self) -> bool:
        return bool(self.paged_planes)

    @property
    def has_state(self) -> bool:
        return bool(self.seq_planes)

    @property
    def plane_names(self) -> tuple:
        return tuple(p.name for p in self.paged_planes + self.seq_planes)

    def with_kv_dtype(self, dtype) -> "CacheDescriptor":
        """Descriptor with ``kind == 'kv'`` planes re-typed (the
        ``init_pool(dtype=...)`` override; scale/state planes keep theirs)."""
        dt = np.dtype(dtype).name
        planes = tuple(
            PlaneSpec(p.name, p.shape, dt, p.kind) if p.kind == "kv" else p
            for p in self.paged_planes)
        return CacheDescriptor(self.family, self.num_layers, self.page_tokens,
                               planes, self.seq_planes, self.kernel)


# ---------------------------------------------------------------------------
# Family registry: (name, predicate, builder) walked in order; first match
# wins. A builder returns (paged_planes, seq_planes, kernel) or None when
# the config cannot be pooled (the family stays on the mirrored path).
# ---------------------------------------------------------------------------
def _dense_planes(cfg, kv_cache_dtype, compute_dtype):
    dt = np.dtype(compute_dtype).name
    K, D = cfg.num_kv_heads, cfg.head_dim
    return ((PlaneSpec("k", (K, D), dt), PlaneSpec("v", (K, D), dt)),
            (), "dense")


def _int8_planes(cfg, kv_cache_dtype, compute_dtype):
    K, D = cfg.num_kv_heads, cfg.head_dim
    return ((PlaneSpec("k", (K, D), "int8"),
             PlaneSpec("v", (K, D), "int8"),
             PlaneSpec("k_scale", (K,), "bfloat16", kind="scale"),
             PlaneSpec("v_scale", (K,), "bfloat16", kind="scale")),
            (), "int8")


def _mla_planes(cfg, kv_cache_dtype, compute_dtype):
    dt = np.dtype(compute_dtype).name
    m = cfg.mla
    return ((PlaneSpec("c", (m.kv_lora_rank,), dt),
             PlaneSpec("kr", (m.qk_rope_head_dim,), dt)),
            (), "mla")


def _ssm_planes(cfg, kv_cache_dtype, compute_dtype):
    dt = np.dtype(compute_dtype).name
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.ngroups * s.d_state
    return ((),
            (PlaneSpec("conv", (s.d_conv - 1, conv_dim), dt, kind="state"),
             PlaneSpec("ssm", (nheads, s.head_dim, s.d_state), "float32",
                       kind="state")),
            "none")


def _is_attn(cfg):
    return cfg.family in ("attn_dense", "vlm", "moe")


_FAMILY_BUILDERS: tuple = (
    # (cache family, predicate(cfg, kv_dtype), builder)
    ("mla", lambda cfg, kd: _is_attn(cfg) and cfg.mla is not None,
     _mla_planes),
    ("int8", lambda cfg, kd: _is_attn(cfg) and cfg.mla is None
     and kd == "int8" and cfg.family != "moe", _int8_planes),
    ("dense", lambda cfg, kd: _is_attn(cfg) and cfg.mla is None,
     _dense_planes),
    ("ssm", lambda cfg, kd: cfg.family == "ssm", _ssm_planes),
    # hybrid (interleaved SSM + shared-attention KV) and encdec (cross-KV)
    # have no pooled layout yet: no entry → descriptor_for returns None and
    # they keep the mirrored dense-cache path.
)


def descriptor_for(cfg, kv_cache_dtype: str = "native",
                   compute_dtype="float32",
                   page_tokens: int = 16) -> Optional[CacheDescriptor]:
    """Build the cache descriptor for a model config, or None when the
    family has no pooled layout (mirror-only)."""
    for fam, pred, build in _FAMILY_BUILDERS:
        if pred(cfg, kv_cache_dtype):
            paged, seq, kernel = build(cfg, kv_cache_dtype, compute_dtype)
            return CacheDescriptor(
                family=fam, num_layers=cfg.num_layers,
                page_tokens=page_tokens, paged_planes=paged,
                seq_planes=seq, kernel=kernel)
    return None


def dense_descriptor(num_layers: int, kv_heads: int, head_dim: int,
                     page_tokens: int, dtype="float16") -> CacheDescriptor:
    """The legacy hard-coded layout as a descriptor: dense ``(k, v)``
    planes. ``KVSpec`` without an explicit descriptor resolves to this, so
    every mirror engine's byte math is unchanged."""
    dt = np.dtype(dtype).name
    return CacheDescriptor(
        family="dense", num_layers=num_layers, page_tokens=page_tokens,
        paged_planes=(PlaneSpec("k", (kv_heads, head_dim), dt),
                      PlaneSpec("v", (kv_heads, head_dim), dt)),
        kernel="dense")


# ---------------------------------------------------------------------------
# Family-support matrix (``python -m repro.core.engines --list``)
# ---------------------------------------------------------------------------
# one representative smoke config per config family, descriptor-resolvable
# without building a model
MATRIX_FAMILIES: tuple = (
    ("dense-gqa", "internlm2-1.8b-smoke", "native"),
    ("int8", "internlm2-1.8b-smoke", "int8"),
    ("mla(+moe)", "deepseek-v2-236b-smoke", "native"),
    ("moe", "arctic-480b-smoke", "native"),
    ("ssm", "mamba2-1.3b-smoke", "native"),
    ("hybrid", "zamba2-1.2b-smoke", "native"),
    ("encdec", "seamless-m4t-large-v2-smoke", "native"),
)


def family_mode(desc: Optional[CacheDescriptor],
                engine_supports_pool: bool) -> str:
    """What path an (engine, config family) pair runs: ``pooled+fused``
    (descriptor + device pool: mirror-free ragged ticks), ``mirror+fused``
    (descriptor but no pool: dense mirror, still one ragged launch per
    tick), or ``mirror`` (no descriptor: unfused per-chunk fallback)."""
    if desc is None:
        return "mirror"
    return "pooled+fused" if engine_supports_pool else "mirror+fused"


def support_matrix() -> list:
    """Rows of (engine, family, mode) over every registered KV engine and
    every config family — sourced from descriptors, not ``supports_*``
    introspection."""
    from repro.configs import get_config
    from repro.core.clock import SimClock
    from repro.core.engines.base import EngineSpec
    from repro.core.engines.kv import create_kv_engine, list_kv_engines
    from repro.core.kvcache import KVSpec

    rows = []
    for name in list_kv_engines():
        eng = create_kv_engine(EngineSpec(engine=name),
                               KVSpec(num_layers=1, kv_heads=1, head_dim=1),
                               SimClock())
        for fam, cfg_name, kv_dtype in MATRIX_FAMILIES:
            desc = descriptor_for(get_config(cfg_name), kv_dtype)
            rows.append((name, fam, family_mode(desc, eng.supports_pool())))
    return rows
