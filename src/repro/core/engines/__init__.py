"""Pluggable cache-engine package: protocol, registry, and the five designs.

Importing this package registers every built-in engine; ``ENGINES`` is the
registry-derived name tuple the facade, benchmarks, and examples enumerate.

    from repro.core.engines import EngineSpec, create_engine, ENGINES

See README.md in this directory for the protocol and how to add an engine.
"""
from repro.core.engines.base import (CacheEngine, EngineSpec, create_engine,
                                     get_engine, list_engines,
                                     register_engine)
# importing the modules registers the engines (order = listing order)
from repro.core.engines import paging      # noqa: F401  (nvpages)
from repro.core.engines import logging     # noqa: F401  (nvlog)
from repro.core.engines import psync       # noqa: F401  (psync, psync_fsync)
from repro.core.engines import hybrid      # noqa: F401  (nvhybrid)
from repro.core.engines.hybrid import HybridEngine
from repro.core.engines.logging import LogEngine
from repro.core.engines.paging import PagedEngine
from repro.core.engines.psync import PsyncEngine, PsyncFsyncEngine

#: built-in engine names, in registration order. This is an import-time
#: snapshot for convenient parametrization; enumerators that must see
#: engines registered later (plugins) call ``list_engines()`` at use time.
ENGINES: tuple[str, ...] = list_engines()

__all__ = ["CacheEngine", "EngineSpec", "ENGINES", "create_engine",
           "get_engine", "list_engines", "register_engine", "HybridEngine",
           "LogEngine", "PagedEngine", "PsyncEngine", "PsyncFsyncEngine"]
