"""Pluggable cache-engine package: protocols, registries, and the designs.

Two registries share one :class:`EngineSpec` config object:

* the FS tier (``CacheEngine``: nvpages/nvlog/psync/psync_fsync/nvhybrid)
  behind the ``NVCacheFS`` facade — importing this package registers them;
* the KV-cache serving tier (``KVCacheEngine``: paged/log/kvhybrid) behind
  the serving engine — built-ins register on first ``create_kv_engine`` /
  ``list_kv_engines`` call (they live in :mod:`repro.core.kvcache`).

    from repro.core.engines import EngineSpec, create_engine, ENGINES
    from repro.core.engines import create_kv_engine, list_kv_engines

See README.md in this directory for the protocols and how to add an engine.
"""
from repro.core.engines.base import (CacheEngine, EngineSpec, create_engine,
                                     get_engine, list_engines,
                                     register_engine)
from repro.core.engines.kv import (KVCacheEngine, create_kv_engine,
                                   get_kv_engine, list_kv_engines,
                                   register_kv_engine)
# importing the modules registers the engines (order = listing order)
from repro.core.engines import paging      # noqa: F401  (nvpages)
from repro.core.engines import logging     # noqa: F401  (nvlog)
from repro.core.engines import psync       # noqa: F401  (psync, psync_fsync)
from repro.core.engines import hybrid      # noqa: F401  (nvhybrid)
from repro.core.engines.hybrid import HybridEngine
from repro.core.engines.logging import LogEngine
from repro.core.engines.paging import PagedEngine
from repro.core.engines.psync import PsyncEngine, PsyncFsyncEngine

#: built-in engine names, in registration order. This is an import-time
#: snapshot for convenient parametrization; enumerators that must see
#: engines registered later (plugins) call ``list_engines()`` at use time.
ENGINES: tuple[str, ...] = list_engines()

__all__ = ["CacheEngine", "EngineSpec", "ENGINES", "create_engine",
           "get_engine", "list_engines", "register_engine", "HybridEngine",
           "LogEngine", "PagedEngine", "PsyncEngine", "PsyncFsyncEngine",
           "KVCacheEngine", "create_kv_engine", "get_kv_engine",
           "list_kv_engines", "register_kv_engine"]
