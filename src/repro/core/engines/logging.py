"""The logging design (paper Fig. 2) as a registered engine."""
from __future__ import annotations

from repro.core.clock import SimClock
from repro.core.disk import Disk
from repro.core.engines.base import CacheEngine, EngineSpec, register_engine
from repro.core.nvlog import NVLog


@register_engine("nvlog")
class LogEngine(NVLog, CacheEngine):
    """Logging: sequential NVMM WAL + DRAM page cache + drainer (NVLog)."""

    @classmethod
    def from_spec(cls, spec: EngineSpec, disk: Disk,
                  clock: SimClock) -> "LogEngine":
        return cls(spec.nvmm_bytes, disk, clock,
                   dram_cache_bytes=spec.dram_cache_bytes,
                   drain_batch=spec.drain_batch,
                   log_shards=max(spec.shards, spec.drain_shards))

    def flush_all(self) -> None:
        self.drain_all()
