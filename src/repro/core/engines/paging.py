"""The paging design (paper Fig. 1) as a registered engine."""
from __future__ import annotations

from repro.core.clock import SimClock
from repro.core.disk import Disk
from repro.core.engines.base import CacheEngine, EngineSpec, register_engine
from repro.core.nvpages import NVPages


@register_engine("nvpages")
class PagedEngine(NVPages, CacheEngine):
    """Paging: 4 KiB NVMM frames, redo log, LRU eviction (NVPages)."""

    @classmethod
    def from_spec(cls, spec: EngineSpec, disk: Disk,
                  clock: SimClock) -> "PagedEngine":
        return cls(spec.nvmm_bytes, disk, clock, o_direct=spec.o_direct,
                   shards=spec.shards)
