"""KV-cache engine protocol and registry (serving tier of the paper).

Mirror of the FS-level registry in :mod:`repro.core.engines.base`, one level
up the stack: where ``CacheEngine`` abstracts NVMM cache designs behind a
POSIX-like facade, ``KVCacheEngine`` abstracts the *serving* translation of
the same question — how decoded KV tokens move between HBM, host memory,
and disk. Both registries construct from the same :class:`EngineSpec`, so a
serving config and an FS config are one object.

``KVCacheEngine`` is the formal contract every tiered KV design implements:

* ``append(seq, kv_tokens)`` — one decoded token ``(L, 2, K, D)`` or a
  prefill batch ``(L, 2, T, K, D)``; durable in the host tier at return.
* ``read(seq, layer)`` — materialize ``(2, T, K, D)`` for attention
  (``gather`` is the historical alias and remains supported).
* ``preempt(seq)`` / ``restore(seq)`` — offload a sequence's KV to disk and
  bring it back (continuous batching under memory pressure).
* ``stats`` — monotone counters merged into serving-engine stats.

New designs register with ``@register_kv_engine("name")`` and are
constructed via ``create_kv_engine(spec, kvspec, clock)``; unknown names
raise ``ValueError``. The built-ins (``paged``, ``log``, ``kvhybrid``) live
in :mod:`repro.core.kvcache` and are registered on first use.
"""
from __future__ import annotations

import abc
from typing import TYPE_CHECKING

import numpy as np

from repro.core.clock import SimClock
from repro.core.engines.base import EngineSpec

if TYPE_CHECKING:                      # avoid a cycle: kvcache imports us
    from repro.core.kvcache import KVSpec


class KVCacheEngine(abc.ABC):
    """Abstract base for tiered KV-cache designs behind the serving engine."""

    #: registry key, filled in by ``@register_kv_engine``
    engine_name: str = "?"
    #: per-engine counters (monotone); serving merges this into its stats
    stats: dict
    #: seq → appended-token count (the serving engine reads this)
    seq_len: dict

    @classmethod
    @abc.abstractmethod
    def from_spec(cls, spec: EngineSpec, kvspec: "KVSpec",
                  clock: SimClock) -> "KVCacheEngine":
        """Construct the engine from the shared config object.

        ``spec`` carries budgets and routing knobs (``kv_hbm_bytes``,
        ``kv_hot_window``, ``drain_batch``, ``drain_shards``,
        ``hybrid_threshold``); ``kvspec`` carries the model geometry.
        """

    # ------------------------------------------------------------------- ops
    @abc.abstractmethod
    def append(self, seq: int, kv_tokens: np.ndarray) -> None:
        """Append KV for ``seq``: ``(L, 2, K, D)`` one token, or
        ``(L, 2, T, K, D)`` a batch of ``T`` consecutive tokens (prefill)."""

    @abc.abstractmethod
    def read(self, seq: int, layer: int) -> np.ndarray:
        """Materialize ``(2, T, K, D)`` for attention over ``seq``."""

    def gather(self, seq: int, layer: int) -> np.ndarray:
        """Historical alias for :meth:`read`."""
        return self.read(seq, layer)

    @abc.abstractmethod
    def preempt(self, seq: int) -> None:
        """Offload ``seq``'s KV to disk and free its host/HBM state.
        Reading or appending a preempted sequence raises ``RuntimeError``
        until :meth:`restore`."""

    @abc.abstractmethod
    def restore(self, seq: int) -> None:
        """Bring a preempted sequence back into the host tier."""


_KV_REGISTRY: dict[str, type[KVCacheEngine]] = {}


def register_kv_engine(name: str, *, override: bool = False):
    """Class decorator: make a KV engine constructible by name.

    Same duplicate-name guard as the FS registry: silently replacing a
    built-in would corrupt every registry-driven construction site.
    """
    def deco(cls: type[KVCacheEngine]) -> type[KVCacheEngine]:
        if not override and name in _KV_REGISTRY:
            raise ValueError(
                f"KV engine {name!r} is already registered "
                f"({_KV_REGISTRY[name].__name__}); pass override=True to "
                f"replace it")
        cls.engine_name = name
        _KV_REGISTRY[name] = cls
        return cls
    return deco


_builtins_loaded = False


def _ensure_builtins() -> None:
    # the built-in engines live in repro.core.kvcache, which imports this
    # module for the protocol — register them lazily to avoid the cycle.
    # Guarded by a flag, not registry emptiness: a plugin registering before
    # first use must not suppress the built-ins.
    global _builtins_loaded
    if not _builtins_loaded:
        import repro.core.kvcache  # noqa: F401  (registers paged/log/kvhybrid)
        _builtins_loaded = True    # only after a successful import: a failed
        # first attempt must retry, not hide the builtins forever


def get_kv_engine(name: str) -> type[KVCacheEngine]:
    _ensure_builtins()
    try:
        return _KV_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown KV engine {name!r}; registered KV engines: "
            f"{', '.join(sorted(_KV_REGISTRY))}") from None


def create_kv_engine(spec: EngineSpec, kvspec: "KVSpec",
                     clock: SimClock) -> KVCacheEngine:
    """Build the KV engine named by ``spec.engine``."""
    return get_kv_engine(spec.engine).from_spec(spec, kvspec, clock)


def list_kv_engines() -> tuple[str, ...]:
    _ensure_builtins()
    return tuple(_KV_REGISTRY)
