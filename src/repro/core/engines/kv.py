"""KV-cache engine protocol and registry (serving tier of the paper).

Mirror of the FS-level registry in :mod:`repro.core.engines.base`, one level
up the stack: where ``CacheEngine`` abstracts NVMM cache designs behind a
POSIX-like facade, ``KVCacheEngine`` abstracts the *serving* translation of
the same question — how decoded KV tokens move between HBM, host memory,
and disk. Both registries construct from the same :class:`EngineSpec`, so a
serving config and an FS config are one object.

``KVCacheEngine`` is the formal contract every tiered KV design implements:

* ``append(seq, kv_tokens)`` — one decoded token ``(L, 2, K, D)`` or a
  prefill batch ``(L, 2, T, K, D)``; durable in the host tier at return.
* ``append_many(items)`` — batched multi-sequence append: one decode step's
  worth of tokens across a whole running batch in one call.
* ``read(seq, layer)`` — materialize ``(2, T, K, D)`` for attention
  (``gather`` is the historical alias and remains supported).
* ``preempt(seq)`` / ``restore(seq)`` — offload a sequence's KV to disk and
  bring it back (continuous batching under memory pressure).
* ``release(seq)`` — drop a finished sequence's state from every tier.
* ``stats`` — monotone counters merged into serving-engine stats.

A scheduler driving preemption reads the *pressure surface* instead of
engine internals: ``pressure()`` (HBM use over budget), ``resident_bytes``
(one sequence's HBM footprint), and ``victim_hint`` (the engine's preferred
preemption victim — ``kvhybrid`` answers from its router's per-sequence
reuse histogram; engines with no opinion return ``None`` and the scheduler
falls back to LRU).

New designs register with ``@register_kv_engine("name")`` and are
constructed via ``create_kv_engine(spec, kvspec, clock)``; unknown names
raise ``ValueError``. The built-ins (``paged``, ``log``, ``kvhybrid``) live
in :mod:`repro.core.kvcache` and are registered on first use.
"""
from __future__ import annotations

import abc
from typing import TYPE_CHECKING, Iterable, Optional, Sequence

import numpy as np

from repro.core.clock import SimClock
from repro.core.engines.base import EngineSpec

if TYPE_CHECKING:                      # avoid a cycle: kvcache imports us
    from repro.core.kvcache import KVSpec


class KVCacheEngine(abc.ABC):
    """Abstract base for tiered KV-cache designs behind the serving engine."""

    #: registry key, filled in by ``@register_kv_engine``
    engine_name: str = "?"
    #: per-engine counters (monotone); serving merges this into its stats
    stats: dict
    #: seq → appended-token count (the serving engine reads this)
    seq_len: dict

    @classmethod
    @abc.abstractmethod
    def from_spec(cls, spec: EngineSpec, kvspec: "KVSpec",
                  clock: SimClock) -> "KVCacheEngine":
        """Construct the engine from the shared config object.

        ``spec`` carries budgets and routing knobs (``kv_hbm_bytes``,
        ``kv_hot_window``, ``drain_batch``, ``drain_shards``,
        ``hybrid_threshold``); ``kvspec`` carries the model geometry.
        """

    # ------------------------------------------------------------------- ops
    @abc.abstractmethod
    def append(self, seq: int, kv_tokens: np.ndarray) -> None:
        """Append KV for ``seq``: ``(L, 2, K, D)`` one token, or
        ``(L, 2, T, K, D)`` a batch of ``T`` consecutive tokens (prefill)."""

    def append_many(self, items: Sequence[tuple[int, np.ndarray]]) -> None:
        """Batched multi-sequence append: ``[(seq, kv_tokens), ...]``.

        The continuous-batching decode path: one scheduler step appends one
        token for every running sequence through a single call. The default
        loops; engines override to amortize per-call work (drainer advance)
        across the batch.
        """
        for seq, kv_tokens in items:
            self.append(seq, kv_tokens)

    @abc.abstractmethod
    def read(self, seq: int, layer: int) -> np.ndarray:
        """Materialize ``(2, T, K, D)`` for attention over ``seq``."""

    def gather(self, seq: int, layer: int) -> np.ndarray:
        """Historical alias for :meth:`read`."""
        return self.read(seq, layer)

    @abc.abstractmethod
    def preempt(self, seq: int) -> None:
        """Offload ``seq``'s KV to disk and free its host/HBM state.
        Reading or appending a preempted sequence raises ``RuntimeError``
        until :meth:`restore`."""

    @abc.abstractmethod
    def restore(self, seq: int) -> None:
        """Bring a preempted sequence back into the host tier."""

    @abc.abstractmethod
    def release(self, seq: int) -> None:
        """Drop a finished sequence from every tier (the scheduler calls
        this when a request completes; frees HBM/host/disk state)."""

    # ------------------------------------------------------ pressure surface
    def hbm_used_bytes(self) -> int:
        """Bytes of HBM this engine currently holds resident."""
        return 0

    def hbm_limit_bytes(self) -> Optional[int]:
        """The engine's HBM budget in bytes (``None`` = unbounded)."""
        return None

    def pressure(self) -> float:
        """HBM occupancy as a fraction of the budget (0.0 when unbounded).

        Reaches 1.0 exactly when the budget binds — the scheduler's
        preemption trigger. Engines self-limit, so the value never exceeds
        1.0; "over budget" is expressed as sitting *at* the ceiling.
        """
        limit = self.hbm_limit_bytes()
        if not limit:
            return 0.0
        return self.hbm_used_bytes() / limit

    def resident_bytes(self, seq: int) -> int:
        """HBM bytes attributable to ``seq`` (what preempting it frees)."""
        return 0

    def victim_hint(self, candidates: Iterable[int]) -> Optional[int]:
        """The engine's preferred preemption victim among ``candidates``.

        ``None`` means no opinion — the scheduler falls back to LRU.
        ``kvhybrid`` overrides this to consult its router's per-sequence
        reuse histogram (cold-read-heavy sequences are the cheapest to
        serve from the spilled tier, so they go first); ``paged`` in pooled
        mode answers at page granularity (the candidate whose preemption
        frees the most device pool pages).
        """
        return None

    def can_admit_tokens(self, n_tokens: int) -> bool:
        """Would admitting a sequence of ``n_tokens`` fit right now?

        Engines with hard allocation limits (the pooled paged engine: a
        fixed number of device pool pages) override this so the scheduler
        never admits a sequence it cannot place. The default is True —
        host-tier engines self-limit through ``pressure()`` alone.
        """
        return True

    # ------------------------------------------------- async tier transfers
    # Asynchronous tiering (ISSUE 8): a pooled engine may move its page
    # spills (D2H) and fault-ins (H2D) through a background transfer
    # pipeline so they overlap the fused forward instead of stalling it.
    # The scheduler publishes next tick's planned batch through prefetch()
    # so spilled pages start their H2D before prepare_step would
    # demand-fault them; the coherence rule is a drain barrier before any
    # read of an in-flight page. Engines without a pipeline keep the no-op
    # defaults — both calls are safe on every engine.

    def prefetch(self, seqs: Sequence[int],
                 n_tokens: Optional[Sequence[int]] = None) -> int:
        """Lookahead hint: the scheduler plans to step ``seqs`` next tick
        (``n_tokens[i]`` advisory slot counts — decode rows ``1 + k``,
        chunk rows their chunk length). An async-tiering engine schedules
        H2D fault-ins for these sequences' spilled pages; the transfers
        drain in the background and the later demand fault only waits for
        the residual time. Purely a timing hint — no allocation and no
        data movement happen here, so prefetching never changes which
        pages spill or fault. Returns the number of transfers scheduled
        (0 on engines without a pipeline)."""
        return 0

    def flush_transfers(self) -> None:
        """Drain every in-flight asynchronous tier transfer (advance the
        clock to the pipeline's idle time). Benchmarks call this before
        reading ``sim_time_s`` so async runs pay for their outstanding
        background traffic; a no-op on engines without a pipeline."""

    # ------------------------------------------------- faults & recovery
    # ISSUE 10: hooks the serving fault layer uses. Engines without an
    # async pipeline (log, kvhybrid — no tier transfers to fail) keep the
    # no-op defaults; pooled engines forward them to their TransferPipeline.

    def set_fault_injector(self, injector) -> None:
        """Attach a :class:`~repro.serving.faults.FaultInjector` so tier
        transfers (and spilled-host-page reads) can fail deterministically.
        No-op on engines without a transfer pipeline."""

    def abort_step(self, seqs: Sequence[int]) -> None:
        """Roll back an in-flight prepared step for ``seqs`` (exception
        between ``prepare_step`` and ``commit_step``): unpin the batch and
        drop any pages allocated beyond each row's committed length, so a
        poisoned tick cannot leak pool pages. No-op on unpooled engines."""

    def stall_transfers(self, direction: int, seconds: float) -> None:
        """Inject a drainer-shard stall on one transfer channel (0 = D2H,
        1 = H2D): the channel serves nothing for ``seconds``. Timing-only;
        no-op on engines without a pipeline."""

    # ----------------------------------------------- device-resident KV pool
    # The mirror-free serving path (ISSUE 4): an engine that supports
    # pooling owns (L, P, T, K, D) device arrays of KV pages; the serving
    # engine decodes *directly* over them with the paged_attention kernel
    # (block-table indirection), so no dense per-sequence mirror and no
    # device→host copy exists on the decode path. Engines that return False
    # from supports_pool() (log, kvhybrid — their layouts are logs, not
    # page pools) transparently stay on the mirrored dense-cache path.

    def supports_pool(self) -> bool:
        """True if this engine can own a device-resident paged KV pool."""
        return False

    @property
    def pooled(self) -> bool:
        """True once :meth:`init_pool` has activated the device pool."""
        return False

    def init_pool(self, dtype=None, pages: Optional[int] = None) -> None:
        """Activate pooled mode: allocate the device page pool (sized from
        the engine's HBM budget unless ``pages`` overrides it). Must be
        called before any append. ``dtype`` defaults to the KVSpec dtype;
        the serving engine passes the model's cache dtype so pooled decode
        is bit-identical to the dense path."""
        raise RuntimeError(
            f"KV engine {self.engine_name!r} has no paged pool; check "
            f"supports_pool() before init_pool()")

    def pool_views(self):
        """The device pool planes in cache-descriptor order — for the
        dense layout the classic ``(pool_k, pool_v)`` pair, each
        ``(L, P, T, K, D)``; other descriptors return their own plane
        tuples (int8 adds scale planes, MLA pools ``(c, kr)``). The
        engine retains ownership — callers must hand updated arrays back
        through :meth:`commit_step_planes` / :meth:`commit_prefill`."""
        raise RuntimeError(
            f"KV engine {self.engine_name!r} has no paged pool")

    def prepare_decode(self, seqs: Sequence[int], max_pages: int):
        """Ready one decode step for ``seqs``: fault every spilled page
        back in, allocate a fresh page for each sequence whose next token
        starts one, and return ``(block_table, lengths)`` — an
        ``(B, max_pages) int32`` table plus current token counts.

        Single-token special case of :meth:`prepare_step`."""
        return self.prepare_step(seqs, [1] * len(seqs), max_pages)

    def commit_decode(self, pool_k, pool_v, seqs: Sequence[int]) -> None:
        """Accept updated pool arrays after the model scattered one new
        token per sequence in ``seqs``; advances ``seq_len`` and the
        resident-page accounting (HBM write charges, no host traffic).

        Single-token special case of :meth:`commit_step`."""
        return self.commit_step(pool_k, pool_v, seqs, [1] * len(seqs))

    def prepare_step(self, seqs: Sequence[int], n_tokens: Sequence[int],
                     max_pages: int):
        """Multi-token generalization of :meth:`prepare_decode` — ready one
        fused mixed-batch step that appends ``n_tokens[i]`` tokens to
        ``seqs[i]`` (decode rows: 1; prefill-chunk rows: up to the chunk
        budget): fault every spilled page back in, allocate pages covering
        each sequence's chunk, and return ``(block_table, ctx_lens)`` —
        ``ctx_lens`` are the token counts BEFORE the step (each row's chunk
        start position)."""
        raise RuntimeError(
            f"KV engine {self.engine_name!r} has no paged pool")

    def commit_step(self, pool_k, pool_v, seqs: Sequence[int],
                    n_tokens: Sequence[int],
                    prepared: Optional[Sequence[int]] = None) -> None:
        """Accept updated pool arrays after the model scattered new tokens
        for ``seqs[i]`` in one fused step; advances ``seq_len`` and the
        resident-page accounting.

        Partial commit (speculative decode): ``n_tokens[i]`` is the number
        of tokens to COMMIT, which may be less than the ``prepared[i]``
        tokens :meth:`prepare_step` was sized for when a speculative tail
        was rejected. Pass the original ``prepare_step`` counts as
        ``prepared`` to roll the tail back: ``seq_len`` advances by the
        accepted count only and pages allocated solely for the rejected
        tail are returned to the free list, so pool pressure never reflects
        tokens that were never committed. Rejected KV left inside retained
        pages is invisible (kernels mask at or past ``lengths``) and is
        overwritten in place by the sequence's next committed tokens.
        ``prepared=None`` (or ``prepared[i] == n_tokens[i]``) is the plain
        full commit."""
        raise RuntimeError(
            f"KV engine {self.engine_name!r} has no paged pool")

    def can_place_step(self, seqs: Sequence[int],
                       n_tokens: Sequence[int]) -> bool:
        """Would :meth:`prepare_step` succeed for this batch right now?

        ``prepare_step`` pins EVERY batch sequence's pages while it
        allocates (a later allocation must never spill a page the kernel is
        about to read), so a fused tick whose chunks need more pages than
        ``free + spillable-from-outside-the-batch`` cannot be placed — the
        scheduler preempts a row and retries instead of crashing into the
        pool-exhausted error. Engines without a pool always say True."""
        return True

    def alloc_prefill(self, seq: int, n_tokens: int):
        """Allocate pages covering ``n_tokens`` upcoming tokens of ``seq``
        and return the sequence's physical-page row (np.int32)."""
        raise RuntimeError(
            f"KV engine {self.engine_name!r} has no paged pool")

    # --------------------------------------------------------- prefix sharing
    # Cross-request KV reuse (ISSUE 6): a prefix index (the token radix trie
    # in repro.serving.prefix_cache) maps shared token prefixes to pool
    # pages; admission of a cache-hit prompt splices the new sequence's
    # block table onto those pages (adopt_pages — zero prefill compute for
    # the covered prefix), the first divergent write triggers copy-on-write
    # of the boundary page, and eviction/spill becomes refcount-aware: a
    # page is freed only when no sequence references it AND the index has
    # unpinned it. The index object registered through set_share_index must
    # provide: ``reclaim_one() -> Optional[int]`` (evict one idle indexed
    # page, freeing it), ``forget_phys(phys)`` (drop the index entry for a
    # page the engine is about to spill), ``on_seq_dropped(seq)`` and
    # ``on_cow(seq, phys)`` (refcount bookkeeping callbacks).

    def supports_sharing(self) -> bool:
        """True when block tables may alias pool pages across sequences
        (refcounted pages + copy-on-write divergence)."""
        return False

    def set_share_index(self, index) -> None:
        """Register the prefix index that pins shared pages (see above)."""
        raise RuntimeError(
            f"KV engine {self.engine_name!r} does not support prefix "
            f"sharing; check supports_sharing() first")

    def adopt_pages(self, seq: int, pages: Sequence[int],
                    covered_tokens: int) -> None:
        """Admission splice: point ``seq``'s (empty) block table at shared
        pool pages covering its first ``covered_tokens`` prompt tokens.
        Pure metadata — refcounts go up, no KV moves, no compute runs."""
        raise RuntimeError(
            f"KV engine {self.engine_name!r} does not support prefix "
            f"sharing")

    def pin_page(self, phys: int) -> None:
        """Index pin: keep ``phys`` alive (and never spilled) even after
        every referencing sequence releases."""
        raise RuntimeError(
            f"KV engine {self.engine_name!r} does not support prefix "
            f"sharing")

    def unpin_page(self, phys: int) -> None:
        """Drop the index pin on ``phys``; frees the page if no sequence
        references it anymore."""
        raise RuntimeError(
            f"KV engine {self.engine_name!r} does not support prefix "
            f"sharing")

    def page_refs(self, phys: int) -> int:
        """Live referents of a pool page: sequences whose block tables
        contain it, plus 1 if the prefix index pins it."""
        return 0

    def commit_prefill(self, pool_k, pool_v, seq: int,
                       n_tokens: int) -> None:
        """Accept updated pool arrays after a prompt's KV was scattered
        into ``seq``'s pages on device (the admission path's one
        device-side copy; still zero device→host traffic). Dense
        ``(k, v)`` special case of :meth:`commit_prefill_planes`."""
        raise RuntimeError(
            f"KV engine {self.engine_name!r} has no paged pool")

    # ------------------------------------------- descriptor plane surface
    # Cache descriptors (ISSUE 9): a pooled engine built from a KVSpec
    # carrying a CacheDescriptor owns one device array PER PLANE. The
    # plane-generic commit twins below accept the full plane tuple in
    # descriptor order; the dense (pool_k, pool_v) entries above remain as
    # the two-plane special case. State-bearing descriptors (SSM) have no
    # pages at all — their per-seq state rows move through
    # state_views()/commit_state() and ride preempt/restore with the row.

    def commit_step_planes(self, planes, seqs: Sequence[int],
                           n_tokens: Sequence[int],
                           prepared: Optional[Sequence[int]] = None) -> None:
        """Plane-generic :meth:`commit_step`: ``planes`` is the updated
        pool-plane tuple in cache-descriptor order."""
        raise RuntimeError(
            f"KV engine {self.engine_name!r} has no paged pool")

    def commit_prefill_planes(self, planes, seq: int,
                              n_tokens: int) -> None:
        """Plane-generic :meth:`commit_prefill`."""
        raise RuntimeError(
            f"KV engine {self.engine_name!r} has no paged pool")

    def state_views(self, seqs: Sequence[int]):
        """Batched per-seq state rows for one step — one ``(L, B, *shape)``
        array per descriptor seq plane. Only state-bearing descriptors
        (SSM) implement this."""
        raise RuntimeError(
            f"KV engine {self.engine_name!r} has no per-seq state rows")

    def commit_state(self, seqs: Sequence[int], n_tokens: Sequence[int],
                     states) -> None:
        """Commit one step's updated state rows; rows with
        ``n_tokens[i] == 0`` commit nothing (speculative/padding rewind)."""
        raise RuntimeError(
            f"KV engine {self.engine_name!r} has no per-seq state rows")


_KV_REGISTRY: dict[str, type[KVCacheEngine]] = {}


def register_kv_engine(name: str, *, override: bool = False):
    """Class decorator: make a KV engine constructible by name.

    Same duplicate-name guard as the FS registry: silently replacing a
    built-in would corrupt every registry-driven construction site.
    """
    def deco(cls: type[KVCacheEngine]) -> type[KVCacheEngine]:
        if not override and name in _KV_REGISTRY:
            raise ValueError(
                f"KV engine {name!r} is already registered "
                f"({_KV_REGISTRY[name].__name__}); pass override=True to "
                f"replace it")
        cls.engine_name = name
        _KV_REGISTRY[name] = cls
        return cls
    return deco


_builtins_loaded = False


def _ensure_builtins() -> None:
    # the built-in engines live in repro.core.kvcache, which imports this
    # module for the protocol — register them lazily to avoid the cycle.
    # Guarded by a flag, not registry emptiness: a plugin registering before
    # first use must not suppress the built-ins.
    global _builtins_loaded
    if not _builtins_loaded:
        import repro.core.kvcache  # noqa: F401  (registers paged/log/kvhybrid)
        _builtins_loaded = True    # only after a successful import: a failed
        # first attempt must retry, not hide the builtins forever


def get_kv_engine(name: str) -> type[KVCacheEngine]:
    _ensure_builtins()
    try:
        return _KV_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown KV engine {name!r}; registered KV engines: "
            f"{', '.join(sorted(_KV_REGISTRY))}") from None


def create_kv_engine(spec: EngineSpec, kvspec: "KVSpec",
                     clock: SimClock) -> KVCacheEngine:
    """Build the KV engine named by ``spec.engine``."""
    return get_kv_engine(spec.engine).from_spec(spec, kvspec, clock)


def list_kv_engines() -> tuple[str, ...]:
    _ensure_builtins()
    return tuple(_KV_REGISTRY)
