"""nvhybrid: the combined design the paper motivates but never builds.

The paper's conclusion: logging wins small synchronous writes (1× NVMM
write, DRAM-speed reads) while paging wins large/aligned IO and absorbs
hot-page overwrites in NVMM. ``HybridEngine`` routes each page-granular
write chunk accordingly:

* chunks below ``EngineSpec.hybrid_threshold`` bytes → an NVLog journal
  (sequential NVMM append, background drain);
* full-page or ≥-threshold chunks, and any write to a page already resident
  in the page cache → an NVPages pool.

Coherence is by **page ownership**: at any moment a page's pending state
lives in exactly one component. Before the page side takes over a page, the
journal is force-drained for it (log drains before page flush — the unified
recovery ordering), and the journal's DRAM copy is invalidated. Reads are
served by whichever side owns the page (NVMM frame if resident, else the
journal's DRAM cache / LPC path).

Crash recovery runs the same ordering: replay the journal to disk first,
then rebuild the page side from NVMM frame headers and flush — ownership
makes the two record sets disjoint, so the combined engine inherits both
components' no-data-loss guarantees (tested against nvlog/nvpages oracles
in tests/test_engine_registry.py).
"""
from __future__ import annotations

from repro.core.clock import SimClock
from repro.core.disk import Disk, PAGE_SIZE, iter_page_chunks
from repro.core.engines.base import CacheEngine, EngineSpec, register_engine
from repro.core.nvlog import NVLog
from repro.core.nvpages import NVPages


@register_engine("nvhybrid")
class HybridEngine(CacheEngine):
    """Hybrid: NVLog journal for small writes, NVPages for large/hot pages."""

    # no keyword defaults: every knob comes from EngineSpec via from_spec,
    # so the single source of default values stays EngineSpec
    def __init__(self, disk: Disk, clock: SimClock, *, nvmm_bytes: int,
                 dram_cache_bytes: int, threshold: int, log_fraction: float,
                 shards: int, drain_batch: int, o_direct: bool,
                 drain_shards: int = 1):
        assert 0.0 < log_fraction < 1.0, log_fraction
        assert nvmm_bytes >= 128 << 10, "nvhybrid needs >=128 KiB of NVMM"
        assert drain_shards >= 1, drain_shards
        # split the budget, never exceed it: a 64 KiB journal floor, but
        # the page pool always keeps at least half
        log_bytes = min(max(int(nvmm_bytes * log_fraction), 64 << 10),
                        nvmm_bytes // 2)
        page_bytes = nvmm_bytes - log_bytes
        self.threshold = threshold
        # journal drainer parallelism is its own knob: WAL shards are the
        # drain shards (one independent FIFO server each, ShardedDrainer),
        # while ``shards`` keeps governing the page pool's structure
        self.log = NVLog(log_bytes, disk, clock,
                         dram_cache_bytes=dram_cache_bytes,
                         drain_batch=drain_batch,
                         log_shards=max(shards, drain_shards))
        self.pages = NVPages(page_bytes, disk, clock, o_direct=o_direct,
                             shards=shards)
        self._stats = {"routed_log": 0, "routed_pages": 0,
                       "page_takeovers": 0}

    @classmethod
    def from_spec(cls, spec: EngineSpec, disk: Disk,
                  clock: SimClock) -> "HybridEngine":
        return cls(disk, clock, nvmm_bytes=spec.nvmm_bytes,
                   dram_cache_bytes=spec.dram_cache_bytes,
                   threshold=spec.hybrid_threshold,
                   log_fraction=spec.hybrid_log_fraction,
                   shards=spec.shards, drain_batch=spec.drain_batch,
                   o_direct=spec.o_direct, drain_shards=spec.drain_shards)

    @property
    def stats(self) -> dict:
        out = dict(self._stats)
        out.update({f"log_{k}": v for k, v in self.log.stats.items()})
        out.update({f"pages_{k}": v for k, v in self.pages.stats.items()})
        return out

    # -------------------------------------------------------------------- IO
    def pwrite(self, offset: int, data: bytes) -> int:
        for pos, pno, in_page, n in iter_page_chunks(offset, len(data)):
            chunk = data[pos:pos + n]
            large = (in_page == 0 and n == PAGE_SIZE) or n >= self.threshold
            if large or self.pages.is_resident(pno):
                # page side takes (or keeps) ownership: the journal must
                # reach disk for this page first, and its DRAM copy dies
                if self.log.has_pending(pno):
                    self.log.force_drain_page(pno)
                    self._stats["page_takeovers"] += 1
                self.log.invalidate(pno)
                self.pages.pwrite(offset + pos, chunk)
                self._stats["routed_pages"] += 1
            else:
                self.log.pwrite(offset + pos, chunk)
                self._stats["routed_log"] += 1
        return len(data)

    def pread(self, offset: int, n: int) -> bytes:
        out = bytearray()
        for pos, pno, _, take in iter_page_chunks(offset, n):
            # is_resident repeats the index lookup pages.pread will do;
            # that costs host wall-clock only — no simulated time is
            # charged for index walks, so the model stays exact
            if self.pages.is_resident(pno):
                out += self.pages.pread(offset + pos, take)
            else:
                out += self.log.pread(offset + pos, take)
        return bytes(out)

    def fsync(self) -> None:
        """No-op: both routes are durable at pwrite return."""

    # --------------------------------------------------- lifecycle / recovery
    def flush_all(self) -> None:
        self.log.drain_all()
        self.pages.flush_all()

    def crash(self) -> None:
        self.log.crash()
        self.pages.crash()

    def remount(self) -> None:
        self.pages.remount()        # the journal's caches rebuild lazily

    def recover(self) -> None:
        # unified ordering: journal replays to disk before the page side
        # rebuilds and flushes (ownership keeps the page sets disjoint).
        # The journal skips its terminal barrier — pages.recover() ends in
        # flush_all → fsync, which persists the replayed journal pages too,
        # so the combined engine pays SSD_FSYNC_LATENCY exactly once.
        self.log.recover(barrier=False)
        self.pages.recover()

    # -------------------------------------------------- capacity accounting
    def nvmm_capacity_bytes(self) -> int:
        return (self.log.nvmm_capacity_bytes()
                + self.pages.nvmm_capacity_bytes())

    def nvmm_used_bytes(self) -> int:
        return self.log.nvmm_used_bytes() + self.pages.nvmm_used_bytes()
