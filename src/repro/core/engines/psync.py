"""The paper's FIO reference baselines as real engines (no NVMM).

``psync`` is plain pread/pwrite through the Linux page cache — no
persistence until fsync, the configuration the paper measures as "the
performance of the LPC in DRAM". ``psync_fsync`` adds an fsync after every
pwrite (the paper's >1 h configuration). Previously these lived as
``cache is None`` branches inside the facade; now they are first-class
engines sharing the byte-granular LPC helpers in :mod:`repro.core.disk`.
"""
from __future__ import annotations

from repro.core.clock import SimClock
from repro.core.disk import Disk, PAGE_SIZE
from repro.core.engines.base import CacheEngine, EngineSpec, register_engine


@register_engine("psync")
class PsyncEngine(CacheEngine):
    """psync: buffered IO through the LPC; durable only at fsync."""

    uses_nvmm = False

    def __init__(self, disk: Disk, clock: SimClock):
        self.disk = disk
        self.clock = clock
        self.stats = {"lpc_writes": 0, "lpc_reads": 0, "fsyncs": 0}

    @classmethod
    def from_spec(cls, spec: EngineSpec, disk: Disk,
                  clock: SimClock) -> "PsyncEngine":
        return cls(disk, clock)

    def pwrite(self, offset: int, data: bytes) -> int:
        self.stats["lpc_writes"] += 1
        return self.disk.write_bytes(offset, data)

    def pread(self, offset: int, n: int) -> bytes:
        self.stats["lpc_reads"] += 1
        return self.disk.read_bytes(offset, n)

    def fsync(self) -> None:
        self.stats["fsyncs"] += 1
        self.disk.fsync()

    def fsync_range(self, offset: int, length: int) -> None:
        """Per-file sync: flush only the range's dirty LPC pages, leaving
        other files' un-synced data volatile (POSIX fsync is per-file)."""
        self.stats["fsyncs"] += 1
        self.disk.fsync_range(offset // PAGE_SIZE,
                              -(-(offset + length) // PAGE_SIZE))

    def flush_all(self) -> None:
        self.disk.fsync()

    def crash(self) -> None:
        self.disk.crash()

    def recover(self) -> None:
        """Nothing to replay: un-fsync'd LPC contents are simply lost."""


@register_engine("psync_fsync")
class PsyncFsyncEngine(PsyncEngine):
    """psync + fsync after every pwrite (durable, catastrophically slow)."""

    def pwrite(self, offset: int, data: bytes) -> int:
        n = super().pwrite(offset, data)
        self.fsync()
        return n
