"""Engine registry CLI.

    PYTHONPATH=src python -m repro.core.engines --list

``--list`` also prints the engine × config-family support matrix: which
serving path (pooled+fused / mirror+fused / mirror) each KV engine runs
for each model family, sourced from the cache descriptors — so "does int8
pool?" is answered by the registry, not by reading the code.
"""
from __future__ import annotations

import argparse

from repro.core.engines import (get_engine, get_kv_engine, list_engines,
                                list_kv_engines)


def _print_support_matrix() -> None:
    from repro.core.engines.desc import MATRIX_FAMILIES, support_matrix
    rows = support_matrix()
    fams = [f for f, _, _ in MATRIX_FAMILIES]
    engines = sorted({e for e, _, _ in rows})
    modes = {(e, f): m for e, f, m in rows}
    width = max(max(len(f) for f in fams),
                max(len(m) for m in modes.values())) + 2
    print("\nKV engine x config family (cache-descriptor support matrix):")
    print("  " + " " * 10 + "".join(f"{f:>{width}}" for f in fams))
    for eng in engines:
        cells = "".join(f"{modes[(eng, f)]:>{width}}" for f in fams)
        print(f"  {eng:10s}{cells}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.core.engines",
        description="inspect the cache-engine registries (FS + KV tiers)")
    ap.add_argument("--list", action="store_true",
                    help="list registered engines and the per-family "
                         "serving-path support matrix (the default and "
                         "only action)")
    ap.parse_args(argv)      # listing is the only mode; this rejects typos
    for name in list_engines():
        cls = get_engine(name)
        # a docstring-less plugin class has __doc__ = None (not inherited)
        doc = next(iter((cls.__doc__ or "").strip().splitlines()), "")
        nvmm = "nvmm" if cls.uses_nvmm else "lpc "
        print(f"{name:12s} [{nvmm}] {doc}")
    for name in list_kv_engines():
        cls = get_kv_engine(name)
        doc = next(iter((cls.__doc__ or "").strip().splitlines()), "")
        print(f"{name:12s} [kv  ] {doc}")
    _print_support_matrix()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
