"""Engine registry CLI.

    PYTHONPATH=src python -m repro.core.engines --list
"""
from __future__ import annotations

import argparse

from repro.core.engines import (get_engine, get_kv_engine, list_engines,
                                list_kv_engines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.core.engines",
        description="inspect the cache-engine registries (FS + KV tiers)")
    ap.add_argument("--list", action="store_true",
                    help="list registered engines (the default and only "
                         "action)")
    ap.parse_args(argv)      # listing is the only mode; this rejects typos
    for name in list_engines():
        cls = get_engine(name)
        # a docstring-less plugin class has __doc__ = None (not inherited)
        doc = next(iter((cls.__doc__ or "").strip().splitlines()), "")
        nvmm = "nvmm" if cls.uses_nvmm else "lpc "
        print(f"{name:12s} [{nvmm}] {doc}")
    for name in list_kv_engines():
        cls = get_kv_engine(name)
        doc = next(iter((cls.__doc__ or "").strip().splitlines()), "")
        print(f"{name:12s} [kv  ] {doc}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
