"""Pure-jnp oracles for paged decode attention (block-table indirection)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_attention_ref(q, pool_k, pool_v, block_table, lengths, *,
                        scale: float | None = None):
    """Decode attention over a paged KV pool.

    q:           (B, H, D)           one query token per sequence
    pool_k/v:    (P, T, K, D)        physical pages of T tokens
    block_table: (B, MaxPages) int32 logical→physical page mapping
    lengths:     (B,) int32          tokens valid per sequence
    Returns (B, H, D). A row with ``lengths[b] == 0`` returns exactly zero
    (the kernel never runs its compute body for such rows).
    """
    B, H, D = q.shape
    P, T, K, _ = pool_k.shape
    G = H // K
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    table = jnp.clip(block_table, 0, P - 1)
    # gather logical KV: (B, MaxPages*T, K, D)
    k = pool_k[table].reshape(B, -1, K, D)
    v = pool_v[table].reshape(B, -1, K, D)
    S = k.shape[1]
    qg = q.reshape(B, K, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k.astype(jnp.float32)) * scale
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    out = jnp.where((lengths > 0)[:, None, None, None], out, 0.0)
    return out.reshape(B, H, D).astype(q.dtype)


def paged_attention_layers_ref(q, pool_k, pool_v, block_table, lengths, *,
                               scale: float | None = None):
    """Multi-layer oracle: q (L,B,H,D); pool_k/v (L,P,T,K,D); one block
    table + ragged lengths shared by every layer. Returns (L,B,H,D)."""
    def one_layer(ql, pkl, pvl):
        return paged_attention_ref(ql, pkl, pvl, block_table, lengths,
                                   scale=scale)
    return jax.vmap(one_layer)(q, pool_k, pool_v)
