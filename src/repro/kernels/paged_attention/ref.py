"""Pure-jnp oracles for paged decode attention (block-table indirection)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_attention_ref(q, pool_k, pool_v, block_table, lengths, *,
                        scale: float | None = None):
    """Decode attention over a paged KV pool.

    q:           (B, H, D)           one query token per sequence
    pool_k/v:    (P, T, K, D)        physical pages of T tokens
    block_table: (B, MaxPages) int32 logical→physical page mapping
    lengths:     (B,) int32          tokens valid per sequence
    Returns (B, H, D). A row with ``lengths[b] == 0`` returns exactly zero
    (the kernel never runs its compute body for such rows).
    """
    B, H, D = q.shape
    P, T, K, _ = pool_k.shape
    G = H // K
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    table = jnp.clip(block_table, 0, P - 1)
    # gather logical KV: (B, MaxPages*T, K, D)
    k = pool_k[table].reshape(B, -1, K, D)
    v = pool_v[table].reshape(B, -1, K, D)
    S = k.shape[1]
    qg = q.reshape(B, K, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k.astype(jnp.float32)) * scale
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    out = jnp.where((lengths > 0)[:, None, None, None], out, 0.0)
    return out.reshape(B, H, D).astype(q.dtype)


def paged_attention_layers_ref(q, pool_k, pool_v, block_table, lengths, *,
                               scale: float | None = None):
    """Multi-layer oracle: q (L,B,H,D); pool_k/v (L,P,T,K,D); one block
    table + ragged lengths shared by every layer. Returns (L,B,H,D)."""
    def one_layer(ql, pkl, pvl):
        return paged_attention_ref(ql, pkl, pvl, block_table, lengths,
                                   scale=scale)
    return jax.vmap(one_layer)(q, pool_k, pool_v)


def paged_attention_ragged_ref(q, pool_k, pool_v, block_table, lengths,
                               q_lens, *, scale: float | None = None):
    """Ragged-query oracle (fused mixed-batch ticks).

    q:           (B, Qmax, H, D)     up to Qmax new-token queries per row
    pool_k/v:    (P, T, K, D)        physical pages of T tokens
    block_table: (B, MaxPages) int32 logical→physical page mapping
    lengths:     (B,) int32          valid pool tokens INCLUDING the chunk
    q_lens:      (B,) int32          valid queries per row (decode: 1)
    Query ``i`` of row ``b`` sits at absolute position
    ``lengths[b] - q_lens[b] + i`` and attends causally to pool positions
    at or before it. Slots at or past ``q_lens[b]`` (and whole rows with
    ``q_lens[b] == 0``) return exactly zero. Returns (B, Qmax, H, D).
    """
    B, Qm, H, D = q.shape
    P, T, K, _ = pool_k.shape
    G = H // K
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    table = jnp.clip(block_table, 0, P - 1)
    k = pool_k[table].reshape(B, -1, K, D)
    v = pool_v[table].reshape(B, -1, K, D)
    S = k.shape[1]
    qg = q.reshape(B, Qm, K, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k.astype(jnp.float32)) * scale
    qpos = (lengths - q_lens)[:, None] + jnp.arange(Qm)[None, :]   # (B, Qm)
    qvalid = jnp.arange(Qm)[None, :] < q_lens[:, None]             # (B, Qm)
    allow = (jnp.arange(S)[None, None, :] <= qpos[:, :, None]) \
        & qvalid[:, :, None]
    s = jnp.where(allow[:, None, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p, v.astype(jnp.float32))
    out = jnp.where((qvalid & (lengths > 0)[:, None])
                    [:, :, None, None, None], out, 0.0)
    return out.reshape(B, Qm, H, D).astype(q.dtype)


def paged_attention_layers_ragged_ref(q, pool_k, pool_v, block_table,
                                      lengths, q_lens, *,
                                      scale: float | None = None):
    """Multi-layer ragged oracle: q (L,B,Qmax,H,D); pool_k/v (L,P,T,K,D);
    one block table + lengths + q_lens shared by every layer."""
    def one_layer(ql, pkl, pvl):
        return paged_attention_ragged_ref(ql, pkl, pvl, block_table,
                                          lengths, q_lens, scale=scale)
    return jax.vmap(one_layer)(q, pool_k, pool_v)


# ---------------------------------------------------------------------------
# Descriptor plane variants (int8 scale planes, MLA latent plane)
# ---------------------------------------------------------------------------
def _dequant_pool(pool_q, pool_scale):
    """int8 pages × per-(token, head) scales → fp32 (the fp32 oracle the
    in-kernel dequant is pinned against)."""
    return pool_q.astype(jnp.float32) * pool_scale.astype(jnp.float32)[..., None]


def paged_attention_ragged_q8_ref(q, pool_k, pool_v, pool_ks, pool_vs,
                                  block_table, lengths, q_lens, *,
                                  scale: float | None = None):
    """int8 ragged oracle: dequantize the whole pool to fp32, then run the
    dense ragged oracle. pool_k/v (P, T, K, D) int8; pool_ks/vs (P, T, K)."""
    return paged_attention_ragged_ref(
        q, _dequant_pool(pool_k, pool_ks).astype(q.dtype),
        _dequant_pool(pool_v, pool_vs).astype(q.dtype),
        block_table, lengths, q_lens, scale=scale)


def paged_attention_layers_ragged_q8_ref(q, pool_k, pool_v, pool_ks, pool_vs,
                                         block_table, lengths, q_lens, *,
                                         scale: float | None = None):
    """Multi-layer int8 ragged oracle: q (L,B,Qmax,H,D); pools
    (L,P,T,K,D) int8 + (L,P,T,K) scales."""
    def one_layer(ql, pkl, pvl, ksl, vsl):
        return paged_attention_ragged_q8_ref(ql, pkl, pvl, ksl, vsl,
                                             block_table, lengths, q_lens,
                                             scale=scale)
    return jax.vmap(one_layer)(q, pool_k, pool_v, pool_ks, pool_vs)


def mla_paged_attention_ragged_ref(q_c, q_r, pool_c, pool_kr, block_table,
                                   lengths, q_lens, *, scale: float):
    """MLA ragged oracle over the latent plane.

    q_c:     (B, Qmax, H, dc)  weight-absorbed queries (q_nope · w_uk)
    q_r:     (B, Qmax, H, dr)  rope queries
    pool_c:  (P, T, dc)        latent plane pages
    pool_kr: (P, T, dr)        rope-key plane pages
    Scores are ``(q_c·cᵀ + q_r·krᵀ) · scale`` (scale =
    1/sqrt(qk_nope + qk_rope), passed by the caller); the output is the
    probability-weighted latent (B, Qmax, H, dc) — ``w_uv``/``wo`` are the
    model's job. Padding slots and empty rows return exactly zero.
    """
    B, Qm, H, dc = q_c.shape
    P, T, _ = pool_c.shape
    table = jnp.clip(block_table, 0, P - 1)
    c = pool_c[table].reshape(B, -1, dc).astype(jnp.float32)    # (B, S, dc)
    kr = pool_kr[table].reshape(B, -1, pool_kr.shape[-1]).astype(jnp.float32)
    S = c.shape[1]
    s = (jnp.einsum("bqhc,btc->bhqt", q_c.astype(jnp.float32), c)
         + jnp.einsum("bqhr,btr->bhqt", q_r.astype(jnp.float32), kr)) * scale
    qpos = (lengths - q_lens)[:, None] + jnp.arange(Qm)[None, :]   # (B, Qm)
    qvalid = jnp.arange(Qm)[None, :] < q_lens[:, None]             # (B, Qm)
    allow = (jnp.arange(S)[None, None, :] <= qpos[:, :, None]) \
        & qvalid[:, :, None]
    s = jnp.where(allow[:, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqt,btc->bqhc", p, c)
    out = jnp.where((qvalid & (lengths > 0)[:, None])
                    [:, :, None, None], out, 0.0)
    return out.astype(q_c.dtype)


def mla_paged_attention_layers_ragged_ref(q_c, q_r, pool_c, pool_kr,
                                          block_table, lengths, q_lens, *,
                                          scale: float):
    """Multi-layer MLA ragged oracle: q_c (L,B,Qmax,H,dc); q_r
    (L,B,Qmax,H,dr); pool_c (L,P,T,dc); pool_kr (L,P,T,dr)."""
    def one_layer(qcl, qrl, pcl, prl):
        return mla_paged_attention_ragged_ref(qcl, qrl, pcl, prl,
                                              block_table, lengths, q_lens,
                                              scale=scale)
    return jax.vmap(one_layer)(q_c, q_r, pool_c, pool_kr)
