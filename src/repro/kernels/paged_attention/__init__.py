from repro.kernels.paged_attention.ops import (
    mla_paged_attention, mla_paged_attention_layers_ragged,
    mla_paged_attention_ragged, paged_attention, paged_attention_layers,
    paged_attention_layers_ragged, paged_attention_layers_ragged_q8,
    paged_attention_q8, paged_attention_ragged, paged_attention_ragged_q8)

__all__ = ["paged_attention", "paged_attention_layers",
           "paged_attention_ragged", "paged_attention_layers_ragged",
           "paged_attention_q8", "paged_attention_ragged_q8",
           "paged_attention_layers_ragged_q8",
           "mla_paged_attention", "mla_paged_attention_ragged",
           "mla_paged_attention_layers_ragged"]
