from repro.kernels.paged_attention.ops import (paged_attention,
                                               paged_attention_layers)

__all__ = ["paged_attention", "paged_attention_layers"]
