from repro.kernels.paged_attention.ops import (
    paged_attention, paged_attention_layers, paged_attention_layers_ragged,
    paged_attention_ragged)

__all__ = ["paged_attention", "paged_attention_layers",
           "paged_attention_ragged", "paged_attention_layers_ragged"]
