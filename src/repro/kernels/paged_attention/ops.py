"""jit'd public wrapper for paged decode attention."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.paged_attention.kernel import paged_attention_pallas
from repro.kernels.paged_attention.ref import paged_attention_ref


@partial(jax.jit, static_argnames=("scale", "force_pallas"))
def paged_attention(q, pool_k, pool_v, block_table, lengths, *, scale=None,
                    force_pallas: bool = False):
    """Decode attention over a paged KV pool (see kernel.py)."""
    if jax.default_backend() == "tpu":
        return paged_attention_pallas(q, pool_k, pool_v, block_table, lengths,
                                      scale=scale)
    if force_pallas:
        return paged_attention_pallas(q, pool_k, pool_v, block_table, lengths,
                                      scale=scale, interpret=True)
    return paged_attention_ref(q, pool_k, pool_v, block_table, lengths,
                               scale=scale)
