"""jit'd public wrappers for paged decode attention.

``paged_attention`` is the single-layer form; ``paged_attention_layers`` is
the serving stack's batched multi-layer entry point (one device-resident
``(L, P, T, K, D)`` pool, one ``(B, MP)`` block table shared across layers,
ragged ``(B,)`` lengths) used by the mirror-free pooled decode path.

``paged_attention_ragged`` / ``paged_attention_layers_ragged`` extend the
same entries from one query token per row to a ragged ``(B, Qmax, H, D)``
query block with per-row ``q_lens`` — the fused mixed-batch tick: decode
rows (``q_len == 1``) and prefill-chunk rows share one kernel launch, with
causal masking *within* the chunk against the page pool.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.paged_attention.kernel import (
    paged_attention_layers_pallas, paged_attention_layers_ragged_pallas,
    paged_attention_pallas, paged_attention_ragged_pallas)
from repro.kernels.paged_attention.ref import (
    paged_attention_layers_ragged_ref, paged_attention_layers_ref,
    paged_attention_ragged_ref, paged_attention_ref)


@partial(jax.jit, static_argnames=("scale", "force_pallas"))
def paged_attention(q, pool_k, pool_v, block_table, lengths, *, scale=None,
                    force_pallas: bool = False):
    """Decode attention over a paged KV pool (see kernel.py)."""
    if jax.default_backend() == "tpu":
        return paged_attention_pallas(q, pool_k, pool_v, block_table, lengths,
                                      scale=scale)
    if force_pallas:
        return paged_attention_pallas(q, pool_k, pool_v, block_table, lengths,
                                      scale=scale, interpret=True)
    return paged_attention_ref(q, pool_k, pool_v, block_table, lengths,
                               scale=scale)


@partial(jax.jit, static_argnames=("scale", "force_pallas"))
def paged_attention_layers(q, pool_k, pool_v, block_table, lengths, *,
                           scale=None, force_pallas: bool = False):
    """Batched multi-layer decode attention over a paged KV pool.

    q: (L, B, H, D); pool_k/v: (L, P, T, K, D); block_table: (B, MP);
    lengths: (B,). Rows with ``lengths[b] == 0`` return zeros.
    """
    if jax.default_backend() == "tpu":
        return paged_attention_layers_pallas(q, pool_k, pool_v, block_table,
                                             lengths, scale=scale)
    if force_pallas:
        return paged_attention_layers_pallas(q, pool_k, pool_v, block_table,
                                             lengths, scale=scale,
                                             interpret=True)
    return paged_attention_layers_ref(q, pool_k, pool_v, block_table,
                                      lengths, scale=scale)


@partial(jax.jit, static_argnames=("scale", "force_pallas"))
def paged_attention_ragged(q, pool_k, pool_v, block_table, lengths, q_lens,
                           *, scale=None, force_pallas: bool = False):
    """Ragged-query decode attention over a paged KV pool.

    q: (B, Qmax, H, D); pool_k/v: (P, T, K, D); block_table: (B, MP);
    lengths: (B,) valid pool tokens including the chunk; q_lens: (B,) valid
    queries per row. Padding query slots and ``q_lens == 0`` rows return
    exactly zero; ``q_lens == 1`` reduces to ``paged_attention``.
    """
    if jax.default_backend() == "tpu":
        return paged_attention_ragged_pallas(q, pool_k, pool_v, block_table,
                                             lengths, q_lens, scale=scale)
    if force_pallas:
        return paged_attention_ragged_pallas(q, pool_k, pool_v, block_table,
                                             lengths, q_lens, scale=scale,
                                             interpret=True)
    return paged_attention_ragged_ref(q, pool_k, pool_v, block_table,
                                      lengths, q_lens, scale=scale)


@partial(jax.jit, static_argnames=("scale", "force_pallas"))
def paged_attention_layers_ragged(q, pool_k, pool_v, block_table, lengths,
                                  q_lens, *, scale=None,
                                  force_pallas: bool = False):
    """Batched multi-layer ragged-query attention — the fused mixed-batch
    tick's one kernel launch. q: (L, B, Qmax, H, D); pool_k/v:
    (L, P, T, K, D); block_table: (B, MP); lengths/q_lens: (B,)."""
    if jax.default_backend() == "tpu":
        return paged_attention_layers_ragged_pallas(
            q, pool_k, pool_v, block_table, lengths, q_lens, scale=scale)
    if force_pallas:
        return paged_attention_layers_ragged_pallas(
            q, pool_k, pool_v, block_table, lengths, q_lens, scale=scale,
            interpret=True)
    return paged_attention_layers_ragged_ref(q, pool_k, pool_v, block_table,
                                             lengths, q_lens, scale=scale)
