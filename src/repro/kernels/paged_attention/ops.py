"""jit'd public wrappers for paged decode attention.

``paged_attention`` is the single-layer form; ``paged_attention_layers`` is
the serving stack's batched multi-layer entry point (one device-resident
``(L, P, T, K, D)`` pool, one ``(B, MP)`` block table shared across layers,
ragged ``(B,)`` lengths) used by the mirror-free pooled decode path.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.paged_attention.kernel import (
    paged_attention_layers_pallas, paged_attention_pallas)
from repro.kernels.paged_attention.ref import (
    paged_attention_layers_ref, paged_attention_ref)


@partial(jax.jit, static_argnames=("scale", "force_pallas"))
def paged_attention(q, pool_k, pool_v, block_table, lengths, *, scale=None,
                    force_pallas: bool = False):
    """Decode attention over a paged KV pool (see kernel.py)."""
    if jax.default_backend() == "tpu":
        return paged_attention_pallas(q, pool_k, pool_v, block_table, lengths,
                                      scale=scale)
    if force_pallas:
        return paged_attention_pallas(q, pool_k, pool_v, block_table, lengths,
                                      scale=scale, interpret=True)
    return paged_attention_ref(q, pool_k, pool_v, block_table, lengths,
                               scale=scale)


@partial(jax.jit, static_argnames=("scale", "force_pallas"))
def paged_attention_layers(q, pool_k, pool_v, block_table, lengths, *,
                           scale=None, force_pallas: bool = False):
    """Batched multi-layer decode attention over a paged KV pool.

    q: (L, B, H, D); pool_k/v: (L, P, T, K, D); block_table: (B, MP);
    lengths: (B,). Rows with ``lengths[b] == 0`` return zeros.
    """
    if jax.default_backend() == "tpu":
        return paged_attention_layers_pallas(q, pool_k, pool_v, block_table,
                                             lengths, scale=scale)
    if force_pallas:
        return paged_attention_layers_pallas(q, pool_k, pool_v, block_table,
                                             lengths, scale=scale,
                                             interpret=True)
    return paged_attention_layers_ref(q, pool_k, pool_v, block_table,
                                      lengths, scale=scale)
