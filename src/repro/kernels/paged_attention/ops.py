"""jit'd public wrappers for paged decode attention.

``paged_attention`` is the single-layer form; ``paged_attention_layers`` is
the serving stack's batched multi-layer entry point (one device-resident
``(L, P, T, K, D)`` pool, one ``(B, MP)`` block table shared across layers,
ragged ``(B,)`` lengths) used by the mirror-free pooled decode path.

``paged_attention_ragged`` / ``paged_attention_layers_ragged`` extend the
same entries from one query token per row to a ragged ``(B, Qmax, H, D)``
query block with per-row ``q_lens`` — the fused mixed-batch tick: decode
rows (``q_len == 1``) and prefill-chunk rows share one kernel launch, with
causal masking *within* the chunk against the page pool.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import (
    paged_attention_layers_pallas, paged_attention_layers_ragged_pallas,
    paged_attention_pallas, paged_attention_ragged_pallas)
from repro.kernels.paged_attention.ref import (
    paged_attention_layers_ragged_ref, paged_attention_layers_ref,
    paged_attention_ragged_ref, paged_attention_ref)


@partial(jax.jit, static_argnames=("scale", "force_pallas"))
def paged_attention(q, pool_k, pool_v, block_table, lengths, *, scale=None,
                    force_pallas: bool = False):
    """Decode attention over a paged KV pool (see kernel.py)."""
    if jax.default_backend() == "tpu":
        return paged_attention_pallas(q, pool_k, pool_v, block_table, lengths,
                                      scale=scale)
    if force_pallas:
        return paged_attention_pallas(q, pool_k, pool_v, block_table, lengths,
                                      scale=scale, interpret=True)
    return paged_attention_ref(q, pool_k, pool_v, block_table, lengths,
                               scale=scale)


@partial(jax.jit, static_argnames=("scale", "force_pallas"))
def paged_attention_layers(q, pool_k, pool_v, block_table, lengths, *,
                           scale=None, force_pallas: bool = False):
    """Batched multi-layer decode attention over a paged KV pool.

    q: (L, B, H, D); pool_k/v: (L, P, T, K, D); block_table: (B, MP);
    lengths: (B,). Rows with ``lengths[b] == 0`` return zeros.
    """
    if jax.default_backend() == "tpu":
        return paged_attention_layers_pallas(q, pool_k, pool_v, block_table,
                                             lengths, scale=scale)
    if force_pallas:
        return paged_attention_layers_pallas(q, pool_k, pool_v, block_table,
                                             lengths, scale=scale,
                                             interpret=True)
    return paged_attention_layers_ref(q, pool_k, pool_v, block_table,
                                      lengths, scale=scale)


@partial(jax.jit, static_argnames=("scale", "force_pallas"))
def paged_attention_ragged(q, pool_k, pool_v, block_table, lengths, q_lens,
                           *, scale=None, force_pallas: bool = False):
    """Ragged-query decode attention over a paged KV pool.

    q: (B, Qmax, H, D); pool_k/v: (P, T, K, D); block_table: (B, MP);
    lengths: (B,) valid pool tokens including the chunk; q_lens: (B,) valid
    queries per row. Padding query slots and ``q_lens == 0`` rows return
    exactly zero; ``q_lens == 1`` reduces to ``paged_attention``.
    """
    if jax.default_backend() == "tpu":
        return paged_attention_ragged_pallas(q, pool_k, pool_v, block_table,
                                             lengths, q_lens, scale=scale)
    if force_pallas:
        return paged_attention_ragged_pallas(q, pool_k, pool_v, block_table,
                                             lengths, q_lens, scale=scale,
                                             interpret=True)
    return paged_attention_ragged_ref(q, pool_k, pool_v, block_table,
                                      lengths, q_lens, scale=scale)


@partial(jax.jit, static_argnames=("scale", "force_pallas"))
def paged_attention_layers_ragged(q, pool_k, pool_v, block_table, lengths,
                                  q_lens, *, scale=None,
                                  force_pallas: bool = False):
    """Batched multi-layer ragged-query attention — the fused mixed-batch
    tick's one kernel launch. q: (L, B, Qmax, H, D); pool_k/v:
    (L, P, T, K, D); block_table: (B, MP); lengths/q_lens: (B,)."""
    if jax.default_backend() == "tpu":
        return paged_attention_layers_ragged_pallas(
            q, pool_k, pool_v, block_table, lengths, q_lens, scale=scale)
    if force_pallas:
        return paged_attention_layers_ragged_pallas(
            q, pool_k, pool_v, block_table, lengths, q_lens, scale=scale,
            interpret=True)
    return paged_attention_layers_ragged_ref(q, pool_k, pool_v, block_table,
                                             lengths, q_lens, scale=scale)


# ---------------------------------------------------------------------------
# Descriptor plane variants: int8 (dequant-in-kernel, per-page scale planes)
# and MLA (attention over the latent plane). Same tpu/interpret/ref dispatch.
# ---------------------------------------------------------------------------
from repro.kernels.paged_attention.kernel import (  # noqa: E402
    mla_paged_attention_layers_ragged_pallas, mla_paged_attention_ragged_pallas,
    paged_attention_layers_ragged_q8_pallas, paged_attention_ragged_q8_pallas)
from repro.kernels.paged_attention.ref import (  # noqa: E402
    mla_paged_attention_layers_ragged_ref, mla_paged_attention_ragged_ref,
    paged_attention_layers_ragged_q8_ref, paged_attention_ragged_q8_ref)


@partial(jax.jit, static_argnames=("scale", "force_pallas"))
def paged_attention_ragged_q8(q, pool_k, pool_v, pool_ks, pool_vs,
                              block_table, lengths, q_lens, *, scale=None,
                              force_pallas: bool = False):
    """Ragged-query attention over an int8 KV pool with per-(token, head)
    scale planes. q: (B, Qmax, H, D); pool_k/v: (P, T, K, D) int8;
    pool_ks/vs: (P, T, K); dequant happens in the kernel body, so pool
    pages move ~half the HBM bytes of fp16."""
    if jax.default_backend() == "tpu":
        return paged_attention_ragged_q8_pallas(
            q, pool_k, pool_v, pool_ks, pool_vs, block_table, lengths,
            q_lens, scale=scale)
    if force_pallas:
        return paged_attention_ragged_q8_pallas(
            q, pool_k, pool_v, pool_ks, pool_vs, block_table, lengths,
            q_lens, scale=scale, interpret=True)
    return paged_attention_ragged_q8_ref(q, pool_k, pool_v, pool_ks, pool_vs,
                                         block_table, lengths, q_lens,
                                         scale=scale)


@partial(jax.jit, static_argnames=("scale", "force_pallas"))
def paged_attention_layers_ragged_q8(q, pool_k, pool_v, pool_ks, pool_vs,
                                     block_table, lengths, q_lens, *,
                                     scale=None, force_pallas: bool = False):
    """Multi-layer int8 ragged entry: q (L, B, Qmax, H, D); pools
    (L, P, T, K, D) int8 + (L, P, T, K) scale planes."""
    if jax.default_backend() == "tpu":
        return paged_attention_layers_ragged_q8_pallas(
            q, pool_k, pool_v, pool_ks, pool_vs, block_table, lengths,
            q_lens, scale=scale)
    if force_pallas:
        return paged_attention_layers_ragged_q8_pallas(
            q, pool_k, pool_v, pool_ks, pool_vs, block_table, lengths,
            q_lens, scale=scale, interpret=True)
    return paged_attention_layers_ragged_q8_ref(
        q, pool_k, pool_v, pool_ks, pool_vs, block_table, lengths, q_lens,
        scale=scale)


@partial(jax.jit, static_argnames=("scale", "force_pallas"))
def paged_attention_q8(q, pool_k, pool_v, pool_ks, pool_vs, block_table,
                       lengths, *, scale=None, force_pallas: bool = False):
    """int8 decode entry (one query token per row): q (B, H, D). Defined as
    the ``q_len == 1`` slice of the ragged entry, so the two stay bitwise
    identical by construction."""
    B = q.shape[0]
    out = paged_attention_ragged_q8(
        q[:, None], pool_k, pool_v, pool_ks, pool_vs, block_table, lengths,
        jnp.ones((B,), jnp.int32), scale=scale, force_pallas=force_pallas)
    return out[:, 0]


@partial(jax.jit, static_argnames=("scale", "force_pallas"))
def mla_paged_attention_ragged(q_c, q_r, pool_c, pool_kr, block_table,
                               lengths, q_lens, *, scale, force_pallas=False):
    """MLA ragged entry over the latent plane. q_c: (B, Qmax, H, dc)
    weight-absorbed queries; q_r: (B, Qmax, H, dr) rope queries; pool_c:
    (P, T, dc); pool_kr: (P, T, dr). Returns the attended latent
    (B, Qmax, H, dc) — the model applies ``w_uv``/``wo`` after."""
    if jax.default_backend() == "tpu":
        return mla_paged_attention_ragged_pallas(
            q_c, q_r, pool_c, pool_kr, block_table, lengths, q_lens,
            scale=scale)
    if force_pallas:
        return mla_paged_attention_ragged_pallas(
            q_c, q_r, pool_c, pool_kr, block_table, lengths, q_lens,
            scale=scale, interpret=True)
    return mla_paged_attention_ragged_ref(q_c, q_r, pool_c, pool_kr,
                                          block_table, lengths, q_lens,
                                          scale=scale)


@partial(jax.jit, static_argnames=("scale", "force_pallas"))
def mla_paged_attention_layers_ragged(q_c, q_r, pool_c, pool_kr, block_table,
                                      lengths, q_lens, *, scale,
                                      force_pallas: bool = False):
    """Multi-layer MLA ragged entry: q_c (L, B, Qmax, H, dc); q_r
    (L, B, Qmax, H, dr); pool_c (L, P, T, dc); pool_kr (L, P, T, dr)."""
    if jax.default_backend() == "tpu":
        return mla_paged_attention_layers_ragged_pallas(
            q_c, q_r, pool_c, pool_kr, block_table, lengths, q_lens,
            scale=scale)
    if force_pallas:
        return mla_paged_attention_layers_ragged_pallas(
            q_c, q_r, pool_c, pool_kr, block_table, lengths, q_lens,
            scale=scale, interpret=True)
    return mla_paged_attention_layers_ragged_ref(
        q_c, q_r, pool_c, pool_kr, block_table, lengths, q_lens, scale=scale)


@partial(jax.jit, static_argnames=("scale", "force_pallas"))
def mla_paged_attention(q_c, q_r, pool_c, pool_kr, block_table, lengths, *,
                        scale, force_pallas: bool = False):
    """MLA decode entry (one query token per row): q_c (B, H, dc); q_r
    (B, H, dr). The ``q_len == 1`` slice of the ragged entry — bitwise
    identical by construction."""
    B = q_c.shape[0]
    out = mla_paged_attention_ragged(
        q_c[:, None], q_r[:, None], pool_c, pool_kr, block_table, lengths,
        jnp.ones((B,), jnp.int32), scale=scale, force_pallas=force_pallas)
    return out[:, 0]
