"""Paged decode-attention Pallas TPU kernels.

The paging design's on-device read path (DESIGN.md §2a): the KV cache lives
as fixed-size token pages in a physical pool; the block table is
scalar-prefetched (SMEM) and drives the BlockSpec index maps, so each grid
step DMAs exactly one page of K and V into VMEM — block-table indirection
*inside* the kernel, the TPU analogue of NVPages' radix-tree → page pointer
walk.

Four entry points, three kernel bodies (the two ragged entries share one
body parameterized by the grid's batch-axis offset):

* ``paged_attention_pallas`` — one layer, one query token per row: grid
  (B, K, max_pages) over a ``(P, T, K, D)`` pool.
* ``paged_attention_layers_pallas`` — the serving stack's batched
  multi-layer form: grid (L, B, K, max_pages) over a device-resident
  ``(L, P, T, K, D)`` pool, one block table shared by every layer (pages
  are allocated per sequence, not per layer). This is the mirror-free
  decode entry: the scheduler hands the kernel the pool + block table and
  no dense per-request KV copy ever exists.
* ``paged_attention_ragged_pallas`` / ``paged_attention_layers_ragged_pallas``
  — the ragged-query extension (ISSUE 5): each row carries a block of up to
  ``Qmax`` new-token queries (``q: (B, Qmax, H, D)``), with per-row
  ``q_lens`` raggedness. Decode rows (``q_len == 1``) and prefill-chunk
  rows (``q_len ≤ chunk``) attend in the SAME launch — the fused
  mixed-batch tick. Query ``i`` of row ``b`` sits at absolute position
  ``lengths[b] - q_lens[b] + i`` and attends causally to pool positions at
  or before it (causal *within* the chunk against the page pool). Slots at
  or past ``q_lens[b]`` produce exactly zero; ``q_lens[b] == 0`` rows
  (batch-width padding) produce exactly zero and are skipped entirely.
  With ``q_len == 1`` the math reduces bit-for-bit to the plain decode
  entries (the CI smoke gate pins this).

Online-softmax state lives in VMEM scratch across the page axis. Pages past
``lengths[b]`` are skipped with ``pl.when`` (no DMA cost on TPU since their
index maps clamp to page 0 and the body is skipped). A row with
``lengths[b] == 0`` never runs the compute body, so its output is exactly
zero — the refs mirror that contract.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pa_kernel(table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
               m_ref, l_ref, acc_ref, *, scale: float, page_tokens: int):
    b = pl.program_id(0)
    p = pl.program_id(2)
    last_p = pl.num_programs(2) - 1
    length = len_ref[b]

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = (p * page_tokens) < length

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (T, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)            # (T, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = p * page_tokens + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)              # (G, T)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        pr = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(pr, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            pr, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(p == last_p)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def paged_attention_pallas(q, pool_k, pool_v, block_table, lengths, *,
                           scale: float | None = None,
                           interpret: bool = False):
    """q: (B,H,D); pool_k/v: (P,T,K,D); block_table: (B,MP); lengths: (B,)."""
    B, H, D = q.shape
    P, T, K, _ = pool_k.shape
    MP = block_table.shape[1]
    G = H // K
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, K, G, D)
    # clamp table so dead pages have a valid physical index (skipped anyway)
    table = jnp.clip(block_table, 0, P - 1).astype(jnp.int32)

    kernel = functools.partial(_pa_kernel, scale=scale, page_tokens=T)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, MP),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, k, p, tbl, ln: (b, k, 0, 0)),
            pl.BlockSpec((1, T, 1, D),
                         lambda b, k, p, tbl, ln: (tbl[b, p], 0, k, 0)),
            pl.BlockSpec((1, T, 1, D),
                         lambda b, k, p, tbl, ln: (tbl[b, p], 0, k, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, k, p, tbl, ln: (b, k, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        interpret=interpret,
    )(table, lengths.astype(jnp.int32), qg, pool_k, pool_v)
    return out.reshape(B, H, D)


def _pa_layers_kernel(table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                      m_ref, l_ref, acc_ref, *, scale: float,
                      page_tokens: int):
    b = pl.program_id(1)
    p = pl.program_id(3)
    last_p = pl.num_programs(3) - 1
    length = len_ref[b]

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = (p * page_tokens) < length

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0, 0].astype(jnp.float32)               # (G, D)
        k = k_ref[0, 0, :, 0, :].astype(jnp.float32)         # (T, D)
        v = v_ref[0, 0, :, 0, :].astype(jnp.float32)         # (T, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = p * page_tokens + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)              # (G, T)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        pr = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(pr, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            pr, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(p == last_p)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, 0] = out.astype(o_ref.dtype)


def paged_attention_layers_pallas(q, pool_k, pool_v, block_table, lengths, *,
                                  scale: float | None = None,
                                  interpret: bool = False):
    """Batched multi-layer entry: q: (L,B,H,D); pool_k/v: (L,P,T,K,D);
    block_table: (B,MP) shared across layers; lengths: (B,) ragged."""
    L, B, H, D = q.shape
    _, P, T, K, _ = pool_k.shape
    MP = block_table.shape[1]
    G = H // K
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    qg = q.reshape(L, B, K, G, D)
    table = jnp.clip(block_table, 0, P - 1).astype(jnp.int32)

    kernel = functools.partial(_pa_layers_kernel, scale=scale, page_tokens=T)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(L, B, K, MP),
        in_specs=[
            pl.BlockSpec((1, 1, 1, G, D),
                         lambda l, b, k, p, tbl, ln: (l, b, k, 0, 0)),
            pl.BlockSpec((1, 1, T, 1, D),
                         lambda l, b, k, p, tbl, ln: (l, tbl[b, p], 0, k, 0)),
            pl.BlockSpec((1, 1, T, 1, D),
                         lambda l, b, k, p, tbl, ln: (l, tbl[b, p], 0, k, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, G, D),
                               lambda l, b, k, p, tbl, ln: (l, b, k, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((L, B, K, G, D), q.dtype),
        interpret=interpret,
    )(table, lengths.astype(jnp.int32), qg, pool_k, pool_v)
    return out.reshape(L, B, H, D)


# ---------------------------------------------------------------------------
# Ragged-query entries (fused mixed-batch ticks, ISSUE 5)
# ---------------------------------------------------------------------------
def _ragged_softmax_step(s, m_ref, l_ref, acc_ref, v):
    """One online-softmax update over a (QG, T) score block whose rows past
    ``q_len`` (query padding) are fully masked. Masked probabilities are
    zeroed explicitly: a fully-masked row's running max stays NEG_INF and
    ``exp(s - m)`` would otherwise evaluate to exp(0) = 1 garbage."""
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    pr = jnp.where(s > NEG_INF * 0.5, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(pr, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        pr, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _pa_ragged_kernel(table_ref, len_ref, qlen_ref, q_ref, k_ref, v_ref,
                      o_ref, m_ref, l_ref, acc_ref, *, scale: float,
                      page_tokens: int, group: int, batch_axis: int):
    """Shared ragged-query kernel body: the single-layer entry runs it with
    ``batch_axis=0`` over grid (B, K, MP), the multi-layer entry with
    ``batch_axis=1`` over grid (L, B, K, MP) — the layer axis only shifts
    the program ids and adds a leading 1 to every block, which the
    reshapes below collapse."""
    b = pl.program_id(batch_axis)
    p = pl.program_id(batch_axis + 2)
    last_p = pl.num_programs(batch_axis + 2) - 1
    length = len_ref[b]
    q_len = qlen_ref[b]

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = ((p * page_tokens) < length) & (q_len > 0)

    @pl.when(live)
    def _compute():
        D = acc_ref.shape[-1]
        q = q_ref[...].reshape(acc_ref.shape).astype(jnp.float32)  # (QG, D)
        k = k_ref[...].reshape(page_tokens, D).astype(jnp.float32)
        v = v_ref[...].reshape(page_tokens, D).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = p * page_tokens + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        qi = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group
        # query i sits at absolute position length - q_len + i: causal
        # within the chunk against the pool; padding query slots masked out
        allow = (pos <= (length - q_len + qi)) & (qi < q_len)
        s = jnp.where(allow, s, NEG_INF)                     # (QG, T)
        _ragged_softmax_step(s, m_ref, l_ref, acc_ref, v)

    @pl.when(p == last_p)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = out.astype(o_ref.dtype).reshape(o_ref.shape)


def paged_attention_ragged_pallas(q, pool_k, pool_v, block_table, lengths,
                                  q_lens, *, scale: float | None = None,
                                  interpret: bool = False):
    """Ragged-query single-layer entry: q (B, Qmax, H, D); pool_k/v
    (P, T, K, D); block_table (B, MP); lengths/q_lens (B,)."""
    B, Qm, H, D = q.shape
    P, T, K, _ = pool_k.shape
    MP = block_table.shape[1]
    G = H // K
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    # (B, K, Qmax*G, D): one contiguous query block per (row, kv-head)
    qg = q.reshape(B, Qm, K, G, D).transpose(0, 2, 1, 3, 4).reshape(
        B, K, Qm * G, D)
    table = jnp.clip(block_table, 0, P - 1).astype(jnp.int32)

    kernel = functools.partial(_pa_ragged_kernel, scale=scale,
                               page_tokens=T, group=G, batch_axis=0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, K, MP),
        in_specs=[
            pl.BlockSpec((1, 1, Qm * G, D),
                         lambda b, k, p, tbl, ln, ql: (b, k, 0, 0)),
            pl.BlockSpec((1, T, 1, D),
                         lambda b, k, p, tbl, ln, ql: (tbl[b, p], 0, k, 0)),
            pl.BlockSpec((1, T, 1, D),
                         lambda b, k, p, tbl, ln, ql: (tbl[b, p], 0, k, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Qm * G, D),
                               lambda b, k, p, tbl, ln, ql: (b, k, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Qm * G, 1), jnp.float32),
            pltpu.VMEM((Qm * G, 1), jnp.float32),
            pltpu.VMEM((Qm * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, Qm * G, D), q.dtype),
        interpret=interpret,
    )(table, lengths.astype(jnp.int32), q_lens.astype(jnp.int32),
      qg, pool_k, pool_v)
    return out.reshape(B, K, Qm, G, D).transpose(0, 2, 1, 3, 4).reshape(
        B, Qm, H, D)


def paged_attention_layers_ragged_pallas(q, pool_k, pool_v, block_table,
                                         lengths, q_lens, *,
                                         scale: float | None = None,
                                         interpret: bool = False):
    """Ragged-query batched multi-layer entry — the fused mixed-batch tick:
    q (L, B, Qmax, H, D); pool_k/v (L, P, T, K, D); block_table (B, MP);
    lengths/q_lens (B,) shared by every layer."""
    L, B, Qm, H, D = q.shape
    _, P, T, K, _ = pool_k.shape
    MP = block_table.shape[1]
    G = H // K
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    qg = q.reshape(L, B, Qm, K, G, D).transpose(0, 1, 3, 2, 4, 5).reshape(
        L, B, K, Qm * G, D)
    table = jnp.clip(block_table, 0, P - 1).astype(jnp.int32)

    kernel = functools.partial(_pa_ragged_kernel, scale=scale,
                               page_tokens=T, group=G, batch_axis=1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(L, B, K, MP),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Qm * G, D),
                         lambda l, b, k, p, tbl, ln, ql: (l, b, k, 0, 0)),
            pl.BlockSpec((1, 1, T, 1, D),
                         lambda l, b, k, p, tbl, ln, ql:
                         (l, tbl[b, p], 0, k, 0)),
            pl.BlockSpec((1, 1, T, 1, D),
                         lambda l, b, k, p, tbl, ln, ql:
                         (l, tbl[b, p], 0, k, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Qm * G, D),
                               lambda l, b, k, p, tbl, ln, ql:
                               (l, b, k, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Qm * G, 1), jnp.float32),
            pltpu.VMEM((Qm * G, 1), jnp.float32),
            pltpu.VMEM((Qm * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((L, B, K, Qm * G, D), q.dtype),
        interpret=interpret,
    )(table, lengths.astype(jnp.int32), q_lens.astype(jnp.int32),
      qg, pool_k, pool_v)
    return out.reshape(L, B, K, Qm, G, D).transpose(0, 1, 3, 2, 4, 5).reshape(
        L, B, Qm, H, D)


# ---------------------------------------------------------------------------
# Descriptor-driven plane variants (ISSUE 9): the ragged entries above are
# the ``dense`` cache family's kernels; the int8 family adds per-page scale
# planes (dequant happens IN the kernel, so pool pages stay int8 in HBM and
# the dominant KV read moves ~half the bytes), and the MLA family attends
# over the latent plane (one (dc,) latent + one (dr,) rope key per token,
# shared by every head — no K grid axis). Which entry a serving step uses
# comes from the model's CacheDescriptor (core/engines/desc.py).
# ---------------------------------------------------------------------------
def _pa_ragged_q8_kernel(table_ref, len_ref, qlen_ref, q_ref, k_ref, v_ref,
                         ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                         scale: float, page_tokens: int, group: int,
                         batch_axis: int):
    """Ragged-query body with in-kernel dequant: K/V pages arrive int8,
    their per-(token, head) bf16 scales ride as separate planes, and the
    fp32 product ``int8 * scale`` feeds the same online softmax as the
    dense body — numerically the ``dequantize_kv`` grid, never
    materialized in HBM."""
    b = pl.program_id(batch_axis)
    p = pl.program_id(batch_axis + 2)
    last_p = pl.num_programs(batch_axis + 2) - 1
    length = len_ref[b]
    q_len = qlen_ref[b]

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = ((p * page_tokens) < length) & (q_len > 0)

    @pl.when(live)
    def _compute():
        D = acc_ref.shape[-1]
        q = q_ref[...].reshape(acc_ref.shape).astype(jnp.float32)  # (QG, D)
        ks = ks_ref[...].reshape(page_tokens, 1).astype(jnp.float32)
        vs = vs_ref[...].reshape(page_tokens, 1).astype(jnp.float32)
        k = k_ref[...].reshape(page_tokens, D).astype(jnp.float32) * ks
        v = v_ref[...].reshape(page_tokens, D).astype(jnp.float32) * vs
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = p * page_tokens + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        qi = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group
        allow = (pos <= (length - q_len + qi)) & (qi < q_len)
        s = jnp.where(allow, s, NEG_INF)
        _ragged_softmax_step(s, m_ref, l_ref, acc_ref, v)

    @pl.when(p == last_p)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = out.astype(o_ref.dtype).reshape(o_ref.shape)


def paged_attention_ragged_q8_pallas(q, pool_k, pool_v, pool_ks, pool_vs,
                                     block_table, lengths, q_lens, *,
                                     scale: float | None = None,
                                     interpret: bool = False):
    """int8 ragged single-layer entry: q (B, Qmax, H, D); pool_k/v
    (P, T, K, D) int8; pool_ks/vs (P, T, K) scale planes; block_table
    (B, MP); lengths/q_lens (B,)."""
    B, Qm, H, D = q.shape
    P, T, K, _ = pool_k.shape
    MP = block_table.shape[1]
    G = H // K
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, Qm, K, G, D).transpose(0, 2, 1, 3, 4).reshape(
        B, K, Qm * G, D)
    table = jnp.clip(block_table, 0, P - 1).astype(jnp.int32)

    kernel = functools.partial(_pa_ragged_q8_kernel, scale=scale,
                               page_tokens=T, group=G, batch_axis=0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, K, MP),
        in_specs=[
            pl.BlockSpec((1, 1, Qm * G, D),
                         lambda b, k, p, tbl, ln, ql: (b, k, 0, 0)),
            pl.BlockSpec((1, T, 1, D),
                         lambda b, k, p, tbl, ln, ql: (tbl[b, p], 0, k, 0)),
            pl.BlockSpec((1, T, 1, D),
                         lambda b, k, p, tbl, ln, ql: (tbl[b, p], 0, k, 0)),
            pl.BlockSpec((1, T, 1),
                         lambda b, k, p, tbl, ln, ql: (tbl[b, p], 0, k)),
            pl.BlockSpec((1, T, 1),
                         lambda b, k, p, tbl, ln, ql: (tbl[b, p], 0, k)),
        ],
        out_specs=pl.BlockSpec((1, 1, Qm * G, D),
                               lambda b, k, p, tbl, ln, ql: (b, k, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Qm * G, 1), jnp.float32),
            pltpu.VMEM((Qm * G, 1), jnp.float32),
            pltpu.VMEM((Qm * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, Qm * G, D), q.dtype),
        interpret=interpret,
    )(table, lengths.astype(jnp.int32), q_lens.astype(jnp.int32),
      qg, pool_k, pool_v, pool_ks, pool_vs)
    return out.reshape(B, K, Qm, G, D).transpose(0, 2, 1, 3, 4).reshape(
        B, Qm, H, D)


def paged_attention_layers_ragged_q8_pallas(q, pool_k, pool_v, pool_ks,
                                            pool_vs, block_table, lengths,
                                            q_lens, *,
                                            scale: float | None = None,
                                            interpret: bool = False):
    """int8 ragged multi-layer entry: q (L, B, Qmax, H, D); pool_k/v
    (L, P, T, K, D) int8; pool_ks/vs (L, P, T, K); shared block table."""
    L, B, Qm, H, D = q.shape
    _, P, T, K, _ = pool_k.shape
    MP = block_table.shape[1]
    G = H // K
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    qg = q.reshape(L, B, Qm, K, G, D).transpose(0, 1, 3, 2, 4, 5).reshape(
        L, B, K, Qm * G, D)
    table = jnp.clip(block_table, 0, P - 1).astype(jnp.int32)

    kernel = functools.partial(_pa_ragged_q8_kernel, scale=scale,
                               page_tokens=T, group=G, batch_axis=1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(L, B, K, MP),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Qm * G, D),
                         lambda l, b, k, p, tbl, ln, ql: (l, b, k, 0, 0)),
            pl.BlockSpec((1, 1, T, 1, D),
                         lambda l, b, k, p, tbl, ln, ql:
                         (l, tbl[b, p], 0, k, 0)),
            pl.BlockSpec((1, 1, T, 1, D),
                         lambda l, b, k, p, tbl, ln, ql:
                         (l, tbl[b, p], 0, k, 0)),
            pl.BlockSpec((1, 1, T, 1),
                         lambda l, b, k, p, tbl, ln, ql:
                         (l, tbl[b, p], 0, k)),
            pl.BlockSpec((1, 1, T, 1),
                         lambda l, b, k, p, tbl, ln, ql:
                         (l, tbl[b, p], 0, k)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Qm * G, D),
                               lambda l, b, k, p, tbl, ln, ql:
                               (l, b, k, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Qm * G, 1), jnp.float32),
            pltpu.VMEM((Qm * G, 1), jnp.float32),
            pltpu.VMEM((Qm * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((L, B, K, Qm * G, D), q.dtype),
        interpret=interpret,
    )(table, lengths.astype(jnp.int32), q_lens.astype(jnp.int32),
      qg, pool_k, pool_v, pool_ks, pool_vs)
    return out.reshape(L, B, K, Qm, G, D).transpose(0, 1, 3, 2, 4, 5).reshape(
        L, B, Qm, H, D)


def _mla_ragged_kernel(table_ref, len_ref, qlen_ref, qc_ref, qr_ref, c_ref,
                       kr_ref, o_ref, m_ref, l_ref, acc_ref, *, scale: float,
                       page_tokens: int, heads: int, batch_axis: int):
    """MLA ragged body (weight-absorbed decode over the latent plane): one
    ``(dc,)`` latent + one ``(dr,)`` rope key per pooled token, shared by
    every query head — scores are ``q_c·cᵀ + q_r·krᵀ`` and the output is
    the probability-weighted latent (the model applies ``w_uv``/``wo``
    after). MQA-like: no K grid axis, the whole head block rides one page
    DMA of the latent."""
    b = pl.program_id(batch_axis)
    p = pl.program_id(batch_axis + 1)
    last_p = pl.num_programs(batch_axis + 1) - 1
    length = len_ref[b]
    q_len = qlen_ref[b]

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = ((p * page_tokens) < length) & (q_len > 0)

    @pl.when(live)
    def _compute():
        dc = acc_ref.shape[-1]
        qh = acc_ref.shape[0]                                  # Qmax * H
        qc = qc_ref[...].reshape(qh, dc).astype(jnp.float32)
        qr = qr_ref[...].reshape(qh, -1).astype(jnp.float32)
        c = c_ref[...].reshape(page_tokens, dc).astype(jnp.float32)
        kr = kr_ref[...].reshape(page_tokens, -1).astype(jnp.float32)
        s = (jax.lax.dot_general(qc, c, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
             + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32)
             ) * scale
        pos = p * page_tokens + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        qi = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // heads
        allow = (pos <= (length - q_len + qi)) & (qi < q_len)
        s = jnp.where(allow, s, NEG_INF)
        _ragged_softmax_step(s, m_ref, l_ref, acc_ref, c)

    @pl.when(p == last_p)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = out.astype(o_ref.dtype).reshape(o_ref.shape)


def mla_paged_attention_ragged_pallas(q_c, q_r, pool_c, pool_kr, block_table,
                                      lengths, q_lens, *, scale: float,
                                      interpret: bool = False):
    """MLA ragged single-layer entry: q_c (B, Qmax, H, dc) absorbed
    queries; q_r (B, Qmax, H, dr) rope queries; pool_c (P, T, dc) latent
    plane; pool_kr (P, T, dr) rope-key plane. Returns the attended latent
    o_c (B, Qmax, H, dc)."""
    B, Qm, H, dc = q_c.shape
    dr = q_r.shape[-1]
    P, T, _ = pool_c.shape
    MP = block_table.shape[1]
    qc = q_c.reshape(B, Qm * H, dc)
    qr = q_r.reshape(B, Qm * H, dr)
    table = jnp.clip(block_table, 0, P - 1).astype(jnp.int32)

    kernel = functools.partial(_mla_ragged_kernel, scale=scale,
                               page_tokens=T, heads=H, batch_axis=0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, MP),
        in_specs=[
            pl.BlockSpec((1, Qm * H, dc),
                         lambda b, p, tbl, ln, ql: (b, 0, 0)),
            pl.BlockSpec((1, Qm * H, dr),
                         lambda b, p, tbl, ln, ql: (b, 0, 0)),
            pl.BlockSpec((1, T, dc),
                         lambda b, p, tbl, ln, ql: (tbl[b, p], 0, 0)),
            pl.BlockSpec((1, T, dr),
                         lambda b, p, tbl, ln, ql: (tbl[b, p], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Qm * H, dc),
                               lambda b, p, tbl, ln, ql: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Qm * H, 1), jnp.float32),
            pltpu.VMEM((Qm * H, 1), jnp.float32),
            pltpu.VMEM((Qm * H, dc), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Qm * H, dc), q_c.dtype),
        interpret=interpret,
    )(table, lengths.astype(jnp.int32), q_lens.astype(jnp.int32),
      qc, qr, pool_c, pool_kr)
    return out.reshape(B, Qm, H, dc)


def mla_paged_attention_layers_ragged_pallas(q_c, q_r, pool_c, pool_kr,
                                             block_table, lengths, q_lens, *,
                                             scale: float,
                                             interpret: bool = False):
    """MLA ragged multi-layer entry: q_c (L, B, Qmax, H, dc); q_r
    (L, B, Qmax, H, dr); pool_c (L, P, T, dc); pool_kr (L, P, T, dr)."""
    L, B, Qm, H, dc = q_c.shape
    dr = q_r.shape[-1]
    _, P, T, _ = pool_c.shape
    MP = block_table.shape[1]
    qc = q_c.reshape(L, B, Qm * H, dc)
    qr = q_r.reshape(L, B, Qm * H, dr)
    table = jnp.clip(block_table, 0, P - 1).astype(jnp.int32)

    kernel = functools.partial(_mla_ragged_kernel, scale=scale,
                               page_tokens=T, heads=H, batch_axis=1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(L, B, MP),
        in_specs=[
            pl.BlockSpec((1, 1, Qm * H, dc),
                         lambda l, b, p, tbl, ln, ql: (l, b, 0, 0)),
            pl.BlockSpec((1, 1, Qm * H, dr),
                         lambda l, b, p, tbl, ln, ql: (l, b, 0, 0)),
            pl.BlockSpec((1, 1, T, dc),
                         lambda l, b, p, tbl, ln, ql: (l, tbl[b, p], 0, 0)),
            pl.BlockSpec((1, 1, T, dr),
                         lambda l, b, p, tbl, ln, ql: (l, tbl[b, p], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Qm * H, dc),
                               lambda l, b, p, tbl, ln, ql: (l, b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Qm * H, 1), jnp.float32),
            pltpu.VMEM((Qm * H, 1), jnp.float32),
            pltpu.VMEM((Qm * H, dc), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((L, B, Qm * H, dc), q_c.dtype),
        interpret=interpret,
    )(table, lengths.astype(jnp.int32), q_lens.astype(jnp.int32),
      qc, qr, pool_c, pool_kr)
    return out.reshape(L, B, Qm, H, dc)
