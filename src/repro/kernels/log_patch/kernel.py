"""log_patch Pallas TPU kernel: apply KV-log records to page buffers.

The logging design's on-device drain/patch path (DESIGN.md §2a): a batch of
log records (token-granular KV vectors with (page, slot) targets) is
scattered into the page pool. The record index drives a scalar-prefetched
page lookup, one grid step per record; TPU grid iteration is sequential, so
records apply in log order (later records win — replay semantics).

The page block is copied through VMEM (read-modify-write of one page per
record); on TPU consecutive records hitting the same page keep the block
resident, which is exactly the sequential-locality the log layout provides.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lp_kernel(page_idx_ref, slot_idx_ref, valid_ref, pool_ref, rec_ref,
               o_ref, *, num_records: int):
    p = pl.program_id(0)
    # each grid step owns one page: copy it through VMEM once...
    o_ref[...] = pool_ref[...]

    # ...then apply every record targeting it, in log order (later wins)
    def body(n, _):
        slot = slot_idx_ref[n]
        match = jnp.logical_and(page_idx_ref[n] == p, valid_ref[n] != 0)

        @pl.when(match)
        def _apply():
            o_ref[0, pl.ds(slot, 1), :] = rec_ref[pl.ds(n, 1), :].astype(
                o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, num_records, body, 0)


def log_patch_pallas(pool, payloads, page_idx, slot_idx, valid=None, *,
                     interpret: bool = False):
    """pool: (P, T, C); payloads: (N, C); page/slot_idx: (N,). → patched pool.

    Grid is over *pages* (each visited exactly once — clean write set,
    no aliasing hazards); the in-kernel loop scans the record batch, which is
    resident in VMEM (drain batches are ≤ a few hundred records).
    """
    P, T, C = pool.shape
    N = payloads.shape[0]
    if valid is None:
        valid = jnp.ones((N,), jnp.int32)
    else:
        valid = valid.astype(jnp.int32)
    page_idx = jnp.clip(page_idx, 0, P - 1).astype(jnp.int32)
    slot_idx = jnp.clip(slot_idx, 0, T - 1).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(P,),
        in_specs=[
            pl.BlockSpec((1, T, C), lambda p, pg, sl, vd: (p, 0, 0)),
            pl.BlockSpec((N, C), lambda p, pg, sl, vd: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, T, C), lambda p, pg, sl, vd: (p, 0, 0)),
    )
    kernel = functools.partial(_lp_kernel, num_records=N)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        interpret=interpret,
    )(page_idx, slot_idx, valid, pool, payloads)
