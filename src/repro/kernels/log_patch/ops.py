"""jit'd public wrapper for log_patch."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.log_patch.kernel import log_patch_pallas
from repro.kernels.log_patch.ref import log_patch_ref


@partial(jax.jit, static_argnames=("force_pallas",), )
def log_patch(pool, payloads, page_idx, slot_idx, valid=None, *,
              force_pallas: bool = False):
    """Apply KV log records onto page buffers (see kernel.py)."""
    if jax.default_backend() == "tpu":
        return log_patch_pallas(pool, payloads, page_idx, slot_idx, valid)
    if force_pallas:
        return log_patch_pallas(pool, payloads, page_idx, slot_idx, valid,
                                interpret=True)
    return log_patch_ref(pool, payloads, page_idx, slot_idx, valid)
