from repro.kernels.log_patch.ops import log_patch

__all__ = ["log_patch"]
