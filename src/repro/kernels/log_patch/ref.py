"""Pure-jnp oracle for log_patch: replay KV log records onto pages in order."""
from __future__ import annotations

import jax.numpy as jnp


def log_patch_ref(pool, payloads, page_idx, slot_idx, valid=None):
    """Apply records in sequence order (later records win).

    pool:     (P, T, C)
    payloads: (N, C)
    page_idx: (N,) int32;  slot_idx: (N,) int32;  valid: (N,) bool
    Returns the patched pool.
    """
    if valid is None:
        valid = jnp.ones(payloads.shape[:1], bool)
    # sequential-order scatter: .at[] with duplicate indices applies in order
    # only for some modes; enforce by masking earlier duplicates
    N = payloads.shape[0]

    def body(pool, i):
        p = pool.at[page_idx[i], slot_idx[i]].set(
            jnp.where(valid[i], payloads[i].astype(pool.dtype),
                      pool[page_idx[i], slot_idx[i]]))
        return p, None
    import jax
    pool, _ = jax.lax.scan(body, pool, jnp.arange(N))
    return pool
