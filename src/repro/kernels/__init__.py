"""Pallas TPU kernels for the framework's compute hot-spots (DESIGN.md §2a):

* flash_attention — chunked online-softmax attention (train/prefill)
* paged_attention — decode attention over a paged KV pool with block-table
  indirection (the paging design's on-device read path)
* paged_attention_layers — the batched multi-layer form of the same kernel:
  the mirror-free serving decode entry point
* paged_attention_ragged / paged_attention_layers_ragged — the ragged-query
  forms: up to ``Qmax`` new-token queries per row, so decode rows and
  prefill-chunk rows share one launch (the fused mixed-batch tick)
* log_patch       — apply KV log records to page-shaped buffers (the logging
  design's on-device drain/patch path)

Block-table contract (shared by the kernels, ``PagedKVCache``'s device pool,
and the pooled serving decode path):

* **Pool layout** — K and V pools are ``(L, P, T, K, D)`` device arrays:
  ``L`` model layers, ``P`` physical pages, ``T = page_tokens`` token slots
  per page, ``K`` KV heads, ``D`` head dim. The single-layer entry takes one
  ``(P, T, K, D)`` slice. Physical page index ``p`` addresses the *same*
  page slot in every layer — pages are allocated per sequence, never per
  layer, so one block table serves the whole stack.
* **Block table** — ``(B, MP) int32``; row ``b`` maps the sequence's logical
  page ``i`` to physical page ``table[b, i]``. Entries at or past
  ``ceil(lengths[b] / T)`` are dead: the kernels clamp them into range and
  skip their compute (and, on TPU, their DMA), so any padding value is safe.
* **Ragged lengths** — ``lengths: (B,) int32`` carries the KV raggedness;
  token slots at or past ``lengths[b]`` inside the last live page are
  masked. ``lengths[b] == 0`` rows produce exactly zero output.
* **Ragged queries** (the ``*_ragged`` entries) — ``q: (B, Qmax, H, D)``
  holds each row's block of new-token queries, padded to a shared ``Qmax``;
  ``q_lens: (B,) int32`` is the per-row query count (decode rows: 1,
  prefill-chunk rows: up to ``chunk_tokens``). ``lengths[b]`` INCLUDES the
  chunk: query ``i < q_lens[b]`` sits at absolute position
  ``lengths[b] - q_lens[b] + i`` and attends causally to pool positions at
  or before it — intra-chunk causal masking against the pool. Query slots
  at or past ``q_lens[b]`` produce exactly zero; ``q_lens[b] == 0`` rows
  (batch-width padding on the bucketing ladder) are skipped entirely and
  produce exactly zero. ``q_len == 1`` is bit-for-bit the plain decode
  entry (pinned by ``kernel_bench --smoke``).
* **Speculative decode rows** (draft-and-verify, ISSUE 7) — a decode row
  may carry ``q_len = 1 + k`` query slots: the committed next token plus
  ``k`` unverified drafts, with their KV already scattered into the pool
  and ``lengths[b]`` counting the whole block. No new kernel semantics:
  slot ``i`` sits at ``lengths[b] - q_lens[b] + i`` exactly like a prefill
  chunk, so verification (does slot ``i-1``'s argmax equal draft ``i``?)
  falls out of the one fused launch. On rejection the engine rolls
  ``lengths`` back to the committed count and frees now-empty trailing
  pages; the rejected KV left inside retained pages and the stale table
  tail are invisible to the next launch because ``lengths`` is the only
  visibility authority — the same discipline that masks padding scatters
  (``mode="drop"``). Pinned by ``tests/test_kernels.py``
  (commit-one-more-slot launches are bit-for-bit prefixes of the block
  launch; poisoned rolled-back slots change nothing).
* **Multi-plane layouts** (cache descriptors, ISSUE 9) — the dense entries
  above are the ``(k, v)``-plane special case. A model family's
  ``CacheDescriptor`` (``repro.core.engines.desc``) names the planes its
  pool actually holds, and each plane is its own ``(L, P, T, *shape)``
  device array sharing ONE block table, ONE ``lengths`` and ONE ``q_lens``
  per batch — everything in this contract (dead-page clamping, ragged
  masking, speculative rewind, bucketing, COW aliasing) applies per plane
  unchanged. Two plane-specific entries exist:

  - ``paged_attention_ragged_q8`` / ``paged_attention_layers_ragged_q8`` —
    int8 family: ``k``/``v`` pages are ``(P, T, K, D) int8`` and ride with
    per-(token, head) **scale planes** ``k_scale``/``v_scale`` of shape
    ``(P, T, K) bfloat16``. Dequant (``int8 × scale → fp32``) happens in
    the kernel body, so the dominant pool read moves ~half the HBM bytes
    of fp16; the fp32 oracle is dequantize-then-dense-ref, pinned within
    tolerance by ``tests/test_kernels.py``.
  - ``mla_paged_attention(_ragged)`` — MLA family: the pool holds ONE
    latent plane ``c: (P, T, dc)`` and one rope-key plane
    ``kr: (P, T, dr)`` per token, shared by every query head (no K axis in
    the grid). Queries arrive weight-absorbed (``q_c = q_nope · w_uk``,
    plus rope ``q_r``), scores are ``(q_c·cᵀ + q_r·krᵀ) · scale`` with the
    caller's ``1/sqrt(qk_nope + qk_rope)``, and the output is the
    attended latent ``(B, Qmax, H, dc)`` — ``w_uv``/``wo`` stay in the
    model.

  SSM state planes never reach a paged kernel: they are per-seq rows that
  ride alongside the block tables in the engine (committed/rewound with
  the row), not per-token pages.
* **Bucketing ladder** — callers (the serving engine) pad batch width and
  ``Qmax`` up to a power-of-two ladder so the jitted entries stop
  recompiling per width; the padding rows/slots are masked by
  ``q_lens``/``lengths`` as above.
* **Ownership** — the device pool is owned by the KV engine
  (``repro.core.kvcache.PagedKVCache`` in pooled mode), which ties page
  alloc/free to its resident/LRU accounting; the FS tier never sees pool
  pages, only whole-sequence spill blobs. Eviction rule: under HBM pressure
  the engine spills least-recently-used *pool pages* to the host tier
  (page-granular), and the scheduler preempts whole sequences only when
  page spills cannot make room.
* **Aliasing** — with the cross-request prefix cache
  (``repro.serving.prefix_cache``) block tables may map logical pages of
  DIFFERENT rows to the SAME physical page (a shared prompt prefix). The
  read path needs no change: the kernels only gather through the table, and
  every aliased slot holds the identical prefix KV by construction. Writes
  are where aliasing matters — the engine copies a shared page before any
  row writes inside it (copy-on-write), so the prefill/decode scatters
  (``mode="drop"``, masked to the row's own slots) still touch only pages
  the row exclusively owns past its covered prefix.
* **Fault paths never touch this contract** (ISSUE 10) — transfer
  retry/backoff, degraded synchronous tiering, lost-page row shedding, and
  journal recovery (``repro.serving.faults`` / ``journal``) all resolve in
  the engine/scheduler BEFORE a launch: by the time a kernel runs, every
  table entry below ``lengths`` is resident and committed, exactly as in a
  fault-free run. No fault state, retry flag, or journal record is ever
  visible to (or handled by) a kernel.

Each package has kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper + XLA fallback) and ref.py (pure-jnp oracle). Kernels are validated
in interpret mode on CPU; the TPU path is selected automatically on TPU
backends.
"""
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.paged_attention.ops import (
    mla_paged_attention, mla_paged_attention_layers_ragged,
    mla_paged_attention_ragged, paged_attention, paged_attention_layers,
    paged_attention_layers_ragged, paged_attention_layers_ragged_q8,
    paged_attention_q8, paged_attention_ragged, paged_attention_ragged_q8)
from repro.kernels.log_patch.ops import log_patch

__all__ = ["flash_attention", "paged_attention", "paged_attention_layers",
           "paged_attention_ragged", "paged_attention_layers_ragged",
           "paged_attention_q8", "paged_attention_ragged_q8",
           "paged_attention_layers_ragged_q8",
           "mla_paged_attention", "mla_paged_attention_ragged",
           "mla_paged_attention_layers_ragged",
           "log_patch"]
