"""Pallas TPU kernels for the framework's compute hot-spots (DESIGN.md §2a):

* flash_attention — chunked online-softmax attention (train/prefill)
* paged_attention — decode attention over a paged KV pool with block-table
  indirection (the paging design's on-device read path)
* log_patch       — apply KV log records to page-shaped buffers (the logging
  design's on-device drain/patch path)

Each package has kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper + XLA fallback) and ref.py (pure-jnp oracle). Kernels are validated
in interpret mode on CPU; the TPU path is selected automatically on TPU
backends.
"""
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.log_patch.ops import log_patch

__all__ = ["flash_attention", "paged_attention", "log_patch"]
