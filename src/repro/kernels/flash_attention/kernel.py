"""Flash attention Pallas TPU kernel (GQA-aware, causal-block skipping).

Grid: (B, H, num_q_blocks, num_kv_blocks) — the kv axis is innermost and
sequential on TPU, so the online-softmax state lives in VMEM scratch across
kv iterations. Block shapes are MXU-aligned (q/kv block 128(+) × head_dim).
Causal runs still visit every block but fully-masked blocks early-out with
``pl.when`` (no MXU work issued).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, block_q: int, block_k: int,
               kv_len: int, q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    last_k = pl.num_programs(3) - 1

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q + q_offset      # query positions in kv coordinates
    k_start = ik * block_k
    # a block is live unless causal and strictly above the diagonal band
    live = jnp.logical_or(not causal, k_start <= q_start + block_q - 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)            # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)            # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 0)
            k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        # mask kv padding (when kv_len % block_k != 0)
        k_idx = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        s = jnp.where(k_idx < kv_len, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == last_k)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           scale: float | None = None, block_q: int = 128,
                           block_k: int = 128, interpret: bool = False):
    """q: (B, Sq, H, D); k, v: (B, Skv, K, D), H = K*G. Returns (B, Sq, H, D).

    Causal convention matches the oracle: queries are the *last* Sq positions
    of the Skv keys (q_offset = Skv - Sq), the standard decode/prefill-
    continuation alignment.
    """
    B, Sq, H, D = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    q_offset = Skv - Sq
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    # pad sequence dims to block multiples
    pq = (-Sq) % block_q
    pk = (-Skv) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq = q.shape[1] // block_q
    nk = k.shape[1] // block_k

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, kv_len=Skv, q_offset=q_offset)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, i, j: (b, j, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D),
                               lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    if pq:
        out = out[:, :Sq]
    return out
