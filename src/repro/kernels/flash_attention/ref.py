"""Pure-jnp oracle for flash_attention (GQA, optional causal)."""
from __future__ import annotations

import jax.numpy as jnp
import jax


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        scale: float | None = None):
    """q: (B, Sq, H, D); k, v: (B, Skv, K, D) with H = K*G. Returns like q."""
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, Sq, K, G, D).astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32)) * scale
    if causal:
        Skv = k.shape[1]
        q_pos = jnp.arange(Sq)[:, None] + (Skv - Sq)
        allow = jnp.arange(Skv)[None, :] <= q_pos
        s = jnp.where(allow[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)
