"""jit'd public wrapper: Pallas on TPU (or interpret for validation), XLA
chunked fallback elsewhere."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "scale", "block_q", "block_k",
                                   "force_pallas"))
def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    block_q: int = 128, block_k: int = 128,
                    force_pallas: bool = False):
    """Flash attention: q (B,Sq,H,D), k/v (B,Skv,K,D) → (B,Sq,H,D)."""
    if _on_tpu():
        return flash_attention_pallas(q, k, v, causal=causal, scale=scale,
                                      block_q=block_q, block_k=block_k)
    if force_pallas:
        return flash_attention_pallas(q, k, v, causal=causal, scale=scale,
                                      block_q=block_q, block_k=block_k,
                                      interpret=True)
    return flash_attention_ref(q, k, v, causal=causal, scale=scale)
