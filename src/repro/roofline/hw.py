"""Target-hardware constants (TPU v5e) used by the roofline model.

The container is CPU-only; these describe the TARGET the dry-run artifacts are
scored against (per the assignment: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI).
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class Chip:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12      # FLOP/s per chip
    hbm_bandwidth: float = 819e9         # B/s per chip
    hbm_bytes: int = 16 * 1024**3        # 16 GiB
    ici_link_bandwidth: float = 50e9     # B/s per link, per direction
    ici_links: int = 4                   # 2D torus: 4 links per chip (x+,x-,y+,y-)
    vmem_bytes: int = 128 * 1024**2      # ~128 MiB vector memory
    mxu_tile: int = 128                  # systolic array dimension


V5E = Chip()


# The paper's tier model (host-side cache benchmarks) — calibrated from
# Izraelevitz et al. [arXiv:1903.05714] Optane DCPMM measurements and vendor
# specs for the paper's Supermicro testbed (Xeon Gold 6326, Optane v200,
# 512 GB NVMe SSD). Seconds per byte + per-op latency.
@dataclass(frozen=True)
class TierSpec:
    name: str
    read_bw: float          # B/s sequential
    write_bw: float         # B/s sequential
    rand_read_bw: float     # B/s at 4 KiB granularity
    rand_write_bw: float    # B/s at 4 KiB granularity
    read_latency: float     # s per operation
    write_latency: float    # s per operation


DRAM = TierSpec("dram", read_bw=100e9, write_bw=80e9,
                rand_read_bw=25e9, rand_write_bw=20e9,
                read_latency=90e-9, write_latency=90e-9)

# Optane v200 (2 interleaved 128 GiB modules): ~8.1/4.6 GB/s seq R/W per
# module pair region; random 4K ~2.5/1.0 GB/s; ~300 ns read latency.
NVMM = TierSpec("nvmm", read_bw=8.1e9, write_bw=4.6e9,
                rand_read_bw=2.5e9, rand_write_bw=1.0e9,
                read_latency=305e-9, write_latency=100e-9)

# Datacenter NVMe SSD: ~3.0/1.5 GB/s seq, 4K random ~500/300 MB/s,
# ~80 µs read latency, ~20 µs buffered write, ~1 ms fsync.
SSD = TierSpec("ssd", read_bw=3.0e9, write_bw=1.5e9,
               rand_read_bw=0.5e9, rand_write_bw=0.3e9,
               read_latency=80e-6, write_latency=20e-6)

SSD_FSYNC_LATENCY = 1e-3   # s per fsync barrier (paper §III: psync+fsync > 1 h)
