"""Analytic parameter counts per architecture (roofline 6·N·D cross-check)."""
from __future__ import annotations


def _dense_ffn_params(d_model: int, d_ff: int, activation: str) -> int:
    if d_ff == 0:
        return 0
    mats = 3 if activation in ("swiglu", "geglu") else 2
    return mats * d_model * d_ff


def _attn_params(cfg) -> int:
    if cfg.mla is not None:
        m = cfg.mla
        qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
        p = 0
        if m.q_lora_rank:
            p += cfg.d_model * m.q_lora_rank + m.q_lora_rank * cfg.num_heads * qk_head
            p += m.q_lora_rank  # q lora norm
        else:
            p += cfg.d_model * cfg.num_heads * qk_head
        p += cfg.d_model * (m.kv_lora_rank + m.qk_rope_head_dim)   # W_dkv, W_kr
        p += m.kv_lora_rank                                        # kv lora norm
        p += m.kv_lora_rank * cfg.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
        p += cfg.num_heads * m.v_head_dim * cfg.d_model            # W_o
        return p
    q = cfg.d_model * cfg.num_heads * cfg.head_dim
    kv = 2 * cfg.d_model * cfg.num_kv_heads * cfg.head_dim
    o = cfg.num_heads * cfg.head_dim * cfg.d_model
    return q + kv + o


def _ssm_params(cfg) -> int:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.ngroups * s.d_state
    p = cfg.d_model * (2 * d_inner + 2 * s.ngroups * s.d_state + nheads)  # in_proj
    p += conv_dim * s.d_conv + conv_dim                                   # conv + bias
    p += 3 * nheads                                                       # A, D, dt_bias
    p += d_inner                                                          # gated norm
    p += d_inner * cfg.d_model                                            # out_proj
    return p


def _moe_ffn_params(cfg, active: bool) -> int:
    m = cfg.moe
    n_routed = m.top_k if active else m.num_experts
    p = cfg.d_model * m.num_experts                       # router
    p += n_routed * 3 * cfg.d_model * m.d_expert          # routed experts (glu)
    p += m.num_shared_experts * 3 * cfg.d_model * m.d_expert
    if m.dense_residual:
        p += 3 * cfg.d_model * m.d_dense_residual
    return p


def _layer_params(cfg, active: bool) -> int:
    fam = cfg.family
    norms = 2 * cfg.d_model
    if fam in ("attn_dense", "vlm"):
        return _attn_params(cfg) + _dense_ffn_params(
            cfg.d_model, cfg.d_ff, cfg.ffn_activation) + norms
    if fam == "moe":
        return _attn_params(cfg) + _moe_ffn_params(cfg, active) + norms
    if fam == "ssm":
        return _ssm_params(cfg) + cfg.d_model
    if fam == "encdec":
        # decoder layer: self + cross + ffn
        return (2 * _attn_params(cfg)
                + _dense_ffn_params(cfg.d_model, cfg.d_ff, cfg.ffn_activation)
                + 3 * cfg.d_model)
    if fam == "hybrid":
        return _ssm_params(cfg) + cfg.d_model
    raise ValueError(fam)


def count_params(cfg, active: bool = False) -> int:
    p = cfg.vocab_size * cfg.d_model                       # embedding
    if not cfg.tie_embeddings:
        p += cfg.vocab_size * cfg.d_model                  # lm head
    p += cfg.d_model                                       # final norm

    if cfg.family == "moe" and cfg.moe.first_k_dense:
        k = cfg.moe.first_k_dense
        dense_layer = _attn_params(cfg) + _dense_ffn_params(
            cfg.d_model, cfg.d_ff, cfg.ffn_activation) + 2 * cfg.d_model
        p += k * dense_layer + (cfg.num_layers - k) * _layer_params(cfg, active)
    else:
        p += cfg.num_layers * _layer_params(cfg, active)

    if cfg.family == "encdec":
        enc_layer = (_attn_params(cfg) + _dense_ffn_params(
            cfg.d_model, cfg.d_ff, cfg.ffn_activation) + 2 * cfg.d_model)
        p += cfg.num_encoder_layers * enc_layer

    if cfg.family == "hybrid":
        h = cfg.hybrid
        shared_block = (_attn_params(cfg) + _dense_ffn_params(
            cfg.d_model, cfg.d_ff, cfg.ffn_activation) + 2 * cfg.d_model)
        p += h.num_shared_blocks * shared_block
        n_invocations = cfg.num_layers // h.shared_block_period
        qkv_out = (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim
        p += n_invocations * h.lora_rank * (cfg.d_model + qkv_out)

    if cfg.frontend.kind == "vision":
        d_f = cfg.frontend.d_frontend
        p += d_f * cfg.d_model + cfg.d_model * cfg.d_model * (
            cfg.frontend.projector_layers - 1)
    return p


def count_active_params(cfg) -> int:
    return count_params(cfg, active=True)
