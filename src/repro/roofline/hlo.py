"""Parse collective ops (with wire-byte estimates) out of compiled HLO text.

``cost_analysis()`` does not report collective bytes, so we walk the HLO for
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops, recover shapes + replica-group sizes, and convert to per-device *wire*
bytes with standard ring-algorithm factors:

    all-gather        (g-1)/g × result_bytes
    reduce-scatter    (g-1)/g × operand_bytes
    all-reduce        2 (g-1)/g × operand_bytes          (RS + AG)
    all-to-all        (g-1)/g × operand_bytes
    collective-permute  operand_bytes

Ops inside ``while`` bodies are counted once per appearance; scan trip counts
are recovered by the L=1/L=2 differencing in repro.roofline.analysis
(DESIGN.md §6).
"""
from __future__ import annotations

import re
from dataclasses import dataclass

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.3 = bf16[16,512]{1,0} all-gather(bf16[1,512]{1,0} %x), ...
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[\d,]*\])(?:\{[^}]*\})?)\s+"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(([^)]*)\)(.*)")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_EXPLICIT_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    operand_bytes: int
    group_size: int

    @property
    def wire_bytes(self) -> float:
        g = max(self.group_size, 1)
        f = (g - 1) / g
        if self.kind.startswith("all-reduce"):
            return 2 * f * self.operand_bytes
        if self.kind.startswith("all-gather"):
            return f * self.result_bytes
        if self.kind == "reduce-scatter":
            return f * self.operand_bytes
        if self.kind == "all-to-all":
            return f * self.operand_bytes
        return float(self.operand_bytes)          # collective-permute


def parse_collectives(hlo_text: str) -> list[CollectiveOp]:
    ops = []
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        tuple_result, single_result, kind, operands, rest = m.groups()
        if kind.endswith("-start"):
            kind = kind[:-6]
        result_src = tuple_result if tuple_result else single_result
        result_bytes = sum(_shape_bytes(d, s)
                           for d, s in _SHAPE_RE.findall(result_src or ""))
        operand_bytes = sum(_shape_bytes(d, s)
                            for d, s in _SHAPE_RE.findall(operands))
        gm = _IOTA_GROUPS_RE.search(rest)
        if gm:
            group_size = int(gm.group(2))
        else:
            em = _EXPLICIT_GROUPS_RE.search(rest)
            group_size = len(em.group(1).split(",")) if em else 2
        ops.append(CollectiveOp(kind, result_bytes, operand_bytes, group_size))
    return ops


def total_wire_bytes(hlo_text: str) -> float:
    return sum(op.wire_bytes for op in parse_collectives(hlo_text))


def collective_summary(hlo_text: str) -> dict:
    ops = parse_collectives(hlo_text)
    out: dict = {}
    for op in ops:
        d = out.setdefault(op.kind, {"count": 0, "wire_bytes": 0.0})
        d["count"] += 1
        d["wire_bytes"] += op.wire_bytes
    out["total_wire_bytes"] = sum(op.wire_bytes for op in ops)
    out["num_ops"] = len(ops)
    return out
