"""Roofline analysis: three-term model (compute / memory / collective) derived
from the compiled multi-pod dry-run artifacts. See DESIGN.md §6."""
