"""Three-term roofline from dry-run artifacts (DESIGN.md §6).

cost_analysis() on this backend reports per-device FLOPs/bytes and counts
scan bodies once, so per-cell totals are reconstructed from reduced-depth
compiles: HLO totals are affine in the block counts, f = out + Σ_b n_b·c_b.
Each family's sample plan makes the system solvable:

    dense/vlm/ssm/moe(k=0)   L ∈ {1,2}
    moe(first_k_dense=1)     L ∈ {2,3}   (dense block folds into `out`)
    encdec                   L ∈ {1,2}   (enc+dec move together, both 24)
    hybrid                   (L,period) ∈ {(2,2),(2,1),(4,2)} → solve
                             (out, mamba, shared) exactly

Roofline samples are compiled at mb=1 so HLO counts equal executed counts;
the full cell's HBM-bytes are corrected for microbatched weight re-reads
(+ (mb-1)·param_bytes), and its *memory footprint* comes from the real
production-mb artifact.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Optional

import numpy as np

from repro.configs import SHAPES_BY_NAME, get_config
from repro.roofline.hw import V5E

METRICS = ("flops", "bytes", "wire")


def _extract(artifact: dict) -> dict:
    cost = artifact.get("cost", {})
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire": float(artifact.get("collectives", {})
                      .get("total_wire_bytes", 0.0)),
    }


def _load(art_dir: Path, tag: str) -> Optional[dict]:
    p = art_dir / f"{tag}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def sample_plan(cfg) -> list[dict]:
    """Reduced-depth compiles needed for this arch (layers/period args)."""
    if cfg.family == "hybrid":
        return [{"layers": 2, "period": 2}, {"layers": 2, "period": 1},
                {"layers": 4, "period": 2}]
    if cfg.family == "moe" and cfg.moe.first_k_dense:
        return [{"layers": 2}, {"layers": 3}]
    return [{"layers": 1}, {"layers": 2}]


def _counts(cfg, layers: int, period: Optional[int]) -> list[float]:
    """Block-count vector [1(out), primary blocks, (hybrid) shared]."""
    if cfg.family == "hybrid":
        p = period or max(layers // 2, 1)
        n_seg = layers // p
        return [1.0, float(layers), float(n_seg)]
    if cfg.family == "moe" and cfg.moe.first_k_dense:
        return [1.0, float(layers - cfg.moe.first_k_dense)]
    return [1.0, float(layers)]


def _full_counts(cfg) -> list[float]:
    if cfg.family == "hybrid":
        n_seg = cfg.num_layers // cfg.hybrid.shared_block_period
        return [1.0, float(cfg.num_layers), float(n_seg)]
    if cfg.family == "moe" and cfg.moe.first_k_dense:
        return [1.0, float(cfg.num_layers - cfg.moe.first_k_dense)]
    return [1.0, float(cfg.num_layers)]


def reconstruct_totals(arch: str, shape_name: str, art_dir: Path,
                       mesh: str = "pod") -> Optional[dict]:
    """Solve the affine system and evaluate at the full config's counts."""
    cfg = get_config(arch)
    plan = sample_plan(cfg)
    rows, rhs = [], []
    for s in plan:
        tag = f"{arch}__{shape_name}__{mesh}__L{s['layers']}"
        if s.get("period"):
            tag += f"P{s['period']}"
        art = _load(art_dir, tag)
        if art is None:
            continue            # tolerate a missing sample (min-norm lstsq)
        rows.append(_counts(cfg, s["layers"], s.get("period")))
        rhs.append(_extract(art))
    if len(rows) < 2:
        return None
    A = np.array(rows)
    full = np.array(_full_counts(cfg))
    out = {}
    for m in METRICS:
        y = np.array([r[m] for r in rhs])
        coef, *_ = np.linalg.lstsq(A, y, rcond=None)
        out[m] = float(np.maximum(full @ coef, 0.0))
    return out


# ---------------------------------------------------------------------------
# Analytic per-device HBM streaming floor.
#
# cost_analysis' "bytes accessed" is *pre-fusion logical traffic*; on the
# unrolled sample compiles it overcounts real HBM traffic by orders of
# magnitude (every intermediate counted as if materialized). We therefore
# report it as an upper bound and attribute the bottleneck with an analytic
# floor: weight reads (× microbatches, × 3 for fwd/bwd/remat-recompute in
# training), residual-stream traffic, optimizer state r/w, KV-cache reads.
# ---------------------------------------------------------------------------
def analytic_memory_bytes(cfg, shape, devices: int, mb: int) -> float:
    N = cfg.param_count()
    model_shards = 16
    if cfg.family == "moe":
        w_local = 2.0 * N / devices            # FSDP+EP: fully sharded
    elif cfg.family in ("ssm", "hybrid"):
        w_local = 2.0 * N                      # mixers replicated on model
    else:
        w_local = 2.0 * N / model_shards       # TP
    tokens_local = shape.tokens / devices
    L = cfg.num_layers + cfg.num_encoder_layers
    act = 2.0 * tokens_local * cfg.d_model * 2 * max(L, 1)   # r+w per layer
    if shape.kind == "train":
        opt = 14.0 * N / devices               # master+mu+nu+grads r/w (≈)
        return mb * 3.0 * w_local + 3.0 * act + opt
    if shape.kind == "prefill":
        return w_local + act
    # decode: weights once + the KV cache read once per token
    S, B = shape.seq_len, shape.global_batch
    if cfg.family == "ssm":
        kv = 0.0
    elif cfg.mla is not None:
        m = cfg.mla
        kv = B * S * (m.kv_lora_rank + m.qk_rope_head_dim) * 2 * cfg.num_layers
    elif cfg.family == "hybrid":
        n_inv = cfg.num_layers // cfg.hybrid.shared_block_period
        kv = B * S * 2 * cfg.num_kv_heads * cfg.head_dim * 2 * n_inv
    else:
        kv = B * S * 2 * cfg.num_kv_heads * cfg.head_dim * 2 * cfg.num_layers
    return w_local + kv / devices


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS (cluster-wide useful flops for the cell)
# ---------------------------------------------------------------------------
def _ssd_flops_per_token_layer(cfg) -> float:
    """Mamba-2 SSD useful work: within-chunk quadratic + state update.
    ≈ 4·Q·d_inner (CB/L/y_diag einsums) + 2·Q·N + state terms."""
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    return 4.0 * s.chunk_size * d_inner + 2.0 * s.chunk_size * s.d_state \
        + 4.0 * d_inner * s.d_state


def model_flops(cfg, shape) -> float:
    n_active = cfg.active_param_count()
    S, B = shape.seq_len, shape.global_batch
    if cfg.frontend.kind == "vision" and shape.kind != "decode":
        S = S + cfg.frontend.num_tokens    # image prefix runs the backbone
    L = cfg.num_layers
    H, Dh = max(cfg.num_heads, 1), max(cfg.head_dim, 1)
    enc_frames = 4096                      # stub audio frontend length
    ssd = 0.0
    if cfg.ssm is not None and shape.kind != "decode":
        n_mamba = L if cfg.family == "ssm" else (
            L)                             # hybrid: all backbone layers
        mult = 3.0 if shape.kind == "train" else 1.0
        ssd = mult * _ssd_flops_per_token_layer(cfg) * S * B * n_mamba
    if shape.kind == "train":
        tokens = S * B
        attn = 3 * 4 * (S / 2) * H * Dh * tokens * L   # fwd+bwd causal attn
        if cfg.family == "encdec":
            # encoder sees 4096 frames, not S; cross-attn is S×4096
            enc_t = enc_frames * B
            attn = 3 * 4 * H * Dh * (
                (S / 2) * tokens * L          # decoder self-attn
                + enc_frames * tokens * L     # cross-attn (kv = enc frames)
                + enc_frames * enc_t * cfg.num_encoder_layers)
            # ≈ half the params in each stack; each sees its own tokens
            return 6.0 * n_active * 0.5 * (tokens + enc_t) + attn
        return 6.0 * n_active * tokens + ssd + (
            attn if cfg.family not in ("ssm",) else 0.0)
    if shape.kind == "prefill":
        tokens = S * B
        attn = 4 * (S / 2) * H * Dh * tokens * L
        if cfg.family == "encdec":
            enc_t = enc_frames * B
            attn = 4 * H * Dh * ((S / 2) * tokens * L
                                 + enc_frames * tokens * L
                                 + enc_frames * enc_t * cfg.num_encoder_layers)
            return 2.0 * n_active * 0.5 * (tokens + enc_t) + attn
        return 2.0 * n_active * tokens + ssd + (
            attn if cfg.family not in ("ssm",) else 0.0)
    # decode: one token per sequence against an S-token cache
    tokens = B
    if cfg.family == "ssm":
        attn = 0.0
    elif cfg.family == "hybrid":
        n_inv = cfg.num_layers // cfg.hybrid.shared_block_period
        attn = 4 * S * H * Dh * tokens * n_inv
    elif cfg.mla is not None:
        m = cfg.mla
        attn = 2 * S * H * (m.qk_nope_head_dim + m.qk_rope_head_dim
                            + m.v_head_dim) * tokens * L
    else:
        attn = 4 * S * cfg.num_kv_heads * Dh * tokens * L \
            * (cfg.num_heads / max(cfg.num_kv_heads, 1))
    return 2.0 * n_active * tokens + attn


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float          # analytic streaming floor (bottleneck attribution)
    memory_hlo_s: float      # cost_analysis pre-fusion upper bound
    collective_s: float
    bound: str
    model_flops_ratio: float
    fits_hbm: bool
    live_gb: float
    note: str = ""

    def as_dict(self):
        return self.__dict__.copy()


def roofline_cell(arch: str, shape_name: str, art_dir: Path,
                  mesh: str = "pod") -> Optional[RooflineRow]:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    totals = reconstruct_totals(arch, shape_name, art_dir, mesh)
    full_art = _load(art_dir, f"{arch}__{shape_name}__{mesh}")
    if totals is None or full_art is None:
        return None
    n_dev = 512 if mesh == "multipod" else 256
    mb = full_art.get("microbatches", 1)
    t_c = totals["flops"] / V5E.peak_flops_bf16
    t_m = analytic_memory_bytes(cfg, shape, n_dev, mb) / V5E.hbm_bandwidth
    t_m_hlo = totals["bytes"] / V5E.hbm_bandwidth
    # ring collectives use both torus directions on the bottleneck axis
    t_x = totals["wire"] / (2 * V5E.ici_link_bandwidth)
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bound = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_cluster_flops = totals["flops"] * n_dev
    ratio = mf / hlo_cluster_flops if hlo_cluster_flops else 0.0
    return RooflineRow(
        arch=arch, shape=shape_name, mesh=mesh,
        compute_s=t_c, memory_s=t_m, memory_hlo_s=t_m_hlo,
        collective_s=t_x, bound=bound,
        model_flops_ratio=ratio,
        fits_hbm=bool(full_art.get("fits_v5e_hbm")),
        live_gb=full_art.get("per_device_live_bytes", 0) / 1e9)


def render_table(rows: list[RooflineRow]) -> str:
    hdr = ("| arch | shape | compute s | memory s (floor) | memory s (HLO ub)"
           " | collective s | bound | useful/HLO flops | fits HBM | live GB |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r.arch} | {r.shape} | {r.compute_s:.4f} | {r.memory_s:.4f} "
            f"| {r.memory_hlo_s:.3f} | {r.collective_s:.4f} | **{r.bound}** "
            f"| {r.model_flops_ratio:.2f} | {'✓' if r.fits_hbm else '✗'} "
            f"| {r.live_gb:.1f} |")
    return "\n".join(lines)
