"""Checkpoint manager: JAX pytree ↔ byte blobs over the core backends.

The design switch (``design="paged" | "log"``, or any registered engine
name such as ``"nvhybrid"``) selects the persistence tier (DESIGN.md §2b);
the tier is built from one :class:`~repro.core.engines.EngineSpec` through
the engine registry. Restore after a crash
runs the paper's recovery procedure first (flag-checked replay/flush), then
reads the manifest — giving bit-exact resume (tested in
tests/test_checkpoint.py).

For the logging design, ``save`` takes ``changed`` names (e.g. only the
shards a delta step touched); unchanged state rides on the last snapshot +
log replay.
"""
from __future__ import annotations

import json
from typing import Any, Optional

import jax
import numpy as np

from repro.core.api import NVCacheFS
from repro.core.ckpt_backend import LogCheckpointBackend, PagedCheckpointBackend
from repro.core.engines import EngineSpec, get_engine

PyTree = Any

# the paper's two design names map onto engines; any registered engine name
# (e.g. "nvhybrid") is also accepted directly
_DESIGN_ENGINES = {"paged": "nvpages", "log": "nvlog"}


def _flatten(tree: PyTree) -> tuple[dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    blobs = {f"leaf{i}": np.asarray(l) for i, l in enumerate(leaves)}
    return blobs, treedef


def _tree_meta(blobs: dict[str, np.ndarray]) -> dict:
    return {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in blobs.items()}


class CheckpointManager:
    _UNSET = object()

    def __init__(self, design=_UNSET, *,
                 nvmm_bytes: Optional[int] = None,
                 snapshot_every: int = 8, fs: Optional[NVCacheFS] = None,
                 spec: Optional[EngineSpec] = None):
        # the backend follows ``design`` (or the engine name passed as
        # design) OR an explicit ``spec`` — mixing the two is ambiguous;
        # an explicit ``fs`` only supplies the filesystem, never the
        # backend choice
        if fs is not None and (spec is not None or nvmm_bytes is not None):
            raise TypeError("an explicit fs already fixes the engine and "
                            "its sizing; pass only design/snapshot_every "
                            "alongside it")
        if spec is not None:
            if design is not self._UNSET:
                raise TypeError("pass either design or spec, not both")
            if nvmm_bytes is not None:
                raise TypeError("pass nvmm_bytes inside the EngineSpec, "
                                "not alongside it")
            engine = spec.engine
        else:
            design = "log" if design is self._UNSET else design
            engine = _DESIGN_ENGINES.get(design, design)
        get_engine(engine)      # typo'd design/engine fails loudly here
        if fs is None:
            if spec is None:
                spec = EngineSpec(engine=engine,
                                  nvmm_bytes=(1 << 30 if nvmm_bytes is None
                                              else nvmm_bytes))
            fs = NVCacheFS(spec)
        self.fs = fs
        # incremental (delta) saves ride on the logging engine; every other
        # engine persists full snapshots
        self.design = "log" if engine == "nvlog" else "paged"
        if self.design == "log":
            self.backend = LogCheckpointBackend(
                self.fs, snapshot_every=snapshot_every)
        else:
            self.backend = PagedCheckpointBackend(self.fs)
        self._meta_fd = self.fs.open("/ckpt/meta")

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: PyTree,
             changed: Optional[set] = None) -> float:
        """Persist a pytree; returns simulated seconds. ``changed`` narrows a
        log-design save to the leaves whose names changed."""
        blobs, _ = _flatten(tree)
        meta = json.dumps({"step": step, "meta": _tree_meta(blobs)}).encode()
        state = {k: v.tobytes() for k, v in blobs.items()}
        if self.design == "log":
            t = self.backend.save(step, state, changed=changed)
        else:
            t = self.backend.save(step, state)
        self.fs.pwrite(self._meta_fd, len(meta).to_bytes(8, "little") + meta,
                       0)
        self.fs.fsync(self._meta_fd)
        return t

    # --------------------------------------------------------------- restore
    def restore(self, like: PyTree) -> tuple[int, PyTree]:
        """Rebuild a pytree shaped like ``like`` (used for treedef/dtypes)."""
        if self.fs.crashed:
            self.fs.recover()
        n = int.from_bytes(self.fs.pread(self._meta_fd, 8, 0), "little")
        if n == 0:
            raise FileNotFoundError("no checkpoint has been saved yet")
        meta = json.loads(self.fs.pread(self._meta_fd, n, 8))
        step, state = self.backend.restore()
        leaves, treedef = jax.tree_util.tree_flatten(like)
        out = []
        for i in range(len(leaves)):
            key = f"leaf{i}"
            m = meta["meta"][key]
            arr = np.frombuffer(state[key], dtype=m["dtype"]).reshape(
                m["shape"])
            out.append(arr)
        return step, jax.tree_util.tree_unflatten(treedef, out)

    def crash(self) -> None:
        self.fs.crash()
