"""Checkpoint manager over the paper's two cache designs."""
from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager"]
