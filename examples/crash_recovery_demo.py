"""Fault-tolerance walkthrough: the paper's crash-flag protocol end-to-end,
at FIO-level and at the training-checkpoint level, plus elastic re-meshing.

    PYTHONPATH=src python examples/crash_recovery_demo.py
"""
import numpy as np

from repro.core import NVCacheFS, PAGE_SIZE
from repro.training.elastic import MeshPlan, StragglerPolicy, replan_mesh


def io_level():
    print("--- paper §II: NVMM flag + recovery (IO level)")
    fs = NVCacheFS("nvlog", nvmm_bytes=4 << 20, dram_cache_bytes=1 << 20)
    fd = fs.open("/db/wal")
    for i in range(200):
        fs.pwrite(fd, f"record-{i:04d}".encode().ljust(64, b"."), i * 64)
    print(f"    nvmm flag = {fs.nvmm_flag} (loaded)")
    fs.crash()
    print("    *** power loss: DRAM cache + LPC gone; NVMM log survives")
    t = fs.recover()
    fd = fs.open("/db/wal")
    rec = fs.pread(fd, 64, 199 * 64)
    print(f"    recovered in {t*1e3:.2f}ms (sim); last record: "
          f"{rec[:11].decode()} ✓")


def elastic_level():
    print("--- DESIGN.md §5: elastic re-mesh + straggler policy")
    plan = MeshPlan(data=16, model=16)
    new = replan_mesh(plan, healthy_devices=224, global_batch=256)
    print(f"    lost 32 chips: {plan.data}x{plan.model} → "
          f"{new.data}x{new.model} (TP intact, batch divides)")
    pol = StragglerPolicy()
    for step in range(8):
        for h in ("h0", "h1", "h2", "h3"):
            pol.observe(h, 4.0 if h == "h3" else 1.0)
    print(f"    stragglers detected: {pol.stragglers()}; shards reassigned: "
          f"{pol.reassign_shards(8, ['h0','h1','h2','h3'])}")
    print("    (deterministic data pipeline ⇒ reassignment moves no data)")


if __name__ == "__main__":
    io_level()
    elastic_level()
