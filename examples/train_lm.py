"""End-to-end driver (deliverable b): train a ~100M-param LM for a few
hundred steps on synthetic data with log-structured checkpointing, a
mid-run simulated crash, and bit-exact resume.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import SyntheticLMDataset, make_batch_iterator
from repro.models import build_model
from repro.training import AdamWConfig, init_train_state, make_train_step


def build_100m():
    """A ~100M-parameter internlm2-family config."""
    base = get_config("internlm2-1.8b")
    return dataclasses.replace(
        base, name="internlm2-100m", num_layers=10, d_model=768,
        num_heads=12, num_kv_heads=4, d_ff=3072, vocab_size=16384,
        head_dim=64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--crash-at", type=int, default=None,
                    help="simulate a crash at this step (default: midway)")
    args = ap.parse_args()
    # crash only after at least one checkpoint exists
    crash_at = args.crash_at or max(args.steps // 2, 11)

    cfg = build_100m()
    model = build_model(cfg, remat=True)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M")
    opt = AdamWConfig(lr=1e-3, schedule="cosine",
                      warmup_steps=args.steps // 20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt))
    ds = SyntheticLMDataset(cfg.vocab_size, args.seq, args.batch, seed=0)
    print(f"synthetic-data loss floor ≈ {ds.entropy_floor:.3f} nats")

    mgr = CheckpointManager("log", nvmm_bytes=2 << 30, snapshot_every=4)
    state = init_train_state(model, jax.random.PRNGKey(0))
    it = make_batch_iterator(ds)
    t0 = time.time()
    step = 0
    while step < args.steps:
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, metrics = step_fn(state, batch)
        step += 1
        if step % 20 == 0 or step == 1:
            print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e}")
        if step % 10 == 0:
            mgr.save(step, state)
        if step == crash_at:
            print(f"*** simulated crash at step {step} "
                  f"(power loss: volatile state dropped) ***")
            mgr.crash()
            restored_step, state = mgr.restore(state)
            print(f"*** recovered via log replay → resuming at step "
                  f"{restored_step} ***")
            step = restored_step
            it = make_batch_iterator(ds, start_step=step)
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"done in {dt:.1f}s ({toks/dt:.0f} tok/s on CPU); final loss "
          f"{float(metrics['loss']):.4f} vs floor {ds.entropy_floor:.3f}")


if __name__ == "__main__":
    main()
