"""Quickstart: every registered cache engine behind one POSIX-like API —
the paper's two designs, the psync references, and the hybrid — exercised
through the same write/read/crash/recover script, with a per-engine table
from the unified ``stats()`` protocol.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import NVCacheFS, PAGE_SIZE
from repro.core.engines import EngineSpec, list_engines

# engine-specific counters worth surfacing per design (all come out of the
# same stats() dict — the protocol is uniform, the designs are not)
_HIGHLIGHTS = ("log_appends", "nvmm_page_writes", "evictions", "dram_hits",
               "routed_log", "routed_pages", "lpc_writes", "fsyncs")


def drive(engine: str) -> dict:
    """One write/read-hot/crash/recover cycle; returns a summary row."""
    fs = NVCacheFS(EngineSpec(engine=engine, nvmm_bytes=8 << 20,
                              dram_cache_bytes=2 << 20))
    fd = fs.open("/demo/file")

    # write 2 MiB full pages + a scatter of small records, read it back hot
    blob = b"\xAB" * PAGE_SIZE
    for off in range(0, 2 << 20, PAGE_SIZE):
        fs.pwrite(fd, blob, off)
    fs.pwritev(fd, [((2 << 20) + 256 * i, b"rec%03d" % i)
                    for i in range(64)])
    for _ in range(2):
        for off in range(0, 2 << 20, PAGE_SIZE):
            fs.pread(fd, PAGE_SIZE, off)
    fs.fsync(fd)

    # crash and recover — fsync'd data must survive on every engine
    fs.crash()
    rec_t = fs.recover()
    fd = fs.open("/demo/file")
    survived = fs.pread(fd, 4, 0) == b"\xAB" * 4
    s = fs.stats()
    s.update(engine=engine, recovery_ms=rec_t * 1e3, survived=survived)
    return s


def main():
    print("=== NVMM cache designs: logging vs paging (Dulong et al. 2023)\n")
    rows = [drive(engine) for engine in list_engines()]
    print(f"{'engine':12s} {'sim_ms':>9s} {'recov_ms':>9s} {'fsyncd_ok':>9s} "
          f"{'nvmm_used':>10s}  notable counters")
    for s in rows:
        notable = "  ".join(f"{k}={s[k]}" for k in _HIGHLIGHTS if k in s)
        print(f"{s['engine']:12s} {s['sim_time_s']*1e3:9.2f} "
              f"{s['recovery_ms']:9.2f} {str(s['survived']):>9s} "
              f"{s['nvmm_used_bytes']:>10d}  {notable}")
    print("\npsync would lose un-fsync'd data — the paper's motivation: the "
          "NVMM designs give persistence at pwrite-return, at very "
          "different costs; nvhybrid routes each write to whichever design "
          "wins it (see benchmarks/fio_bench.py for the full grid).")


if __name__ == "__main__":
    main()
