"""Quickstart: the paper's two cache designs behind one POSIX-like API,
then the same switch at the framework's checkpoint call-site.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import NVCacheFS, PAGE_SIZE


def main():
    print("=== NVMM cache designs: logging vs paging (Dulong et al. 2023)\n")
    for engine in ("nvpages", "nvlog", "psync"):
        fs = NVCacheFS(engine, nvmm_bytes=8 << 20, dram_cache_bytes=2 << 20)
        fd = fs.open("/demo/file")

        # write 2 MiB, read it back hot
        blob = b"\xAB" * PAGE_SIZE
        for off in range(0, 2 << 20, PAGE_SIZE):
            fs.pwrite(fd, blob, off)
        for _ in range(2):
            for off in range(0, 2 << 20, PAGE_SIZE):
                fs.pread(fd, PAGE_SIZE, off)

        # crash and recover — acked writes must survive (except psync!)
        fs.crash()
        rec_t = fs.recover()
        fd = fs.open("/demo/file")
        survived = fs.pread(fd, 4, 0) == b"\xAB" * 4
        s = fs.stats()
        print(f"{engine:9s} sim={s['sim_time_s']*1e3:8.2f}ms "
              f"recovery={rec_t*1e3:6.2f}ms "
              f"data_survived_crash={survived}")
    print("\npsync loses un-synced data — the paper's motivation: both NVMM "
          "designs give persistence at pwrite-return, at very different "
          "costs (see benchmarks/fio_bench.py for the full Figs. 3-4 grid).")


if __name__ == "__main__":
    main()
