"""Serving example: continuous-batching generation with the tiered KV
cache, comparing the paper's designs at the serving call-site (DESIGN.md
§2a) — including preemption under HBM pressure and the mirror-free pooled
decode path (decode straight over the device page pool, zero device→host
mirror traffic). The cache-descriptor support matrix shows which serving
path each (engine, model family) pair runs, and the family sweep at the
end drives int8 and SSM through the same pooled mirror-free path.

    PYTHONPATH=src python examples/serve_kv_offload.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.engines import EngineSpec, list_kv_engines
from repro.core.engines.desc import MATRIX_FAMILIES, support_matrix
from repro.models import build_model
from repro.serving import Request, ServeConfig, ServingEngine


def print_matrix():
    rows = support_matrix()
    fams = [f for f, _, _ in MATRIX_FAMILIES]
    modes = {(e, f): m for e, f, m in rows}
    engines = sorted({e for e, _, _ in rows})
    width = max(max(len(f) for f in fams),
                max(len(m) for m in modes.values())) + 2
    print("KV engine x config family (from the cache descriptors):")
    print("  " + " " * 10 + "".join(f"{f:>{width}}" for f in fams))
    for eng in engines:
        print(f"  {eng:10s}" + "".join(f"{modes[(eng, f)]:>{width}}"
                                       for f in fams))
    print()


def family_sweep():
    """int8 and SSM through the SAME pooled mirror-free path dense runs:
    the descriptor decides the layout (int8 pages + bf16 scale planes at
    half the HBM bytes/token; SSM state rows instead of pages), and greedy
    tokens still match the sequential mirrored reference exactly."""
    print("descriptor-driven families on the pooled path")
    cfg = get_config("internlm2-1.8b-smoke")
    scfg = get_config("mamba2-1.3b-smoke")
    runs = (
        ("int8", build_model(cfg, remat=False, kv_cache_dtype="int8"), cfg),
        ("ssm", build_model(scfg, remat=False), scfg),
    )
    for fam, model, mcfg in runs:
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        prompts = [rng.integers(0, mcfg.vocab_size, 12, dtype=np.int32)
                   for _ in range(2)]

        def reqs():
            return [Request(rid=i, prompt=p.copy(), max_new=8)
                    for i, p in enumerate(prompts)]

        def engine():
            return ServingEngine(model, params, ServeConfig(
                max_len=32, page_tokens=8,
                engine_spec=EngineSpec(engine="paged", kv_hot_window=16,
                                       kv_hbm_bytes=64 << 20),
                max_batch_seqs=2))
        ref = reqs()
        engine().generate_sequential(ref)
        eng, rs = engine(), reqs()
        assert eng.pooled and eng.fused
        eng.generate(rs)
        s = eng.stats()
        assert [r.generated for r in rs] == [r.generated for r in ref], fam
        assert s["mirror_d2h_bytes"] == 0
        desc = model.cache_descriptor(8)
        print(f"  family={fam:5s} planes={','.join(desc.plane_names):24s} "
              f"mirror_d2h_bytes=0 tokens=reference "
              f"(token_bytes={desc.token_group_bytes or desc.seq_state_bytes})")
    print()


def crash_and_recover():
    """Fault tolerance (ISSUE 10): crash the scheduler mid-run with chaos
    transfer faults underneath, then recover a FRESH engine from the shared
    NVMM token journal — the spliced stream is token-identical to the
    uninterrupted reference."""
    from repro.serving.faults import CrashFault, FaultPlan
    from repro.serving.journal import ServingJournal
    print("crash-and-recover through the NVMM token journal")
    cfg = get_config("internlm2-1.8b-smoke")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, 12, dtype=np.int32)
               for _ in range(3)]

    def reqs():
        return [Request(rid=i, prompt=p.copy(), max_new=12)
                for i, p in enumerate(prompts)]

    def engine(journal, plan):
        return ServingEngine(model, params, ServeConfig(
            max_len=32, page_tokens=8,
            engine_spec=EngineSpec(engine="paged", kv_hot_window=16,
                                   drain_shards=2, kv_hbm_bytes=64 << 20,
                                   async_tiering=True),
            max_batch_seqs=2, journal=journal, fault_plan=plan))

    ref = reqs()
    engine(None, None).generate_sequential(ref)
    reference = [r.generated for r in ref]

    journal = ServingJournal()
    plan = FaultPlan(seed=7, transfer_fail_rate=0.2,
                     transfer_delay_rate=0.2, crash_at_tick=6)
    crashed, rs = engine(journal, plan), reqs()
    try:
        crashed.generate(rs)
        raise AssertionError("the injected crash must fire")
    except CrashFault as e:
        state, last_tick = journal.replay()
        durable = sum(len(t) for t in state.values())
        print(f"  {e} — journal holds {durable} committed tokens "
              f"across {len(state)} rows through tick {last_tick}")
    recovered = engine(journal, None)
    recovered.recover(rs)
    assert [r.generated for r in rs] == reference, \
        "recovery must splice to the exact reference stream"
    print(f"  recovered engine finished all rows; tokens identical to the "
          f"uninterrupted reference "
          f"(journal_appends={recovered.stats()['journal_appends']})")
    print()


def main():
    print_matrix()
    family_sweep()
    crash_and_recover()
    cfg = get_config("internlm2-1.8b-smoke")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 24, dtype=np.int32)
               for _ in range(3)]

    def run(design, hbm_bytes, sequential=False, paged_decode=None,
            chunk=None):
        engine = ServingEngine(model, params, ServeConfig(
            max_len=64, page_tokens=8,
            engine_spec=EngineSpec(engine=design, kv_hot_window=16,
                                   drain_shards=2, kv_hbm_bytes=hbm_bytes),
            max_batch_seqs=4, paged_decode=paged_decode,
            prefill_chunk_tokens=chunk))
        reqs = [Request(rid=i, prompt=p.copy(), max_new=16)
                for i, p in enumerate(prompts)]
        (engine.generate_sequential if sequential
         else engine.generate)(reqs)
        return [r.generated for r in reqs], engine

    # the reference every path below must reproduce token-for-token: the
    # one-request-at-a-time loop over the dense mirror
    reference, _ = run("log", 64 << 20, sequential=True,
                       paged_decode=False)
    designs = list_kv_engines()          # paged, log, kvhybrid, plugins...

    # ---- mirror-free pooled decode: every registered engine, unconstrained
    # budget. Pool-capable engines decode over their device page pool with
    # ZERO device→host mirror bytes; the rest fall back to the mirror path
    # transparently — and everyone still generates the reference tokens.
    print("pooled decode (auto: pool-capable engines go mirror-free)")
    for design in designs:
        out, eng = run(design, 64 << 20, chunk=12)
        s = eng.stats()
        mode = "pooled" if eng.pooled else "mirror"
        print(f"  design={design:8s} path={mode:6s} "
              f"mirror_d2h_bytes={s['mirror_d2h_bytes']:8d} "
              f"prefill_chunks={s['sched_prefill_chunks']}")
        assert out == reference, (design, "pooled decode must match the "
                                  "sequential mirrored reference")
        if eng.pooled:
            assert s["mirror_d2h_bytes"] == 0, \
                "the pooled path must never mirror a token device→host"
        assert s["sched_prefill_chunks"] >= 1, \
            "24-token prompts over a 12-token chunk budget must split"

    # ---- preemption under HBM pressure: a budget with room for two
    # requests to co-run, not three, so the scheduler must preempt/restore
    # mid-decode, and tokens must not change
    token_bytes = (cfg.num_layers * 2 * cfg.num_kv_heads * cfg.head_dim * 2)
    print("preemption under a binding HBM budget")
    outputs = {}
    for design in designs:
        # pooled paged accounts whole fp32 pool pages (2x the fp16
        # token_bytes) and refuses admissions it cannot place, so its
        # squeeze point differs: 9 pool pages — the smallest pool the
        # liveness floor (max_len/page_tokens + 1) accepts — admit two
        # prompts (3 pages each) but not their decoded growth (5 each)
        budget = (9 * 8 * token_bytes * 2 if design == "paged"
                  else 40 * token_bytes)
        outputs[design], eng = run(design, budget)
        assert (design != "paged") or eng.pooled, \
            "paged must stay on the pooled path in the pressure run"
        s = eng.stats()
        print(f"  design={design:8s} sim_tier_time="
              f"{s['sim_time_s']*1e6:9.1f}us preempts={s['preempts']} "
              f"restores={s['restores']} "
              f"peak_batch={s['sched_peak_running']}")
        assert s["preempts"] >= 1, "budget should have forced a preemption"
    assert all(outputs[d] == reference for d in designs), \
        "batched + preempted decode must match the sequential reference"
    print(f"\nall {len(designs)} registered KV designs, decoding as ONE "
          "continuously-batched pool under a budget that forces "
          "preempt/restore cycles, generated exactly the sequential "
          "reference tokens — and the paged design did it MIRROR-FREE: "
          "decode ran the paged_attention kernel straight over its "
          "device-resident page pool (block-table indirection), spilling "
          "LRU pool pages at page granularity under pressure, with zero "
          "device→host mirror traffic. The designs differ only in tier "
          "traffic (paging pays page DMA + page-granular spills; logging "
          "pays 1x sequential writes + patch reads; kvhybrid routes each "
          "append to whichever side wins it) — the paper's trade-off "
          "transplanted to the serving tier.")


if __name__ == "__main__":
    main()
