"""Serving example: continuous-batching generation with the tiered KV
cache, comparing the paper's designs at the serving call-site (DESIGN.md
§2a) — including preemption under HBM pressure.

    PYTHONPATH=src python examples/serve_kv_offload.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.engines import EngineSpec, list_kv_engines
from repro.models import build_model
from repro.serving import Request, ServeConfig, ServingEngine


def main():
    cfg = get_config("internlm2-1.8b-smoke")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 24, dtype=np.int32)
               for _ in range(3)]

    def run(design, hbm_bytes, sequential=False):
        engine = ServingEngine(model, params, ServeConfig(
            max_len=64, page_tokens=8,
            engine_spec=EngineSpec(engine=design, kv_hot_window=16,
                                   drain_shards=2, kv_hbm_bytes=hbm_bytes),
            max_batch_seqs=4))
        reqs = [Request(rid=i, prompt=p.copy(), max_new=16)
                for i, p in enumerate(prompts)]
        (engine.generate_sequential if sequential
         else engine.generate)(reqs)
        return [r.generated for r in reqs], engine.stats()

    reference, _ = run("log", 64 << 20, sequential=True)

    # tight HBM budget: ~40 resident tokens across the whole batch — room
    # for two requests to co-run, not three, so the scheduler must
    # preempt/restore mid-decode, and tokens must not change
    token_bytes = (cfg.num_layers * 2 * cfg.num_kv_heads * cfg.head_dim * 2)
    outputs = {}
    designs = list_kv_engines()          # paged, log, kvhybrid, plugins...
    for design in designs:
        outputs[design], s = run(design, 40 * token_bytes)
        print(f"design={design:8s} sim_tier_time={s['sim_time_s']*1e6:9.1f}us "
              f"preempts={s['preempts']} restores={s['restores']} "
              f"peak_batch={s['sched_peak_running']}")
        assert s["preempts"] >= 1, "budget should have forced a preemption"
    assert all(outputs[d] == reference for d in designs), \
        "batched + preempted decode must match the sequential reference"
    print(f"\nall {len(designs)} registered KV designs, decoding as ONE "
          "continuously-batched pool under a budget that forces "
          "preempt/restore cycles, generated exactly the sequential "
          "reference tokens — designs differ only in tier traffic (paging "
          "pays 2x writes + page DMA on miss; logging pays 1x sequential "
          "writes + patch reads; kvhybrid routes each append to whichever "
          "side wins it), exactly the paper's trade-off transplanted to "
          "the serving tier.")


if __name__ == "__main__":
    main()
