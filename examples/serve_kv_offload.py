"""Serving example: batched generation with the tiered KV cache, comparing
the paper's two designs at the serving call-site (DESIGN.md §2a).

    PYTHONPATH=src python examples/serve_kv_offload.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.engines import EngineSpec, list_kv_engines
from repro.models import build_model
from repro.serving import ServeConfig, ServingEngine
from repro.serving.engine import Request


def main():
    cfg = get_config("internlm2-1.8b-smoke")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 24, dtype=np.int32)
               for _ in range(3)]

    outputs = {}
    designs = list_kv_engines()          # paged, log, kvhybrid, plugins...
    for design in designs:
        engine = ServingEngine(model, params, ServeConfig(
            max_len=64, page_tokens=8,
            engine_spec=EngineSpec(engine=design, kv_hot_window=16,
                                   drain_shards=2)))
        reqs = [Request(rid=i, prompt=p.copy(), max_new=16)
                for i, p in enumerate(prompts)]
        engine.generate(reqs)
        outputs[design] = [r.generated for r in reqs]
        s = engine.stats()
        print(f"design={design:6s} sim_tier_time={s['sim_time_s']*1e6:9.1f}us "
              f"stats={ {k: v for k, v in s.items() if k != 'sim_time_s'} }")
    first = outputs[designs[0]]
    assert all(outputs[d] == first for d in designs), \
        "designs must agree on tokens"
    print(f"\nall {len(designs)} registered KV designs generated identical "
          "tokens — they differ only in tier traffic (paging pays 2× writes "
          "+ page DMA on miss; logging pays 1× sequential writes + patch "
          "reads; kvhybrid learns to route each append to whichever side "
          "wins it), exactly the paper's trade-off transplanted to the KV "
          "cache.")


if __name__ == "__main__":
    main()
