"""Tiered KV-cache designs: functional equality + the paper's asymmetries
transferred to the serving call-site (DESIGN.md §2a)."""
import numpy as np
import pytest

from repro.core import SimClock
from repro.core.kvcache import KVSpec, LogKVCache, PagedKVCache

SPEC = KVSpec(num_layers=3, kv_heads=2, head_dim=8, page_tokens=4)


def _fill(kv, n_tokens, seq=0, seed=0):
    rng = np.random.default_rng(seed)
    oracle = []
    for _ in range(n_tokens):
        tok = rng.standard_normal(
            (SPEC.num_layers, 2, SPEC.kv_heads, SPEC.head_dim)).astype(
            np.float16)
        kv.append(seq, tok)
        oracle.append(tok)
    return oracle


@pytest.mark.parametrize("design", ["paged", "log"])
def test_gather_matches_appends(design):
    clock = SimClock()
    kv = (PagedKVCache(SPEC, clock, hbm_budget_bytes=1 << 13)
          if design == "paged" else
          LogKVCache(SPEC, clock, hot_window_tokens=6))
    oracle = _fill(kv, 29)
    for layer in range(SPEC.num_layers):
        got = kv.gather(0, layer)
        want = np.stack([o[layer] for o in oracle], axis=1)
        assert np.array_equal(got, want), (design, layer)


def test_designs_functionally_identical_multi_seq():
    clock_p, clock_l = SimClock(), SimClock()
    paged = PagedKVCache(SPEC, clock_p, hbm_budget_bytes=1 << 13)
    log = LogKVCache(SPEC, clock_l, hot_window_tokens=4)
    rng = np.random.default_rng(1)
    for t in range(40):
        seq = t % 3
        tok = rng.standard_normal((3, 2, 2, 8)).astype(np.float16)
        paged.append(seq, tok)
        log.append(seq, tok)
    for seq in range(3):
        for layer in range(3):
            assert np.array_equal(paged.gather(seq, layer),
                                  log.gather(seq, layer))


def test_paged_write_amplification_vs_log():
    """The paging design writes every KV token to the host tier twice
    (redo + page); the log design once."""
    clock_p, clock_l = SimClock(), SimClock()
    paged = PagedKVCache(SPEC, clock_p, hbm_budget_bytes=1 << 13)
    log = LogKVCache(SPEC, clock_l)
    _fill(paged, 32)
    _fill(log, 32)
    paged_bytes = clock_p.bytes_moved("host", "write")
    log_bytes = clock_l.bytes_moved("host", "write")
    assert paged_bytes >= 1.95 * log_bytes


def test_log_hot_window_serves_recent_tokens_from_hbm():
    clock = SimClock()
    kv = LogKVCache(SPEC, clock, hot_window_tokens=8)
    _fill(kv, 32)
    before = clock.bytes_moved("host", "read")
    kv.gather(0, 0)
    host_read = clock.bytes_moved("host", "read") - before
    # only the cold 24 tokens come over the host link
    assert host_read <= 25 * SPEC.token_bytes
    assert kv.stats["hot_hits"] >= 8


def test_paged_hbm_miss_dma_cost():
    """Cache misses DMA whole pages — the paper's miss-copy cost."""
    clock = SimClock()
    kv = PagedKVCache(SPEC, clock, hbm_budget_bytes=2 * SPEC.page_bytes)
    _fill(kv, 32)                      # 8 pages/layer, HBM holds 2
    kv.gather(0, 0)
    assert kv.stats["hbm_misses"] > 0
    assert kv.stats["dma_up_bytes"] >= kv.stats["hbm_misses"] * SPEC.page_bytes
