"""Engine behaviour: read-after-write, persistence semantics, the paper's
write-amplification and bandwidth asymmetries."""
import random

import pytest

from repro.core import NVCacheFS, PAGE_SIZE
from repro.roofline.hw import DRAM, NVMM


def _rand_ops(fs, fd, n_ops, file_bytes, seed=7, write_frac=0.5):
    rng = random.Random(seed)
    oracle = {}
    for _ in range(n_ops):
        off = rng.randrange(0, file_bytes - 64)
        if rng.random() < write_frac:
            data = bytes([rng.randrange(256)]) * rng.randrange(1, 64)
            fs.pwrite(fd, data, off)
            for j, b in enumerate(data):
                oracle[off + j] = b
        else:
            n = rng.randrange(1, 64)
            got = fs.pread(fd, n, off)
            want = bytes(oracle.get(off + j, 0) for j in range(n))
            assert got == want
    return oracle


@pytest.mark.parametrize("engine", ["nvpages", "nvlog", "psync",
                                    "psync_fsync", "nvhybrid"])
def test_read_after_write(engine):
    fs = NVCacheFS(engine, nvmm_bytes=1 << 20, dram_cache_bytes=1 << 18)
    fd = fs.open("/f")
    _rand_ops(fs, fd, 1500, 1 << 18)


@pytest.mark.parametrize("engine", ["nvpages", "nvlog", "nvhybrid"])
def test_crash_recovery_no_data_loss(engine):
    fs = NVCacheFS(engine, nvmm_bytes=1 << 20, dram_cache_bytes=1 << 17)
    fd = fs.open("/f")
    oracle = _rand_ops(fs, fd, 1200, 1 << 18)
    fs.crash()
    fs.recover()
    fd = fs.open("/f")
    for off in range(0, 1 << 18, PAGE_SIZE):
        got = fs.pread(fd, PAGE_SIZE, off)
        want = bytes(oracle.get(off + j, 0) for j in range(PAGE_SIZE))
        assert got == want, f"lost page at {off}"


def test_psync_loses_unsynced_data():
    """The paper's point: the LPC gives no persistence without fsync."""
    fs = NVCacheFS("psync")
    fd = fs.open("/f")
    fs.pwrite(fd, b"\xAA" * PAGE_SIZE, 0)
    fs.fsync(fd)
    fs.pwrite(fd, b"\xBB" * PAGE_SIZE, PAGE_SIZE)    # never synced
    fs.crash()
    fs.recover()
    fd = fs.open("/f")
    assert fs.pread(fd, 4, 0) == b"\xAA" * 4          # fsync'd survived
    assert fs.pread(fd, 4, PAGE_SIZE) == b"\x00" * 4  # unsynced lost


def test_nvpages_double_write_amplification():
    """Paper §III: the redo log makes NVPages write data to NVMM twice."""
    fs = NVCacheFS("nvpages", nvmm_bytes=8 << 20)
    fd = fs.open("/f")
    payload = 256 * 1024
    for off in range(0, payload, PAGE_SIZE):
        fs.pwrite(fd, b"\x11" * PAGE_SIZE, off)
    written = fs.clock.bytes_moved("nvmm", "write")
    assert written >= 2 * payload                     # redo + page
    assert written < 2.2 * payload


def test_nvlog_single_write_amplification():
    fs = NVCacheFS("nvlog", nvmm_bytes=8 << 20)
    fd = fs.open("/f")
    payload = 256 * 1024
    for off in range(0, payload, PAGE_SIZE):
        fs.pwrite(fd, b"\x22" * PAGE_SIZE, off)
    written = fs.clock.bytes_moved("nvmm", "write")
    assert payload <= written < 1.1 * payload         # log header overhead only


def test_nvlog_reads_at_dram_speed_nvpages_at_nvmm_speed():
    """The paper's root cause: NVLog serves hot reads from DRAM, NVPages from
    NVMM — and NVMM read bandwidth ≪ DRAM."""
    results = {}
    for engine in ("nvlog", "nvpages"):
        fs = NVCacheFS(engine, nvmm_bytes=32 << 20,
                       dram_cache_bytes=32 << 20)
        fd = fs.open("/f")
        blob = b"\x33" * PAGE_SIZE
        for off in range(0, 1 << 20, PAGE_SIZE):
            fs.pwrite(fd, blob, off)
        t0 = fs.simulated_time
        for _ in range(3):
            for off in range(0, 1 << 20, PAGE_SIZE):
                fs.pread(fd, PAGE_SIZE, off)
        results[engine] = fs.simulated_time - t0
    # DRAM rand read 25 GB/s vs NVMM rand read 2.5 GB/s → ~10× gap
    assert results["nvpages"] > 3 * results["nvlog"]


def test_nvlog_stalls_when_log_full():
    fs = NVCacheFS("nvlog", nvmm_bytes=64 << 10)      # tiny log
    fd = fs.open("/f")
    for off in range(0, 1 << 20, PAGE_SIZE):
        fs.pwrite(fd, b"\x44" * PAGE_SIZE, off)
    assert fs.cache.stats["stall_time"] > 0           # drainer became the limit


def test_nvpages_eviction_bounded_by_capacity():
    nvmm = 1 << 20
    fs = NVCacheFS("nvpages", nvmm_bytes=nvmm)
    fd = fs.open("/f")
    for off in range(0, 4 << 20, PAGE_SIZE):          # 4× the cache
        fs.pwrite(fd, b"\x55" * PAGE_SIZE, off)
    cache = fs.cache
    resident = sum(len(sh.pool) for sh in cache.shards)
    max_frames = sum(sh.max_frames for sh in cache.shards)
    assert cache.stats["evictions"] > 0
    assert resident <= max_frames


def test_sharded_nvpages_multithread_design():
    """Paper §IV future work: independent redo logs per shard."""
    fs = NVCacheFS("nvpages", nvmm_bytes=4 << 20, shards=4)
    fd = fs.open("/f")
    oracle = _rand_ops(fs, fd, 800, 1 << 19, seed=3)
    fs.crash()
    fs.recover()
    fd = fs.open("/f")
    for off in range(0, 1 << 19, PAGE_SIZE):
        got = fs.pread(fd, PAGE_SIZE, off)
        want = bytes(oracle.get(off + j, 0) for j in range(PAGE_SIZE))
        assert got == want
