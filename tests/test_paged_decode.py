"""Mirror-free pooled decode + chunked prefill (ISSUE 4).

Four suites lock the block-table-native serving path down:

* **pooled equivalence** — decode over the device page pool (the
  paged_attention kernel, block-table indirection) is token-identical to
  the sequential mirrored reference for EVERY registered engine (pool
  -capable ones go mirror-free, the rest fall back transparently), under
  random admission order, preemption, and chunked prefill;
* **zero-mirror pin** — ``mirror_d2h_bytes == 0`` on the pooled path, in
  steady state AND under preemption churn (the regression that would
  silently reintroduce the dense mirror);
* **chunked prefill** — prompts longer than the chunk budget split across
  ticks and still generate exactly the one-shot-prefill tokens, on both
  the pooled and the mirrored path;
* **pooled engine unit surface** — page alloc/free tied to the LRU
  accounting: page-granular spill/fault keeps reads exact under a thrashing
  pool, victim_hint answers by reclaimable pages, and the pool guards
  (init-after-append, pool on a log engine, paged_decode=True on an
  unsupported config) fail loudly.
"""
import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core import SimClock
from repro.core.engines import (EngineSpec, create_kv_engine,
                                list_kv_engines)
from repro.core.kvcache import KVSpec
from repro.models import build_model
from repro.serving import Request, ServeConfig, ServingEngine

ARCH = "internlm2-1.8b-smoke"
MAX_LEN = 24                  # small so a tight pool still fits one seq
PAGE_TOKENS = 4
PROMPT_LENS = (8, 12, 8)
MAX_NEW = 6


@pytest.fixture(scope="module")
def lm():
    cfg = get_config(ARCH)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _group_bytes(mcfg):
    """One fp32 pool page group (all layers)."""
    return (mcfg.num_layers * 2 * PAGE_TOKENS * mcfg.num_kv_heads
            * mcfg.head_dim * 4)


def _requests(cfg, seed=0, max_new=MAX_NEW):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, n, dtype=np.int32),
                    max_new=max_new)
            for i, n in enumerate(PROMPT_LENS)]


def _engine(lm, engine, *, hbm_bytes=64 << 20, paged_decode=None,
            max_batch_tokens=None, chunk=None, max_batch_seqs=4,
            fuse=True):
    cfg, model, params = lm
    return ServingEngine(model, params, ServeConfig(
        max_len=MAX_LEN, page_tokens=PAGE_TOKENS,
        engine_spec=EngineSpec(engine=engine, kv_hbm_bytes=hbm_bytes,
                               kv_hot_window=8, drain_shards=2),
        max_batch_seqs=max_batch_seqs, max_batch_tokens=max_batch_tokens,
        paged_decode=paged_decode, prefill_chunk_tokens=chunk,
        fuse_ticks=fuse))


@pytest.fixture(scope="module")
def reference(lm):
    cfg, _, _ = lm
    reqs = _requests(cfg)
    _engine(lm, "log", paged_decode=False).generate_sequential(reqs)
    return {r.rid: list(r.generated) for r in reqs}


# --------------------------------------------------------- pooled equivalence
def test_paged_engine_auto_enables_pool(lm):
    eng = _engine(lm, "paged")
    assert eng.pooled
    assert eng.tiered.pooled


@pytest.mark.parametrize("engine_name", list_kv_engines())
def test_every_engine_matches_reference_under_auto_pooling(lm, reference,
                                                           engine_name):
    """The acceptance bar: pooled decode (or the transparent mirror
    fallback) equals the sequential mirrored reference for every
    registered engine, across admission orders."""
    cfg, _, _ = lm
    for order in ((0, 1, 2), (2, 0, 1), (1, 2, 0)):
        reqs = _requests(cfg)
        eng = _engine(lm, engine_name, max_batch_seqs=2)
        eng.generate([reqs[i] for i in order])
        for r in reqs:
            assert r.done
            assert r.generated == reference[r.rid], (engine_name, order)


def test_pooled_decode_under_preemption_matches_reference(lm, reference):
    """A pool with room for ~1.5 sequences forces whole-sequence preemption
    (page-granular spill of every resident page) — tokens must not move."""
    cfg, model, _ = lm
    budget = 8 * _group_bytes(model.cfg)        # 8 pool pages of 4 tokens
    reqs = _requests(cfg)
    eng = _engine(lm, "paged", hbm_bytes=budget)
    assert eng.pooled
    eng.generate(reqs)
    s = eng.stats()
    assert s["preempts"] >= 1 and s["restores"] >= 1, s
    assert s["pool_page_spills"] >= 1
    for r in reqs:
        assert r.generated == reference[r.rid]


def test_log_engines_fall_back_to_mirror(lm, reference):
    for name in ("log", "kvhybrid"):
        eng = _engine(lm, name)
        assert not eng.pooled
        reqs = _requests(lm[0])
        eng.generate(reqs)
        assert all(r.generated == reference[r.rid] for r in reqs)
        assert eng.stats()["mirror_d2h_bytes"] > 0


def test_ssm_family_runs_pooled_mirror_free():
    """ISSUE 9 flip of the old fallback pin: the SSM descriptor pools ZERO
    pages — its fixed-size state rows ride in the engine
    (``state_views``/``commit_state``) — so a pool-capable engine now runs
    Mamba-2 POOLED, fused, and mirror-free, token-identical to the
    sequential mirrored reference."""
    cfg = get_config("mamba2-1.3b-smoke")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))

    def engine():
        return ServingEngine(model, params, ServeConfig(
            max_len=16, page_tokens=4,
            engine_spec=EngineSpec(engine="paged")))
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
    ref = Request(rid=0, prompt=prompt.copy(), max_new=4)
    engine().generate_sequential([ref])
    eng = engine()
    assert eng.pooled and eng.fused
    assert not eng.desc.has_pages and eng.desc.has_state
    req = Request(rid=0, prompt=prompt.copy(), max_new=4)
    eng.generate([req])
    assert req.generated == ref.generated
    assert eng.stats()["mirror_d2h_bytes"] == 0
    assert eng.stats()["pool_appends"] > 0


# --------------------------------------------------------------- zero-mirror
def test_mirror_d2h_bytes_pinned_zero_on_pooled_path(lm):
    """THE regression pin: the pooled path must never move a KV byte over
    the device→host link — not at admission, not per decode step, not
    under preemption churn or chunked prefill."""
    cfg, model, _ = lm
    for kwargs in ({},                                        # steady state
                   {"hbm_bytes": 8 * _group_bytes(model.cfg)},  # preempting
                   {"max_batch_tokens": 10}):                 # chunking
        reqs = _requests(cfg)
        eng = _engine(lm, "paged", **kwargs)
        assert eng.pooled
        eng.generate(reqs)
        assert eng.stats()["mirror_d2h_bytes"] == 0, kwargs
    # the mirrored baseline moves exactly one fp16 token/seq/step + prompts
    reqs = _requests(cfg)
    eng = _engine(lm, "paged", paged_decode=False)
    eng.generate(reqs)
    token_bytes = (model.cfg.num_layers * 2 * model.cfg.num_kv_heads
                   * model.cfg.head_dim * 2)
    expect = sum(n + MAX_NEW for n in PROMPT_LENS) * token_bytes
    assert eng.stats()["mirror_d2h_bytes"] == expect


# ----------------------------------------------------------- chunked prefill
@pytest.mark.parametrize("engine_name", ("paged", "log"))
def test_chunked_prefill_token_identical_to_one_shot(lm, reference,
                                                     engine_name):
    """Prompts split across ticks (chunk budget below every prompt length)
    generate exactly the one-shot-prefill tokens, pooled and mirrored."""
    cfg, _, _ = lm
    reqs = _requests(cfg)
    eng = _engine(lm, engine_name, chunk=5)
    eng.generate(reqs)
    assert eng.sched_stats["sched_prefill_chunks"] >= 2
    for r in reqs:
        assert r.generated == reference[r.rid], engine_name


def test_chunk_budget_defaults_to_max_batch_tokens(lm, reference):
    cfg, _, _ = lm
    reqs = _requests(cfg)
    eng = _engine(lm, "log", max_batch_tokens=6)
    eng.generate(reqs)
    assert eng.sched_stats["sched_prefill_chunks"] >= 2
    for r in reqs:
        assert r.generated == reference[r.rid]


def test_chunked_prefill_mirrors_one_append_per_chunk(lm):
    """The mirror path appends each chunk as ONE batched transfer: the
    tiered engine sees prefill-burst-sized appends, not token dribbles."""
    cfg, _, _ = lm
    reqs = [_requests(cfg)[1]]                  # the 12-token prompt
    eng = _engine(lm, "log", chunk=5, max_batch_seqs=1)
    eng.generate(reqs)
    # 12-token prompt = chunks of 5/5/2 → first chunk via prefill append,
    # two continuation chunks via extend_one's batched range append
    assert eng.sched_stats["sched_prefill_chunks"] == 2


# ------------------------------------------------------ fused mixed-batch ticks
@pytest.mark.parametrize("engine_name", list_kv_engines())
@pytest.mark.parametrize("chunk", (None, 3, 5))
def test_fused_ticks_match_sequential_per_engine(lm, reference, engine_name,
                                                 chunk):
    """The tentpole's acceptance sweep: fused mixed-batch ticks (decode
    rows + prefill-chunk rows in ONE forward) are token-identical to the
    sequential mirrored reference for every registered engine × chunk
    schedule."""
    cfg, _, _ = lm
    reqs = _requests(cfg)
    eng = _engine(lm, engine_name, chunk=chunk, max_batch_seqs=3)
    eng.generate(reqs)
    assert eng.fused
    for r in reqs:
        assert r.generated == reference[r.rid], (engine_name, chunk)
    if chunk is not None:
        assert eng.sched_stats["sched_prefill_chunks"] >= 1


def test_one_fused_forward_per_tick_on_pooled_path(lm, reference):
    """THE launch pin: with chunked prefill active on the pooled path,
    every tick is exactly ONE model step — no batch=1 chunk launches ride
    along (step_calls == ticks == fused_ticks)."""
    cfg, _, _ = lm
    reqs = _requests(cfg)
    eng = _engine(lm, "paged", chunk=5)
    assert eng.pooled and eng.fused
    eng.generate(reqs)
    s = eng.stats()
    assert s["sched_prefill_chunks"] >= 2          # chunking really happened
    assert s["step_calls"] == s["sched_ticks"] == s["sched_fused_ticks"]
    assert s["mirror_d2h_bytes"] == 0
    for r in reqs:
        assert r.generated == reference[r.rid]


def test_unfused_baseline_matches_and_launches_more(lm, reference):
    """fuse_ticks=False keeps the batch=1-per-chunk baseline: same tokens,
    strictly more model launches per tick (what kvcache_bench's fused gate
    measures)."""
    cfg, _, _ = lm
    fused_calls = {}
    for fuse in (True, False):
        reqs = _requests(cfg)
        eng = _engine(lm, "paged", chunk=3, fuse=fuse)
        eng.generate(reqs)
        fused_calls[fuse] = eng.stats()["step_calls"]
        assert eng.fused is fuse
        for r in reqs:
            assert r.generated == reference[r.rid], fuse
    assert fused_calls[False] > fused_calls[True]


def test_fused_mirror_gathers_once_per_tick(lm, reference):
    """On the mirrored fused path a chunked tick still moves its tokens in
    ONE device→host transfer (the ragged gather), and the engine sees each
    chunk as one multi-token append."""
    cfg, _, _ = lm
    reqs = [_requests(cfg)[1]]                    # the 12-token prompt
    eng = _engine(lm, "log", chunk=5, max_batch_seqs=1)
    eng.generate(reqs)
    s = eng.stats()
    assert s["sched_prefill_chunks"] == 2
    assert s["step_calls"] == s["sched_ticks"]
    assert reqs[0].generated == reference[1]


def test_fused_tick_survives_tight_pool_with_chunks(lm):
    """Review regression: prepare_step pins the WHOLE batch while
    allocating chunk pages, so a pool at the liveness floor could hit the
    'paged pool exhausted' hard error where the unfused path survived by
    thrashing. The scheduler's pre-step guard must preempt a row and
    continue — graceful, token-identical, no crash."""
    cfg, model, _ = lm
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab_size, 14, dtype=np.int32)
               for _ in range(2)]

    def reqs():
        return [Request(rid=i, prompt=p.copy(), max_new=6)
                for i, p in enumerate(prompts)]

    ref = reqs()
    _engine(lm, "log", paged_decode=False).generate_sequential(ref)
    want = [list(r.generated) for r in ref]
    # 7 pool pages = the exact liveness floor (max_pages + 1): two admitted
    # 14-token prompts chunking at 8 need 4 new pages on the first fused
    # tick with only 3 free and every resident page pinned
    eng = _engine(lm, "paged", hbm_bytes=7 * _group_bytes(model.cfg),
                  chunk=8)
    assert eng.pooled and eng.fused
    rs = reqs()
    eng.generate(rs)                    # must not raise pool-exhausted
    assert [list(r.generated) for r in rs] == want
    assert eng.stats()["preempts"] >= 1
    assert eng.stats()["mirror_d2h_bytes"] == 0


# ------------------------------------------------------- pooled engine surface
def _pooled_kv(pages, *, page_tokens=4):
    kvspec = KVSpec(num_layers=2, kv_heads=2, head_dim=8,
                    page_tokens=page_tokens)
    clock = SimClock()
    kv = create_kv_engine(EngineSpec(engine="paged", kv_hbm_bytes=1 << 30),
                          kvspec, clock)
    kv.init_pool(dtype=np.float32, pages=pages)
    return kv, kvspec


def test_pooled_reads_exact_under_page_thrash():
    """A pool smaller than the working set spills/faults LRU pages at page
    granularity — reads must stay bit-exact through arbitrary thrash."""
    kv, kvspec = _pooled_kv(pages=3)
    rng = np.random.default_rng(0)
    shape = (2, 2, 2, 8)
    ref = {}
    for s in range(3):                  # 3 seqs × 2 pages > 3-page pool
        toks = [rng.standard_normal(shape).astype(np.float32)
                for _ in range(6)]
        ref[s] = np.stack(toks)
        for t in toks:
            kv.append(s, t)
    assert kv.stats["pool_page_spills"] >= 1
    for s in range(3):
        for layer in range(2):
            got = kv.read(s, layer).astype(np.float32)
            want = ref[s][:, layer].transpose(1, 0, 2, 3)
            np.testing.assert_allclose(
                got, want.astype(kvspec.dtype).astype(np.float32),
                atol=1e-3)
    assert kv.stats["pool_faults"] >= 1


def test_pooled_preempt_restore_frees_and_rebuilds_pages():
    kv, _ = _pooled_kv(pages=8)
    rng = np.random.default_rng(1)
    tok = lambda: rng.standard_normal((2, 2, 2, 8)).astype(np.float32)
    for s in (0, 1):
        for _ in range(7):
            kv.append(s, tok())
    free_before = len(kv.free_pages)
    kv.preempt(0)
    assert len(kv.free_pages) == free_before + 2    # ceil(7/4) pages freed
    with pytest.raises(RuntimeError):
        kv.read(0, 0)
    kv.restore(0)
    assert kv.seq_len[0] == 7
    kv.release(0)
    kv.release(1)
    assert len(kv.free_pages) == kv.pool_pages
    assert not kv.page_users and not kv.host_pages


def test_pooled_victim_hint_prefers_most_pages():
    kv, _ = _pooled_kv(pages=8)
    rng = np.random.default_rng(2)
    tok = lambda: rng.standard_normal((2, 2, 2, 8)).astype(np.float32)
    for _ in range(9):                  # 3 pages
        kv.append(0, tok())
    for _ in range(2):                  # 1 page
        kv.append(1, tok())
    assert kv.victim_hint([0, 1]) == 0
    assert kv.victim_hint([1]) == 1
    assert kv.victim_hint([]) is None


def test_pooled_prepare_commit_step_multi_token():
    """The fused tick's engine surface: prepare_step allocates pages
    covering each sequence's WHOLE chunk (not just the next token),
    returns pre-step lengths, and commit_step advances them by the chunk;
    prepare_decode/commit_decode remain the n=1 special case."""
    kv, kvspec = _pooled_kv(pages=8)
    rng = np.random.default_rng(5)
    burst = rng.standard_normal((2, 2, 3, 2, 8)).astype(np.float32)
    kv.append(0, burst)                       # seq 0: 3 tokens (1 page)
    kv.append(1, burst[:, :, 0])              # seq 1: 1 token
    tbl, ctx = kv.prepare_step([0, 1], [6, 1], max_pages=4)
    assert ctx.tolist() == [3, 1]
    # seq 0 needs ceil((3+6)/4)=3 pages, seq 1 ceil((1+1)/4)=1 page
    assert len(kv.block_table[0]) == 3
    assert len(kv.block_table[1]) == 1
    pk, pv = kv.pool_views()
    kv.commit_step(pk, pv, [0, 1], [6, 1])
    assert kv.seq_len[0] == 9 and kv.seq_len[1] == 2
    # the single-token wrappers stay equivalent
    tbl2, ctx2 = kv.prepare_decode([1], max_pages=4)
    assert ctx2.tolist() == [2]
    kv.commit_decode(pk, pv, [1])
    assert kv.seq_len[1] == 3


def test_per_plane_byte_counters_uniform_and_exact():
    """Satellite pin (ISSUE 9): every registered engine exposes the SAME
    ``pool_d2h_bytes_<plane>``/``pool_h2d_bytes_<plane>`` key set — zeroed
    on engines without a pool — and on a pooled int8 descriptor the paged
    -plane counters are exact: ``spills × that plane's page bytes``, so
    the aggregate splits by plane with nothing lost."""
    from repro.core.engines.desc import PLANE_STAT_NAMES, descriptor_for
    kvspec = KVSpec(num_layers=2, kv_heads=2, head_dim=8, page_tokens=4)
    for name in list_kv_engines():
        kv = create_kv_engine(EngineSpec(engine=name), kvspec, SimClock())
        for p in PLANE_STAT_NAMES:
            assert kv.stats[f"pool_d2h_bytes_{p}"] == 0, (name, p)
            assert kv.stats[f"pool_h2d_bytes_{p}"] == 0, (name, p)
    # int8 pool under page thrash: the spill/fault traffic splits by plane
    cfg = get_config(ARCH)
    desc = descriptor_for(cfg, "int8", page_tokens=4)
    spec8 = KVSpec(num_layers=cfg.num_layers, kv_heads=max(cfg.num_kv_heads, 1),
                   head_dim=max(cfg.head_dim, 1), page_tokens=4, desc=desc)
    kv = create_kv_engine(EngineSpec(engine="paged", kv_hbm_bytes=1 << 30),
                          spec8, SimClock())
    kv.init_pool(pages=2)
    kv.alloc_prefill(0, 8)                  # 2 pages: fills the pool
    kv.commit_prefill_planes(kv.pool_views(), 0, 8)
    kv.alloc_prefill(1, 4)                  # forces an LRU page spill
    spills = kv.stats["pool_page_spills"]
    assert spills >= 1
    for p in desc.paged_planes:
        assert (kv.stats[f"pool_d2h_bytes_{p.name}"]
                == spills * desc.plane_page_bytes(p)), p.name
    assert kv.stats["pool_d2h_bytes"] == spills * desc.page_group_bytes
    # scale planes really ride next to int8 pages: half-ish the fp16 bytes
    assert desc.token_group_bytes < 0.55 * (
        cfg.num_layers * 2 * cfg.num_kv_heads * cfg.head_dim * 2)


def test_pooled_can_admit_tokens_counts_free_pages():
    kv, _ = _pooled_kv(pages=4)
    rng = np.random.default_rng(3)
    burst = rng.standard_normal((2, 2, 8, 2, 8)).astype(np.float32)
    kv.append(0, burst)                 # 8 tokens = 2 pages
    assert kv.can_admit_tokens(4)       # 1 page fits (reserve 1 for seq 0)
    assert not kv.can_admit_tokens(8)   # 2 pages + reserve 1 > 2 free


def test_pool_guards_fail_loudly(lm):
    kv, _ = _pooled_kv(pages=4)
    with pytest.raises(RuntimeError, match="twice"):
        kv.init_pool()
    kvspec = KVSpec(num_layers=2, kv_heads=2, head_dim=8, page_tokens=4)
    log = create_kv_engine(EngineSpec(engine="log"), kvspec, SimClock())
    assert not log.supports_pool()
    with pytest.raises(RuntimeError, match="no paged pool"):
        log.init_pool()
    with pytest.raises(RuntimeError, match="no paged pool"):
        log.pool_views()
    # a pool too small for one max-length sequence refuses paged_decode=True
    with pytest.raises(ValueError, match="pool pages"):
        _engine(lm, "paged", hbm_bytes=1024, paged_decode=True)
    # ...and paged_decode=True on a pool-less engine refuses too
    with pytest.raises(ValueError, match="pool-capable"):
        _engine(lm, "log", paged_decode=True)
