"""Config registry: published sizes, shape applicability, reduced siblings."""
import pytest

from repro.configs import (ARCH_IDS, REGISTRY, applicable_shapes, get_config,
                           skipped_shapes)

# published parameter counts (±12% — analytic counts vs reported marketing
# numbers differ by embeddings/rounding)
PUBLISHED = {
    "starcoder2-15b": 15.5e9,
    "internlm2-1.8b": 1.9e9,
    "minicpm-2b": 2.7e9,       # 2.4B non-embedding + tied embeddings
    "gemma-7b": 8.5e9,
    "arctic-480b": 480e9,
    "deepseek-v2-236b": 236e9,
    "seamless-m4t-large-v2": 1.6e9,   # text backbone (speech tower stubbed)
    "mamba2-1.3b": 1.3e9,
    "zamba2-1.2b": 1.2e9,
    "llava-next-mistral-7b": 7.2e9,
}

ACTIVE = {"arctic-480b": 17e9, "deepseek-v2-236b": 21e9}


def test_registry_has_all_archs():
    assert len(ARCH_IDS) == 10
    for a in ARCH_IDS:
        assert a in REGISTRY and a + "-smoke" in REGISTRY


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_counts_match_published(arch):
    got = get_config(arch).param_count()
    want = PUBLISHED[arch]
    assert abs(got - want) / want < 0.12, (arch, got, want)


@pytest.mark.parametrize("arch,want", sorted(ACTIVE.items()))
def test_active_params(arch, want):
    got = get_config(arch).active_param_count()
    assert abs(got - want) / want < 0.15, (arch, got, want)


def test_shape_applicability():
    # long_500k only for sub-quadratic backbones
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        names = [s.name for s in applicable_shapes(cfg)]
        if arch in ("mamba2-1.3b", "zamba2-1.2b"):
            assert "long_500k" in names
        else:
            assert "long_500k" not in names
            assert any(s == "long_500k" for s, _ in skipped_shapes(cfg))
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(names)


def test_total_cell_count():
    live = sum(len(applicable_shapes(get_config(a))) for a in ARCH_IDS)
    skipped = sum(len(skipped_shapes(get_config(a))) for a in ARCH_IDS)
    assert live + skipped == 40          # the assigned 10×4 grid


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_configs_are_small(arch):
    cfg = get_config(arch + "-smoke")
    assert cfg.param_count() < 50e6
    assert cfg.family == get_config(arch).family


def test_padded_vocab_divisible():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
        assert cfg.padded_vocab % 16 == 0     # TP axis of the production mesh
