"""Sharding rules: divisibility guarantees on the production mesh shapes
(pure spec computation over AbstractMesh — no devices needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.distributed.sharding import (_axes_of, batch_specs,
                                        make_abstract_mesh, param_specs,
                                        zero1_specs)
from repro.launch.specs import abstract_params, abstract_state
from repro.models import build_model


def _mesh(multi=False):
    if multi:
        return make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    return make_abstract_mesh((16, 16), ("data", "model"))


def _check_divisible(shape_tree, spec_tree, mesh):
    def check(leaf, spec):
        for i, entry in enumerate(list(spec)):
            for name_group in [_axes_of(entry)]:
                if not name_group:
                    continue
                size = int(np.prod([mesh.shape[a] for a in name_group]))
                assert leaf.shape[i] % size == 0, (leaf.shape, spec)
    jax.tree.map(check, shape_tree, spec_tree,
                 is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("multi", [False, True])
def test_param_specs_divisible_on_production_mesh(arch, multi):
    cfg = get_config(arch)
    model = build_model(cfg, param_dtype=jnp.bfloat16,
                        compute_dtype=jnp.bfloat16)
    mesh = _mesh(multi)
    shapes = abstract_params(model)
    specs = param_specs(shapes, cfg, mesh)
    _check_divisible(shapes, specs, mesh)


@pytest.mark.parametrize("arch", ["starcoder2-15b", "deepseek-v2-236b",
                                  "arctic-480b"])
def test_zero1_specs_divisible(arch):
    cfg = get_config(arch)
    model = build_model(cfg, param_dtype=jnp.bfloat16,
                        compute_dtype=jnp.bfloat16)
    mesh = _mesh(True)
    state = abstract_state(model)
    specs = zero1_specs(state.opt_state["master"], cfg, mesh)
    _check_divisible(state.opt_state["master"], specs, mesh)


def test_expert_weights_are_fsdp_sharded():
    cfg = get_config("arctic-480b")
    model = build_model(cfg, param_dtype=jnp.bfloat16,
                        compute_dtype=jnp.bfloat16)
    mesh = _mesh(False)
    shapes = abstract_params(model)
    specs = param_specs(shapes, cfg, mesh)
    gate_spec = specs["moe_blocks"]["ffn"]["experts"]["w_gate"]
    assert "model" in [a for e in gate_spec for a in _axes_of(e)]
    assert "data" in [a for e in gate_spec for a in _axes_of(e)]


def test_nondivisible_vocab_replicated_but_padded_is_sharded():
    cfg = get_config("minicpm-2b")     # vocab 122753 → padded 122880
    model = build_model(cfg, param_dtype=jnp.bfloat16,
                        compute_dtype=jnp.bfloat16)
    shapes = abstract_params(model)
    specs = param_specs(shapes, cfg, _mesh(False))
    assert specs["embed"]["table"] == P("model", None)   # padded divides


def test_batch_specs_small_batch_replicates():
    mesh = _mesh(False)
    batch = {"tokens": jax.ShapeDtypeStruct((1, 524288), jnp.int32)}
    specs = batch_specs(batch, mesh)
    assert specs["tokens"] == P(None, None)   # batch 1 can't shard over 16
