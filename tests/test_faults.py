"""Fault-tolerant serving: the ISSUE 10 battery.

Three suites pin the tentpole down at its three layers:

* **injector units** — fault decisions are pure hashes of ``(seed, kind,
  key, attempt)``: bit-replayable, order-independent, scripted events fire
  at exactly their tick, armed page losses are one-shot, and the per-page
  loss generation re-rolls the die after a shed (no lost-forever livelock);
* **journal units** — the committed-token journal over the NVMM log tier:
  round-trip replay, idempotent absolute-index overlay, snapshot
  compaction on a full ring, torn-tail truncation losing exactly the last
  tick, gap rejection, and the sequential-NVMM-write clock charge;
* **serving integration** — a poisoned fused tick leaks no pool pages
  (satellite regression), a scripted lost host page sheds exactly one row
  and the stream stays token-identical, and a crash at a tick boundary
  recovers through ``ServingEngine.recover`` to the same tokens an
  uninterrupted run produces.
"""
import numpy as np
import pytest

from repro.core import SimClock
from repro.serving.faults import (CrashFault, FaultEvent, FaultInjector,
                                  FaultPlan, _u01)
from repro.serving.journal import ServingJournal


# ------------------------------------------------------------ injector units
def test_injector_decisions_are_replayable_and_order_free():
    plan = FaultPlan(seed=5, transfer_fail_rate=0.3, transfer_delay_rate=0.3)
    probes = [(("d2h", s, l), att) for s in range(4) for l in range(4)
              for att in range(3)]
    a, b = FaultInjector(plan), FaultInjector(plan)
    got = [(a.transfer_fails(k, att), a.transfer_delay(k))
           for k, att in probes]
    # same plan, same probes → bit-identical decisions and tallies
    assert got == [(b.transfer_fails(k, att), b.transfer_delay(k))
                   for k, att in probes]
    assert a.counts == b.counts and a.injected() > 0
    # decisions are pure hashes, not RNG draws: probing in reverse order
    # answers every key identically
    c = FaultInjector(plan)
    rev = [(c.transfer_fails(k, att), c.transfer_delay(k))
           for k, att in reversed(probes)]
    assert rev == list(reversed(got))
    # a different seed fails a different subset
    d = FaultInjector(FaultPlan(seed=6, transfer_fail_rate=0.3,
                                transfer_delay_rate=0.3))
    assert got != [(d.transfer_fails(k, att), d.transfer_delay(k))
                   for k, att in probes]


def test_armed_page_loss_is_one_shot_and_generations_reroll():
    inj = FaultInjector(FaultPlan())
    inj.arm_page_loss((3, 1))
    assert inj.page_lost(3, 1) and not inj.page_lost(3, 1)
    inj.arm_page_loss(4)                   # bare seq arms any logical page
    assert inj.page_lost(4, 7) and not inj.page_lost(4, 7)
    assert inj.counts["page_lost"] == 2
    # seeded losses fold a per-page generation into the hash: after a loss
    # the re-spilled copy rolls a FRESH die (the shed → re-prefill →
    # re-spill → lost-again livelock guard)
    seed = next(s for s in range(1000)
                if _u01(s, "plost", 0, 0, 0) < 0.5
                and _u01(s, "plost", 0, 0, 1) >= 0.5)
    inj = FaultInjector(FaultPlan(seed=seed, page_loss_rate=0.5))
    assert inj.page_lost(0, 0)             # lost once...
    assert not inj.page_lost(0, 0)         # ...the replacement survives


def test_scripted_events_fire_at_exactly_their_tick():
    plan = FaultPlan(crash_at_tick=4, script=(
        FaultEvent(tick=2, kind="shard_stall", key=1, value=0.5),
        FaultEvent(tick=3, kind="page_lost", key=(0, 0)),
        FaultEvent(tick=5, kind="crash"),
    ))
    inj = FaultInjector(plan)
    assert inj.begin_tick(1) == []
    assert [e.kind for e in inj.begin_tick(2)] == ["shard_stall"]
    assert [e.kind for e in inj.begin_tick(3)] == ["page_lost"]
    assert inj.begin_tick(5) == []         # crash is NOT a begin-tick event
    assert not inj.crash_now(3)
    assert inj.crash_now(4) and inj.crash_now(5)   # seeded AND scripted
    assert inj.counts["crash"] == 2


# ------------------------------------------------------------- journal units
def test_journal_round_trip():
    j = ServingJournal(capacity=1 << 12)
    j.append_tick(1, [(0, 0, [11, 12])])
    j.append_tick(2, [(0, 2, [13]), (1, 0, [21])])
    state, tick = j.replay()
    assert state == {0: [11, 12, 13], 1: [21]} and tick == 2
    assert j.committed(0) == [11, 12, 13] and j.committed(9) == []
    assert j.stats["journal_appends"] == 2 and j.stats["journal_bytes"] > 0


def test_journal_replay_is_idempotent():
    """A crash DURING recovery restarts replay — scanning twice must give
    the same state (records are absolute-indexed overlays)."""
    j = ServingJournal(capacity=1 << 12)
    j.append_tick(1, [(0, 0, [1, 2])])
    j.append_tick(2, [(0, 2, [3])])
    assert j.replay() == j.replay() == ({0: [1, 2, 3]}, 2)
    # a re-executed tick re-commits the same slots in place
    j.append_tick(3, [(0, 1, [2, 3])])
    assert j.replay()[0] == {0: [1, 2, 3]}


def test_journal_rejects_gaps():
    j = ServingJournal(capacity=1 << 12)
    j.append_tick(1, [(0, 0, [1])])
    with pytest.raises(ValueError, match="journal gap"):
        j.append_tick(2, [(0, 5, [9])])


def test_journal_compaction_snapshots_and_replays_full_state():
    """A full ring reclaims into one snapshot record seeding the new tail;
    replay after many laps still reconstructs every committed token."""
    j = ServingJournal(capacity=512)
    want: dict[int, list] = {}
    for tick in range(1, 60):
        rid = tick % 3
        start = len(want.setdefault(rid, []))
        toks = [tick, tick + 1]
        want[rid][start:start + 2] = toks
        j.append_tick(tick, [(rid, start, toks)])
    assert j.stats["journal_compactions"] > 0
    state, tick = j.replay()
    assert state == want and tick == 59


def test_journal_torn_tail_loses_only_the_last_tick():
    """A crash mid-append tears the newest record: replay stops at the CRC
    failure and recovers everything before it, nothing after."""
    j = ServingJournal(capacity=1 << 12)
    j.append_tick(1, [(0, 0, [1])])
    j.append_tick(2, [(0, 1, [2])])
    j.append_tick(3, [(0, 2, [3])])
    j.wal.buf[(j.wal.head - 1) % j.wal.capacity] ^= 0xFF
    state, tick = j.replay()
    assert state == {0: [1, 2]} and tick == 2


def test_journal_charges_sequential_nvmm_writes():
    clock = SimClock()
    j = ServingJournal(capacity=1 << 12, clock=clock)
    j.append_tick(1, [(0, 0, [5, 6, 7])])
    # the persist is the ack point: foreground time, sequential NVMM rate
    assert clock.bytes_moved("nvmm", "write") == j.stats["journal_bytes"]
    assert clock.now > 0.0
    j2 = ServingJournal(capacity=1 << 12, clock=SimClock(),
                        charge_clock=False)
    j2.append_tick(1, [(0, 0, [5])])
    assert j2.clock.now == 0.0             # accounting-free mode


# ------------------------------------------------------- serving integration
_MODEL = None


def _model():
    global _MODEL
    if _MODEL is None:
        import jax
        from repro.configs import get_config
        from repro.models import build_model
        cfg = get_config("internlm2-1.8b-smoke")
        model = build_model(cfg, remat=False)
        params = model.init(jax.random.PRNGKey(0))
        _MODEL = (cfg, model, params)
    return _MODEL


def _mk_engine(pool_pages=None, **cfg_kw):
    from repro.core.engines import EngineSpec
    from repro.serving import ServeConfig, ServingEngine
    cfg, model, params = _model()
    if pool_pages is None:
        hbm = 64 << 20
    else:
        group = (model.cfg.num_layers * 2 * 4 * model.cfg.num_kv_heads
                 * model.cfg.head_dim
                 * np.dtype(model.compute_dtype).itemsize)
        hbm = pool_pages * group
    return cfg, ServingEngine(model, params, ServeConfig(
        max_len=16, page_tokens=4,
        engine_spec=EngineSpec(engine="paged", kv_hbm_bytes=hbm,
                               kv_hot_window=4, drain_shards=2),
        max_batch_seqs=2, **cfg_kw))


def _reqs(cfg, max_new=4, seed=1):
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, n,
                                               dtype=np.int32),
                    max_new=max_new)
            for i, n in enumerate((6, 9))]


@pytest.mark.slow
def test_poisoned_tick_leaves_no_pinned_pool_pages():
    """Satellite regression at the serving level: an exception raised
    between ``prepare_step`` and ``commit_step`` inside a fused tick must
    leave the pool exactly ``free + live + idle-index`` — the old code
    left that tick's fresh allocations pinned forever."""
    cfg, eng = _mk_engine()
    assert eng.pooled
    reqs = _reqs(cfg)
    real = eng.tiered.commit_step_planes
    calls = {"n": 0}

    def poisoned(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("poisoned tick")
        return real(*a, **kw)

    eng.tiered.commit_step_planes = poisoned
    with pytest.raises(RuntimeError, match="poisoned tick"):
        eng.generate(reqs)
    kv = eng.tiered
    live = {p for tbl in kv.block_table.values() for p in tbl if p >= 0}
    assert len(kv.free_pages) + len(live) + kv._idle_index_pages() \
        == kv.pool_pages
    assert calls["n"] == 2                 # the poison stopped the run


@pytest.mark.slow
def test_lost_page_sheds_row_and_stream_stays_identical():
    """A lost host page surfacing mid-tick: the losing row is shed back to
    the FRONT of waiting, re-prefilled from ``prompt + committed``, and
    every request still finishes with the fault-free run's exact tokens.

    The loss is injected at the step boundary (the engine raise itself is
    pinned at the KV level in tests/test_tiering.py): the scheduler's
    admission and placement guards resolve pool pressure by whole-row
    preempt/restore, so a running row only holds a spilled page — the
    organic trigger — under engine-API schedules, not model-backed ones."""
    from repro.serving.faults import LostPageError
    cfg, ref_eng = _mk_engine()
    ref = _reqs(cfg)
    ref_eng.generate(ref)
    want = {r.rid: list(r.generated) for r in ref}

    cfg, eng = _mk_engine()
    reqs = _reqs(cfg)
    real = eng.step_batch
    calls = {"n": 0}

    def lossy(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 2:            # rid 0's host page comes up lost
            raise LostPageError(0, 0)  # (before the step commits anything)
        return real(*a, **kw)

    eng.step_batch = lossy
    eng.generate(reqs)
    for r in reqs:
        assert r.done and r.generated == want[r.rid], r.rid
    assert eng.sched_stats["sched_rows_shed"] == 1
    # the shed cost re-prefill ticks but never a token
    assert eng.sched_stats["sched_ticks"] > ref_eng.sched_stats["sched_ticks"]


@pytest.mark.slow
def test_crash_at_tick_recovers_token_identically():
    """Crash at a tick boundary, then recovery on a FRESH engine sharing
    the same journal (the NVMM region survives, the process does not):
    the recovered streams equal the uninterrupted run's."""
    cfg, ref_eng = _mk_engine()
    ref = _reqs(cfg, max_new=5)
    ref_eng.generate(ref)
    want = {r.rid: list(r.generated) for r in ref}

    journal = ServingJournal(capacity=1 << 16)
    cfg, eng = _mk_engine(journal=journal,
                          fault_plan=FaultPlan(crash_at_tick=3))
    reqs = _reqs(cfg, max_new=5)
    with pytest.raises(CrashFault):
        eng.generate(reqs)
    state, last_tick = journal.replay()
    assert last_tick == 3 and state             # durable mid-stream commits
    assert any(0 < len(t) < 5 for t in state.values())

    cfg, eng2 = _mk_engine(journal=journal)     # fresh engine, same journal
    reqs2 = _reqs(cfg, max_new=5)
    eng2.recover(reqs2)
    for r in reqs2:
        assert r.done and r.generated == want[r.rid], r.rid
    # recovery journaled the resumed ticks too: a second crash replays more
    state2, t2 = journal.replay()
    assert t2 >= last_tick
    assert {r: list(map(int, t)) for r, t in state2.items()} == want
