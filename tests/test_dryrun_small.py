"""Dry-run machinery on a reduced mesh in a subprocess (the full 512-device
grid runs via `python -m repro.launch.dryrun --all --mesh both`; artifacts in
artifacts/dryrun are the deliverable-e record)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
ENV = dict(os.environ,
           PYTHONPATH=str(REPO / "src"),
           REPRO_DRYRUN_XLA_FLAGS="--xla_force_host_platform_device_count=8",
           REPRO_TEST_MESH="2x2")


def _run(args, env=ENV):
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600)


@pytest.mark.parametrize("arch,shape", [
    ("internlm2-1.8b-smoke", "train_4k"),
    ("deepseek-v2-236b-smoke", "train_4k"),     # MoE+MLA w/ EP shard_map
    ("mamba2-1.3b-smoke", "decode_32k"),
    ("zamba2-1.2b-smoke", "decode_32k"),
])
def test_dryrun_smoke_cells(arch, shape, tmp_path):
    r = _run(["--arch", arch, "--shape", shape, "--out", str(tmp_path)])
    assert r.returncode == 0, r.stdout + r.stderr
    arts = list(tmp_path.glob("*.json"))
    assert len(arts) == 1
    data = json.loads(arts[0].read_text())
    assert data["collectives"]["num_ops"] >= 0
    assert data["per_device_live_bytes"] > 0


def test_dryrun_multipod_mesh(tmp_path):
    env = dict(ENV, REPRO_TEST_MESH="2x2x2")
    r = _run(["--arch", "internlm2-1.8b-smoke", "--shape", "train_4k",
              "--mesh", "multi", "--out", str(tmp_path)], env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    data = json.loads(next(tmp_path.glob("*.json")).read_text())
    assert data["mesh"] == "multipod"


def test_full_grid_artifacts_exist_if_generated():
    """When the production dry-run has been run, every live cell must have
    an artifact and every artifact must record collective + memory data."""
    art_dir = REPO / "artifacts" / "dryrun"
    if not art_dir.exists():
        pytest.skip("production dry-run not yet executed")
    arts = list(art_dir.glob("*__pod.json"))
    if not arts:
        pytest.skip("no single-pod artifacts")
    for a in arts:
        d = json.loads(a.read_text())
        assert "collectives" in d and "memory" in d
        assert d["memory"]["argument_bytes"] > 0
