"""Serving engine: generation correctness + the tiered designs' behavioural
equivalence (they may only differ in timing/amplification, never tokens)."""
import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.serving import ServeConfig, ServingEngine
from repro.serving.engine import Request


def _engine(design, arch="internlm2-1.8b-smoke"):
    cfg = get_config(arch)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, ServingEngine(model, params, ServeConfig(
        max_len=64, design=design, page_tokens=4, hot_window_tokens=8))


@pytest.mark.parametrize("design", ["log", "paged"])
def test_generates_tokens(design):
    cfg, engine = _engine(design)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 16,
                                               dtype=np.int32), max_new=8)
            for i in range(2)]
    engine.generate(reqs)
    for r in reqs:
        assert r.done and len(r.generated) == 8
        assert all(0 <= t < cfg.vocab_size for t in r.generated)


def test_designs_generate_identical_tokens():
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, 512, 16, dtype=np.int32)
    outs = {}
    for design in ("log", "paged"):
        _, engine = _engine(design)
        req = Request(rid=0, prompt=prompt.copy(), max_new=12)
        engine.generate([req])
        outs[design] = req.generated
    assert outs["log"] == outs["paged"]


def test_tiered_mirror_consistent_with_model_cache():
    cfg, engine = _engine("paged")
    rng = np.random.default_rng(2)
    req = Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, 12,
                                             dtype=np.int32), max_new=4)
    engine.generate_sequential([req])
    n = engine.tiered.seq_len[0]
    assert n == 12 + 4
    got = engine.tiered.gather(0, layer=0)
    assert got.shape[1] == n
    assert np.isfinite(got.astype(np.float32)).all()


def test_batched_generate_releases_finished_sequences():
    """The scheduler frees a finished request's KV from every tier — that
    is what makes room for the next admission under pressure."""
    cfg, engine = _engine("paged")
    rng = np.random.default_rng(2)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 12,
                                               dtype=np.int32), max_new=4)
            for i in range(2)]
    engine.generate(reqs)
    assert all(r.done for r in reqs)
    assert engine.tiered.seq_len == {}
    assert engine.stats()["releases"] == 2


def test_mirror_transfers_only_the_new_token_bytes():
    """Regression: the decode mirror must slice the new token on device and
    transfer exactly one (L, 2, K, D) fp16 token per generated token — the
    byte stat would be ~max_len× larger if a whole cache row round-tripped."""
    prompt_len, max_new = 12, 4
    cfg, engine = _engine("log")
    rng = np.random.default_rng(4)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, prompt_len,
                                               dtype=np.int32),
                    max_new=max_new) for i in range(2)]
    engine.generate(reqs)
    token_bytes = (engine.model.cfg.num_layers * 2
                   * engine.model.cfg.num_kv_heads
                   * engine.model.cfg.head_dim * 2)        # fp16
    expect = 2 * (prompt_len + max_new) * token_bytes      # 2 requests
    assert engine.stats()["mirror_d2h_bytes"] == expect


def test_ssm_arch_skips_kv_mirroring():
    cfg, engine = _engine("log", arch="mamba2-1.3b-smoke")
    rng = np.random.default_rng(3)
    req = Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, 8,
                                             dtype=np.int32), max_new=4)
    engine.generate([req])
    assert len(req.generated) == 4
    assert engine.tiered.stats["log_appends"] == 0   # O(1) state, no paging
