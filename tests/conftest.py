import os

# tests run on ONE device (the dry-run sets its own 512-device env in a
# subprocess); keep any inherited dry-run flags out of the test process
os.environ.pop("XLA_FLAGS", None)

import numpy as np
import pytest


def pytest_configure(config):
    # `-m "not slow"` gives a quick iteration loop; tier-1 runs everything
    config.addinivalue_line(
        "markers",
        "slow: heavyweight serving/property tests (deselect with "
        "-m \"not slow\")")

try:        # hypothesis is optional: property tests skip when it is absent
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:
    pass
else:
    settings.register_profile(
        "repro", deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large])
    settings.load_profile("repro")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
