"""Training substrate: loss decreases, schedules, microbatch equivalence,
gradient compression with error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import SyntheticLMDataset, make_batch_iterator
from repro.distributed.compression import (
    dequantize_int8, make_error_feedback_compressor, quantize_int8)
from repro.models import build_model
from repro.training import (AdamWConfig, init_train_state, lr_at,
                            make_train_step)


def test_loss_decreases_internlm_smoke():
    cfg = get_config("internlm2-1.8b-smoke")
    model = build_model(cfg, remat=False)
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, opt))
    ds = SyntheticLMDataset(cfg.vocab_size, 128, 8, seed=0)
    it = make_batch_iterator(ds)
    losses = []
    for _ in range(60):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < 0.75 * np.mean(losses[:5])
    assert np.mean(losses[-5:]) > ds.entropy_floor - 0.05


def test_wsd_schedule_shape():
    cfg = AdamWConfig(lr=1.0, schedule="wsd", warmup_steps=10,
                      total_steps=100, decay_frac=0.2)
    lrs = [float(lr_at(cfg, s)) for s in range(101)]
    assert lrs[0] < 0.2                     # warmup start
    assert lrs[10] == pytest.approx(1.0)    # warmup done
    assert lrs[50] == pytest.approx(1.0)    # stable plateau
    assert lrs[79] == pytest.approx(1.0)    # last stable step
    assert lrs[100] == pytest.approx(0.1, rel=0.05)   # decayed tail
    assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))


def test_cosine_schedule_monotone_after_warmup():
    cfg = AdamWConfig(lr=1.0, schedule="cosine", warmup_steps=5,
                      total_steps=50)
    lrs = [float(lr_at(cfg, s)) for s in range(51)]
    assert lrs[5] == pytest.approx(1.0, rel=0.05)
    assert lrs[50] == pytest.approx(0.1, rel=0.05)
    assert all(a >= b - 1e-9 for a, b in zip(lrs[5:], lrs[6:]))


def test_microbatch_equivalence():
    """mb=2 grad accumulation == one big batch (same tokens)."""
    cfg = get_config("internlm2-1.8b-smoke")
    model = build_model(cfg, remat=False)
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state1 = init_train_state(model, jax.random.PRNGKey(0))
    state2 = jax.tree.map(lambda x: x, state1)
    ds = SyntheticLMDataset(cfg.vocab_size, 64, 8, seed=0)
    big = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    micro = {k: v.reshape(2, 4, *v.shape[1:]) for k, v in big.items()}
    s1, m1 = jax.jit(make_train_step(model, opt))(state1, big)
    s2, m2 = jax.jit(make_train_step(model, opt, microbatches=2))(
        state2, micro)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000) * 3, jnp.float32)
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s, x.shape)
    err = np.max(np.abs(np.asarray(deq - x)))
    assert err <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6


def test_error_feedback_converges_on_quadratic():
    """EF-compressed SGD reaches the optimum of a quadratic — the classic
    error-feedback guarantee (plain int8 without EF stalls at the
    quantization floor)."""
    target = jnp.asarray(np.linspace(-2, 2, 512), jnp.float32)
    w = {"w": jnp.zeros(512)}
    init_state, compress = make_error_feedback_compressor(w)
    err = init_state()
    lr = 0.5
    for _ in range(200):
        g = {"w": (w["w"] - target) * 0.5}
        g, err = compress(g, err)
        w = {"w": w["w"] - lr * g["w"]}
    final = float(jnp.max(jnp.abs(w["w"] - target)))
    assert final < 0.05
