"""Radix tree + LRU list unit tests."""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.core.lru import LRUList
from repro.core.radix import RadixTree


def test_radix_basic():
    t = RadixTree()
    t.insert(0, "a")
    t.insert(12345678, "b")
    t.insert(2 ** 32 - 1, "c")
    assert t.lookup(12345678) == "b"
    assert t.lookup(99) is None
    assert len(t) == 3
    t.delete(12345678)
    assert t.lookup(12345678) is None
    assert len(t) == 2
    assert dict(t.items()) == {0: "a", 2 ** 32 - 1: "c"}


@given(st.lists(st.integers(0, 2 ** 20), max_size=200))
def test_radix_matches_dict(keys):
    t, d = RadixTree(), {}
    for i, k in enumerate(keys):
        t.insert(k, i)
        d[k] = i
    assert len(t) == len(d)
    for k in keys:
        assert t.lookup(k) == d[k]
    assert dict(t.items()) == d


def test_lru_order():
    l = LRUList()
    for k in "abc":
        l.touch(k)
    l.touch("a")                       # a becomes MRU
    assert l.pop_lru() == "b"
    assert l.pop_lru() == "c"
    assert l.pop_lru() == "a"
    assert l.pop_lru() is None


@given(st.lists(st.tuples(st.sampled_from("tpr"), st.integers(0, 20)),
                max_size=300))
def test_lru_matches_ordered_dict_model(ops):
    from collections import OrderedDict
    l, model = LRUList(), OrderedDict()
    for op, k in ops:
        if op == "t":
            l.touch(k)
            model.pop(k, None)
            model[k] = True
        elif op == "r":
            l.remove(k)
            model.pop(k, None)
        else:
            got = l.pop_lru()
            want = next(iter(model)) if model else None
            if want is not None:
                model.pop(want)
            assert got == want
    assert list(l.lru_order()) == list(model.keys())
