"""Continuous-batching scheduler: the serving-tier battery (ISSUE 3).

Four suites lock the scheduler down:

* **equivalence** — greedy batched decode is token-identical to the
  sequential reference for every KV engine, any admission order, and any
  batch width (raggedness/padding never leaks into logits);
* **preemption round-trip** — a preempt→restore cycle mid-decode changes no
  generated token for any engine (host/disk spills are exact);
* **forced pressure** — an HBM-budget-constrained run completes all
  requests, observes at least one preempt/restore cycle in the engine
  stats, and every stat counter stays monotone tick by tick;
* **starvation guard** — every admitted request finishes even when the
  budget forces constant preemption churn.
"""
import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core.engines import EngineSpec
from repro.models import build_model
from repro.serving import Request, Scheduler, ServeConfig, ServingEngine

ARCH = "internlm2-1.8b-smoke"
KV_ENGINES = ("paged", "log", "kvhybrid")
MAX_LEN = 48
PROMPT_LENS = (8, 12, 8)     # two distinct lengths bound jit compiles
MAX_NEW = 6


@pytest.fixture(scope="module")
def lm():
    cfg = get_config(ARCH)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _token_bytes(mcfg) -> int:
    """One mirrored fp16 KV token, all layers."""
    return mcfg.num_layers * 2 * mcfg.num_kv_heads * mcfg.head_dim * 2


def _requests(cfg, seed=0, max_new=MAX_NEW):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, n, dtype=np.int32),
                    max_new=max_new)
            for i, n in enumerate(PROMPT_LENS)]


def _engine(lm, engine, *, hbm_bytes=64 << 20, max_batch_seqs=4,
            max_batch_tokens=None, chunk=None, fuse=True):
    cfg, model, params = lm
    return ServingEngine(model, params, ServeConfig(
        max_len=MAX_LEN, page_tokens=4,
        engine_spec=EngineSpec(engine=engine, kv_hbm_bytes=hbm_bytes,
                               kv_hot_window=8, drain_shards=2),
        max_batch_seqs=max_batch_seqs, max_batch_tokens=max_batch_tokens,
        prefill_chunk_tokens=chunk, fuse_ticks=fuse))


@pytest.fixture(scope="module")
def reference(lm):
    """Sequential greedy tokens per rid — engine-independent (the tiered
    mirror never feeds back into the model)."""
    cfg, _, _ = lm
    reqs = _requests(cfg)
    _engine(lm, "log").generate_sequential(reqs)
    return {r.rid: list(r.generated) for r in reqs}


# ------------------------------------------------------------- equivalence
@pytest.mark.parametrize("engine", KV_ENGINES)
@pytest.mark.parametrize("max_batch_seqs", [1, 2, 4])
def test_batched_decode_token_identical_to_sequential(lm, reference, engine,
                                                      max_batch_seqs):
    cfg, _, _ = lm
    reqs = _requests(cfg)
    eng = _engine(lm, engine, max_batch_seqs=max_batch_seqs)
    eng.generate(reqs)
    for r in reqs:
        assert r.done
        assert r.generated == reference[r.rid], (engine, max_batch_seqs,
                                                 r.rid)


@pytest.mark.parametrize("engine", KV_ENGINES)
def test_admission_order_never_changes_tokens(lm, reference, engine):
    """Submitting the same requests in any order gives each request the
    same tokens (batch composition must not leak into any row)."""
    cfg, _, _ = lm
    for order in ((2, 0, 1), (1, 2, 0)):
        reqs = _requests(cfg)
        eng = _engine(lm, engine, max_batch_seqs=2)
        eng.generate([reqs[i] for i in order])
        for r in reqs:
            assert r.generated == reference[r.rid], (engine, order, r.rid)


def test_max_batch_tokens_caps_admission(lm, reference):
    """A token cap admits fewer sequences at once but changes no output."""
    cfg, _, _ = lm
    reqs = _requests(cfg)
    eng = _engine(lm, "log", max_batch_tokens=PROMPT_LENS[0] + MAX_NEW + 1)
    eng.generate(reqs)
    assert eng.sched_stats["sched_peak_running"] == 1
    for r in reqs:
        assert r.generated == reference[r.rid]


def test_max_batch_tokens_enforced_as_batch_grows(lm, reference):
    """Decode growth past the token cap preempts (admission headroom is one
    step; the cap holds over the whole run) — and changes no output."""
    cfg, _, _ = lm
    reqs = _requests(cfg)
    # submit the two 8-token prompts first: both admit (8 + 8+1 <= 20)
    # and then grow to 14 tokens each, crossing the cap mid-decode
    eng = _engine(lm, "log", max_batch_tokens=20)
    sched = Scheduler(eng, [reqs[0], reqs[2], reqs[1]])
    while sched.tick():
        assert sum(r.length for r in sched.running) <= 20
    assert sched.stats.preempts >= 1
    for r in reqs:
        assert r.generated == reference[r.rid]


def test_zero_max_new_matches_sequential(lm):
    """max_new=0 requests finish without decoding a single token on both
    paths (the batched step must not run before the finish check)."""
    cfg, _, _ = lm
    for runner in ("generate", "generate_sequential"):
        reqs = _requests(cfg, max_new=0)
        reqs[1].max_new = 2              # mixed batch: others still decode
        eng = _engine(lm, "log")
        getattr(eng, runner)(reqs)
        assert [len(r.generated) for r in reqs] == [0, 2, 0], runner
        assert all(r.done for r in reqs), runner


# ------------------------------------------- preempt/restore round-trip
@pytest.mark.parametrize("engine", KV_ENGINES)
def test_preempt_restore_mid_decode_preserves_tokens(lm, reference, engine):
    """A tiny HBM budget forces preemption mid-decode; spilled sequences
    must come back bit-identical (same greedy tokens as unconstrained)."""
    cfg, model, _ = lm
    budget = 10 * _token_bytes(model.cfg)     # ~10 resident tokens total
    reqs = _requests(cfg)
    eng = _engine(lm, engine, hbm_bytes=budget)
    eng.generate(reqs)
    stats = eng.stats()
    assert stats["preempts"] >= 1, engine
    assert stats["restores"] >= 1, engine
    for r in reqs:
        assert r.done
        assert r.generated == reference[r.rid], (engine, r.rid)


# --------------------------------------------------------- forced pressure
@pytest.mark.parametrize("engine", KV_ENGINES)
def test_forced_pressure_preempts_and_stats_stay_monotone(lm, engine):
    cfg, model, _ = lm
    reqs = _requests(cfg)
    eng = _engine(lm, engine, hbm_bytes=10 * _token_bytes(model.cfg))
    sched = Scheduler(eng, reqs)
    prev = eng.stats()
    while sched.tick():
        cur = eng.stats()
        assert set(cur) == set(prev)
        for k, v in cur.items():
            assert v >= prev[k], (engine, k)
        prev = cur
    assert eng.tiered.stats["preempts"] >= 1
    assert eng.tiered.stats["restores"] >= 1
    assert sched.stats.preempts == eng.tiered.stats["preempts"]
    assert all(r.done and len(r.generated) == MAX_NEW for r in reqs)


def test_pressure_surface_is_scheduler_sufficient(lm):
    """The scheduler only ever needs pressure()/resident_bytes()/
    victim_hint() — check the surface behaves: pressure hits 1.0 under the
    tight budget, drops after the run releases everything."""
    cfg, model, _ = lm
    eng = _engine(lm, "kvhybrid", hbm_bytes=10 * _token_bytes(model.cfg))
    assert eng.tiered.pressure() == 0.0
    eng.generate(_requests(cfg))
    assert eng.sched_stats["sched_preempts"] >= 1
    assert eng.tiered.pressure() == 0.0       # all released at the end
    assert eng.tiered.hbm_limit_bytes() > 0


# --------------------------------------------------- jit-shape bucketing pins
@pytest.mark.parametrize("engine", ("paged", "log"))
def test_jit_bucketing_pins_compile_counts(lm, reference, engine):
    """The recompile pin: batch width and Qmax bucket to the power-of-two
    ladder, so a run over chunked prompts compiles a handful of step shapes
    — and a SECOND schedule with a different batch width (4 vs 3, same
    bucket) plus the same chunking adds ZERO new compiles, only cache
    hits."""
    cfg, _, _ = lm
    reqs = _requests(cfg)
    eng = _engine(lm, engine, chunk=5)
    eng.generate(reqs)                    # widths 3→bucket 4; chunks 5/2/1
    s1 = eng.stats()
    assert s1["step_compiles"] <= 4, s1["step_compiles"]
    for r in reqs:
        assert r.generated == reference[r.rid]
    rng = np.random.default_rng(3)
    reqs4 = [Request(rid=i,
                     prompt=rng.integers(0, cfg.vocab_size, 12,
                                         dtype=np.int32), max_new=MAX_NEW)
             for i in range(4)]
    eng.generate(reqs4)                   # width 4 → the same bucket
    s2 = eng.stats()
    assert s2["step_compiles"] == s1["step_compiles"], (
        "a new batch width inside an existing bucket must not recompile")
    assert s2["step_cache_hits"] > s1["step_cache_hits"]


def test_jit_bucketing_across_chunk_sizes(lm, reference):
    """Chunk budgets that bucket to the same Qmax share compiles: chunk 5
    and chunk 7 both pad to Qmax 8, so the second engine-warm run of either
    adds no shapes the first didn't."""
    cfg, _, _ = lm
    eng = _engine(lm, "log", chunk=7)
    eng.generate(_requests(cfg))
    base = eng.stats()["step_compiles"]
    # rerun with the same engine: everything is warm
    reqs = _requests(cfg)
    eng.generate(reqs)
    assert eng.stats()["step_compiles"] == base
    for r in reqs:
        assert r.generated == reference[r.rid]


# --------------------------------------------------------- starvation guard
@pytest.mark.parametrize("engine", KV_ENGINES)
def test_chunk_rows_progress_under_preemption_churn(lm, reference, engine):
    """The chunk-row starvation pin (ISSUE 5 satellite): chunked prompts
    under a budget that preempts constantly — every row that sits in the
    running batch must advance ≥1 chunk or token per tick (the scheduler's
    forward-progress guard raises otherwise), every request finishes, and
    no token moves."""
    cfg, model, _ = lm
    reqs = _requests(cfg)
    eng = _engine(lm, engine, hbm_bytes=10 * _token_bytes(model.cfg),
                  chunk=3)
    eng.generate(reqs)                    # must not trip the progress guard
    s = eng.stats()
    assert s["preempts"] >= 1, engine
    assert s["sched_prefill_chunks"] >= 2
    assert s["sched_stalled_row_ticks"] == 0
    for r in reqs:
        assert r.done and r.generated == reference[r.rid], engine


@pytest.mark.slow
@pytest.mark.parametrize("engine", KV_ENGINES)
def test_every_admitted_request_finishes(lm, engine):
    """Churn case: more requests than batch slots, budget small enough to
    preempt constantly — every request still completes with exactly
    max_new tokens (min_running guarantees per-tick progress)."""
    cfg, model, _ = lm
    rng = np.random.default_rng(7)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               PROMPT_LENS[i % 2],
                                               dtype=np.int32), max_new=4)
            for i in range(6)]
    eng = _engine(lm, engine, hbm_bytes=10 * _token_bytes(model.cfg),
                  max_batch_seqs=2)
    eng.generate(reqs)
    assert all(r.done and len(r.generated) == 4 for r in reqs), engine
    assert eng.sched_stats["sched_finished"] == 6
    assert eng.sched_stats["sched_admitted"] == 6
