"""WAL framing: append/iterate, torn-write detection, wrap-around, stale-lap
protection."""
import pytest

from repro.core.wal import HEADER_SIZE, CircularWAL


def test_append_iterate_roundtrip():
    wal = CircularWAL(4096)
    recs = [(i * 100, bytes([i]) * 10) for i in range(5)]
    for off, payload in recs:
        wal.append(off, payload)
    got = [(r.offset, r.payload) for _, r in wal.iter_from(wal.tail)]
    assert got == recs


def test_log_full_raises():
    wal = CircularWAL(128)
    wal.append(0, b"x" * (128 - HEADER_SIZE))
    with pytest.raises(BufferError):
        wal.append(0, b"y")


def test_wraparound():
    wal = CircularWAL(256)
    for i in range(50):
        wal.append(i, bytes([i % 256]) * 20)
        wal.reclaim_to(wal.head, wal.next_seqno)
    # last record still readable after many laps
    wal2_records = wal.recover_scan()
    assert wal2_records == []            # everything reclaimed


def test_recover_scan_returns_unreclaimed():
    wal = CircularWAL(4096)
    for i in range(4):
        wal.append(i * 10, b"a" * 8)
    # reclaim first two
    recs = list(wal.iter_from(wal.tail))
    wal.reclaim_to(recs[2][0], recs[2][1].seqno)
    out = wal.recover_scan()
    assert [r.seqno for r in out] == [3, 4]


def test_torn_write_detected():
    wal = CircularWAL(4096)
    wal.append(0, b"good" * 4)
    start = wal.head
    wal.append(100, b"torn" * 4)
    # corrupt one payload byte of the second record (simulated torn write)
    pos = (start + HEADER_SIZE) % wal.capacity
    wal.buf[pos] ^= 0xFF
    out = wal.recover_scan()
    assert [r.offset for r in out] == [0]     # scan stops at the torn record


def test_stale_lap_records_not_replayed():
    wal = CircularWAL(128)
    wal.append(0, b"old!" * 4)                # lap 1
    wal.reclaim_to(wal.head, wal.next_seqno)
    # crash now: tail==head, but the old bytes are still in the buffer
    out = wal.recover_scan()
    assert out == []                          # seqno guard rejects stale lap
