"""Speculative multi-token decode: the draft-and-verify battery (ISSUE 7).

Five suites lock the tentpole down:

* **acceptance edges** — scripted proposers force 0 accepted, all-k
  accepted, and mid-run rejection per tick; the committed stream equals
  the sequential reference in every case (a proposer can only change
  speed, never tokens) and the ``spec_proposed``/``spec_accepted``
  counters land exactly where the script says;
* **rollback accounting** — a pool run under an always-wrong proposer
  rewinds every speculative page allocation: the PR 6 churn invariant
  (``pool == free + idle-index`` pages, no stranded users) holds after
  heavy rejection churn;
* **composition** — speculation × preemption round-trips and speculation
  × prefix-cache splices change no tokens;
* **stats** — ``spec_proposed``/``spec_accepted`` are monotone tick by
  tick and never cross;
* **launch economy** — a fused tick with speculation is still exactly ONE
  launch (``step_calls == ticks``), and an all-accepting proposer commits
  more than one token per decode row-launch.
"""
import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core.engines import EngineSpec
from repro.models import build_model
from repro.serving import (NGramProposer, Request, Scheduler, ServeConfig,
                           ServingEngine)

ARCH = "internlm2-1.8b-smoke"
KV_ENGINES = ("paged", "log", "kvhybrid")
MAX_LEN = 48
PROMPT_LENS = (8, 12, 8)
MAX_NEW = 6


@pytest.fixture(scope="module")
def lm():
    cfg = get_config(ARCH)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _token_bytes(mcfg) -> int:
    return mcfg.num_layers * 2 * mcfg.num_kv_heads * mcfg.head_dim * 2


def _group_bytes(model) -> int:
    """One 4-token pool page group, all layers (pool sizing)."""
    mcfg = model.cfg
    return (mcfg.num_layers * 2 * 4 * mcfg.num_kv_heads * mcfg.head_dim
            * np.dtype(model.compute_dtype).itemsize)


def _requests(cfg, seed=0, max_new=MAX_NEW):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, n, dtype=np.int32),
                    max_new=max_new)
            for i, n in enumerate(PROMPT_LENS)]


def _engine(lm, engine, *, k=4, proposer=None, hbm_bytes=64 << 20,
            max_batch_seqs=4, chunk=None, prefix_tokens=0):
    cfg, model, params = lm
    return ServingEngine(model, params, ServeConfig(
        max_len=MAX_LEN, page_tokens=4,
        engine_spec=EngineSpec(engine=engine, kv_hbm_bytes=hbm_bytes,
                               kv_hot_window=8, drain_shards=2,
                               prefix_cache_tokens=prefix_tokens),
        max_batch_seqs=max_batch_seqs, prefill_chunk_tokens=chunk,
        speculate_k=k, draft_proposer=proposer))


@pytest.fixture(scope="module")
def reference(lm):
    cfg, _, _ = lm
    reqs = _requests(cfg)
    ServingEngine(lm[1], lm[2], ServeConfig(
        max_len=MAX_LEN, page_tokens=4,
        engine_spec=EngineSpec(engine="log", kv_hbm_bytes=64 << 20,
                               kv_hot_window=8, drain_shards=2),
    )).generate_sequential(reqs)
    return {r.rid: list(r.generated) for r in reqs}


class OracleProposer:
    """Scripted drafts derived from the known greedy continuation: proposes
    the TRUE next tokens, corrupting every draft at or past ``wrong_at``
    (None = never). ``wrong_at=0`` rejects every draft, ``wrong_at=j``
    forces a mid-run rejection after exactly ``j`` accepted drafts."""

    def __init__(self, truth: dict, vocab: int, wrong_at=None):
        self.truth = truth             # rid -> prompt + sequential tokens
        self.vocab = vocab
        self.wrong_at = wrong_at

    def propose(self, seq, tokens, k):
        full = self.truth[seq]
        pos = len(tokens)
        out = []
        for j in range(k):
            if pos + j >= len(full):
                break
            t = int(full[pos + j])
            if self.wrong_at is not None and j >= self.wrong_at:
                t = (t + 1) % self.vocab
            out.append(t)
        return out

    def drop(self, seq):
        pass


def _truth(cfg, reference):
    reqs = _requests(cfg)
    return {r.rid: [int(t) for t in r.prompt] + reference[r.rid]
            for r in reqs}


# ---------------------------------------------------------- acceptance edges
@pytest.mark.parametrize("engine", ("paged", "log"))
@pytest.mark.parametrize("wrong_at,expect", [
    (0, "none"),        # every draft rejected: rollback every tick
    (1, "partial"),     # mid-run rejection: accept 1, roll the tail back
    (None, "all"),      # every draft accepted: full multi-token commits
])
def test_acceptance_edges_token_identical(lm, reference, engine, wrong_at,
                                          expect):
    cfg, _, _ = lm
    prop = OracleProposer(_truth(cfg, reference), cfg.vocab_size,
                          wrong_at=wrong_at)
    reqs = _requests(cfg)
    eng = _engine(lm, engine, k=4, proposer=prop)
    eng.generate(reqs)
    for r in reqs:
        assert r.done and r.generated == reference[r.rid], (engine, wrong_at,
                                                            r.rid)
    s = eng.stats()
    assert s["spec_proposed"] > 0
    if expect == "none":
        assert s["spec_accepted"] == 0
    elif expect == "partial":
        assert 0 < s["spec_accepted"] < s["spec_proposed"]
    else:
        # the oracle only ever proposes true greedy tokens
        assert s["spec_accepted"] == s["spec_proposed"]
        # launch economy: multi-token commits finish rows in fewer
        # decode row-launches than tokens generated
        assert s["sched_decode_rows"] < sum(
            len(reference[r.rid]) for r in reqs)


def test_rejected_tail_never_reaches_the_mirror(lm, reference):
    """Mirrored rollback is byte-exact: an always-wrong proposer moves
    exactly the same device→host mirror traffic as no speculation at all
    (the rejected tail is truncated ON DEVICE, before the transfer)."""
    cfg, _, _ = lm
    base = _engine(lm, "log", k=0)
    base.generate(_requests(cfg))
    prop = OracleProposer(_truth(cfg, reference), cfg.vocab_size, wrong_at=0)
    spec = _engine(lm, "log", k=4, proposer=prop)
    reqs = _requests(cfg)
    spec.generate(reqs)
    assert spec.mirror_d2h_bytes == base.mirror_d2h_bytes
    for r in reqs:
        assert r.generated == reference[r.rid]


# --------------------------------------------------------- rollback invariant
@pytest.mark.parametrize("wrong_at", [0, 2, None])
def test_rollback_preserves_pool_churn_invariant(lm, reference, wrong_at):
    """The PR 6 churn invariant survives speculative rollback: a tight pool
    run whose every tick allocates draft pages and (for wrong_at != None)
    rewinds them leaves zero stranded page users and pool == free +
    idle-index pages."""
    cfg, model, _ = lm
    prop = OracleProposer(_truth(cfg, reference), cfg.vocab_size,
                          wrong_at=wrong_at)
    eng = _engine(lm, "paged", k=4, proposer=prop,
                  hbm_bytes=(MAX_LEN // 4 + 3) * _group_bytes(model))
    assert eng.pooled
    reqs = _requests(cfg)
    eng.generate(reqs)
    for r in reqs:
        assert r.generated == reference[r.rid], (wrong_at, r.rid)
    kv = eng.tiered
    assert not kv.page_users
    assert len(kv.free_pages) + kv._idle_index_pages() == kv.pool_pages
    # byte counters stay the exact bytes-moved record through the churn:
    # pages spilled mid-tick then rolled back keep their D2H on the books
    # (the bytes DID move) without ever double-counting (ISSUE 8)
    s = kv.stats
    assert s["pool_d2h_bytes"] == s["pool_page_spills"] * kv._group_bytes
    assert s["pool_h2d_bytes"] == (
        (s["pool_faults"] + s["prefetch_hits"]) * kv._group_bytes
        + s["restore_in_bytes"])


# -------------------------------------------------------------- composition
@pytest.mark.parametrize("engine", KV_ENGINES)
def test_speculation_preemption_roundtrip(lm, reference, engine):
    """Preemption mid-draft: a tiny budget forces preempt/restore cycles
    while every decode row is speculating; the n-gram proposer's state is
    derived from the committed stream, so restores change nothing."""
    cfg, model, _ = lm
    reqs = _requests(cfg)
    eng = _engine(lm, engine, k=4, hbm_bytes=10 * _token_bytes(model.cfg))
    eng.generate(reqs)
    s = eng.stats()
    assert s["preempts"] >= 1 and s["restores"] >= 1, engine
    for r in reqs:
        assert r.done and r.generated == reference[r.rid], (engine, r.rid)


def test_speculation_prefix_splice(lm):
    """Speculation over spliced admissions: duplicate prompts adopt shared
    pool pages (zero prefill for the covered prefix) and then speculate —
    tokens must equal the sequential reference and at least one admission
    must actually have spliced."""
    cfg, _, _ = lm
    rng = np.random.default_rng(11)
    base = rng.integers(0, cfg.vocab_size, 9, dtype=np.int32)
    prompts = [base.copy(), base.copy(),
               np.concatenate([base[:6], rng.integers(0, cfg.vocab_size, 2,
                                                      dtype=np.int32)])]
    ref = [Request(rid=i, prompt=p.copy(), max_new=MAX_NEW)
           for i, p in enumerate(prompts)]
    _engine(lm, "paged", k=0, prefix_tokens=0).generate_sequential(ref)
    want = {r.rid: list(r.generated) for r in ref}

    eng = _engine(lm, "paged", k=4, prefix_tokens=1 << 12)
    assert eng.pooled and eng.prefix_cache is not None
    reqs = [Request(rid=i, prompt=p.copy(), max_new=MAX_NEW)
            for i, p in enumerate(prompts)]
    eng.generate(reqs)
    s = eng.stats()
    assert s["sched_spliced"] >= 1
    assert s["spec_proposed"] > 0
    for r in reqs:
        assert r.done and r.generated == want[r.rid], r.rid


# -------------------------------------------------------------------- stats
def test_spec_stats_monotone_and_ordered(lm):
    """spec_proposed/spec_accepted never run backwards tick by tick, never
    cross (accepted ≤ proposed), and show up — zeroed — on every engine
    even with speculation off (uniform stats key set)."""
    cfg, _, _ = lm
    eng = _engine(lm, "paged", k=4)
    sched = Scheduler(eng, _requests(cfg))
    prev = eng.stats()
    while sched.tick():
        cur = eng.stats()
        assert cur["spec_proposed"] >= prev["spec_proposed"]
        assert cur["spec_accepted"] >= prev["spec_accepted"]
        assert cur["spec_accepted"] <= cur["spec_proposed"]
        prev = cur
    assert eng.stats()["spec_proposed"] > 0
    for engine in KV_ENGINES:
        off = _engine(lm, engine, k=0)
        off.generate(_requests(cfg))
        s = off.stats()
        assert s["spec_proposed"] == 0 and s["spec_accepted"] == 0, engine


# ------------------------------------------------------------ launch economy
@pytest.mark.parametrize("engine", ("paged", "log"))
def test_fused_tick_with_speculation_is_one_launch(lm, reference, engine):
    """The PR 5 pin extended: speculation rides INSIDE the fused tick —
    drafts and their verification add zero extra launches, so
    ``step_calls == ticks`` exactly (admission prefills are counted
    separately in ``prefill_calls``)."""
    cfg, _, _ = lm
    reqs = _requests(cfg)
    eng = _engine(lm, engine, k=4)
    eng.generate(reqs)
    s = eng.stats()
    assert s["step_calls"] == s["sched_ticks"], engine
    assert s["fused_steps"] == s["sched_ticks"], engine
    for r in reqs:
        assert r.generated == reference[r.rid]


def test_ngram_proposer_suffix_order_and_reset():
    """Unit pins for the self-drafting proposer: longest-suffix context
    wins, proposals extend recursively, unknown contexts stop early, and
    drop() forgets the sequence."""
    p = NGramProposer(max_n=3)
    # stream with a repeating 1,2,3 cycle: suffix (1,2,3)->1, (2,3,1)->2 ...
    assert p.propose(0, [1, 2, 3, 1, 2, 3], 4) == [1, 2, 3, 1]
    # extending the stream with a surprise token leaves every ladder rung
    # unseen for the new suffix: nothing to propose
    assert p.propose(0, [1, 2, 3, 1, 2, 3, 1, 2, 9], 1) == []
    # most recent continuation wins: (1,2) was followed by 7, then by 8
    assert p.propose(1, [1, 2, 7, 1, 2, 8, 1, 2], 1) == [8]
    p.drop(0)
    assert p.propose(0, [7], 2) == []       # fresh history, nothing learned
