"""Async tier transfers + hot/cold victim model: the ISSUE 8 battery.

Five suites lock the tentpole and its satellite bugfixes down:

* **pipeline units** — the double-buffered :class:`TransferPipeline` over a
  SimClock: FIFO per channel, independent channels, ``after=`` chaining,
  barrier/cancel/flush semantics, and the backlog gauge;
* **heat model** — :class:`PageHeat` ranks often/recently touched pages
  hot, decays cold, and forgets a slot's previous tenant on ``assign``;
* **headroom honesty** (satellite 1) — pages pinned without an index
  object behind them are either real headroom (idle: freed directly) or
  real spill victims (live: pin dropped at spill) — never pages the
  pressure surface promises and allocation then crashes on;
* **thrash + rewind churn** (satellites 2 and 3) — a multi-page fault
  burst is not its own next victim, and speculative rollback of pages
  spilled mid-tick drops the dead staging copies while the byte counters
  stay the monotone bytes-moved record;
* **sync/async equivalence** — the pipeline is timing-only: identical
  reads, identical allocation decisions, ``prefetch_hits + pool_faults ==
  sync pool_faults`` exactly, and a simulated clock that never runs
  slower than the synchronous baseline.
"""
import numpy as np
import pytest

from repro.core import SimClock, create_kv_engine
from repro.core.engines import EngineSpec, list_kv_engines
from repro.core.kvcache import HOST_LINK, KVSpec
from repro.serving.tiering import PageHeat, TransferPipeline

KV_SPEC = KVSpec(num_layers=2, kv_heads=2, head_dim=8, page_tokens=4,
                 dtype=np.dtype(np.float32))   # exact round trips for
                                               # assert_array_equal below


def _pooled_kv(pages, *, async_tiering=False):
    clock = SimClock()
    kv = create_kv_engine(
        EngineSpec(engine="paged", kv_hbm_bytes=1 << 30,
                   async_tiering=async_tiering), KV_SPEC, clock)
    kv.init_pool(dtype=np.float32, pages=pages)
    return kv, clock


def _toks(rng, n):
    return rng.standard_normal(
        (KV_SPEC.num_layers, 2, n, KV_SPEC.kv_heads,
         KV_SPEC.head_dim)).astype(np.float32)


# ------------------------------------------------------------ pipeline units
def test_pipeline_submit_is_background_barrier_is_stall():
    clock = SimClock()
    p = TransferPipeline(clock)
    fin = p.submit(p.D2H, ("d2h", 0, 0), HOST_LINK, "write", 1 << 20)
    assert clock.now == 0.0 and fin > 0.0        # submit never advances
    assert p.finish_of(("d2h", 0, 0)) == fin
    assert p.backlog_s() == pytest.approx(fin)
    stall = p.barrier(("d2h", 0, 0))
    assert stall == pytest.approx(fin) and clock.now == pytest.approx(fin)
    assert p.barrier(("d2h", 0, 0)) == 0.0       # idempotent: already done
    assert p.pending == 0


def test_pipeline_channels_fifo_and_independent():
    clock = SimClock()
    p = TransferPipeline(clock)
    f1 = p.submit(p.D2H, ("d2h", 0, 0), HOST_LINK, "write", 1 << 20)
    f2 = p.submit(p.D2H, ("d2h", 0, 1), HOST_LINK, "write", 1 << 20)
    g1 = p.submit(p.H2D, ("h2d", 1, 0), HOST_LINK, "read", 1 << 20)
    assert f2 > f1                                # FIFO within a channel
    assert g1 < f2                                # channels don't queue on
    assert g1 == pytest.approx(f1)                # each other (double-buffer)


def test_pipeline_after_chains_across_channels():
    clock = SimClock()
    p = TransferPipeline(clock)
    f = p.submit(p.D2H, ("d2h", 0, 0), HOST_LINK, "write", 1 << 20)
    g = p.submit(p.H2D, ("h2d", 0, 0), HOST_LINK, "read", 1 << 18, after=f)
    assert g > f                                  # starts once the D2H lands
    free = p.submit(p.H2D, ("h2d", 0, 1), HOST_LINK, "read", 1 << 18)
    assert free > g                               # but the channel stays FIFO


def test_pipeline_cancel_and_flush():
    clock = SimClock()
    p = TransferPipeline(clock)
    p.submit(p.D2H, ("d2h", 3, 0), HOST_LINK, "write", 1 << 20)
    p.submit(p.H2D, ("h2d", 3, 1), HOST_LINK, "read", 1 << 20)
    f = p.submit(p.D2H, ("d2h", 4, 0), HOST_LINK, "write", 1 << 20)
    assert p.cancel(("d2h", 3, 0)) and not p.cancel(("d2h", 3, 0))
    assert p.cancel_seq(3) == 1 and p.pending == 1
    assert p.flush() == pytest.approx(f)          # waits the max finish
    assert clock.now == pytest.approx(f) and p.flush() == 0.0


def test_page_heat_ranks_and_resets():
    h = PageHeat()
    h.assign(0), h.assign(1)
    h.touch(1)
    for _ in range(3):
        h.touch(0)
    assert h.hotness(0) > h.hotness(1) > 0.0      # frequent+recent wins
    for _ in range(10):
        h.touch(1)                                # page 0 ages out
    assert h.hotness(1) > h.hotness(0)
    hot = h.hotness(1)
    h.assign(1)                                   # slot handed to a new page
    assert h.hotness(1) == 0.0 < hot              # no inherited heat


# --------------------------------------------- satellite 1: headroom honesty
def test_stale_pinned_idle_pages_are_usable_headroom():
    """Pages pinned via raw ``pin_page`` with no index object, then
    orphaned by their sequence's release, counted as admission headroom
    but the allocator could never free them: ``can_admit_tokens`` said
    yes, ``_alloc_page`` raised pool-exhausted. They must free directly."""
    kv, _ = _pooled_kv(pages=2)
    rng = np.random.default_rng(0)
    kv.append(0, _toks(rng, 8))                   # both pool pages
    for phys in list(kv.block_table[0]):
        kv.pin_page(phys)
    kv.release(0)                                 # idle but still pinned
    assert not kv.free_pages
    assert kv.can_admit_tokens(8)                 # headroom promised...
    want = _toks(rng, 8)
    kv.append(1, want)                            # ...must be deliverable
    assert not kv.trie_refs                       # stale pins gone
    got = kv.read(1, layer=0)
    np.testing.assert_array_equal(got[0], want[0, 0])


def test_spill_drops_stale_pin_instead_of_skipping():
    """A live sequence's page under a stale pin is a spill candidate (the
    pin drops), not a permanently resident page that shrinks the pool."""
    kv, _ = _pooled_kv(pages=2)
    rng = np.random.default_rng(1)
    a = _toks(rng, 4)
    kv.append(0, a)
    kv.pin_page(kv.block_table[0][0])
    kv.append(1, _toks(rng, 4))                   # pool now full
    kv.append(1, _toks(rng, 4))                   # must spill seq 0's page
    assert kv.block_table[0][0] == -1 and not kv.trie_refs
    got = kv.read(0, layer=1)                     # faults it back, bit-exact
    np.testing.assert_array_equal(got[0], a[1, 0])
    assert kv.stats["pool_faults"] == 1


def test_can_place_step_headroom_is_deliverable_under_churn():
    """The pressure-surface audit as an invariant: whenever
    ``can_admit_tokens``/``can_place_step`` promise room, the allocation
    they vetted must succeed — across stale pins, spills, and faults."""
    kv, _ = _pooled_kv(pages=4)
    rng = np.random.default_rng(2)
    for round_ in range(6):
        seq = round_ % 3
        if kv.can_admit_tokens(8):
            kv.append(seq, _toks(rng, 8))         # may spill, never raises
        if round_ == 2:
            for phys in kv.block_table.get(0, []):
                if phys >= 0:
                    kv.pin_page(phys)
            kv.release(0)                         # stale-pin the pool
        if kv.can_place_step([seq], [2]):
            k, v = kv.pool_views()
            kv.prepare_step([seq], [2], max_pages=16)
            kv.commit_step(k, v, [seq], [2])


# ------------------------------------- satellite 2: fault-burst thrash guard
def test_fault_burst_pages_are_not_next_victims():
    """After a multi-page fault burst, the just-faulted pages must not be
    the next allocations' first victims: no (seq, logical) page may spill
    again right after paying its H2D (the fault-then-spill churn)."""
    kv, _ = _pooled_kv(pages=6)
    rng = np.random.default_rng(3)
    a = _toks(rng, 16)
    kv.append(0, a)                               # 4 pages
    kv.append(1, _toks(rng, 8))                   # pool full at 6
    kv.append(2, _toks(rng, 8))                   # spills seq 0's LRU pages
    assert kv.block_table[0][0] == -1 and kv.block_table[0][1] == -1
    kv.read(0, layer=0)                           # burst: faults both back
    assert kv.stats["pool_faults"] == 2
    spills_before = kv.stats["pool_page_spills"]
    kv.append(1, _toks(rng, 4))                   # refault seq 1 under pressure
    assert kv.stats["pool_page_spills"] > spills_before
    # the burst pages survived: victims came from seq 0's colder tail
    assert kv.block_table[0][0] >= 0 and kv.block_table[0][1] >= 0
    got = kv.read(0, layer=1)                     # still bit-exact throughout
    np.testing.assert_array_equal(got[0], a[1, 0])


def test_no_page_round_trips_twice_in_one_tick():
    """One prepare/commit tick with a fault burst inside it never spills a
    page it faulted in the same tick (the churn the victim key's
    recently-faulted term exists to prevent)."""
    kv, _ = _pooled_kv(pages=6)
    rng = np.random.default_rng(4)
    kv.append(0, _toks(rng, 8))                   # 2 pages
    kv.append(1, _toks(rng, 8))                   # 2 more
    kv.append(2, _toks(rng, 16))                  # 4 pages: spills seq 0
    h2d_before = {key for key in kv.host_pages}
    assert h2d_before                             # seq 0 partly spilled
    k, v = kv.pool_views()
    kv.prepare_step([0, 1], [2, 2], max_pages=16)     # faults seq 0's pages
    kv.commit_step(k, v, [0, 1], [2, 2])
    faulted = h2d_before - set(kv.host_pages)
    assert faulted                                # the tick did fault
    respilled = faulted & set(kv.host_pages)
    assert not respilled                          # and never re-spilled them


# ------------------------------- satellite 3: rewind vs mid-tick spill bytes
def test_rewind_drops_spilled_speculative_pages():
    """A page allocated for speculative slots, spilled mid-tick by an
    out-of-batch admission, then rolled back: the rewind must drop the
    dead host staging copy (old code stopped at the -1 and leaked it) and
    keep the byte counters monotone and exact."""
    kv, _ = _pooled_kv(pages=4)
    rng = np.random.default_rng(5)
    k, v = kv.pool_views()
    kv.prepare_step([0], [6], max_pages=16)       # 2 pages for 6 planned slots
    kv.append(1, _toks(rng, 16))                  # spills BOTH prepared pages
    assert kv.block_table[0] == [-1, -1]
    assert set(kv.host_pages) == {(0, 0), (0, 1)}
    kv.commit_step(k, v, [0], [1], prepared=[6])  # accept 1 of 6
    assert kv.block_table[0] == [-1]              # trailing page rewound
    assert set(kv.host_pages) == {(0, 0)}         # its staging copy dropped
    group = kv._group_bytes
    assert kv.stats["pool_d2h_bytes"] == kv.stats["pool_page_spills"] * group
    kv.release(0)
    kv.release(1)
    assert not kv.host_pages and not kv.page_users
    assert len(kv.free_pages) == kv.pool_pages
    # monotone: the rewound spill's bytes are still on the record
    assert kv.stats["pool_d2h_bytes"] == kv.stats["pool_page_spills"] * group


def test_pool_byte_counters_match_bytes_moved():
    """``pool_d2h_bytes``/``pool_h2d_bytes`` equal pages-moved × page bytes
    (plus restore uploads) AND the clock's own tallies — through spills,
    faults, preempt/restore, and rollback churn."""
    for async_tiering in (False, True):
        kv, clock = _pooled_kv(pages=4, async_tiering=async_tiering)
        rng = np.random.default_rng(6)
        for seq in (0, 1, 2):
            kv.append(seq, _toks(rng, 8))
        kv.read(0, layer=0)
        kv.preempt(1)
        kv.restore(1)
        k, v = kv.pool_views()
        kv.prepare_step([2], [6], max_pages=16)
        kv.append(0, _toks(rng, 8))
        kv.commit_step(k, v, [2], [1], prepared=[6])
        kv.flush_transfers()
        s, group = kv.stats, kv._group_bytes
        assert s["pool_d2h_bytes"] == s["pool_page_spills"] * group
        assert s["pool_h2d_bytes"] == (
            (s["pool_faults"] + s["prefetch_hits"]) * group
            + s["restore_in_bytes"])
        # the clock saw at least the counted traffic (preempting a partly
        # spilled sequence legitimately reads host copies on top of it)
        assert clock.bytes_moved("host", "write") >= s["pool_d2h_bytes"]
        assert clock.bytes_moved("host", "read") >= s["pool_h2d_bytes"]


# ----------------------------------------------- sync/async: timing-only-ness
def _drive_schedule(kv, *, prefetch):
    """A fixed spill/fault-heavy schedule; returns every read's bytes."""
    rng = np.random.default_rng(7)
    reads = []
    for step in range(8):
        for seq in (0, 1, 2):
            kv.append(seq, _toks(rng, 3 if step == 0 else 1))
        if prefetch:
            kv.prefetch([0, 1, 2])
        if step % 2:
            for seq in (0, 1, 2):
                reads.append(kv.read(seq, layer=step % 2))
    kv.preempt(0)
    kv.restore(0)
    reads.append(kv.read(0, layer=1))
    kv.flush_transfers()
    return reads


def test_async_is_timing_only_and_conserves_faults():
    """The tentpole's core invariant: async mode changes WHEN transfers
    are paid, never what happens — reads bit-identical, identical spill
    decisions, every prefetch hit exactly displacing one demand fault,
    and a clock that only ever gets faster."""
    sync_kv, sync_clock = _pooled_kv(pages=5, async_tiering=False)
    async_kv, async_clock = _pooled_kv(pages=5, async_tiering=True)
    sync_reads = _drive_schedule(sync_kv, prefetch=True)   # no-op pipeline
    async_reads = _drive_schedule(async_kv, prefetch=True)
    for got, want in zip(async_reads, sync_reads):
        np.testing.assert_array_equal(got, want)
    s, a = sync_kv.stats, async_kv.stats
    assert a["pool_page_spills"] == s["pool_page_spills"]
    assert async_kv.block_table == sync_kv.block_table
    # exact conservation: the lookahead only RESCHEDULES transfers
    assert s["pool_faults"] > 0
    assert a["prefetch_hits"] + a["pool_faults"] == s["pool_faults"]
    assert a["prefetch_hits"] > 0 and a["async_spills"] > 0
    assert a["stall_ticks_saved"] > 0
    # sync mode never touches the async counters
    assert s["async_spills"] == s["prefetch_hits"] == 0
    assert s["stall_ticks_saved"] == 0
    # same bytes moved, strictly less foreground time
    assert async_clock.bytes_moved("host", "write") == \
        sync_clock.bytes_moved("host", "write")
    assert async_clock.now < sync_clock.now


def test_prefetch_is_a_pure_timing_hint():
    """prefetch() must not allocate, move data, or change any stat — it
    only enqueues background transfers for spilled pages."""
    kv, _ = _pooled_kv(pages=3, async_tiering=True)
    rng = np.random.default_rng(8)
    kv.append(0, _toks(rng, 8))
    kv.append(1, _toks(rng, 8))                   # spills seq 0 pages
    state = (dict(kv.block_table), dict(kv.host_pages), list(kv.free_pages),
             dict(kv.stats))
    n = kv.prefetch([0, 1])
    assert n == sum(1 for p in kv.block_table[0] if p < 0)
    assert (dict(kv.block_table), dict(kv.host_pages), list(kv.free_pages),
            dict(kv.stats)) == state
    assert kv.prefetch([0, 1]) == 0               # already in flight
    kv.flush_transfers()


def test_preempt_barriers_on_inflight_spill_copies():
    """Coherence rule at the preempt boundary: building the preemption
    blob reads spilled pages' host staging copies, so it must barrier on
    their in-flight D2H — the round trip stays bit-exact in async mode."""
    kv, clock = _pooled_kv(pages=3, async_tiering=True)
    rng = np.random.default_rng(9)
    a = _toks(rng, 8)
    kv.append(0, a)
    kv.append(1, _toks(rng, 8))                   # spills a page of seq 0
    assert -1 in kv.block_table[0] and kv._pipeline.pending > 0
    kv.preempt(0)                                 # must wait for the D2H
    kv.restore(0)
    got = kv.read(0, layer=0)
    np.testing.assert_array_equal(got[0], a[0, 0])
    kv.flush_transfers()


def test_async_counters_zeroed_on_every_engine():
    """Uniform stats key set: the ISSUE 8 counters exist — zeroed — on
    every registered KV engine, and prefetch/flush_transfers are safe
    no-ops outside the pooled paged path."""
    for name in list_kv_engines():
        kv = create_kv_engine(
            EngineSpec(engine=name, kv_hbm_bytes=1 << 20), KV_SPEC,
            SimClock())
        for key in ("async_spills", "prefetch_hits", "stall_ticks_saved"):
            assert kv.stats[key] == 0, (name, key)
        assert kv.prefetch([0, 1]) == 0
        kv.flush_transfers()


# ------------------------------------ ISSUE 10: faults, retries, degradation
def test_pipeline_retry_reenters_fifo_after_backoff():
    """One injected failure: the failed attempt occupies the channel as
    history, the retry re-enters the FIFO after a capped exponential
    backoff, the foreground never stalls, and the retry classification is
    one-shot."""
    from repro.serving.faults import FaultInjector, FaultPlan, _u01
    key, rate = ("d2h", 0, 0), 0.5
    # pick (deterministically) a seed whose hash fails attempt 0 and
    # passes attempt 1 for this key's first submit epoch
    seed = next(s for s in range(10_000)
                if _u01(s, "xfail", (key, 1), 0) < rate
                and _u01(s, "xfail", (key, 1), 1) >= rate)
    clock, stats = SimClock(), {}
    p = TransferPipeline(
        clock, stats=stats,
        injector=FaultInjector(FaultPlan(seed=seed, transfer_fail_rate=rate)))
    base = TransferPipeline(SimClock()).submit(
        TransferPipeline.D2H, key, HOST_LINK, "write", 1 << 20)
    fin = p.submit(p.D2H, key, HOST_LINK, "write", 1 << 20)
    # attempt 0 burned [0, base); retry started at base + 2*backoff_s
    assert fin == pytest.approx(2 * base + 2 * p.backoff_s)
    assert clock.now == 0.0                       # background throughout
    assert stats["transfer_failures"] == 1 and stats["transfer_retries"] == 1
    assert not p.degraded and "tiering_degraded" not in stats
    assert p.took_retries(key) and not p.took_retries(key)
    assert p.barrier(key) == pytest.approx(fin)


def test_pipeline_terminal_failure_goes_sync_and_degrades():
    """Past the attempt budget the pipeline escalates: waits out the last
    failed attempt, pays the copy synchronously on the foreground, and
    flips ``degraded`` so the engine falls back to synchronous tiering."""
    from repro.serving.faults import FaultInjector, FaultPlan
    clock, stats = SimClock(), {}
    p = TransferPipeline(
        clock, stats=stats, max_retries=2,
        injector=FaultInjector(FaultPlan(transfer_fail_rate=1.0)))
    fin = p.submit(p.D2H, ("d2h", 0, 0), HOST_LINK, "write", 1 << 20)
    assert p.degraded and stats["tiering_degraded"] == 1
    assert fin == clock.now > 0.0                 # foreground paid the copy
    assert stats["transfer_failures"] == 3        # max_retries + 1 attempts
    assert stats["transfer_retries"] == 2
    assert p.barrier(("d2h", 0, 0)) == 0.0        # nothing left in flight


def test_cancel_seq_reclaims_unserved_backlog():
    """Satellite pin: cancelling every in-flight transfer of a sequence
    reclaims its unserved channel reservations — ``backlog_s() == 0`` after
    cancel-all (the old ledger kept counting work that would never run) —
    while time already served stays on the record."""
    clock = SimClock()
    p = TransferPipeline(clock)
    for logical in range(3):
        p.submit(p.D2H, ("d2h", 7, logical), HOST_LINK, "write", 1 << 20)
    p.submit(p.H2D, ("h2d", 7, 0), HOST_LINK, "read", 1 << 20)
    assert p.backlog_s() > 0.0
    assert p.cancel_seq(7) == 4 and p.pending == 0
    assert p.backlog_s() == 0.0
    # a half-served transfer: the unserved half is reclaimed, the served
    # half is history — the next transfer starts now, not in the past
    f = p.submit(p.D2H, ("d2h", 8, 0), HOST_LINK, "write", 1 << 20)
    cost = f - clock.now
    clock.wait_until(clock.now + cost / 2)
    p.cancel_seq(8)
    assert p.backlog_s() == 0.0
    g = p.submit(p.D2H, ("d2h", 9, 0), HOST_LINK, "write", 1 << 20)
    assert g == pytest.approx(clock.now + cost)   # starts at now: no refund
                                                  # of the served half


def test_stall_channel_delays_queue_not_foreground():
    """An injected drainer-shard stall pushes queued transfers out without
    stalling the foreground, and leaves the other channel alone."""
    clock, stats = SimClock(), {}
    p = TransferPipeline(clock, stats=stats)
    base = TransferPipeline(SimClock()).submit(
        TransferPipeline.D2H, ("d2h", 0, 0), HOST_LINK, "write", 1 << 20)
    p.stall_channel(p.D2H, 0.25)
    fin = p.submit(p.D2H, ("d2h", 0, 0), HOST_LINK, "write", 1 << 20)
    assert fin == pytest.approx(0.25 + base)      # queued behind the stall
    assert clock.now == 0.0 and stats["shard_stalls"] == 1
    assert p.submit(p.H2D, ("h2d", 0, 0), HOST_LINK, "read", 1 << 20) < fin


def test_abort_step_returns_poisoned_tick_pages():
    """Satellite pin: an exception between ``prepare_step`` and
    ``commit_step`` (the poisoned tick) must leak no pool pages —
    ``abort_step`` returns exactly the tick's fresh allocations, and the
    retried tick then runs clean."""
    kv, _ = _pooled_kv(pages=6)
    rng = np.random.default_rng(11)
    kv.append(0, _toks(rng, 8))                   # 2 committed pages
    kv.append(1, _toks(rng, 4))                   # 1 committed page
    free_before = len(kv.free_pages)
    kv.prepare_step([0, 1], [2, 2], max_pages=16)
    assert len(kv.free_pages) < free_before       # the tick allocated
    kv.abort_step([0, 1])                         # tick poisoned: no commit
    assert len(kv.free_pages) == free_before
    assert len(kv.block_table[0]) == 2 and len(kv.block_table[1]) == 1
    k, v = kv.pool_views()                        # the retried tick commits
    kv.prepare_step([0, 1], [1, 1], max_pages=16)
    kv.commit_step(k, v, [0, 1], [1, 1])
    kv.release(0)
    kv.release(1)
    assert not kv.page_users
    assert len(kv.free_pages) + kv._idle_index_pages() == kv.pool_pages


def test_lost_host_page_raises_and_drops_the_copy():
    """An armed page loss fires on the demand-fault read: LostPageError
    names the victim (seq, logical), the corrupt staging copy is dropped,
    the loss is counted, and releasing the shed row leaves the pool
    consistent."""
    from repro.serving.faults import FaultInjector, FaultPlan, LostPageError
    kv, _ = _pooled_kv(pages=2)
    kv.set_fault_injector(FaultInjector(FaultPlan()))
    rng = np.random.default_rng(12)
    kv.append(0, _toks(rng, 8))                   # fills the pool
    kv.append(1, _toks(rng, 4))                   # spills seq 0's LRU page
    lost = (0, kv.block_table[0].index(-1))
    kv._injector.arm_page_loss(lost)
    with pytest.raises(LostPageError) as ei:
        kv.read(0, layer=0)
    assert (ei.value.seq, ei.value.logical) == lost
    assert kv.stats["host_pages_lost"] == 1
    assert lost not in kv.host_pages              # corrupt copy is gone
    kv.release(0)                                 # the scheduler sheds it
    assert len(kv.free_pages) == kv.pool_pages - 1    # only seq 1 lives on


def test_fault_api_and_counters_on_every_engine():
    """Uniform surface: the ISSUE 10 counters exist — zeroed — on every
    registered KV engine, and the fault hooks are safe no-ops off the
    pooled paged path."""
    from repro.serving.faults import FaultInjector, FaultPlan
    for name in list_kv_engines():
        kv = create_kv_engine(
            EngineSpec(engine=name, kv_hbm_bytes=1 << 20), KV_SPEC,
            SimClock())
        for key in ("transfer_retries", "transfer_failures", "retried_faults",
                    "host_pages_lost", "shard_stalls", "tiering_degraded"):
            assert kv.stats[key] == 0, (name, key)
        kv.set_fault_injector(FaultInjector(FaultPlan()))
        kv.abort_step([0, 1])
        kv.stall_transfers(0, 1e-3)
