"""Cross-request prefix sharing (ISSUE 6): the serving-tier battery.

Five suites lock the prefix cache down:

* **trie** — the token radix tree's exact-find / refcount / eviction
  surface the cache is built on (unit level, no model);
* **splice** — re-admitting a cached prompt splices shared pool pages:
  the covered prefix costs ZERO prefill calls (pinned via ``jit_stats``),
  the hit counters move, and the tokens stay identical to the sequential
  reference;
* **copy-on-write** — concurrent duplicate prompts alias the mid-page
  boundary page; the first divergent decode write copies it (``cow_copies``
  moves) and nobody's tokens change;
* **pressure** — sharing under a tight HBM budget: preemption fires, every
  stat counter (including the new prefix counters) stays monotone tick by
  tick, and the output still matches sequential;
* **release** — churn leaves no page refs behind (pool drains back to
  free + idle-index), and ``release()`` forgets router state even for
  preempted sequences (the ``_on_release`` hook regression).
"""
import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core import SimClock, create_kv_engine
from repro.core.engines import EngineSpec
from repro.core.kvcache import KVSpec
from repro.core.radix import TokenRadixTree
from repro.models import build_model
from repro.serving import Request, Scheduler, ServeConfig, ServingEngine

ARCH = "internlm2-1.8b-smoke"
MAX_LEN = 48
MAX_NEW = 6
PROMPT_LEN = 10          # % page_tokens(4) = 2: the last chunk is mid-page


@pytest.fixture(scope="module")
def lm():
    cfg = get_config(ARCH)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _token_bytes(mcfg) -> int:
    return mcfg.num_layers * 2 * mcfg.num_kv_heads * mcfg.head_dim * 2


def _engine(lm, engine="paged", *, share_tokens=4096, hbm_bytes=64 << 20,
            max_batch_seqs=4, chunk=None):
    cfg, model, params = lm
    return ServingEngine(model, params, ServeConfig(
        max_len=MAX_LEN, page_tokens=4,
        engine_spec=EngineSpec(engine=engine, kv_hbm_bytes=hbm_bytes,
                               kv_hot_window=8, drain_shards=2,
                               prefix_cache_tokens=share_tokens),
        max_batch_seqs=max_batch_seqs, prefill_chunk_tokens=chunk))


def _prompt(cfg, seed=0, n=PROMPT_LEN):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, n, dtype=np.int32)


def _sequential(lm, prompts, max_new=MAX_NEW):
    reqs = [Request(rid=i, prompt=p.copy(), max_new=max_new)
            for i, p in enumerate(prompts)]
    _engine(lm, "log", share_tokens=0).generate_sequential(reqs)
    return [list(r.generated) for r in reqs]


# ------------------------------------------------------------------- trie
def test_token_trie_find_and_match():
    t = TokenRadixTree()
    n1 = t.insert((1, 2, 3, 4), "a")
    n2 = t.insert((1, 2, 3, 4, 5, 6), "b")
    assert t.find((1, 2, 3, 4)) is n1
    assert t.find((1, 2, 3)) is None          # interior node, no value
    assert t.find((9,)) is None
    assert t.lookup((1, 2, 3, 4, 5, 6)) == "b"
    # match returns every value node on the path, shallowest first
    assert t.match((1, 2, 3, 4, 5, 6, 7)) == [n1, n2]
    assert t.match((1, 2, 9)) == []


def test_token_trie_refcounts_gate_eviction():
    t = TokenRadixTree()
    n1 = t.insert((1, 2), "a")
    n2 = t.insert((1, 2, 3), "b")
    t.acquire(n2)
    # a referenced leaf is not evictable; an interior value node never is
    assert not t.evictable(n2)
    assert not t.evictable(n1)                # subtree_values == 2
    t.release(n2)
    assert t.evictable(n2)
    t.remove(n2)
    assert t.evictable(n1)                    # now a refcount-0 leaf
    with pytest.raises(RuntimeError):
        t.release(n2)                         # underflow is loud


# ----------------------------------------------------------------- splice
def test_cached_readmission_skips_prefill_and_matches_sequential(lm):
    """The zero-prefill pin: the second admission of an identical prompt
    splices pool pages — ``prefill_calls`` does not move, the hit counters
    do, and the tokens equal the sequential reference."""
    cfg, _, _ = lm
    prompt = _prompt(cfg)
    want = _sequential(lm, [prompt])[0]
    eng = _engine(lm)
    assert eng.prefix_cache is not None

    r0 = Request(rid=0, prompt=prompt.copy(), max_new=MAX_NEW)
    eng.generate([r0])
    s1 = eng.stats()
    assert s1["prefix_hits"] == 0 and s1["prefill_calls"] >= 1

    r1 = Request(rid=1, prompt=prompt.copy(), max_new=MAX_NEW)
    eng.generate([r1])
    s2 = eng.stats()
    assert s2["prefix_hits"] == 1
    # a full duplicate is covered up to len-1 (one pending token keeps the
    # first-logits contract); none of the covered tokens re-prefill
    assert s2["prefix_tokens_reused"] == PROMPT_LEN - 1
    assert s2["prefill_calls"] == s1["prefill_calls"]
    assert s2["mirror_d2h_bytes"] == 0        # still the mirror-free path
    assert r0.generated == want and r1.generated == want


def test_shared_prefix_families_splice_across_tails(lm):
    """Distinct tails behind one hot prefix: later family members cover the
    page-aligned prefix chunks and only prefill their private tail."""
    cfg, _, _ = lm
    rng = np.random.default_rng(1)
    fam = rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)   # 2 full pages
    prompts = [np.concatenate([fam, rng.integers(0, cfg.vocab_size, n,
                                                 dtype=np.int32)])
               for n in (3, 5, 2)]
    want = _sequential(lm, prompts)
    eng = _engine(lm, max_batch_seqs=1)       # strictly one at a time
    for i, p in enumerate(prompts):
        eng.generate([Request(rid=i, prompt=p.copy(), max_new=MAX_NEW)])
    s = eng.stats()
    assert s["prefix_hits"] == 2              # every admission after the 1st
    assert s["prefix_tokens_reused"] == 2 * len(fam)
    reqs = [Request(rid=10 + i, prompt=p.copy(), max_new=MAX_NEW)
            for i, p in enumerate(prompts)]
    eng.generate(reqs)                        # warm trie, batched this time
    for r, w in zip(reqs, want):
        assert r.generated == w


# ---------------------------------------------------------- copy-on-write
def test_concurrent_duplicates_cow_on_boundary_page(lm):
    """Duplicates admitted into ONE batch alias the mid-page boundary page;
    the first decode write while others still trust it must copy, and every
    row's tokens stay identical to the sequential reference."""
    cfg, _, _ = lm
    prompt = _prompt(cfg, seed=2)
    prompts = [prompt, prompt, prompt, _prompt(cfg, seed=3)]
    want = _sequential(lm, prompts)
    eng = _engine(lm)
    reqs = [Request(rid=i, prompt=p.copy(), max_new=MAX_NEW)
            for i, p in enumerate(prompts)]
    eng.generate(reqs)
    s = eng.stats()
    assert s["prefix_hits"] >= 2              # both later duplicates spliced
    assert s["cow_copies"] >= 1
    assert s["shared_pages"] >= 1
    for r, w in zip(reqs, want):
        assert r.done and r.generated == w, r.rid


# --------------------------------------------------------------- pressure
def test_sharing_under_pressure_stays_monotone_and_token_identical(lm):
    """Tight budget + chunked prefill + duplicates: preemption fires, the
    full stat surface (prefix counters included) is monotone tick by tick,
    and sharing never changes a token."""
    cfg, model, _ = lm
    prompt = _prompt(cfg, seed=4)
    prompts = [prompt, prompt, _prompt(cfg, seed=5, n=12), prompt]
    want = _sequential(lm, prompts)
    # the smallest budget that still takes the POOLED path (max_pages + 1
    # pool pages — any less and sharing is off by construction): the
    # warm-up row fits without spilling its prefix pages, four growing
    # rows do not
    mcfg = model.cfg
    group = (mcfg.num_layers * 2 * 4 * mcfg.num_kv_heads * mcfg.head_dim
             * np.dtype(model.compute_dtype).itemsize)
    eng = _engine(lm, hbm_bytes=(MAX_LEN // 4 + 1) * group, chunk=5)
    assert eng.pooled and eng.prefix_cache is not None
    warm = Request(rid=99, prompt=prompt.copy(), max_new=MAX_NEW)
    eng.generate([warm])                      # publishes the prompt's pages
    assert warm.generated == want[0]
    reqs = [Request(rid=i, prompt=p.copy(), max_new=MAX_NEW)
            for i, p in enumerate(prompts)]
    sched = Scheduler(eng, reqs)
    prev = eng.stats()
    for k in ("prefix_hits", "prefix_tokens_reused", "cow_copies",
              "shared_pages"):
        assert k in prev                      # uniform key set, all engines
    while sched.tick():
        cur = eng.stats()
        assert set(cur) == set(prev)
        for k, v in cur.items():
            assert v >= prev[k], k
        prev = cur
    assert eng.tiered.stats["preempts"] >= 1
    assert eng.tiered.stats["prefix_hits"] >= 1
    for r, w in zip(reqs, want):
        assert r.done and r.generated == w, r.rid


@pytest.mark.parametrize("engine", ("log", "kvhybrid"))
def test_sharing_flag_is_noop_for_unpooled_engines(lm, engine):
    """``prefix_cache_tokens`` on a log-structured engine must change
    nothing: no cache object, zero hit counters, identical tokens."""
    cfg, _, _ = lm
    prompt = _prompt(cfg, seed=6)
    prompts = [prompt, prompt]
    want = _sequential(lm, prompts)
    eng = _engine(lm, engine)
    assert eng.prefix_cache is None
    reqs = [Request(rid=i, prompt=p.copy(), max_new=MAX_NEW)
            for i, p in enumerate(prompts)]
    eng.generate(reqs)
    s = eng.stats()
    assert s["prefix_hits"] == 0 and s["shared_pages"] == 0
    for r, w in zip(reqs, want):
        assert r.generated == w


# ---------------------------------------------------------------- release
def test_churn_releases_every_shared_page(lm):
    """After a sharing-heavy run completes, no page holds a live user ref:
    the pool is exactly free pages + idle index pages, and pressure is
    back to zero (idle index pages are reclaimable headroom)."""
    cfg, _, _ = lm
    prompt = _prompt(cfg, seed=7)
    eng = _engine(lm)
    for round_ in range(3):
        reqs = [Request(rid=10 * round_ + i, prompt=prompt.copy(),
                        max_new=MAX_NEW) for i in range(3)]
        eng.generate(reqs)
    kv = eng.tiered
    assert not kv.page_users                  # no live user refs anywhere
    assert len(kv.free_pages) + kv._idle_index_pages() == kv.pool_pages
    assert kv.pressure() == 0.0
    assert eng.stats()["prefix_hits"] >= 1    # the index did real work


def test_release_forgets_router_state_even_when_preempted():
    """The ``_on_release`` hook regression: releasing a PREEMPTED sequence
    must still forget the adaptive router's per-seq reuse state (the old
    kvhybrid-only forget sat on the active-release branch and leaked)."""
    spec = KVSpec(num_layers=2, kv_heads=2, head_dim=4, page_tokens=4)
    kv = create_kv_engine(
        EngineSpec(engine="kvhybrid", kv_hbm_bytes=1 << 14, kv_hot_window=4,
                   drain_shards=2), spec, SimClock())
    rng = np.random.default_rng(0)
    for seq in (0, 1):
        kv.append(seq, rng.standard_normal(
            (spec.num_layers, 2, 6, spec.kv_heads,
             spec.head_dim)).astype(np.float16))
        kv.read(seq, layer=0)                 # materialize reuse state
    assert 0 in kv.router.seq_reuse and 1 in kv.router.seq_reuse
    kv.preempt(0)
    kv.release(0)                             # preempted-release branch
    kv.release(1)                             # active-release branch
    assert 0 not in kv.router.seq_reuse
    assert 1 not in kv.router.seq_reuse
