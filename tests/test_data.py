"""Data pipeline: determinism, shard reassembly (elastic property)."""
import numpy as np

from repro.data import SyntheticLMDataset, make_batch_iterator


def test_deterministic_across_instances():
    a = SyntheticLMDataset(512, 64, 8, seed=3).batch(5)
    b = SyntheticLMDataset(512, 64, 8, seed=3).batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_labels_are_shifted_tokens():
    b = SyntheticLMDataset(512, 64, 4, seed=1).batch(0)
    # label[t] is the successor of token[t] on the chain
    assert b["tokens"].shape == b["labels"].shape == (4, 64)
    assert not np.array_equal(b["tokens"], b["labels"])


def test_shards_reassemble_to_global_batch():
    """Any host can regenerate any shard: shard batches concatenate to the
    unsharded batch (zero-data-movement rebalancing, DESIGN.md §5)."""
    full = SyntheticLMDataset(512, 32, 8, seed=2).batch(7)
    parts = [SyntheticLMDataset(512, 32, 8, seed=2, num_shards=4,
                                shard=s).batch(7) for s in range(4)]
    np.testing.assert_array_equal(
        np.concatenate([p["tokens"] for p in parts]), full["tokens"])


def test_iterator_restart_stable():
    ds = SyntheticLMDataset(512, 32, 4, seed=0)
    it1 = make_batch_iterator(ds, start_step=0)
    for _ in range(3):
        ref = next(it1)
    it2 = make_batch_iterator(ds, start_step=2)     # resume at step 2
    got = next(it2)
    np.testing.assert_array_equal(ref["tokens"], got["tokens"])


def test_microbatch_layout():
    ds = SyntheticLMDataset(512, 32, 8, seed=0)
    it = make_batch_iterator(ds, microbatches=2)
    b = next(it)
    assert b["tokens"].shape == (2, 4, 32)


def test_entropy_floor_positive():
    ds = SyntheticLMDataset(512, 32, 4, branching=4)
    assert 0.3 < ds.entropy_floor < np.log(4) + 1e-6
