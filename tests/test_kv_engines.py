"""KV engine registry conformance (DESIGN.md §2a).

Three suites lock the new surface down:

* registry + 3-engine conformance — paged/log/kvhybrid constructed from one
  ``EngineSpec``, append→read round-trips (single-token and batched),
  preempt/restore, stats monotonicity;
* per-shard drainers — shard independence and the force-drain-before-
  page-ownership coherence rule (log-before-pages ordering);
* adaptive routing — the learned threshold converges on deterministic
  small-/large-append-heavy workloads, and FS ``nvhybrid`` crash recovery
  is equivalent with ``drain_shards > 1`` vs ``== 1``.
"""
import numpy as np
import pytest

from repro.core import NVCacheFS, SimClock
from repro.core.clock import ShardedDrainer
from repro.core.engines import (EngineSpec, create_kv_engine, get_kv_engine,
                                list_kv_engines, register_kv_engine)
from repro.core.kvcache import (AdaptiveRouter, HybridKVCache, KVSpec,
                                LogKVCache, PagedKVCache)

SPEC = KVSpec(num_layers=3, kv_heads=2, head_dim=8, page_tokens=4)
KV_ENGINES = ("paged", "log", "kvhybrid")


def _mk(engine, **spec_kw):
    spec_kw.setdefault("kv_hbm_bytes", 1 << 13)
    spec_kw.setdefault("kv_hot_window", 6)
    clock = SimClock()
    return create_kv_engine(EngineSpec(engine=engine, **spec_kw), SPEC,
                            clock), clock


def _tok(rng):
    return rng.standard_normal(
        (SPEC.num_layers, 2, SPEC.kv_heads, SPEC.head_dim)).astype(np.float16)


def _burst(rng, n):
    return rng.standard_normal(
        (SPEC.num_layers, 2, n, SPEC.kv_heads,
         SPEC.head_dim)).astype(np.float16)


# ---------------------------------------------------------------- registry
def test_registry_serves_all_engines_from_enginespec():
    assert set(KV_ENGINES) <= set(list_kv_engines())
    for name, cls in (("paged", PagedKVCache), ("log", LogKVCache),
                      ("kvhybrid", HybridKVCache)):
        kv, _ = _mk(name)
        assert isinstance(kv, cls)
        assert kv.engine_name == name
        assert get_kv_engine(name) is cls


def test_unknown_kv_engine_raises_with_listing():
    with pytest.raises(ValueError, match="kvhybrid"):
        _mk("no_such_design")


def test_duplicate_kv_registration_guard():
    with pytest.raises(ValueError, match="already registered"):
        register_kv_engine("paged")(PagedKVCache)


# ------------------------------------------------------------- conformance
@pytest.mark.parametrize("engine", KV_ENGINES)
def test_append_read_round_trip(engine):
    kv, _ = _mk(engine)
    rng = np.random.default_rng(0)
    oracle = {s: [] for s in range(3)}
    # interleaved singles and bursts over three sequences
    for step in range(30):
        s = step % 3
        if step % 7 == 3:
            burst = _burst(rng, 5)
            kv.append(s, burst)
            oracle[s].extend(burst[:, :, t] for t in range(5))
        else:
            tok = _tok(rng)
            kv.append(s, tok)
            oracle[s].append(tok)
    for s in range(3):
        assert kv.seq_len[s] == len(oracle[s])
        for layer in range(SPEC.num_layers):
            want = np.stack([o[layer] for o in oracle[s]], axis=1)
            assert np.array_equal(kv.read(s, layer), want), (engine, s, layer)
            # gather stays as the historical alias
            assert np.array_equal(kv.gather(s, layer), want)


def test_engines_functionally_identical():
    """All three designs must be observationally identical — only timing and
    amplification may differ (the paper's whole point)."""
    kvs = {e: _mk(e)[0] for e in KV_ENGINES}
    rng = np.random.default_rng(1)
    for t in range(40):
        seq = t % 3
        if t % 11 == 5:
            burst = _burst(rng, 6)
            for kv in kvs.values():
                kv.append(seq, burst)
        else:
            tok = _tok(rng)
            for kv in kvs.values():
                kv.append(seq, tok)
    for seq in range(3):
        for layer in range(SPEC.num_layers):
            reads = {e: kv.read(seq, layer) for e, kv in kvs.items()}
            for e in KV_ENGINES[1:]:
                assert np.array_equal(reads[e], reads["paged"]), (e, seq,
                                                                  layer)


@pytest.mark.parametrize("engine", KV_ENGINES)
def test_preempt_restore_round_trip(engine):
    kv, clock = _mk(engine)
    rng = np.random.default_rng(2)
    for _ in range(13):
        kv.append(0, _tok(rng))
        kv.append(1, _tok(rng))
    before = {layer: kv.read(0, layer).copy()
              for layer in range(SPEC.num_layers)}
    other = kv.read(1, 0).copy()
    kv.preempt(0)
    assert clock.bytes_moved("ssd", "write") > 0       # spilled to disk
    with pytest.raises(RuntimeError, match="preempted"):
        kv.read(0, 0)
    with pytest.raises(RuntimeError, match="preempted"):
        kv.append(0, _tok(rng))
    # untouched sequences keep serving while 0 is offloaded
    assert np.array_equal(kv.read(1, 0), other)
    kv.restore(0)
    assert clock.bytes_moved("ssd", "read") > 0
    for layer in range(SPEC.num_layers):
        assert np.array_equal(kv.read(0, layer), before[layer]), (engine,
                                                                  layer)
    with pytest.raises(RuntimeError, match="not preempted"):
        kv.restore(0)


@pytest.mark.parametrize("engine", KV_ENGINES)
def test_stats_monotone(engine):
    kv, _ = _mk(engine)
    rng = np.random.default_rng(3)
    prev = dict(kv.stats)

    def check():
        nonlocal prev
        cur = dict(kv.stats)
        assert set(cur) == set(prev), engine
        for k, v in cur.items():
            assert v >= prev[k], (engine, k)
        prev = cur

    for step in range(25):
        kv.append(step % 2, _burst(rng, 5) if step % 9 == 4 else _tok(rng))
        check()
        if step % 5 == 2:
            kv.read(step % 2, step % SPEC.num_layers)
            check()
    kv.preempt(0)
    check()
    kv.restore(0)
    check()


# ------------------------------------------------------- per-shard drainers
def test_sharded_drainer_queues_are_independent():
    d = ShardedDrainer(3)
    # pile work on shard 0
    for _ in range(10):
        f0 = d.push(0, 0.0, 1.0)
    assert f0 == pytest.approx(10.0)
    # shard 1 is idle: work arriving now finishes after one service time
    assert d.push(1, 0.5, 1.0) == pytest.approx(1.5)
    assert d.last_finish(2) == 0.0
    assert d.idle_time() == pytest.approx(10.0)
    assert len({d.shard_of(k) for k in range(9)}) == 3


@pytest.mark.parametrize("engine", ["log", "kvhybrid"])
def test_kv_shard_independence(engine):
    """A backlog on one sequence's shard must not delay another shard —
    for both log-structured designs (they share the drain machinery)."""
    finishes = {}
    for shards in (1, 2):
        kv, clock = _mk(engine, drain_shards=shards,
                        hybrid_threshold=1 << 20)   # everything routes log
        kv._drain_service = lambda: 1.0             # slow drainer → backlog
        rng = np.random.default_rng(4)
        for _ in range(8):                          # seq 0 → shard 0
            kv.append(0, _tok(rng))
        kv.append(1, _tok(rng))                     # seq 1 → shard 1 if 2
        assert kv.pending_for(1) == 1
        shard = kv.drainer.shard_of(1)
        finishes[shards] = kv.shard_log[shard][-1][3] - clock.now
    # with its own shard, seq 1 drains after ~one service time; behind
    # seq 0's backlog it waits for all eight entries first
    assert finishes[2] < 2.0 < finishes[1]


def test_log_engine_drains_shards_without_head_of_line_blocking():
    """An entry whose drain finished must be applied (not patched) even
    while another shard's head is still pending."""
    kv, clock = _mk("log", drain_shards=2)
    kv._drain_service = lambda: 1.0
    rng = np.random.default_rng(11)
    for _ in range(8):
        kv.append(0, _tok(rng))                     # shard 0: backlog to t≈8
    kv.append(1, _tok(rng))                         # shard 1: finishes t≈1
    clock.advance(3.0)                              # past seq 1's finish only
    kv.read(1, 0)
    assert kv.pending_for(1) == 0                   # drained on its schedule
    assert kv.pending_for(0) > 0                    # other shard still busy


def test_nvlog_rejects_undersized_drain_shards():
    """drain_shards repartitions the journal WAL; a per-shard WAL too small
    for a page record must fail loudly at construction, not crash pwrite."""
    with pytest.raises(ValueError, match="drain_shards"):
        NVCacheFS(EngineSpec(engine="nvhybrid", nvmm_bytes=128 << 10,
                             drain_shards=64))


def test_force_drain_before_page_ownership():
    """The coherence rule: the page side only takes ownership of a page
    after that sequence's shard has drained (log-before-pages)."""
    kv, clock = _mk("kvhybrid", drain_shards=2, hybrid_threshold=1 << 20)
    kv._drain_service = lambda: 1.0                 # keep entries pending
    rng = np.random.default_rng(5)
    oracle = []
    for _ in range(3):                              # small appends → log
        tok = _tok(rng)
        kv.append(0, tok)
        oracle.append(tok)
    kv.append(1, _tok(rng))                         # entry on the other shard
    assert kv.pending_for(0) == 3
    assert kv.stats["routed_log"] == 4 and kv.stats["routed_pages"] == 0
    other_shard = kv.drainer.shard_of(1)
    other_finish = kv.drainer.last_finish(other_shard)
    kv.router.threshold = 1                         # flip routing to pages
    burst = _burst(rng, 6)                          # page side takes over
    kv.append(0, burst)
    oracle.extend(burst[:, :, t] for t in range(6))
    # the sequence's shard force-drained before the page write...
    assert kv.pending_for(0) == 0
    assert kv.stats["force_drains"] == 1
    assert kv.stats["stall_time"] > 0
    assert 0 in kv.page_owned.get(0, set())
    # ...while the other shard kept its own schedule (never delayed by the
    # stall — its entry drains at the finish time it already had)
    assert kv.drainer.last_finish(other_shard) == other_finish
    # ...and no token was lost in the handover
    for layer in range(SPEC.num_layers):
        want = np.stack([o[layer] for o in oracle], axis=1)
        assert np.array_equal(kv.read(0, layer), want), layer


def test_page_route_without_pending_log_skips_force_drain():
    kv, _ = _mk("kvhybrid", hybrid_threshold=1)     # everything → pages
    rng = np.random.default_rng(6)
    kv.append(0, _burst(rng, 8))
    assert kv.stats["routed_pages"] == 1
    assert kv.stats["force_drains"] == 0


# --------------------------------------------------------- adaptive routing
def test_adaptive_routing_converges_small_append_heavy():
    """Decode-style workload (single-token appends) must converge to the
    log path even from a pages-everything prior."""
    kv, _ = _mk("kvhybrid", hybrid_threshold=1)     # wrong prior: all pages
    rng = np.random.default_rng(7)
    n = 400
    for t in range(n):
        kv.append(t % 4, _tok(rng))
    assert kv.threshold > SPEC.token_bytes * SPEC.num_layers
    assert kv.stats["routed_log"] >= 0.9 * n


def test_adaptive_routing_converges_large_append_heavy():
    """Prefill-style workload (page-sized bursts) must converge to the page
    path even from a log-everything prior."""
    kv, _ = _mk("kvhybrid", hybrid_threshold=1 << 20)   # wrong prior: log
    rng = np.random.default_rng(8)
    n = 200
    burst_tokens = 8 * SPEC.page_tokens
    for t in range(n):
        kv.append(t % 4, _burst(rng, burst_tokens))
    assert kv.threshold <= SPEC.page_bytes
    assert kv.stats["routed_pages"] >= 0.9 * n


def test_adaptive_routing_splits_mixed_workload():
    """With both modes present the learned threshold separates them: decode
    tokens keep logging while prefill bursts page."""
    kv, _ = _mk("kvhybrid", kv_hot_window=64)
    rng = np.random.default_rng(9)
    for s in range(4):
        kv.append(s, _burst(rng, 8 * SPEC.page_tokens))   # prefill
    for t in range(200):
        kv.append(t % 4, _tok(rng))                       # decode
        if t % 50 == 25:
            kv.read(t % 4, 0)
    small = SPEC.token_bytes * SPEC.num_layers
    assert small < kv.threshold <= 8 * SPEC.page_bytes
    assert kv.stats["routed_pages"] >= 4
    assert kv.stats["routed_log"] >= 0.9 * 200


def test_gather_latency_feedback_converges_from_wrong_prior():
    """Observed gather *latency* (not just hot/cold counts) must steer the
    threshold: with identical bimodal histograms and neutral reuse counts,
    the router that measures slow gathers (patch-dominated reads) converges
    below the valley — prefill bursts route to pages — while the router
    measuring cheap gathers keeps a higher threshold. Both start from the
    wrong log-everything prior."""
    page_cost = 1e-6
    routers = {
        "slow": AdaptiveRouter(1 << 20, SPEC.page_bytes,
                               page_per_token_s=page_cost),
        "fast": AdaptiveRouter(1 << 20, SPEC.page_bytes,
                               page_per_token_s=page_cost),
    }
    lat = {"slow": 10 * page_cost, "fast": page_cost}
    for i in range(64):
        for name, r in routers.items():
            r.route(128 if i % 2 else 8192)          # bimodal sizes
            # neutral reuse split (no count bias), distinct latencies
            r.observe_read(seq=i % 3, hot_tokens=5, cold_tokens=5,
                           latency_s=lat[name] * 10)
    assert routers["slow"].gather_lat_s > routers["fast"].gather_lat_s
    assert routers["slow"].threshold < routers["fast"].threshold
    # slow gathers: the large mode must have crossed to the page side
    assert routers["slow"].threshold <= 8192
    assert routers["slow"].route(8192) == "pages"
    assert routers["slow"].route(128) == "log"       # small writes still log


def test_hybrid_engine_feeds_real_gather_latency_to_router():
    """The engine wires simulated read latency into the router (and tracks
    per-sequence reuse for victim selection)."""
    kv, _ = _mk("kvhybrid")
    rng = np.random.default_rng(12)
    for _ in range(10):
        kv.append(0, _tok(rng))
    assert kv.router.gather_lat_s is None
    kv.read(0, 0)
    assert kv.router.gather_lat_s is not None and kv.router.gather_lat_s > 0
    assert kv.router.reuse_score(0) is not None


def test_hybrid_victim_hint_prefers_cold_sequences():
    """victim_hint consults the router's per-sequence reuse histogram: the
    sequence whose reads never touch the hot window is the cheapest spill."""
    kv, _ = _mk("kvhybrid")
    rng = np.random.default_rng(13)
    for _ in range(24):                  # long history: hot window covers
        kv.append(0, _tok(rng))          # only a sliver → cold-heavy reads
    for _ in range(5):                   # short history: mostly hot reads
        kv.append(1, _tok(rng))
    assert kv.victim_hint([0, 1]) is None            # nothing read yet → LRU
    kv.read(0, 0)
    kv.read(1, 0)
    assert kv.router.reuse_score(0) < kv.router.reuse_score(1)
    assert kv.victim_hint([0, 1]) == 0               # coldest reuse goes first
    kv.release(0)
    assert kv.router.reuse_score(0) is None          # reuse state released


# ------------------------------------------- nvhybrid crash equivalence (FS)
@pytest.mark.parametrize("crash", [False, True])
def test_nvhybrid_recovery_equivalent_across_drain_shards(crash):
    """Per-shard drainer parallelism changes timing, never the recovered
    image: drain_shards=4 must recover byte-identically to drain_shards=1."""
    images = {}
    for ds in (1, 4):
        fs = NVCacheFS(EngineSpec(engine="nvhybrid", nvmm_bytes=2 << 20,
                                  dram_cache_bytes=256 << 10,
                                  drain_shards=ds))
        fd = fs.open("/f")
        rng = np.random.default_rng(10)
        oracle = bytearray(1 << 16)
        for _ in range(120):
            off = int(rng.integers(0, (1 << 16) - 6000))
            size = int(rng.choice([64, 300, 4096, 6000]))
            val = int(rng.integers(1, 255))
            data = bytes([val]) * size
            fs.pwrite(fd, data, off)
            oracle[off:off + size] = data
        if crash:
            fs.crash()
            fs.recover()
            fd = fs.open("/f")
        else:
            fs.cache.flush_all()
        images[ds] = fs.pread(fd, 1 << 16, 0)
        assert images[ds] == bytes(oracle), f"drain_shards={ds} lost data"
    assert images[1] == images[4]
