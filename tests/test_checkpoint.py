"""Checkpoint/restart through both cache designs: bit-exact resume,
crash-mid-training recovery, delta-save semantics (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import SyntheticLMDataset, make_batch_iterator
from repro.models import build_model
from repro.training import AdamWConfig, init_train_state, make_train_step


def _setup(arch="internlm2-1.8b-smoke", steps=6, seed=0):
    cfg = get_config(arch)
    model = build_model(cfg, remat=False)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=steps)
    state = init_train_state(model, jax.random.PRNGKey(seed))
    step_fn = jax.jit(make_train_step(model, opt))
    ds = SyntheticLMDataset(cfg.vocab_size, 64, 4, seed=seed)
    return state, step_fn, ds


def _run(state, step_fn, ds, start, stop):
    it = make_batch_iterator(ds, start)
    for _ in range(start, stop):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, metrics = step_fn(state, batch)
    return state, metrics


@pytest.mark.parametrize("design", ["paged", "log"])
def test_bit_exact_resume(design):
    # uninterrupted run
    state, step_fn, ds = _setup()
    ref_state, ref_metrics = _run(state, step_fn, ds, 0, 6)

    # run 3 steps, checkpoint, crash, recover, resume 3 more
    state, step_fn, ds = _setup()
    mgr = CheckpointManager(design, nvmm_bytes=256 << 20)
    state, _ = _run(state, step_fn, ds, 0, 3)
    mgr.save(3, state)
    mgr.crash()
    step_restored, state2 = mgr.restore(state)
    assert step_restored == 3
    state2, metrics2 = _run(state2, step_fn, ds, 3, 6)

    for a, b in zip(jax.tree.leaves(ref_state.params),
                    jax.tree.leaves(state2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(ref_metrics["loss"]) == pytest.approx(
        float(metrics2["loss"]), abs=0)


def test_design_arg_picks_backend_even_with_explicit_fs():
    """An explicit fs supplies the filesystem; ``design`` still chooses
    the backend (regression: design was silently ignored)."""
    from repro.core import NVCacheFS
    from repro.core.ckpt_backend import (LogCheckpointBackend,
                                         PagedCheckpointBackend)
    fs = NVCacheFS("nvpages", nvmm_bytes=16 << 20)
    mgr = CheckpointManager("log", fs=fs)
    assert isinstance(mgr.backend, LogCheckpointBackend)
    assert mgr.design == "log" and mgr.fs is fs
    mgr = CheckpointManager("nvhybrid", fs=fs)     # engine name as design
    assert isinstance(mgr.backend, PagedCheckpointBackend)
    with pytest.raises(ValueError, match="unknown cache engine"):
        CheckpointManager("lgo", fs=fs)            # typo fails loudly
    from repro.core import EngineSpec
    with pytest.raises(TypeError, match="inside the EngineSpec"):
        CheckpointManager(nvmm_bytes=1 << 28,
                          spec=EngineSpec(engine="nvlog"))
    with pytest.raises(TypeError, match="either design or spec"):
        CheckpointManager("paged", spec=EngineSpec(engine="nvlog"))
    with pytest.raises(TypeError, match="explicit fs"):
        CheckpointManager("log", nvmm_bytes=16 << 20, fs=fs)


def test_log_design_delta_saves_are_cheaper():
    state, step_fn, ds = _setup()
    state, _ = _run(state, step_fn, ds, 0, 1)
    log_mgr = CheckpointManager("log", nvmm_bytes=512 << 20,
                                snapshot_every=100)
    paged_mgr = CheckpointManager("paged", nvmm_bytes=512 << 20)
    t_full_log = log_mgr.save(1, state)                 # snapshot
    t_paged = paged_mgr.save(1, state)
    # delta save: only one leaf changed
    t_delta = log_mgr.save(2, state, changed={"leaf0"})
    assert t_delta < 0.25 * t_full_log
    assert t_delta < 0.25 * t_paged


@pytest.mark.parametrize("design", ["paged", "log"])
def test_restore_after_multiple_saves(design):
    state, step_fn, ds = _setup()
    mgr = CheckpointManager(design, nvmm_bytes=512 << 20, snapshot_every=2)
    for s in range(1, 5):
        state, _ = _run(state, step_fn, ds, s - 1, s)
        mgr.save(s, state)
    step, restored = mgr.restore(state)
    assert step == 4
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
