"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (deliverable c).

All kernels run in interpret mode on CPU (the TPU path is the same kernel
body with real BlockSpecs — see kernels/*/kernel.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:        # only the hypothesis property test skips without hypothesis —
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:          # the shape/dtype sweeps always run
    given = None

from repro.kernels import (flash_attention, log_patch, mla_paged_attention,
                           mla_paged_attention_layers_ragged,
                           mla_paged_attention_ragged, paged_attention,
                           paged_attention_layers,
                           paged_attention_layers_ragged,
                           paged_attention_layers_ragged_q8,
                           paged_attention_q8, paged_attention_ragged,
                           paged_attention_ragged_q8)
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.log_patch.ref import log_patch_ref
from repro.kernels.paged_attention.ref import (
    mla_paged_attention_layers_ragged_ref,
    paged_attention_layers_ragged_q8_ref, paged_attention_layers_ragged_ref,
    paged_attention_layers_ref, paged_attention_ragged_ref,
    paged_attention_ref)

_RTOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return _RTOL[dtype]


# ---------------------------------------------------------------- flash attn
FLASH_CASES = [
    # (B, Sq, Skv, H, K, D, causal, bq, bk)
    (2, 128, 128, 8, 2, 64, True, 64, 64),
    (1, 100, 260, 4, 4, 32, True, 32, 64),       # ragged + GQA=1
    (2, 64, 192, 6, 2, 128, False, 64, 64),      # cross-attn shape
    (1, 256, 256, 4, 1, 128, True, 128, 128),    # MQA
    (1, 37, 129, 2, 2, 256, True, 16, 32),       # gemma head_dim, unaligned
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(case, dtype):
    B, Sq, Skv, H, K, D, causal, bq, bk = case
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, Sq, H, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Skv, K, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Skv, K, D)), dtype)
    out = flash_attention(q, k, v, causal=causal, force_pallas=True,
                          block_q=bq, block_k=bk)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=5 * _tol(dtype), rtol=_tol(dtype))


# ---------------------------------------------------------------- paged attn
PAGED_CASES = [
    # (B, H, K, D, page_tokens, pool_pages, max_pages)
    (3, 8, 4, 64, 16, 24, 6),
    (1, 4, 4, 128, 8, 8, 4),       # MHA-per-kv
    (2, 16, 2, 64, 32, 10, 4),     # large GQA group
    (4, 8, 8, 256, 16, 40, 8),     # gemma-like head_dim
]


@pytest.mark.parametrize("case", PAGED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_matches_oracle(case, dtype):
    B, H, K, D, T, P, MP = case
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((B, H, D)), dtype)
    pk = jnp.asarray(rng.standard_normal((P, T, K, D)), dtype)
    pv = jnp.asarray(rng.standard_normal((P, T, K, D)), dtype)
    tbl = jnp.asarray(
        rng.permutation(P)[:B * MP].reshape(B, MP)
        if P >= B * MP else rng.integers(0, P, (B, MP)), jnp.int32)
    lens = jnp.asarray(rng.integers(1, T * MP, B), jnp.int32)
    out = paged_attention(q, pk, pv, tbl, lens, force_pallas=True)
    ref = paged_attention_ref(q, pk, pv, tbl, lens)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=5 * _tol(dtype), rtol=2 * _tol(dtype))


# ------------------------------------------- multi-layer batched entry
LAYERS_CASES = [
    # (L, B, H, K, D, page_tokens, pool_pages, max_pages)
    (2, 3, 8, 4, 64, 16, 24, 6),
    (4, 1, 4, 4, 128, 8, 8, 4),       # single sequence
    (3, 2, 16, 2, 64, 32, 10, 4),     # large GQA group
    (1, 4, 8, 8, 256, 16, 40, 2),     # L=1 degenerate, short tables
]


@pytest.mark.parametrize("case", LAYERS_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_layers_matches_oracle(case, dtype):
    L, B, H, K, D, T, P, MP = case
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((L, B, H, D)), dtype)
    pk = jnp.asarray(rng.standard_normal((L, P, T, K, D)), dtype)
    pv = jnp.asarray(rng.standard_normal((L, P, T, K, D)), dtype)
    tbl = jnp.asarray(rng.integers(0, P, (B, MP)), jnp.int32)
    lens = jnp.asarray(rng.integers(1, T * MP, B), jnp.int32)
    out = paged_attention_layers(q, pk, pv, tbl, lens, force_pallas=True)
    ref = paged_attention_layers_ref(q, pk, pv, tbl, lens)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=5 * _tol(dtype), rtol=2 * _tol(dtype))


@pytest.mark.parametrize("entry", ["single", "layers"])
def test_paged_attention_contract_edges(entry):
    """The block-table contract's edge rows in one batch: an empty row
    (exactly-zero output), a single-token row, a single-page row, and a
    ragged mid-page row — Pallas and oracle must agree on all of them."""
    L, B, H, K, D, T, P, MP = 2, 4, 8, 4, 64, 8, 24, 4
    rng = np.random.default_rng(6)
    q = jnp.asarray(rng.standard_normal((L, B, H, D)), jnp.float32)
    pk = jnp.asarray(rng.standard_normal((L, P, T, K, D)), jnp.float32)
    pv = jnp.asarray(rng.standard_normal((L, P, T, K, D)), jnp.float32)
    tbl = jnp.asarray(rng.integers(0, P, (B, MP)), jnp.int32)
    lens = jnp.asarray([0, 1, T, T * MP - 3], jnp.int32)
    if entry == "single":
        out = paged_attention(q[0], pk[0], pv[0], tbl, lens,
                              force_pallas=True)
        ref = paged_attention_ref(q[0], pk[0], pv[0], tbl, lens)
        empty = np.asarray(out)[0]
    else:
        out = paged_attention_layers(q, pk, pv, tbl, lens,
                                     force_pallas=True)
        ref = paged_attention_layers_ref(q, pk, pv, tbl, lens)
        empty = np.asarray(out)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=2e-5)
    assert np.all(empty == 0.0), "empty rows must produce exactly zero"


# ----------------------------------------------------- ragged-query entries
RAGGED_CASES = [
    # (L, B, Qmax, H, K, D, page_tokens, pool_pages, max_pages)
    (2, 3, 4, 8, 4, 64, 16, 24, 6),
    (1, 1, 8, 4, 4, 128, 8, 8, 4),      # one long chunk row
    (3, 2, 2, 16, 2, 64, 32, 10, 4),    # large GQA group
    (2, 4, 1, 8, 8, 256, 16, 40, 4),    # Qmax=1 degenerate (pure decode)
]


def _ragged_inputs(case, dtype, seed=12):
    L, B, Qm, H, K, D, T, P, MP = case
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((L, B, Qm, H, D)), dtype)
    pk = jnp.asarray(rng.standard_normal((L, P, T, K, D)), dtype)
    pv = jnp.asarray(rng.standard_normal((L, P, T, K, D)), dtype)
    tbl = jnp.asarray(rng.integers(0, P, (B, MP)), jnp.int32)
    qls = rng.integers(1, Qm + 1, B).astype(np.int32)
    lens = (rng.integers(0, T * MP - Qm, B) + qls).astype(np.int32)
    return q, pk, pv, tbl, jnp.asarray(lens), jnp.asarray(qls)


@pytest.mark.parametrize("case", RAGGED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_ragged_matches_oracle(case, dtype):
    q, pk, pv, tbl, lens, qls = _ragged_inputs(case, dtype)
    out = paged_attention_ragged(q[0], pk[0], pv[0], tbl, lens, qls,
                                 force_pallas=True)
    ref = paged_attention_ragged_ref(q[0], pk[0], pv[0], tbl, lens, qls)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=5 * _tol(dtype), rtol=2 * _tol(dtype))


@pytest.mark.parametrize("case", RAGGED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_layers_ragged_matches_oracle(case, dtype):
    q, pk, pv, tbl, lens, qls = _ragged_inputs(case, dtype)
    out = paged_attention_layers_ragged(q, pk, pv, tbl, lens, qls,
                                        force_pallas=True)
    ref = paged_attention_layers_ragged_ref(q, pk, pv, tbl, lens, qls)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=5 * _tol(dtype), rtol=2 * _tol(dtype))


def test_ragged_qlen1_is_bitwise_decode_kernel():
    """The fused entries at q_len=1 must be the plain decode entries BIT
    FOR BIT — the contract that lets the batched decode launch route
    through the ragged step without a numerics audit."""
    L, B, H, K, D, T, P, MP = 2, 4, 8, 4, 64, 8, 24, 4
    rng = np.random.default_rng(13)
    q = jnp.asarray(rng.standard_normal((L, B, 1, H, D)), jnp.float32)
    pk = jnp.asarray(rng.standard_normal((L, P, T, K, D)), jnp.float32)
    pv = jnp.asarray(rng.standard_normal((L, P, T, K, D)), jnp.float32)
    tbl = jnp.asarray(rng.integers(0, P, (B, MP)), jnp.int32)
    lens = jnp.asarray([1, 7, T, T * MP - 2], jnp.int32)
    qls = jnp.ones(B, jnp.int32)
    r1 = paged_attention_ragged(q[0], pk[0], pv[0], tbl, lens, qls,
                                force_pallas=True)
    d1 = paged_attention(q[0, :, 0], pk[0], pv[0], tbl, lens,
                         force_pallas=True)
    assert np.array_equal(np.asarray(r1[:, 0]), np.asarray(d1))
    rl = paged_attention_layers_ragged(q, pk, pv, tbl, lens, qls,
                                       force_pallas=True)
    dl = paged_attention_layers(q[:, :, 0], pk, pv, tbl, lens,
                                force_pallas=True)
    assert np.array_equal(np.asarray(rl[:, :, 0]), np.asarray(dl))


@pytest.mark.parametrize("entry", ["single", "layers"])
def test_ragged_contract_edges(entry):
    """Ragged contract edges in one batch: a q_len=0 padding row (exactly
    zero even with a nonzero length), a decode row, a chunk ending exactly
    on a page boundary, and a ragged mid-page chunk — plus exact zeros in
    every padding query slot."""
    L, B, Qm, H, K, D, T, P, MP = 2, 4, 4, 8, 4, 64, 8, 24, 4
    rng = np.random.default_rng(14)
    q = jnp.asarray(rng.standard_normal((L, B, Qm, H, D)), jnp.float32)
    pk = jnp.asarray(rng.standard_normal((L, P, T, K, D)), jnp.float32)
    pv = jnp.asarray(rng.standard_normal((L, P, T, K, D)), jnp.float32)
    tbl = jnp.asarray(rng.integers(0, P, (B, MP)), jnp.int32)
    lens = jnp.asarray([6, 5, 2 * T, T * MP - 3], jnp.int32)
    qls = jnp.asarray([0, 1, T // 2, 3], jnp.int32)
    if entry == "single":
        out = paged_attention_ragged(q[0], pk[0], pv[0], tbl, lens, qls,
                                     force_pallas=True)
        ref = paged_attention_ragged_ref(q[0], pk[0], pv[0], tbl, lens, qls)
        o = np.asarray(out)[None]
    else:
        out = paged_attention_layers_ragged(q, pk, pv, tbl, lens, qls,
                                            force_pallas=True)
        ref = paged_attention_layers_ragged_ref(q, pk, pv, tbl, lens, qls)
        o = np.asarray(out)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=2e-5)
    for b in range(B):
        assert np.all(o[:, b, int(qls[b]):] == 0.0), b


def test_ragged_ignores_dead_pages():
    """Poisoning pages and slots past each row's length must not change the
    ragged output — per-query masking against the pool is exact."""
    L, B, Qm, H, K, D, T, MP = 2, 2, 4, 4, 2, 64, 16, 4
    P = B * MP
    rng = np.random.default_rng(15)
    lens = [7, 39]
    qls = jnp.asarray([2, 4], jnp.int32)
    q = jnp.asarray(rng.standard_normal((L, B, Qm, H, D)), jnp.float32)
    pk = np.asarray(rng.standard_normal((L, P, T, K, D)), np.float32)
    pv = np.asarray(rng.standard_normal((L, P, T, K, D)), np.float32)
    tbl = np.arange(P, dtype=np.int32).reshape(B, MP)
    lens_arr = jnp.asarray(lens, jnp.int32)
    out1 = paged_attention_layers_ragged(q, jnp.asarray(pk), jnp.asarray(pv),
                                         jnp.asarray(tbl), lens_arr, qls,
                                         force_pallas=True)
    pk2, pv2 = pk.copy(), pv.copy()
    for b in range(B):
        for lp in range(MP):
            phys = tbl[b, lp]
            start = lp * T
            if start >= lens[b]:
                pk2[:, phys] = 1e6
                pv2[:, phys] = -1e6
            elif start + T > lens[b]:
                pk2[:, phys, lens[b] - start:] = 1e6
                pv2[:, phys, lens[b] - start:] = -1e6
    out2 = paged_attention_layers_ragged(q, jnp.asarray(pk2),
                                         jnp.asarray(pv2), jnp.asarray(tbl),
                                         lens_arr, qls, force_pallas=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)


def test_paged_attention_layers_ignores_dead_pages():
    """Poisoning pool pages past each sequence's length must not change the
    multi-layer entry's output (per-layer masking is exact)."""
    L, B, H, K, D, T, MP = 2, 2, 4, 2, 64, 16, 4
    P = B * MP
    rng = np.random.default_rng(8)
    lens = [5, 37]
    q = jnp.asarray(rng.standard_normal((L, B, H, D)), jnp.float32)
    pk = np.asarray(rng.standard_normal((L, P, T, K, D)), np.float32)
    pv = np.asarray(rng.standard_normal((L, P, T, K, D)), np.float32)
    tbl = np.arange(P, dtype=np.int32).reshape(B, MP)
    lens_arr = jnp.asarray(lens, jnp.int32)
    out1 = paged_attention_layers(q, jnp.asarray(pk), jnp.asarray(pv),
                                  jnp.asarray(tbl), lens_arr,
                                  force_pallas=True)
    pk2, pv2 = pk.copy(), pv.copy()
    for b in range(B):
        for lp in range(MP):
            phys = tbl[b, lp]
            start = lp * T
            if start >= lens[b]:
                pk2[:, phys] = 1e6
                pv2[:, phys] = -1e6
            elif start + T > lens[b]:
                pk2[:, phys, lens[b] - start:] = 1e6
                pv2[:, phys, lens[b] - start:] = -1e6
    out2 = paged_attention_layers(q, jnp.asarray(pk2), jnp.asarray(pv2),
                                  jnp.asarray(tbl), lens_arr,
                                  force_pallas=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)


def _dead_pages_body(lens):
    """Poisoning pool pages past each sequence's length must not change the
    output (the kernel's length masking / pl.when skip is exact)."""
    B, H, K, D, T, MP = 2, 4, 2, 64, 16, 4
    P = B * MP                                     # disjoint tables
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    pk = np.asarray(rng.standard_normal((P, T, K, D)), np.float32)
    pv = np.asarray(rng.standard_normal((P, T, K, D)), np.float32)
    tbl = np.arange(P, dtype=np.int32).reshape(B, MP)
    lens_arr = jnp.asarray(lens, jnp.int32)
    out1 = paged_attention(q, jnp.asarray(pk), jnp.asarray(pv),
                           jnp.asarray(tbl), lens_arr, force_pallas=True)
    pk2, pv2 = pk.copy(), pv.copy()
    for b in range(B):
        for lp in range(MP):
            phys = tbl[b, lp]
            start = lp * T
            if start >= lens[b]:                   # fully dead page
                pk2[phys] = 1e6
                pv2[phys] = -1e6
            elif start + T > lens[b]:              # partially dead slots
                pk2[phys, lens[b] - start:] = 1e6
                pv2[phys, lens[b] - start:] = -1e6
    out2 = paged_attention(q, jnp.asarray(pk2), jnp.asarray(pv2),
                           jnp.asarray(tbl), lens_arr, force_pallas=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)


@pytest.mark.parametrize("lens", [[1, 63], [16, 16], [7, 40]])
def test_paged_attention_ignores_dead_pages_fixed(lens):
    _dead_pages_body(lens)


if given is not None:
    @given(lens=st.lists(st.integers(1, 63), min_size=2, max_size=2))
    @settings(max_examples=10)
    def test_paged_attention_ignores_dead_pages(lens):
        _dead_pages_body(lens)


def test_ragged_speculative_block_bitwise_vs_sequential_decode():
    """A speculative decode row — q_len = 1 + k query slots over KV that
    was already scattered for the whole block — must equal 1 + k
    SUCCESSIVE commit-one-more-slot launches bit for bit: the launch with
    ``lengths = base + i + 1, q_lens = i + 1`` (what a sequential tick
    sequence sees after committing ``i`` tokens) reproduces slots
    ``0..i`` of the full block exactly. The successive launches keep the
    padded query shape fixed — crossing shapes changes the score-matmul
    reduction order by a ulp, which is why the shape-crossing pin lives
    at q_len=1 (``test_ragged_qlen1_is_bitwise_decode_kernel``). Against
    the plain decode entry, slot ``i`` at position ``lengths - q_len + i``
    matches a decode of length ``base + i + 1`` to float32 tolerance."""
    L, B, H, K, D, T, P, MP = 2, 3, 8, 4, 64, 8, 24, 4
    S = 4                                       # 1 real + 3 draft slots
    rng = np.random.default_rng(21)
    q = jnp.asarray(rng.standard_normal((L, B, S, H, D)), jnp.float32)
    pk = jnp.asarray(rng.standard_normal((L, P, T, K, D)), jnp.float32)
    pv = jnp.asarray(rng.standard_normal((L, P, T, K, D)), jnp.float32)
    tbl = jnp.asarray(rng.integers(0, P, (B, MP)), jnp.int32)
    base = np.asarray([3, T - 1, 2 * T + 5], np.int32)  # pre-block lengths
    out = paged_attention_ragged(q[0], pk[0], pv[0], tbl,
                                 jnp.asarray(base + S),
                                 jnp.full(B, S, jnp.int32),
                                 force_pallas=True)
    outl = paged_attention_layers_ragged(q, pk, pv, tbl,
                                         jnp.asarray(base + S),
                                         jnp.full(B, S, jnp.int32),
                                         force_pallas=True)
    for i in range(S):
        li = jnp.asarray(base + i + 1)
        qi = jnp.full(B, i + 1, jnp.int32)
        oi = paged_attention_ragged(q[0], pk[0], pv[0], tbl, li, qi,
                                    force_pallas=True)
        assert np.array_equal(np.asarray(out[:, :i + 1]),
                              np.asarray(oi[:, :i + 1])), i
        oli = paged_attention_layers_ragged(q, pk, pv, tbl, li, qi,
                                            force_pallas=True)
        assert np.array_equal(np.asarray(outl[:, :, :i + 1]),
                              np.asarray(oli[:, :, :i + 1])), i
        d = paged_attention(q[0, :, i], pk[0], pv[0], tbl, li,
                            force_pallas=True)
        np.testing.assert_allclose(np.asarray(out[:, i]), np.asarray(d),
                                   atol=1e-6, rtol=1e-6)
        dl = paged_attention_layers(q[:, :, i], pk, pv, tbl, li,
                                    force_pallas=True)
        np.testing.assert_allclose(np.asarray(outl[:, :, i]),
                                   np.asarray(dl), atol=1e-6, rtol=1e-6)


def test_ragged_rolled_back_draft_slots_are_invisible():
    """Rollback leaves rejected draft KV inside retained pool pages and
    stale block-table tail entries pointing at freed pages — the next
    launch must see neither. Poisoning every slot at or past the
    committed length, every fully dead page, AND repointing the stale
    table tail at a garbage page changes nothing (lengths is the only
    visibility authority, same discipline as padding scatter)."""
    L, B, Qm, H, K, D, T, MP = 2, 2, 4, 4, 2, 64, 8, 4
    P = B * MP + 1                 # disjoint tables + one garbage page
    rng = np.random.default_rng(22)
    cl = [9, 19]                   # committed lengths after rollback
    qls = jnp.asarray([1, 3], jnp.int32)      # next tick speculates again
    q = jnp.asarray(rng.standard_normal((L, B, Qm, H, D)), jnp.float32)
    pk = np.asarray(rng.standard_normal((L, P, T, K, D)), np.float32)
    pv = np.asarray(rng.standard_normal((L, P, T, K, D)), np.float32)
    tbl = np.arange(B * MP, dtype=np.int32).reshape(B, MP)
    lens_arr = jnp.asarray(cl, jnp.int32)
    out1 = paged_attention_layers_ragged(q, jnp.asarray(pk), jnp.asarray(pv),
                                         jnp.asarray(tbl), lens_arr, qls,
                                         force_pallas=True)
    pk2, pv2 = pk.copy(), pv.copy()
    tbl2 = tbl.copy()
    pk2[:, P - 1] = 1e6            # the garbage page stale entries hit
    pv2[:, P - 1] = -1e6
    for b in range(B):
        for lp in range(MP):
            phys = tbl[b, lp]
            start = lp * T
            if start >= cl[b]:                 # page freed by the rewind
                pk2[:, phys] = 1e6
                pv2[:, phys] = -1e6
                tbl2[b, lp] = P - 1            # stale table tail entry
            elif start + T > cl[b]:            # rejected tail in a kept page
                pk2[:, phys, cl[b] - start:] = 1e6
                pv2[:, phys, cl[b] - start:] = -1e6
    out2 = paged_attention_layers_ragged(q, jnp.asarray(pk2),
                                         jnp.asarray(pv2), jnp.asarray(tbl2),
                                         lens_arr, qls, force_pallas=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)


# ------------------------------------- descriptor-plane entries (int8 + MLA)
def _q8_inputs(seed=31):
    """Small int8 pool + bf16 per-(token, head) scale planes, ragged batch
    with a padding row (q_len = 0), a decode row, and two chunk rows."""
    L, B, Qm, H, K, D, T, MP = 2, 4, 3, 4, 2, 64, 8, 3
    P = B * MP                                       # disjoint tables
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((L, B, Qm, H, D)), jnp.float32)
    pk = jnp.asarray(rng.integers(-127, 128, (L, P, T, K, D)), jnp.int8)
    pv = jnp.asarray(rng.integers(-127, 128, (L, P, T, K, D)), jnp.int8)
    ks = jnp.asarray(rng.random((L, P, T, K)) * 0.1 + 0.01, jnp.bfloat16)
    vs = jnp.asarray(rng.random((L, P, T, K)) * 0.1 + 0.01, jnp.bfloat16)
    tbl = jnp.asarray(np.arange(P, dtype=np.int32).reshape(B, MP))
    lens = jnp.asarray([6, 5, T, T * MP - 2], jnp.int32)
    qls = jnp.asarray([0, 1, 2, 3], jnp.int32)
    return q, pk, pv, ks, vs, tbl, lens, qls


def _mla_inputs(seed=32):
    """Latent + rope-key planes (no KV-head axis), same ragged batch edges."""
    L, B, Qm, H, dc, dr, T, MP = 2, 4, 3, 4, 64, 32, 8, 3
    P = B * MP
    rng = np.random.default_rng(seed)
    q_c = jnp.asarray(rng.standard_normal((L, B, Qm, H, dc)), jnp.float32)
    q_r = jnp.asarray(rng.standard_normal((L, B, Qm, H, dr)), jnp.float32)
    pc = jnp.asarray(rng.standard_normal((L, P, T, dc)), jnp.float32)
    pkr = jnp.asarray(rng.standard_normal((L, P, T, dr)), jnp.float32)
    tbl = jnp.asarray(np.arange(P, dtype=np.int32).reshape(B, MP))
    lens = jnp.asarray([6, 5, T, T * MP - 2], jnp.int32)
    qls = jnp.asarray([0, 1, 2, 3], jnp.int32)
    scale = float(1.0 / np.sqrt(dc + dr))
    return q_c, q_r, pc, pkr, tbl, lens, qls, scale


def test_q8_ragged_matches_oracle_and_pads_zero():
    """int8 ragged entries vs the pure-jnp oracle, plus exact zeros in every
    padding query slot (q_len = 0 rows included)."""
    q, pk, pv, ks, vs, tbl, lens, qls = _q8_inputs()
    out = paged_attention_layers_ragged_q8(q, pk, pv, ks, vs, tbl, lens,
                                           qls, force_pallas=True)
    ref = paged_attention_layers_ragged_q8_ref(q, pk, pv, ks, vs, tbl,
                                               lens, qls)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=4e-5)
    o1 = paged_attention_ragged_q8(q[0], pk[0], pv[0], ks[0], vs[0], tbl,
                                   lens, qls, force_pallas=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(out)[0],
                               atol=1e-4, rtol=4e-5)
    o = np.asarray(out)
    for b in range(o.shape[1]):
        assert np.all(o[:, b, int(qls[b]):] == 0.0), b


def test_q8_dequant_parity_vs_fp32_oracle():
    """Kernel-body dequant (int8 × bf16 scale → fp32) must agree with the
    dense fp32 oracle run over a MANUALLY dequantized pool — the pin that
    the half-bytes pool read does not change the numerics contract."""
    q, pk, pv, ks, vs, tbl, lens, qls = _q8_inputs(seed=33)
    deq_k = pk.astype(jnp.float32) * ks.astype(jnp.float32)[..., None]
    deq_v = pv.astype(jnp.float32) * vs.astype(jnp.float32)[..., None]
    out = paged_attention_layers_ragged_q8(q, pk, pv, ks, vs, tbl, lens,
                                           qls, force_pallas=True)
    ref = paged_attention_layers_ragged_ref(q, deq_k, deq_v, tbl, lens, qls)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=4e-5)


def test_q8_qlen1_is_bitwise_decode_entry():
    """q_len = 1 through the int8 ragged entries IS the int8 decode entry
    bit for bit, and the multi-layer launch is bitwise the stacked
    single-layer launches — no numerics audit needed to route batched int8
    decode through the fused tick."""
    q, pk, pv, ks, vs, tbl, lens, _ = _q8_inputs(seed=34)
    lens = jnp.maximum(lens, 1)
    q1 = q[:, :, :1]
    qls = jnp.ones(q.shape[1], jnp.int32)
    r = paged_attention_layers_ragged_q8(q1, pk, pv, ks, vs, tbl, lens,
                                         qls, force_pallas=True)
    d = paged_attention_q8(q1[0, :, 0], pk[0], pv[0], ks[0], vs[0], tbl,
                           lens, force_pallas=True)
    assert np.array_equal(np.asarray(r[0, :, 0]), np.asarray(d))
    per_layer = [paged_attention_ragged_q8(q1[l], pk[l], pv[l], ks[l],
                                           vs[l], tbl, lens, qls,
                                           force_pallas=True)
                 for l in range(q.shape[0])]
    assert np.array_equal(np.asarray(r), np.stack([np.asarray(x)
                                                   for x in per_layer]))


def test_q8_ragged_ignores_dead_pages():
    """Poisoning int8 slots AND their scale planes past each row's length
    must not change the int8 ragged output."""
    q, pk, pv, ks, vs, tbl, lens, qls = _q8_inputs(seed=35)
    out1 = paged_attention_layers_ragged_q8(q, pk, pv, ks, vs, tbl, lens,
                                            qls, force_pallas=True)
    pk2, pv2 = np.asarray(pk).copy(), np.asarray(pv).copy()
    ks2 = np.asarray(ks.astype(jnp.float32)).copy()
    vs2 = np.asarray(vs.astype(jnp.float32)).copy()
    T, MP = pk.shape[2], tbl.shape[1]
    tl = np.asarray(tbl)
    ln = np.asarray(lens)
    for b in range(tl.shape[0]):
        for lp in range(MP):
            phys, start = tl[b, lp], lp * T
            if start >= ln[b]:
                pk2[:, phys], pv2[:, phys] = 127, -127
                ks2[:, phys], vs2[:, phys] = 1e6, 1e6
            elif start + T > ln[b]:
                pk2[:, phys, ln[b] - start:] = 127
                pv2[:, phys, ln[b] - start:] = -127
                ks2[:, phys, ln[b] - start:] = 1e6
                vs2[:, phys, ln[b] - start:] = 1e6
    out2 = paged_attention_layers_ragged_q8(
        q, jnp.asarray(pk2), jnp.asarray(pv2),
        jnp.asarray(ks2, jnp.bfloat16), jnp.asarray(vs2, jnp.bfloat16),
        tbl, lens, qls, force_pallas=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)


def test_mla_ragged_matches_oracle_and_pads_zero():
    """MLA ragged entries vs the pure-jnp oracle over the latent + rope-key
    planes, plus exact zeros in every padding query slot."""
    q_c, q_r, pc, pkr, tbl, lens, qls, scale = _mla_inputs()
    out = mla_paged_attention_layers_ragged(q_c, q_r, pc, pkr, tbl, lens,
                                            qls, scale=scale,
                                            force_pallas=True)
    ref = mla_paged_attention_layers_ragged_ref(q_c, q_r, pc, pkr, tbl,
                                                lens, qls, scale=scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=4e-5)
    o1 = mla_paged_attention_ragged(q_c[0], q_r[0], pc[0], pkr[0], tbl,
                                    lens, qls, scale=scale,
                                    force_pallas=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(out)[0],
                               atol=1e-4, rtol=4e-5)
    o = np.asarray(out)
    for b in range(o.shape[1]):
        assert np.all(o[:, b, int(qls[b]):] == 0.0), b


def test_mla_qlen1_is_bitwise_decode_entry():
    """q_len = 1 through the MLA ragged entries IS the MLA decode entry bit
    for bit, and the multi-layer launch is bitwise the stacked
    single-layer launches."""
    q_c, q_r, pc, pkr, tbl, lens, _, scale = _mla_inputs(seed=36)
    lens = jnp.maximum(lens, 1)
    qc1, qr1 = q_c[:, :, :1], q_r[:, :, :1]
    qls = jnp.ones(q_c.shape[1], jnp.int32)
    r = mla_paged_attention_layers_ragged(qc1, qr1, pc, pkr, tbl, lens,
                                          qls, scale=scale,
                                          force_pallas=True)
    d = mla_paged_attention(qc1[0, :, 0], qr1[0, :, 0], pc[0], pkr[0], tbl,
                            lens, scale=scale, force_pallas=True)
    assert np.array_equal(np.asarray(r[0, :, 0]), np.asarray(d))
    per_layer = [mla_paged_attention_ragged(qc1[l], qr1[l], pc[l], pkr[l],
                                            tbl, lens, qls, scale=scale,
                                            force_pallas=True)
                 for l in range(q_c.shape[0])]
    assert np.array_equal(np.asarray(r), np.stack([np.asarray(x)
                                                   for x in per_layer]))


def test_mla_ragged_ignores_dead_pages():
    """Poisoning latent AND rope-key slots past each row's length must not
    change the MLA ragged output."""
    q_c, q_r, pc, pkr, tbl, lens, qls, scale = _mla_inputs(seed=37)
    out1 = mla_paged_attention_layers_ragged(q_c, q_r, pc, pkr, tbl, lens,
                                             qls, scale=scale,
                                             force_pallas=True)
    pc2, pkr2 = np.asarray(pc).copy(), np.asarray(pkr).copy()
    T, MP = pc.shape[2], tbl.shape[1]
    tl, ln = np.asarray(tbl), np.asarray(lens)
    for b in range(tl.shape[0]):
        for lp in range(MP):
            phys, start = tl[b, lp], lp * T
            if start >= ln[b]:
                pc2[:, phys], pkr2[:, phys] = 1e6, -1e6
            elif start + T > ln[b]:
                pc2[:, phys, ln[b] - start:] = 1e6
                pkr2[:, phys, ln[b] - start:] = -1e6
    out2 = mla_paged_attention_layers_ragged(
        q_c, q_r, jnp.asarray(pc2), jnp.asarray(pkr2), tbl, lens, qls,
        scale=scale, force_pallas=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)


# ------------------------------------------------------------------ log patch
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("P,T,C,N", [(5, 8, 16, 20), (3, 16, 128, 64),
                                     (2, 4, 8, 1)])
def test_log_patch_matches_oracle(P, T, C, N, dtype):
    rng = np.random.default_rng(3)
    pool = jnp.asarray(rng.standard_normal((P, T, C)), dtype)
    pays = jnp.asarray(rng.standard_normal((N, C)), dtype)
    pg = jnp.asarray(rng.integers(0, P, N), jnp.int32)
    sl = jnp.asarray(rng.integers(0, T, N), jnp.int32)
    valid = jnp.asarray(rng.integers(0, 2, N), jnp.int32)
    out = log_patch(pool, pays, pg, sl, valid, force_pallas=True)
    ref = log_patch_ref(pool, pays, pg, sl, valid.astype(bool))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=1e-6)


def test_log_patch_replay_order():
    """Later log records must win on slot collisions (replay semantics)."""
    pool = jnp.zeros((1, 4, 8), jnp.float32)
    pays = jnp.stack([jnp.full((8,), 1.0), jnp.full((8,), 2.0)])
    pg = jnp.zeros((2,), jnp.int32)
    sl = jnp.zeros((2,), jnp.int32)
    out = log_patch(pool, pays, pg, sl, force_pallas=True)
    assert float(out[0, 0, 0]) == 2.0
