"""Elastic re-meshing + straggler policy (fault-tolerance substrate)."""
from repro.training.elastic import MeshPlan, StragglerPolicy, replan_mesh


def test_replan_keeps_tp_whole():
    plan = MeshPlan(data=16, model=16)
    new = replan_mesh(plan, healthy_devices=240, global_batch=256)
    assert new.model == 16
    assert new.data * new.model <= 240
    assert 256 % new.data == 0


def test_replan_after_losing_half_a_pod():
    plan = MeshPlan(data=16, model=16)
    new = replan_mesh(plan, healthy_devices=128, global_batch=256)
    assert new.model == 16 and new.data == 8


def test_replan_multi_pod():
    plan = MeshPlan(data=16, model=16, pod=2)
    new = replan_mesh(plan, healthy_devices=384, global_batch=256)
    assert new.model == 16 and new.pod == 2
    assert new.devices <= 384


def test_straggler_detection_and_reassignment():
    pol = StragglerPolicy(threshold=2.0)
    hosts = [f"h{i}" for i in range(4)]
    for step in range(10):
        for h in hosts:
            pol.observe(h, 1.0 if h != "h2" else 5.0)
    assert pol.stragglers() == ["h2"]
    assign = pol.reassign_shards(8, hosts)
    assert "h2" not in assign.values()
    assert sorted(assign) == list(range(8))


def test_no_straggler_no_change():
    pol = StragglerPolicy()
    for h in ("a", "b"):
        pol.observe(h, 1.0)
    assert pol.stragglers() == []
    assign = pol.reassign_shards(4, ["a", "b"])
    assert set(assign.values()) == {"a", "b"}
