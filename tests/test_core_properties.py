"""Hypothesis property tests on the cache invariants (deliverable c).

Core invariant (both persistent designs): after ANY op sequence, an optional
crash, and recovery, every acked write is readable — the recovered file
equals the oracle built from acked writes. The same functional-equality
invariant covers the KV-cache tier: every registered KV engine must return
identical reads for any append/read/preempt/restore sequence.

``hypothesis`` is a declared test dependency (requirements-test.txt, run in
CI); the importorskip guard only covers stripped-down local images.
"""
import random

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import NVCacheFS, PAGE_SIZE, SimClock, create_kv_engine
from repro.core.engines import EngineSpec, list_kv_engines
from repro.core.kvcache import KVSpec

FILE_BYTES = 1 << 16


ops_strategy = st.lists(
    st.tuples(
        st.sampled_from(["w", "r", "f"]),
        st.integers(0, FILE_BYTES - 64),
        st.integers(1, 64),
        st.integers(0, 255),
    ),
    min_size=1, max_size=120,
)


def _apply(fs, fd, ops):
    oracle = {}
    for kind, off, n, val in ops:
        if kind == "w":
            data = bytes([val]) * n
            fs.pwrite(fd, data, off)
            for j in range(n):
                oracle[off + j] = val
        elif kind == "r":
            got = fs.pread(fd, n, off)
            want = bytes(oracle.get(off + j, 0) for j in range(n))
            assert got == want
        else:
            fs.fsync(fd)
    return oracle


def _check_oracle(fs, fd, oracle):
    for off in range(0, FILE_BYTES, PAGE_SIZE):
        got = fs.pread(fd, PAGE_SIZE, off)
        want = bytes(oracle.get(off + j, 0) for j in range(PAGE_SIZE))
        assert got == want, f"mismatch at page {off // PAGE_SIZE}"


@settings(max_examples=30)
@given(ops=ops_strategy, engine=st.sampled_from(["nvpages", "nvlog"]),
       crash=st.booleans())
def test_acked_writes_survive_any_sequence(ops, engine, crash):
    fs = NVCacheFS(engine, nvmm_bytes=256 << 10, dram_cache_bytes=64 << 10)
    fd = fs.open("/f")
    oracle = _apply(fs, fd, ops)
    if crash:
        fs.crash()
        fs.recover()
        fd = fs.open("/f")
    _check_oracle(fs, fd, oracle)


@settings(max_examples=20)
@given(ops=ops_strategy)
def test_designs_agree_functionally(ops):
    """Paging and logging must be observationally identical — only the
    timing/amplification differ (the paper's whole point)."""
    fss = {e: NVCacheFS(e, nvmm_bytes=256 << 10, dram_cache_bytes=64 << 10)
           for e in ("nvpages", "nvlog")}
    fds = {e: fs.open("/f") for e, fs in fss.items()}
    for kind, off, n, val in ops:
        if kind == "w":
            for e in fss:
                fss[e].pwrite(fds[e], bytes([val]) * n, off)
        elif kind == "r":
            reads = {e: fss[e].pread(fds[e], n, off) for e in fss}
            assert reads["nvpages"] == reads["nvlog"]


@settings(max_examples=20)
@given(ops=ops_strategy, seed=st.integers(0, 2 ** 16))
def test_recovery_idempotent(ops, seed):
    """Recovering twice (crash during recovery restart) must be safe."""
    fs = NVCacheFS("nvlog", nvmm_bytes=256 << 10, dram_cache_bytes=32 << 10)
    fd = fs.open("/f")
    oracle = _apply(fs, fd, ops)
    fs.crash()
    fs.recover()
    fs.crash()          # crash again immediately after recovery
    fs.recover()
    fd = fs.open("/f")
    _check_oracle(fs, fd, oracle)


KV_SPEC = KVSpec(num_layers=2, kv_heads=2, head_dim=4, page_tokens=4)

# (op, seq, arg): append `arg` tokens / read layer `arg % L` / preempt-or-
# restore (interpreted from current state, so every sequence is valid)
kv_ops_strategy = st.lists(
    st.tuples(st.sampled_from(["append", "read", "flip"]),
              st.integers(0, 2), st.integers(1, 6)),
    min_size=1, max_size=60,
)


@settings(max_examples=25)
@given(ops=kv_ops_strategy)
def test_kv_engines_agree_on_any_op_sequence(ops):
    """Registry-wide functional equality: random op sequences give identical
    reads across every registered KV engine (designs may only differ in
    timing/amplification, never bytes)."""
    engines = {
        name: create_kv_engine(
            EngineSpec(engine=name, kv_hbm_bytes=1 << 12, kv_hot_window=5,
                       drain_shards=2, hybrid_threshold=256),
            KV_SPEC, SimClock())
        for name in list_kv_engines()}
    rng = np.random.default_rng(0)
    preempted: set[int] = set()
    for op, seq, arg in ops:
        if op == "append" and seq not in preempted:
            toks = rng.standard_normal(
                (KV_SPEC.num_layers, 2, arg, KV_SPEC.kv_heads,
                 KV_SPEC.head_dim)).astype(np.float16)
            for kv in engines.values():
                kv.append(seq, toks if arg > 1 else toks[:, :, 0])
        elif op == "read" and seq not in preempted:
            layer = arg % KV_SPEC.num_layers
            reads = {n: kv.read(seq, layer) for n, kv in engines.items()}
            first = next(iter(reads.values()))
            for name, got in reads.items():
                assert np.array_equal(got, first), (name, seq, layer)
        elif op == "flip":
            if seq in preempted:
                preempted.discard(seq)
                for kv in engines.values():
                    kv.restore(seq)
            else:
                preempted.add(seq)
                for kv in engines.values():
                    kv.preempt(seq)
    for seq in {0, 1, 2} - preempted:
        for layer in range(KV_SPEC.num_layers):
            reads = {n: kv.read(seq, layer) for n, kv in engines.items()}
            first = next(iter(reads.values()))
            for name, got in reads.items():
                assert np.array_equal(got, first), (name, seq, layer)


# --------------------------------------------------------------------------
# Continuous-batching scheduler: batched == sequential for ANY schedule
# --------------------------------------------------------------------------

_SERVE_MODEL = None


def _serve_model():
    """One tiny model shared by every hypothesis example (jit caches too)."""
    global _SERVE_MODEL
    if _SERVE_MODEL is None:
        import jax
        from repro.configs import get_config
        from repro.models import build_model
        cfg = get_config("internlm2-1.8b-smoke")
        model = build_model(cfg, remat=False)
        params = model.init(jax.random.PRNGKey(0))
        _SERVE_MODEL = (cfg, model, params)
    return _SERVE_MODEL


@pytest.mark.slow
@settings(max_examples=5)
@given(
    n_requests=st.integers(1, 3),
    arrival_perm=st.permutations(range(3)),
    max_new=st.integers(1, 4),
    max_batch_seqs=st.integers(1, 3),
    budget_tokens=st.sampled_from([6, 12, 1 << 20]),
    speculate_k=st.sampled_from([0, 1, 2, 4]),
    seed=st.integers(0, 3),
)
def test_scheduler_matches_sequential_for_any_schedule(
        n_requests, arrival_perm, max_new, max_batch_seqs, budget_tokens,
        speculate_k, seed):
    """Random arrival schedules × batch widths × HBM budgets × speculation
    depths: the continuous-batching scheduler's greedy tokens equal the
    sequential reference for every registered KV engine (tiny budgets
    force preempt/restore cycles mid-decode; speculative drafts and their
    rollbacks must be just as invisible)."""
    from repro.serving import Request, ServeConfig, ServingEngine
    cfg, model, params = _serve_model()
    rng = np.random.default_rng(seed)
    lens = [(6, 9)[i % 2] for i in range(n_requests)]
    prompts = [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
               for n in lens]
    token_bytes = (model.cfg.num_layers * 2 * model.cfg.num_kv_heads
                   * model.cfg.head_dim * 2)

    def mk_engine(name):
        return ServingEngine(model, params, ServeConfig(
            max_len=16, page_tokens=4,
            engine_spec=EngineSpec(engine=name,
                                   kv_hbm_bytes=budget_tokens * token_bytes,
                                   kv_hot_window=4, drain_shards=2),
            max_batch_seqs=max_batch_seqs, speculate_k=speculate_k))

    ref = [Request(rid=i, prompt=p.copy(), max_new=max_new)
           for i, p in enumerate(prompts)]
    mk_engine("log").generate_sequential(ref)
    want = {r.rid: list(r.generated) for r in ref}

    order = [i for i in arrival_perm if i < n_requests]
    for name in list_kv_engines():
        reqs = [Request(rid=i, prompt=p.copy(), max_new=max_new)
                for i, p in enumerate(prompts)]
        mk_engine(name).generate([reqs[i] for i in order])
        for r in reqs:
            assert r.done and r.generated == want[r.rid], (name, r.rid)


# one model per cache-layout family (ISSUE 9), shared across examples so
# the jit caches warm once: dense GQA, MLA latent (the deepseek smoke
# config with its MoE stripped — the MLA cache is the axis under test),
# int8 quantized KV + scale planes, and Mamba-2 SSM state rows
_FAMILY_MODELS: dict = {}


def _family_model(fam):
    if fam not in _FAMILY_MODELS:
        import dataclasses
        import jax
        from repro.configs import get_config
        from repro.models import build_model
        if fam == "mla":
            cfg = dataclasses.replace(get_config("deepseek-v2-236b-smoke"),
                                      family="attn_dense", moe=None)
            model = build_model(cfg, remat=False)
        elif fam == "int8":
            cfg = get_config("internlm2-1.8b-smoke")
            model = build_model(cfg, remat=False, kv_cache_dtype="int8")
        elif fam == "ssm":
            cfg = get_config("mamba2-1.3b-smoke")
            model = build_model(cfg, remat=False)
        else:
            cfg = get_config("internlm2-1.8b-smoke")
            model = build_model(cfg, remat=False)
        params = model.init(jax.random.PRNGKey(0))
        _FAMILY_MODELS[fam] = (cfg, model, params)
    return _FAMILY_MODELS[fam]


@pytest.mark.slow
@settings(max_examples=8)
@given(
    family=st.sampled_from(["dense", "mla", "int8", "ssm"]),
    engine=st.sampled_from(["paged", "log", "kvhybrid"]),
    arrival_perm=st.permutations(range(3)),
    max_new=st.integers(1, 3),
    max_batch_seqs=st.integers(1, 3),
    chunk=st.sampled_from([None, 5]),
    speculate_k=st.sampled_from([0, 2]),
    seed=st.integers(0, 2),
)
def test_families_match_sequential_for_any_schedule(
        family, engine, arrival_perm, max_new, max_batch_seqs, chunk,
        speculate_k, seed):
    """ISSUE 9 invariant — the config-family axis: every cache-descriptor
    family (dense GQA, MLA, int8, SSM) through every registered KV engine,
    random arrival schedules, batch widths, chunked prefill, and
    speculation depths is token-identical to the sequential mirrored
    reference. Pool-capable engines must run these families MIRROR-FREE
    (``mirror_d2h_bytes == 0``); the rest fall back transparently."""
    from repro.serving import Request, ServeConfig, ServingEngine
    cfg, model, params = _family_model(family)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, (6, 9, 7)[i], dtype=np.int32)
               for i in range(3)]

    def mk_engine(name):
        return ServingEngine(model, params, ServeConfig(
            max_len=16, page_tokens=4,
            engine_spec=EngineSpec(engine=name, kv_hbm_bytes=64 << 20,
                                   kv_hot_window=4, drain_shards=2),
            max_batch_seqs=max_batch_seqs, prefill_chunk_tokens=chunk,
            speculate_k=speculate_k))

    ref = [Request(rid=i, prompt=p.copy(), max_new=max_new)
           for i, p in enumerate(prompts)]
    mk_engine("log").generate_sequential(ref)
    want = {r.rid: list(r.generated) for r in ref}

    reqs = [Request(rid=i, prompt=p.copy(), max_new=max_new)
            for i, p in enumerate(prompts)]
    eng = mk_engine(engine)
    eng.generate([reqs[i] for i in arrival_perm])
    for r in reqs:
        assert r.done and r.generated == want[r.rid], (family, engine, r.rid)
    if eng.tiered.supports_pool():
        assert eng.pooled, (family, engine)
        assert eng.stats()["mirror_d2h_bytes"] == 0, (family, engine)


@pytest.mark.slow
@settings(max_examples=5)
@given(
    arrival_perm=st.permutations(range(4)),
    max_new=st.integers(1, 3),
    max_batch_seqs=st.integers(2, 4),
    pool_pages=st.sampled_from([5, 16]),
    chunk=st.sampled_from([None, 5]),
    speculate_k=st.sampled_from([0, 1, 2, 4]),
    seed=st.integers(0, 3),
)
def test_prefix_sharing_matches_sequential_for_any_schedule(
        arrival_perm, max_new, max_batch_seqs, pool_pages, chunk,
        speculate_k, seed):
    """ISSUE 6 invariant, extended with the ISSUE 7 axis: Zipf-style prompt
    reuse (hot prefix families plus exact duplicates) through the prefix
    cache is token-identical to the sequential reference under ANY
    admission order, batch width, chunked prefill, speculation depth, and
    a pool tight enough to force preemption and refcount-aware spills —
    splices, COWs, index evictions, and speculative rollbacks must all be
    invisible."""
    from repro.serving import Request, ServeConfig, ServingEngine
    cfg, model, params = _serve_model()
    rng = np.random.default_rng(seed)
    fam = rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)  # hot family
    dup = np.concatenate(
        [fam, rng.integers(0, cfg.vocab_size, 3, dtype=np.int32)])
    prompts = [dup.copy(), dup.copy(),     # exact duplicates (COW path)
               np.concatenate([fam, rng.integers(0, cfg.vocab_size, 2,
                                                 dtype=np.int32)]),
               rng.integers(0, cfg.vocab_size, 7, dtype=np.int32)]
    group_bytes = (model.cfg.num_layers * 2 * 4 * model.cfg.num_kv_heads
                   * model.cfg.head_dim
                   * np.dtype(model.compute_dtype).itemsize)

    def mk_engine(share_tokens):
        return ServingEngine(model, params, ServeConfig(
            max_len=16, page_tokens=4,
            engine_spec=EngineSpec(engine="paged",
                                   kv_hbm_bytes=pool_pages * group_bytes,
                                   kv_hot_window=4, drain_shards=2,
                                   prefix_cache_tokens=share_tokens),
            max_batch_seqs=max_batch_seqs, prefill_chunk_tokens=chunk,
            speculate_k=speculate_k))

    ref = [Request(rid=i, prompt=p.copy(), max_new=max_new)
           for i, p in enumerate(prompts)]
    mk_engine(0).generate_sequential(ref)
    want = {r.rid: list(r.generated) for r in ref}

    eng = mk_engine(1 << 12)
    assert eng.pooled and eng.prefix_cache is not None
    reqs = [Request(rid=i, prompt=p.copy(), max_new=max_new)
            for i, p in enumerate(prompts)]
    eng.generate([reqs[i] for i in arrival_perm])
    for r in reqs:
        assert r.done and r.generated == want[r.rid], r.rid
    # churn never strands a page: live user refs all released, the pool is
    # exactly free + idle-index, monotone counters never ran backwards
    kv = eng.tiered
    assert not kv.page_users
    assert len(kv.free_pages) + kv._idle_index_pages() == kv.pool_pages


# --------------------------------------------------------------------------
# Async tiering (ISSUE 8): timing-only for ANY schedule
# --------------------------------------------------------------------------

def _pool_capable_engines():
    return [name for name in list_kv_engines()
            if create_kv_engine(EngineSpec(engine=name, kv_hbm_bytes=1 << 12),
                                KV_SPEC, SimClock()).supports_pool()]


@settings(max_examples=20)
@given(ops=kv_ops_strategy, pool_pages=st.sampled_from([3, 4, 6]),
       seed=st.integers(0, 3))
def test_async_tiering_is_timing_only_at_engine_level(ops, pool_pages, seed):
    """Engine-level half of the ISSUE 8 invariant, where fault traffic is
    real: for ANY append/read/preempt/restore sequence against a pool
    tight enough to spill, the async pipeline returns byte-identical
    reads, makes identical placement decisions, and every prefetch hit
    displaces exactly one demand fault (``prefetch_hits + pool_faults ==
    sync pool_faults``)."""
    spec = KVSpec(num_layers=2, kv_heads=2, head_dim=4, page_tokens=4,
                  dtype=np.dtype(np.float32))
    kvs = {}
    for mode in (False, True):
        kv = create_kv_engine(
            EngineSpec(engine="paged", kv_hbm_bytes=1 << 30,
                       async_tiering=mode), spec, SimClock())
        kv.init_pool(dtype=np.float32, pages=pool_pages)
        kvs[mode] = kv
    rng = np.random.default_rng(seed)
    preempted: set[int] = set()
    for op, seq, arg in ops:
        if op == "append" and seq not in preempted:
            toks = rng.standard_normal(
                (spec.num_layers, 2, arg, spec.kv_heads,
                 spec.head_dim)).astype(np.float32)
            if not all(kv.can_admit_tokens(arg) for kv in kvs.values()):
                continue
            for kv in kvs.values():
                kv.append(seq, toks)
            # the scheduler's lookahead publication, every tick
            kvs[True].prefetch(sorted(kvs[True].block_table))
        elif op == "read" and seq not in preempted:
            if seq not in kvs[False].seq_len:
                continue
            layer = arg % spec.num_layers
            a = kvs[False].read(seq, layer)
            b = kvs[True].read(seq, layer)
            assert np.array_equal(a, b), (seq, layer)
        elif op == "flip":
            if seq in preempted:
                preempted.discard(seq)
                for kv in kvs.values():
                    kv.restore(seq)
            elif seq in kvs[False].seq_len:
                preempted.add(seq)
                for kv in kvs.values():
                    kv.preempt(seq)
    for kv in kvs.values():
        kv.flush_transfers()
    s, a = kvs[False].stats, kvs[True].stats
    assert kvs[True].block_table == kvs[False].block_table
    assert a["pool_page_spills"] == s["pool_page_spills"]
    assert a["prefetch_hits"] + a["pool_faults"] == s["pool_faults"]
    assert s["prefetch_hits"] == s["async_spills"] == 0
    assert s["stall_ticks_saved"] == 0
    assert kvs[True].clock.now <= kvs[False].clock.now


@pytest.mark.slow
@settings(max_examples=4)
@given(
    arrival_perm=st.permutations(range(3)),
    max_new=st.integers(1, 4),
    max_batch_seqs=st.integers(1, 3),
    pool_pages=st.sampled_from([5, 8, 1 << 10]),
    speculate_k=st.sampled_from([0, 2, 4]),
    seed=st.integers(0, 3),
)
def test_async_tiering_matches_sequential_for_any_schedule(
        arrival_perm, max_new, max_batch_seqs, pool_pages, speculate_k,
        seed):
    """Serving-level half of the ISSUE 8 invariant: async tiering on/off ×
    every pool-capable engine × random arrival schedules × speculation
    depths is token-identical to the sequential reference, and the
    lookahead only reschedules transfers: ``prefetch_hits + pool_faults``
    equals the synchronous run's ``pool_faults`` exactly."""
    from repro.serving import Request, ServeConfig, ServingEngine
    cfg, model, params = _serve_model()
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, (6, 9, 7)[i], dtype=np.int32)
               for i in range(3)]
    group_bytes = (model.cfg.num_layers * 2 * 4 * model.cfg.num_kv_heads
                   * model.cfg.head_dim
                   * np.dtype(model.compute_dtype).itemsize)

    def mk_engine(name, async_tiering):
        return ServingEngine(model, params, ServeConfig(
            max_len=16, page_tokens=4,
            engine_spec=EngineSpec(engine=name,
                                   kv_hbm_bytes=pool_pages * group_bytes,
                                   kv_hot_window=4, drain_shards=2,
                                   async_tiering=async_tiering),
            max_batch_seqs=max_batch_seqs, speculate_k=speculate_k))

    ref = [Request(rid=i, prompt=p.copy(), max_new=max_new)
           for i, p in enumerate(prompts)]
    mk_engine("paged", False).generate_sequential(ref)
    want = {r.rid: list(r.generated) for r in ref}

    for name in _pool_capable_engines():
        faults = {}
        for mode in (False, True):
            reqs = [Request(rid=i, prompt=p.copy(), max_new=max_new)
                    for i, p in enumerate(prompts)]
            eng = mk_engine(name, mode)
            eng.generate([reqs[i] for i in arrival_perm])
            for r in reqs:
                assert r.done and r.generated == want[r.rid], (name, mode,
                                                               r.rid)
            s = eng.tiered.stats
            faults[mode] = (s["pool_faults"], s["prefetch_hits"])
            if not mode:
                assert s["prefetch_hits"] == s["async_spills"] == 0
        assert faults[True][0] + faults[True][1] == faults[False][0], name


# --------------------------------------------------------------------------
# Fault tolerance (ISSUE 10): chaos is timing-only; crashes recover exactly
# --------------------------------------------------------------------------

@settings(max_examples=10)
@given(ops=kv_ops_strategy, pool_pages=st.sampled_from([3, 4, 6]),
       fail_rate=st.sampled_from([0.0, 0.4, 0.9]),
       delay_rate=st.sampled_from([0.0, 0.6]),
       seed=st.integers(0, 3))
def test_chaos_transfer_faults_are_timing_only(ops, pool_pages, fail_rate,
                                               delay_rate, seed):
    """ISSUE 10 chaos law, at the engine level where transfer faults are
    real: for ANY schedule and ANY seeded mix of failed/delayed transfers,
    the faulty async engine returns byte-identical reads, makes identical
    placement decisions, and the three-way fault split is exactly
    conservative — ``prefetch_hits + pool_faults + retried_faults`` equals
    the fault-free synchronous run's ``pool_faults``. A second run under
    the same FaultPlan injects the identical fault sequence (replayable)."""
    from repro.serving.faults import FaultInjector, FaultPlan
    plan = FaultPlan(seed=seed, transfer_fail_rate=fail_rate,
                     transfer_delay_rate=delay_rate)
    spec = KVSpec(num_layers=2, kv_heads=2, head_dim=4, page_tokens=4,
                  dtype=np.dtype(np.float32))
    kvs = {}
    for mode in ("sync", "chaos", "replay"):
        kv = create_kv_engine(
            EngineSpec(engine="paged", kv_hbm_bytes=1 << 30,
                       async_tiering=mode != "sync"), spec, SimClock())
        kv.init_pool(dtype=np.float32, pages=pool_pages)
        if mode != "sync":
            kv.set_fault_injector(FaultInjector(plan))
        kvs[mode] = kv
    rng = np.random.default_rng(seed)
    preempted: set[int] = set()
    for op, seq, arg in ops:
        if op == "append" and seq not in preempted:
            toks = rng.standard_normal(
                (spec.num_layers, 2, arg, spec.kv_heads,
                 spec.head_dim)).astype(np.float32)
            if not all(kv.can_admit_tokens(arg) for kv in kvs.values()):
                continue
            for kv in kvs.values():
                kv.append(seq, toks)
            for mode in ("chaos", "replay"):
                kvs[mode].prefetch(sorted(kvs[mode].block_table))
        elif op == "read" and seq not in preempted:
            if seq not in kvs["sync"].seq_len:
                continue
            layer = arg % spec.num_layers
            want = kvs["sync"].read(seq, layer)
            for mode in ("chaos", "replay"):
                assert np.array_equal(want, kvs[mode].read(seq, layer)), \
                    (mode, seq, layer)
        elif op == "flip":
            if seq in preempted:
                preempted.discard(seq)
                for kv in kvs.values():
                    kv.restore(seq)
            elif seq in kvs["sync"].seq_len:
                preempted.add(seq)
                for kv in kvs.values():
                    kv.preempt(seq)
    for kv in kvs.values():
        kv.flush_transfers()
    s, a = kvs["sync"].stats, kvs["chaos"].stats
    assert kvs["chaos"].block_table == kvs["sync"].block_table
    assert a["pool_page_spills"] == s["pool_page_spills"]
    # exact conservation: every demand fault lands in exactly one bucket
    assert (a["prefetch_hits"] + a["pool_faults"] + a["retried_faults"]
            == s["pool_faults"])
    # counter coherence with the injector's own tally
    inj = kvs["chaos"]._injector
    assert a["transfer_failures"] == inj.counts["transfer_fail"]
    assert a["transfer_retries"] <= a["transfer_failures"]
    assert a["retried_faults"] <= a["transfer_retries"]
    if fail_rate == 0.0:
        assert a["transfer_failures"] == a["transfer_retries"] == 0
        assert a["retried_faults"] == a["tiering_degraded"] == 0
    # determinism: the same plan over the same schedule injects the same
    # faults and lands every counter in the same place
    r = kvs["replay"].stats
    assert r == a
    assert kvs["replay"]._injector.counts == inj.counts


@pytest.mark.slow
@settings(max_examples=4)
@given(
    arrival_perm=st.permutations(range(3)),
    max_new=st.integers(2, 5),
    max_batch_seqs=st.integers(1, 3),
    speculate_k=st.sampled_from([0, 2]),
    crash_tick=st.integers(1, 6),
    seed=st.integers(0, 3),
)
def test_crash_at_any_tick_recovers_token_identically(
        arrival_perm, max_new, max_batch_seqs, speculate_k, crash_tick,
        seed):
    """ISSUE 10 recovery law: every pool-capable engine × random arrival
    schedule × speculation depth × crash-at-ANY-tick — with transfer
    fail/delay chaos running underneath — recovers through the shared NVMM
    journal to a stream token-identical to the uninterrupted sequential
    reference. Crash ticks past the run's end degenerate to a clean run
    whose journal replays to the same (already complete) state."""
    from repro.serving import Request, ServeConfig, ServingEngine
    from repro.serving.faults import CrashFault, FaultPlan
    from repro.serving.journal import ServingJournal
    cfg, model, params = _serve_model()
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, (6, 9, 7)[i], dtype=np.int32)
               for i in range(3)]
    group_bytes = (model.cfg.num_layers * 2 * 4 * model.cfg.num_kv_heads
                   * model.cfg.head_dim
                   * np.dtype(model.compute_dtype).itemsize)

    def mk_engine(name, journal=None, fault_plan=None):
        return ServingEngine(model, params, ServeConfig(
            max_len=16, page_tokens=4,
            engine_spec=EngineSpec(engine=name,
                                   kv_hbm_bytes=6 * group_bytes,
                                   kv_hot_window=4, drain_shards=2,
                                   async_tiering=True),
            max_batch_seqs=max_batch_seqs, speculate_k=speculate_k,
            journal=journal, fault_plan=fault_plan))

    ref = [Request(rid=i, prompt=p.copy(), max_new=max_new)
           for i, p in enumerate(prompts)]
    mk_engine("paged").generate_sequential(ref)
    want = {r.rid: list(r.generated) for r in ref}

    for name in _pool_capable_engines():
        journal = ServingJournal(capacity=1 << 16)
        plan = FaultPlan(seed=seed, transfer_fail_rate=0.3,
                         transfer_delay_rate=0.3, crash_at_tick=crash_tick)
        eng = mk_engine(name, journal=journal, fault_plan=plan)
        reqs = [Request(rid=i, prompt=p.copy(), max_new=max_new)
                for i, p in enumerate(prompts)]
        try:
            eng.generate([reqs[i] for i in arrival_perm])
            crashed = False
        except CrashFault:
            crashed = True
        if not crashed:   # crash tick past run end: clean finish first
            for r in reqs:
                assert r.done and r.generated == want[r.rid], (name, r.rid)
        # a fresh engine sharing the SAME journal picks up where the last
        # durable tick stopped — token-identical either way
        reqs2 = [Request(rid=i, prompt=p.copy(), max_new=max_new)
                 for i, p in enumerate(prompts)]
        eng2 = mk_engine(name, journal=journal)
        eng2.recover(reqs2)
        for r in reqs2:
            assert r.done and r.generated == want[r.rid], \
                (name, crashed, r.rid)


@settings(max_examples=15)
@given(st.integers(2, 64))
def test_monotone_capacity_no_data_loss(cache_pages):
    """Shrinking NVPages capacity changes timing, never correctness."""
    fs = NVCacheFS("nvpages", nvmm_bytes=cache_pages * PAGE_SIZE + (64 << 10))
    fd = fs.open("/f")
    rng = random.Random(5)
    oracle = {}
    for _ in range(200):
        off = rng.randrange(0, FILE_BYTES - 64)
        data = bytes([rng.randrange(256)]) * 32
        fs.pwrite(fd, data, off)
        for j in range(32):
            oracle[off + j] = data[0]
    _check_oracle(fs, fd, oracle)
