"""Engine registry, EngineSpec, protocol conformance, and the hybrid
engine's crash→recover equivalence against its two parents."""
import random
import subprocess
import sys
from pathlib import Path

import pytest

from repro.core import ENGINES, NVCacheFS, PAGE_SIZE
from repro.core.engines import (CacheEngine, EngineSpec, create_engine,
                                get_engine, list_engines, register_engine)

ALL_ENGINES = ("nvpages", "nvlog", "psync", "psync_fsync", "nvhybrid")


# ----------------------------------------------------------------- registry
def test_engines_derived_from_registry():
    assert ENGINES == list_engines()
    assert set(ALL_ENGINES) == set(ENGINES)
    for name in ENGINES:
        assert issubclass(get_engine(name), CacheEngine)
        assert get_engine(name).engine_name == name


def test_unknown_engine_raises_value_error():
    with pytest.raises(ValueError, match="unknown cache engine"):
        NVCacheFS("nvtapes")
    with pytest.raises(ValueError, match="nvtapes"):
        get_engine("nvtapes")


def test_register_engine_round_trip():
    @register_engine("_test_engine")
    class _TestEngine(get_engine("psync")):
        pass
    try:
        assert "_test_engine" in list_engines()
        fs = NVCacheFS("_test_engine")
        fd = fs.open("/f")
        fs.pwrite(fd, b"x" * 100, 5)
        assert fs.pread(fd, 100, 5) == b"x" * 100
        # the --list CLI must survive a docstring-less plugin class
        from repro.core.engines.__main__ import main as engines_main
        assert engines_main(["--list"]) == 0
        # silently replacing a registered engine is refused
        with pytest.raises(ValueError, match="already registered"):
            register_engine("_test_engine")(_TestEngine)
        register_engine("_test_engine", override=True)(_TestEngine)
    finally:
        from repro.core.engines.base import _REGISTRY
        _REGISTRY.pop("_test_engine", None)


def test_engine_spec_defaults():
    spec = EngineSpec()
    assert spec.engine == "nvlog"
    assert spec.nvmm_bytes == 2 << 30
    assert spec.dram_cache_bytes == 2 << 30
    assert spec.shards == 1
    assert spec.drain_batch == 64
    assert spec.o_direct is False
    assert spec.lpc_capacity_pages is None
    assert 0 < spec.hybrid_threshold <= PAGE_SIZE
    assert 0.0 < spec.hybrid_log_fraction < 1.0


def test_facade_constructs_from_spec():
    spec = EngineSpec(engine="nvpages", nvmm_bytes=1 << 20, shards=2)
    fs = NVCacheFS(spec)
    assert fs.engine == "nvpages" and fs.spec is spec
    assert fs.cache.num_shards == 2
    assert fs.cache.nvmm_capacity_bytes() == 1 << 20
    # mixing a spec with engine kwargs is ambiguous → loud failure, even
    # when the kwarg happens to equal its default value
    with pytest.raises(TypeError, match="inside the EngineSpec"):
        NVCacheFS(spec, nvmm_bytes=2 << 20)
    with pytest.raises(TypeError, match="shards"):
        NVCacheFS(spec, shards=1)


# -------------------------------------------------------------- conformance
@pytest.mark.parametrize("engine", ALL_ENGINES)
def test_engine_conformance_round_trip(engine):
    """The shared contract: write/read, fsync, crash, recover — fsync'd
    data survives on every engine; un-synced data survives iff the engine
    persists at pwrite-return."""
    fs = NVCacheFS(EngineSpec(engine=engine, nvmm_bytes=1 << 20,
                              dram_cache_bytes=1 << 18))
    fd = fs.open("/f")
    fs.pwrite(fd, b"\xAA" * PAGE_SIZE, 0)
    fs.pwrite(fd, b"tiny", PAGE_SIZE + 17)            # sub-page write
    assert fs.pread(fd, PAGE_SIZE, 0) == b"\xAA" * PAGE_SIZE
    assert fs.pread(fd, 4, PAGE_SIZE + 17) == b"tiny"
    fs.fsync(fd)
    fs.pwrite(fd, b"\xBB" * 64, 2 * PAGE_SIZE)        # never fsync'd
    fs.crash()
    fs.recover()
    fd = fs.open("/f")
    assert fs.pread(fd, PAGE_SIZE, 0) == b"\xAA" * PAGE_SIZE
    assert fs.pread(fd, 4, PAGE_SIZE + 17) == b"tiny"
    durable_at_return = fs.cache.uses_nvmm or engine == "psync_fsync"
    want = b"\xBB" * 64 if durable_at_return else b"\x00" * 64
    assert fs.pread(fd, 64, 2 * PAGE_SIZE) == want


@pytest.mark.parametrize("engine", ALL_ENGINES)
def test_vectorized_iov_round_trip(engine):
    fs = NVCacheFS(EngineSpec(engine=engine, nvmm_bytes=1 << 20,
                              dram_cache_bytes=1 << 18))
    fd = fs.open("/f")
    iov = [(1000 * i, bytes([i]) * (i + 1)) for i in range(20)]
    assert fs.pwritev(fd, iov) == sum(len(d) for _, d in iov)
    got = fs.preadv(fd, [(off, len(d)) for off, d in iov])
    assert got == [d for _, d in iov]


def test_capacity_accounting():
    for engine in ("nvpages", "nvlog", "nvhybrid"):
        fs = NVCacheFS(EngineSpec(engine=engine, nvmm_bytes=1 << 20,
                                  dram_cache_bytes=1 << 18))
        fd = fs.open("/f")
        cap = fs.cache.nvmm_capacity_bytes()
        assert 0 < cap <= 1 << 20
        fs.pwrite(fd, b"\x77" * PAGE_SIZE, 0)
        s = fs.stats()
        assert 0 <= s["nvmm_used_bytes"] <= cap == s["nvmm_capacity_bytes"]


def test_io_range_must_fit_file_span():
    """Regression: a multi-byte IO ending past the 2^36 span must be
    rejected, not silently spill into the next file's address space."""
    fs = NVCacheFS("psync")
    fa = fs.open("/a")
    fs.open("/b")
    with pytest.raises(AssertionError, match="file span"):
        fs.pwrite(fa, b"x" * 100, (1 << 36) - 4)
    with pytest.raises(AssertionError, match="file span"):
        fs.pread(fa, 100, (1 << 36) - 4)
    with pytest.raises(AssertionError, match="file span"):
        fs.pwritev(fa, [((1 << 36) - 4, b"x" * 100)])


def test_hybrid_never_overcommits_small_budgets():
    """The journal/pool split must partition the budget, not exceed it,
    even where the 64 KiB journal floor kicks in."""
    for nvmm in (128 << 10, 256 << 10, 1 << 20):
        fs = NVCacheFS(EngineSpec(engine="nvhybrid", nvmm_bytes=nvmm,
                                  dram_cache_bytes=1 << 17))
        assert fs.cache.nvmm_capacity_bytes() == nvmm


# ----------------------------------------------------- hybrid vs its parents
def _mixed_ops(fs, fd, n_ops, file_bytes, seed):
    """Mixed write sizes: tiny records, mid-size, and full aligned pages."""
    rng = random.Random(seed)
    oracle = {}
    for _ in range(n_ops):
        kind = rng.random()
        if kind < 0.4:                                    # small record
            off = rng.randrange(0, file_bytes - 64)
            data = bytes([rng.randrange(256)]) * rng.randrange(1, 64)
        elif kind < 0.6:                                  # mid-size write
            off = rng.randrange(0, file_bytes - 3000)
            data = bytes([rng.randrange(256)]) * rng.randrange(1024, 3000)
        else:                                             # full aligned page
            off = rng.randrange(0, file_bytes // PAGE_SIZE) * PAGE_SIZE
            data = bytes([rng.randrange(256)]) * PAGE_SIZE
        fs.pwrite(fd, data, off)
        for j, b in enumerate(data):
            oracle[off + j] = b
        if rng.random() < 0.3:
            off = rng.randrange(0, file_bytes - 256)
            got = fs.pread(fd, 256, off)
            want = bytes(oracle.get(off + j, 0) for j in range(256))
            assert got == want
    return oracle


@pytest.mark.parametrize("seed", [1, 2, 3])
def test_hybrid_crash_recover_matches_nvlog_and_nvpages(seed):
    """On the same mixed-size op stream, nvhybrid must recover to exactly
    the state nvlog and nvpages recover to (all equal the oracle)."""
    file_bytes = 1 << 18
    images = {}
    for engine in ("nvhybrid", "nvlog", "nvpages"):
        fs = NVCacheFS(EngineSpec(engine=engine, nvmm_bytes=1 << 20,
                                  dram_cache_bytes=1 << 17))
        fd = fs.open("/f")
        oracle = _mixed_ops(fs, fd, 400, file_bytes, seed)
        fs.crash()
        fs.recover()
        fd = fs.open("/f")
        img = b"".join(fs.pread(fd, PAGE_SIZE, off)
                       for off in range(0, file_bytes, PAGE_SIZE))
        want = bytes(oracle.get(j, 0) for j in range(file_bytes))
        assert img == want, f"{engine} diverged from the acked-write oracle"
        images[engine] = img
    assert images["nvhybrid"] == images["nvlog"] == images["nvpages"]


def test_hybrid_routes_by_size():
    fs = NVCacheFS(EngineSpec(engine="nvhybrid", nvmm_bytes=2 << 20,
                              dram_cache_bytes=1 << 18))
    fd = fs.open("/f")
    for i in range(32):
        fs.pwrite(fd, b"s" * 32, 3 * PAGE_SIZE * i + 7)   # small → journal
    for i in range(32):
        fs.pwrite(fd, b"L" * PAGE_SIZE, (100 + i) * PAGE_SIZE)  # → pages
    s = fs.stats()
    assert s["routed_log"] == 32
    assert s["routed_pages"] == 32
    assert s["log_log_appends"] == 32
    assert s["pages_nvmm_page_writes"] >= 32


def test_hybrid_page_takeover_preserves_journal_data():
    """A large write to a journal-owned page must drain the journal first
    (log before pages — the unified recovery ordering)."""
    fs = NVCacheFS(EngineSpec(engine="nvhybrid", nvmm_bytes=1 << 20,
                              dram_cache_bytes=1 << 17))
    fd = fs.open("/f")
    fs.pwrite(fd, b"abc", 10)                  # journal owns page 0
    fs.pwrite(fd, b"Z" * PAGE_SIZE, 0)         # pages takes over page 0
    assert fs.stats()["page_takeovers"] == 1
    # the full-page write supersedes the record; both must be crash-safe
    fs.pwrite(fd, b"tail", PAGE_SIZE + 5)      # journal owns page 1
    fs.crash()
    fs.recover()
    fd = fs.open("/f")
    assert fs.pread(fd, PAGE_SIZE, 0) == b"Z" * PAGE_SIZE
    assert fs.pread(fd, 4, PAGE_SIZE + 5) == b"tail"


# ------------------------------------------------- facade lifecycle fixes
def test_open_after_unload_rearms_nvmm_flag():
    """Regression: unload() left nvmm_flag 0 forever, so a crash after
    re-open skipped recovery and lost acked writes."""
    fs = NVCacheFS(EngineSpec(engine="nvlog", nvmm_bytes=1 << 20,
                              dram_cache_bytes=1 << 17))
    fd = fs.open("/f")
    fs.pwrite(fd, b"one", 0)
    fs.unload()
    assert fs.nvmm_flag == 0
    fd = fs.open("/f")
    assert fs.nvmm_flag == 1                   # re-armed
    fs.pwrite(fd, b"two", PAGE_SIZE)
    fs.crash()
    fs.recover()
    fd = fs.open("/f")
    assert fs.pread(fd, 3, 0) == b"one"
    assert fs.pread(fd, 3, PAGE_SIZE) == b"two"


@pytest.mark.parametrize("engine", ["nvpages", "nvlog", "nvhybrid"])
def test_recover_clean_image_remounts_usable_cache(engine):
    """Regression: crash after a clean unload (flag==0) must still rebuild
    the engine's volatile indices — a full NVPages cache previously died
    with 'evicting from empty LRU' on the next write."""
    fs = NVCacheFS(EngineSpec(engine=engine, nvmm_bytes=160 << 10,
                              dram_cache_bytes=1 << 16))
    fd = fs.open("/f")
    for off in range(0, 256 << 10, PAGE_SIZE):     # overfill: force evicts
        fs.pwrite(fd, bytes([off // PAGE_SIZE % 256]) * PAGE_SIZE, off)
    fs.unload()
    fs.crash()
    fs.recover()
    fd = fs.open("/f")
    for off in range(0, 256 << 10, PAGE_SIZE):     # full write-over again
        fs.pwrite(fd, b"\x9A" * PAGE_SIZE, off)
    assert fs.pread(fd, 4, 0) == b"\x9A" * 4


def test_nvpages_used_bytes_tracks_occupancy_not_high_water():
    fs = NVCacheFS(EngineSpec(engine="nvpages", nvmm_bytes=160 << 10))
    fd = fs.open("/f")
    for off in range(0, 1 << 20, PAGE_SIZE):       # churn ≫ capacity
        fs.pwrite(fd, b"\x3C" * PAGE_SIZE, off)
    cache = fs.cache
    assert cache.stats["evictions"] > 0
    occupied = sum(sh.max_frames - len(sh.free_frames)
                   for sh in cache.shards)
    assert cache.nvmm_used_bytes() >= occupied * PAGE_SIZE
    assert cache.nvmm_used_bytes() <= cache.nvmm_capacity_bytes()
    pooled = sum(len(sh.pool) for sh in cache.shards)
    assert pooled == occupied                      # evicted frames freed


def test_write_on_stale_fd_after_unload_rearms_flag():
    """Regression: fds stay valid across unload(); a write through one must
    re-mark the image dirty or the next crash skips recovery."""
    fs = NVCacheFS(EngineSpec(engine="nvlog", nvmm_bytes=1 << 20,
                              dram_cache_bytes=1 << 17))
    fd = fs.open("/f")
    fs.unload()
    assert fs.nvmm_flag == 0
    fs.pwrite(fd, b"two", PAGE_SIZE)           # stale fd, no re-open
    assert fs.nvmm_flag == 1
    fs.crash()
    fs.recover()
    fd = fs.open("/f")
    assert fs.pread(fd, 3, PAGE_SIZE) == b"two"


def test_runtime_registered_engine_visible_to_enumerators():
    """list_engines() is live: benches enumerate plugins registered after
    import (ENGINES is only an import-time snapshot)."""
    from benchmarks.fio_bench import resolve_engines
    from benchmarks.recovery_bench import persistent_engines

    @register_engine("_plug")
    class _Plug(get_engine("nvlog")):
        pass
    try:
        assert "_plug" in resolve_engines("all")
        assert "_plug" in persistent_engines()
        assert "_plug" not in ENGINES          # the snapshot stays built-in
    finally:
        from repro.core.engines.base import _REGISTRY
        _REGISTRY.pop("_plug", None)


def test_close_flushes_path_state():
    """Last close of a path flushes it (close-to-open consistency): data
    written then closed survives a crash even on the psync baseline."""
    fs = NVCacheFS("psync")
    fd = fs.open("/f")
    fs.pwrite(fd, b"\xAA" * PAGE_SIZE, 0)
    fs.close(fd)
    fs.crash()
    fs.recover()
    fd = fs.open("/f")
    assert fs.pread(fd, 4, 0) == b"\xAA" * 4


def test_close_flush_is_scoped_to_the_closed_path():
    """Closing /a must not durably flush /b's un-synced data as a side
    effect — the psync baseline's 'no persistence until fsync' contract
    holds per file."""
    fs = NVCacheFS("psync")
    fa = fs.open("/a")
    fb = fs.open("/b")
    fs.pwrite(fa, b"\xAA" * PAGE_SIZE, 0)
    fs.pwrite(fb, b"\xBB" * PAGE_SIZE, 0)      # never fsync'd, stays open
    fs.close(fa)                               # flushes /a only
    fs.crash()
    fs.recover()
    fa, fb = fs.open("/a"), fs.open("/b")
    assert fs.pread(fa, 4, 0) == b"\xAA" * 4   # closed file survived
    assert fs.pread(fb, 4, 0) == b"\x00" * 4   # open un-synced file lost


def test_fsync_is_per_file():
    """POSIX fsync syncs one file: syncing /a must not persist /b."""
    fs = NVCacheFS("psync")
    fa, fb = fs.open("/a"), fs.open("/b")
    fs.pwrite(fa, b"\xAA" * PAGE_SIZE, 0)
    fs.pwrite(fb, b"\xBB" * PAGE_SIZE, 0)
    fs.fsync(fa)
    fs.crash()
    fs.recover()
    fa, fb = fs.open("/a"), fs.open("/b")
    assert fs.pread(fa, 4, 0) == b"\xAA" * 4
    assert fs.pread(fb, 4, 0) == b"\x00" * 4


def test_close_keeps_other_fds_open():
    fs = NVCacheFS("psync")
    fd1 = fs.open("/f")
    fd2 = fs.open("/f")
    fs.close(fd1)                              # fd2 still references /f
    fs.pwrite(fd2, b"live", 0)
    assert fs.pread(fd2, 4, 0) == b"live"


# ------------------------------------------------------------ CLI entry point
def test_engines_list_entry_point():
    src = str(Path(__file__).resolve().parent.parent / "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.core.engines", "--list"],
        capture_output=True, text=True, env={"PYTHONPATH": src},
        timeout=120)
    assert proc.returncode == 0, proc.stderr
    for name in ALL_ENGINES:
        assert name in proc.stdout
