"""Per-arch smoke tests (deliverable f): reduced config, one forward/train
step on CPU, output shapes + no NaNs; plus decode/full-forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model


def _batch_for(cfg, B, S, rng_seed=2):
    toks = jax.random.randint(jax.random.PRNGKey(rng_seed), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend.kind == "vision":
        batch["frontend_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3),
            (B, cfg.frontend.num_tokens, cfg.frontend.d_frontend))
    if cfg.family == "encdec":
        batch["frontend_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, 32, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_config(arch + "-smoke")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, B=2, S=128)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss)), arch
    # one grad step produces finite grads
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch + "-smoke")
    if cfg.moe is not None:   # no-drop capacity for exact equality
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    model = build_model(cfg, remat=False, chunk_size=32)
    params = model.init(jax.random.PRNGKey(1))
    B, S_total, S_pre = 2, 72, 64       # intentionally not chunk-aligned
    batch_full = _batch_for(cfg, B, S_total)
    lg_full, _ = jax.jit(lambda p, b: model.prefill(p, b, 128))(
        params, batch_full)

    batch_pre = dict(batch_full)
    batch_pre["tokens"] = batch_full["tokens"][:, :S_pre]
    batch_pre["labels"] = batch_pre["tokens"]
    lg, cache = jax.jit(lambda p, b: model.prefill(p, b, 128))(
        params, batch_pre)
    dstep = jax.jit(model.decode_step)
    for t in range(S_pre, S_total):
        lg, cache = dstep(params, cache, batch_full["tokens"][:, t:t + 1],
                          cache["pos"])
    ref = np.asarray(lg_full[:, 0])
    got = np.asarray(lg[:, 0])
    rel = np.max(np.abs(got - ref)) / (np.max(np.abs(ref)) + 1e-9)
    assert rel < 2e-3, (arch, rel)


def test_padded_vocab_never_predicted():
    cfg = get_config("seamless-m4t-large-v2-smoke")
    cfg = dataclasses.replace(cfg, vocab_size=500)   # padded_vocab = 512
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, 2, 16)
    logits, _ = jax.jit(lambda p, b: model.prefill(p, b, 32))(params, batch)
    assert logits.shape[-1] == 512
    assert np.all(np.asarray(logits[..., 500:]) < -1e29)


def test_mamba2_padding_is_noop():
    """SSD chunk padding must not perturb outputs or final state."""
    cfg = get_config("mamba2-1.3b-smoke")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    b1 = _batch_for(cfg, 1, 64)          # chunk-aligned (chunk=64)
    b2 = {k: v[:, :50] for k, v in b1.items()}   # needs padding
    lg1, _ = jax.jit(lambda p, b: model.prefill(p, b, 128))(params, b1)
    lg2, c2 = jax.jit(lambda p, b: model.prefill(p, b, 128))(params, b2)
    # decode the remaining 14 tokens from the padded prefill
    dstep = jax.jit(model.decode_step)
    lg = lg2
    for t in range(50, 64):
        lg, c2 = dstep(params, c2, b1["tokens"][:, t:t + 1], c2["pos"])
    rel = np.max(np.abs(np.asarray(lg[:, 0]) - np.asarray(lg1[:, 0])))
    assert rel / (np.max(np.abs(np.asarray(lg1))) + 1e-9) < 2e-3


def test_triangular_attention_matches_full():
    """§Perf hillclimb B: the lower-triangle-only scan must be exact."""
    import jax.numpy as jnp
    from repro.models.attention import (chunked_attention_tri,
                                        full_attention)
    rng = np.random.default_rng(0)
    B, S, K, G, D, C = 2, 256, 2, 3, 32, 64
    q = jnp.asarray(rng.standard_normal((B, S, K, G, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, K, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, K, D)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    ref = full_attention(q, k, v, scale=0.2, q_positions=pos,
                         kv_positions=jnp.arange(S), causal=True)
    tri = chunked_attention_tri(q, k, v, scale=0.2, chunk_size=C)
    np.testing.assert_allclose(np.asarray(tri), np.asarray(ref), atol=2e-5)


def test_int8_kv_decode_quality():
    """§Perf hillclimb C: int8 KV decode stays within 1% of fp logits."""
    cfg = get_config("internlm2-1.8b-smoke")
    m_fp = build_model(cfg, remat=False)
    m_q8 = build_model(cfg, remat=False, kv_cache_dtype="int8")
    params = m_fp.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    lg_fp, c_fp = jax.jit(lambda p, b: m_fp.prefill(p, b, 96))(params, batch)
    lg_q8, c_q8 = jax.jit(lambda p, b: m_q8.prefill(p, b, 96))(params, batch)
    d_fp, d_q8 = jax.jit(m_fp.decode_step), jax.jit(m_q8.decode_step)
    nt = jnp.ones((2, 1), jnp.int32)
    for _ in range(6):
        lg_fp, c_fp = d_fp(params, c_fp, nt, c_fp["pos"])
        lg_q8, c_q8 = d_q8(params, c_q8, nt, c_q8["pos"])
    rel = (np.max(np.abs(np.asarray(lg_q8) - np.asarray(lg_fp)))
           / np.max(np.abs(np.asarray(lg_fp))))
    assert rel < 0.02, rel
    assert c_q8["k"].dtype == jnp.int8
