"""Benchmark runner: one section per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV per the harness contract, where
``derived`` carries the benchmark's headline quantity.

    PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import sys
import time


def _section(title):
    print(f"\n### {title}", flush=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller scales (CI)")
    ap.add_argument("--with-roofline-compiles", action="store_true",
                    help="also run the reduced-depth dry-run compiles "
                         "(slow; usually done via benchmarks.roofline_bench)")
    args = ap.parse_args(argv)

    from benchmarks import fio_bench, kernel_bench, kvcache_bench, \
        recovery_bench

    t0 = time.time()
    print("name,us_per_call,derived")

    _section("fio grid (paper Figs. 3-4)")
    scale = "8MiB" if args.fast else "32MiB"
    runs = 2 if args.fast else 5
    results, checks = fio_bench.main(["--scale", scale, "--runs", str(runs)])
    n_ops = (8 << 20 if args.fast else 32 << 20) // 4096
    for r in results:
        print(f"fio/{r['figure']}/{r['workload']}/{r['engine']},"
              f"{r['sim_time_s'] / n_ops * 1e6:.3f},"
              f"sim_total_s={r['sim_time_s']:.4f}")
    failed = [c for c in checks if c.startswith("FAIL")]
    print(f"fio/claims,{0.0},passed={len(checks)-len(failed)}/{len(checks)}")

    _section("recovery (paper §II crash protocol)")
    for r in recovery_bench.main(["--sizes", "1,4" if args.fast else "1,4,16"]):
        print(f"recovery/{r['engine']}/{r['dirty_mib']}MiB,"
              f"{r['recovery_s'] * 1e6:.1f},lost={r['lost']}")

    _section("kv-cache tiering (serving call-site)")
    for r in kvcache_bench.main(["--tokens", "128" if args.fast else "512"]):
        print(f"kvcache/{r['design']},{r['sim_time_s'] * 1e6:.1f},"
              f"write_amp={r['write_amplification']:.2f}")

    _section("kernels (interpret-mode vs oracle + TPU roofline)")
    for r in kernel_bench.main([]):
        print(f"kernel/{r['kernel']},{r['pallas_interp_us']:.0f},"
              f"tpu_roofline_us={r['tpu_roofline_us']:.2f}")

    _section("roofline table (from dry-run artifacts)")
    try:
        from benchmarks import roofline_bench
        rows = roofline_bench.main(["--skip-compile"] +
                                   ([] if not args.with_roofline_compiles
                                    else []))
        for r in rows:
            print(f"roofline/{r.arch}/{r.shape},{max(r.compute_s, r.memory_s, r.collective_s)*1e6:.0f},"
                  f"bound={r.bound}:useful={r.model_flops_ratio:.2f}")
    except Exception as e:  # artifacts may not exist yet
        print(f"roofline/skipped,0,reason={type(e).__name__}")

    print(f"\n# total bench wall time: {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
