"""Kernel micro-benchmarks: wall-time of the interpret-mode Pallas kernels vs
their jnp oracles on CPU (correctness-scale), plus the analytic TPU-side
FLOP/byte counts the roofline uses. Real-TPU timing happens on hardware; the
bench records the work the kernels would do.

``--smoke`` is the CI gate for the serving decode kernel: it runs
``paged_attention`` (single-layer and the batched multi-layer entry) in
Pallas **interpret mode** against the jnp oracles over the block-table
contract's edge cases — ragged lengths, an empty row, single-page
sequences — and exits nonzero on any mismatch, so kernel regressions fail
the workflow before the serving tier ever sees them.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import (flash_attention, log_patch, paged_attention,
                           paged_attention_layers)
from repro.kernels.paged_attention.ref import (paged_attention_layers_ref,
                                               paged_attention_ref)
from repro.roofline.hw import V5E


def _time(fn, *args, reps=3):
    fn(*args)                       # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def bench_flash(B=1, S=512, H=8, K=2, D=128):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, K, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, K, D)), jnp.float32)
    t_ref = _time(lambda *a: flash_attention(*a, causal=True), q, k, v)
    t_pal = _time(lambda *a: flash_attention(*a, causal=True,
                                             force_pallas=True), q, k, v)
    flops = 4 * B * H * S * S * D / 2            # causal
    return {"kernel": "flash_attention", "shape": f"B{B} S{S} H{H} D{D}",
            "ref_us": t_ref * 1e6, "pallas_interp_us": t_pal * 1e6,
            "tpu_flops": flops,
            "tpu_roofline_us": flops / V5E.peak_flops_bf16 * 1e6}


def bench_paged(B=8, H=8, K=4, D=128, T=16, P=256, MP=16):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    pk = jnp.asarray(rng.standard_normal((P, T, K, D)), jnp.float32)
    pv = jnp.asarray(rng.standard_normal((P, T, K, D)), jnp.float32)
    tbl = jnp.asarray(rng.integers(0, P, (B, MP)), jnp.int32)
    lens = jnp.asarray(rng.integers(T, T * MP, B), jnp.int32)
    t_ref = _time(paged_attention, q, pk, pv, tbl, lens)
    t_pal = _time(lambda *a: paged_attention(*a, force_pallas=True),
                  q, pk, pv, tbl, lens)
    bytes_moved = B * MP * T * K * D * 2 * 2 * 4   # K+V pages per batch row
    return {"kernel": "paged_attention", "shape": f"B{B} pages{MP}x{T}",
            "ref_us": t_ref * 1e6, "pallas_interp_us": t_pal * 1e6,
            "tpu_bytes": bytes_moved,
            "tpu_roofline_us": bytes_moved / V5E.hbm_bandwidth * 1e6}


def bench_paged_layers(L=4, B=8, H=8, K=4, D=128, T=16, P=256, MP=16):
    """The batched multi-layer pooled-decode entry: one kernel launch for
    the whole (L, B) decode attention read over the device page pool."""
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.standard_normal((L, B, H, D)), jnp.float32)
    pk = jnp.asarray(rng.standard_normal((L, P, T, K, D)), jnp.float32)
    pv = jnp.asarray(rng.standard_normal((L, P, T, K, D)), jnp.float32)
    tbl = jnp.asarray(rng.integers(0, P, (B, MP)), jnp.int32)
    lens = jnp.asarray(rng.integers(T, T * MP, B), jnp.int32)
    t_ref = _time(paged_attention_layers, q, pk, pv, tbl, lens)
    t_pal = _time(lambda *a: paged_attention_layers(*a, force_pallas=True),
                  q, pk, pv, tbl, lens)
    bytes_moved = L * B * MP * T * K * D * 2 * 2 * 4
    return {"kernel": "paged_attention_layers",
            "shape": f"L{L} B{B} pages{MP}x{T}",
            "ref_us": t_ref * 1e6, "pallas_interp_us": t_pal * 1e6,
            "tpu_bytes": bytes_moved,
            "tpu_roofline_us": bytes_moved / V5E.hbm_bandwidth * 1e6}


def smoke_check() -> dict:
    """Interpret-mode parity gate over the block-table contract edges:
    ragged lengths, an empty row, a single-token row, single-page
    sequences, for both paged_attention entries. Raises on mismatch."""
    rng = np.random.default_rng(7)
    L, B, H, K, D, T, P, MP = 2, 4, 8, 4, 64, 8, 24, 4
    q = jnp.asarray(rng.standard_normal((L, B, H, D)), jnp.float32)
    pk = jnp.asarray(rng.standard_normal((L, P, T, K, D)), jnp.float32)
    pv = jnp.asarray(rng.standard_normal((L, P, T, K, D)), jnp.float32)
    tbl = jnp.asarray(rng.integers(0, P, (B, MP)), jnp.int32)
    # empty row, single token, exactly one page, ragged mid-page
    lens = jnp.asarray([0, 1, T, T * MP - 3], jnp.int32)
    cases = {
        "paged_attention": (
            paged_attention(q[0], pk[0], pv[0], tbl, lens,
                            force_pallas=True),
            paged_attention_ref(q[0], pk[0], pv[0], tbl, lens)),
        "paged_attention_layers": (
            paged_attention_layers(q, pk, pv, tbl, lens, force_pallas=True),
            paged_attention_layers_ref(q, pk, pv, tbl, lens)),
    }
    errs = {}
    for name, (out, ref) in cases.items():
        err = float(jnp.max(jnp.abs(out - ref)))
        errs[name] = err
        if not np.isfinite(err) or err > 2e-5:
            raise SystemExit(
                f"kernel smoke FAILED: {name} diverges from its oracle "
                f"(max abs err {err:.3e}) on the ragged/empty/single-page "
                f"contract cases")
        empty = np.asarray(out)[..., 0, :, :] if out.ndim == 4 else \
            np.asarray(out)[0]
        if np.any(empty != 0):
            raise SystemExit(
                f"kernel smoke FAILED: {name} returned nonzero output for "
                f"an empty (length 0) row")
    return {"kernel": "smoke_gate", "shape": f"lens={list(map(int, lens))}",
            "max_abs_err": errs}


def bench_log_patch(P=64, T=16, C=512, N=128):
    rng = np.random.default_rng(2)
    pool = jnp.asarray(rng.standard_normal((P, T, C)), jnp.float32)
    pays = jnp.asarray(rng.standard_normal((N, C)), jnp.float32)
    pg = jnp.asarray(rng.integers(0, P, N), jnp.int32)
    sl = jnp.asarray(rng.integers(0, T, N), jnp.int32)
    t_ref = _time(log_patch, pool, pays, pg, sl)
    t_pal = _time(lambda *a: log_patch(*a, force_pallas=True),
                  pool, pays, pg, sl)
    bytes_moved = P * T * C * 4 * 2 + N * C * 4
    return {"kernel": "log_patch", "shape": f"P{P} N{N} C{C}",
            "ref_us": t_ref * 1e6, "pallas_interp_us": t_pal * 1e6,
            "tpu_bytes": bytes_moved,
            "tpu_roofline_us": bytes_moved / V5E.hbm_bandwidth * 1e6}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/kernel_bench.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: interpret-mode paged_attention parity on "
                         "the block-table contract edges + small timing "
                         "rows; exits nonzero on kernel regression")
    args = ap.parse_args(argv)
    if args.smoke:
        rows = [smoke_check(),
                bench_paged(B=4, K=4, D=64, T=8, P=32, MP=4),
                bench_paged_layers(L=2, B=4, K=4, D=64, T=8, P=32, MP=4)]
        print("paged_attention smoke gate passed:", rows[0]["max_abs_err"])
    else:
        rows = [bench_flash(), bench_paged(), bench_paged_layers(),
                bench_log_patch()]
    print("kernel,shape,ref_us,pallas_interp_us,tpu_roofline_us")
    for r in rows:
        if r["kernel"] == "smoke_gate":
            continue
        print(f"{r['kernel']},{r['shape']},{r['ref_us']:.0f},"
              f"{r['pallas_interp_us']:.0f},{r['tpu_roofline_us']:.2f}")
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rows, indent=1))
    return rows


if __name__ == "__main__":
    main()
