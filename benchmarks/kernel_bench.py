"""Kernel micro-benchmarks: wall-time of the interpret-mode Pallas kernels vs
their jnp oracles on CPU (correctness-scale), plus the analytic TPU-side
FLOP/byte counts the roofline uses. Real-TPU timing happens on hardware; the
bench records the work the kernels would do.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention, log_patch, paged_attention
from repro.roofline.hw import V5E


def _time(fn, *args, reps=3):
    fn(*args)                       # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def bench_flash(B=1, S=512, H=8, K=2, D=128):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, K, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, K, D)), jnp.float32)
    t_ref = _time(lambda *a: flash_attention(*a, causal=True), q, k, v)
    t_pal = _time(lambda *a: flash_attention(*a, causal=True,
                                             force_pallas=True), q, k, v)
    flops = 4 * B * H * S * S * D / 2            # causal
    return {"kernel": "flash_attention", "shape": f"B{B} S{S} H{H} D{D}",
            "ref_us": t_ref * 1e6, "pallas_interp_us": t_pal * 1e6,
            "tpu_flops": flops,
            "tpu_roofline_us": flops / V5E.peak_flops_bf16 * 1e6}


def bench_paged(B=8, H=8, K=4, D=128, T=16, P=256, MP=16):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    pk = jnp.asarray(rng.standard_normal((P, T, K, D)), jnp.float32)
    pv = jnp.asarray(rng.standard_normal((P, T, K, D)), jnp.float32)
    tbl = jnp.asarray(rng.integers(0, P, (B, MP)), jnp.int32)
    lens = jnp.asarray(rng.integers(T, T * MP, B), jnp.int32)
    t_ref = _time(paged_attention, q, pk, pv, tbl, lens)
    t_pal = _time(lambda *a: paged_attention(*a, force_pallas=True),
                  q, pk, pv, tbl, lens)
    bytes_moved = B * MP * T * K * D * 2 * 2 * 4   # K+V pages per batch row
    return {"kernel": "paged_attention", "shape": f"B{B} pages{MP}x{T}",
            "ref_us": t_ref * 1e6, "pallas_interp_us": t_pal * 1e6,
            "tpu_bytes": bytes_moved,
            "tpu_roofline_us": bytes_moved / V5E.hbm_bandwidth * 1e6}


def bench_log_patch(P=64, T=16, C=512, N=128):
    rng = np.random.default_rng(2)
    pool = jnp.asarray(rng.standard_normal((P, T, C)), jnp.float32)
    pays = jnp.asarray(rng.standard_normal((N, C)), jnp.float32)
    pg = jnp.asarray(rng.integers(0, P, N), jnp.int32)
    sl = jnp.asarray(rng.integers(0, T, N), jnp.int32)
    t_ref = _time(log_patch, pool, pays, pg, sl)
    t_pal = _time(lambda *a: log_patch(*a, force_pallas=True),
                  pool, pays, pg, sl)
    bytes_moved = P * T * C * 4 * 2 + N * C * 4
    return {"kernel": "log_patch", "shape": f"P{P} N{N} C{C}",
            "ref_us": t_ref * 1e6, "pallas_interp_us": t_pal * 1e6,
            "tpu_bytes": bytes_moved,
            "tpu_roofline_us": bytes_moved / V5E.hbm_bandwidth * 1e6}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/kernel_bench.json")
    args = ap.parse_args(argv)
    rows = [bench_flash(), bench_paged(), bench_log_patch()]
    print("kernel,shape,ref_us,pallas_interp_us,tpu_roofline_us")
    for r in rows:
        print(f"{r['kernel']},{r['shape']},{r['ref_us']:.0f},"
              f"{r['pallas_interp_us']:.0f},{r['tpu_roofline_us']:.2f}")
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rows, indent=1))
    return rows


if __name__ == "__main__":
    main()
