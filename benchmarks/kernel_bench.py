"""Kernel micro-benchmarks: wall-time of the interpret-mode Pallas kernels vs
their jnp oracles on CPU (correctness-scale), plus the analytic TPU-side
FLOP/byte counts the roofline uses. Real-TPU timing happens on hardware; the
bench records the work the kernels would do.

``--smoke`` is the CI gate for the serving decode kernel: it runs
``paged_attention`` (single-layer and the batched multi-layer entry) in
Pallas **interpret mode** against the jnp oracles over the block-table
contract's edge cases — ragged lengths, an empty row, single-page
sequences — and exits nonzero on any mismatch, so kernel regressions fail
the workflow before the serving tier ever sees them.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import (flash_attention, log_patch, paged_attention,
                           paged_attention_layers,
                           paged_attention_layers_ragged,
                           paged_attention_ragged)
from repro.kernels.paged_attention.ref import (
    paged_attention_layers_ragged_ref, paged_attention_layers_ref,
    paged_attention_ragged_ref, paged_attention_ref)
from repro.roofline.hw import V5E


def _time(fn, *args, reps=3):
    fn(*args)                       # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def bench_flash(B=1, S=512, H=8, K=2, D=128):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, K, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, K, D)), jnp.float32)
    t_ref = _time(lambda *a: flash_attention(*a, causal=True), q, k, v)
    t_pal = _time(lambda *a: flash_attention(*a, causal=True,
                                             force_pallas=True), q, k, v)
    flops = 4 * B * H * S * S * D / 2            # causal
    return {"kernel": "flash_attention", "shape": f"B{B} S{S} H{H} D{D}",
            "ref_us": t_ref * 1e6, "pallas_interp_us": t_pal * 1e6,
            "tpu_flops": flops,
            "tpu_roofline_us": flops / V5E.peak_flops_bf16 * 1e6}


def bench_paged(B=8, H=8, K=4, D=128, T=16, P=256, MP=16):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    pk = jnp.asarray(rng.standard_normal((P, T, K, D)), jnp.float32)
    pv = jnp.asarray(rng.standard_normal((P, T, K, D)), jnp.float32)
    tbl = jnp.asarray(rng.integers(0, P, (B, MP)), jnp.int32)
    lens = jnp.asarray(rng.integers(T, T * MP, B), jnp.int32)
    t_ref = _time(paged_attention, q, pk, pv, tbl, lens)
    t_pal = _time(lambda *a: paged_attention(*a, force_pallas=True),
                  q, pk, pv, tbl, lens)
    bytes_moved = B * MP * T * K * D * 2 * 2 * 4   # K+V pages per batch row
    return {"kernel": "paged_attention", "shape": f"B{B} pages{MP}x{T}",
            "ref_us": t_ref * 1e6, "pallas_interp_us": t_pal * 1e6,
            "tpu_bytes": bytes_moved,
            "tpu_roofline_us": bytes_moved / V5E.hbm_bandwidth * 1e6}


def bench_paged_layers(L=4, B=8, H=8, K=4, D=128, T=16, P=256, MP=16):
    """The batched multi-layer pooled-decode entry: one kernel launch for
    the whole (L, B) decode attention read over the device page pool."""
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.standard_normal((L, B, H, D)), jnp.float32)
    pk = jnp.asarray(rng.standard_normal((L, P, T, K, D)), jnp.float32)
    pv = jnp.asarray(rng.standard_normal((L, P, T, K, D)), jnp.float32)
    tbl = jnp.asarray(rng.integers(0, P, (B, MP)), jnp.int32)
    lens = jnp.asarray(rng.integers(T, T * MP, B), jnp.int32)
    t_ref = _time(paged_attention_layers, q, pk, pv, tbl, lens)
    t_pal = _time(lambda *a: paged_attention_layers(*a, force_pallas=True),
                  q, pk, pv, tbl, lens)
    bytes_moved = L * B * MP * T * K * D * 2 * 2 * 4
    return {"kernel": "paged_attention_layers",
            "shape": f"L{L} B{B} pages{MP}x{T}",
            "ref_us": t_ref * 1e6, "pallas_interp_us": t_pal * 1e6,
            "tpu_bytes": bytes_moved,
            "tpu_roofline_us": bytes_moved / V5E.hbm_bandwidth * 1e6}


def smoke_check() -> dict:
    """Interpret-mode parity gate over the block-table contract edges:
    ragged lengths, an empty row, a single-token row, single-page
    sequences, for both paged_attention entries. Raises on mismatch."""
    rng = np.random.default_rng(7)
    L, B, H, K, D, T, P, MP = 2, 4, 8, 4, 64, 8, 24, 4
    q = jnp.asarray(rng.standard_normal((L, B, H, D)), jnp.float32)
    pk = jnp.asarray(rng.standard_normal((L, P, T, K, D)), jnp.float32)
    pv = jnp.asarray(rng.standard_normal((L, P, T, K, D)), jnp.float32)
    tbl = jnp.asarray(rng.integers(0, P, (B, MP)), jnp.int32)
    # empty row, single token, exactly one page, ragged mid-page
    lens = jnp.asarray([0, 1, T, T * MP - 3], jnp.int32)
    cases = {
        "paged_attention": (
            paged_attention(q[0], pk[0], pv[0], tbl, lens,
                            force_pallas=True),
            paged_attention_ref(q[0], pk[0], pv[0], tbl, lens)),
        "paged_attention_layers": (
            paged_attention_layers(q, pk, pv, tbl, lens, force_pallas=True),
            paged_attention_layers_ref(q, pk, pv, tbl, lens)),
    }
    errs = {}
    for name, (out, ref) in cases.items():
        err = float(jnp.max(jnp.abs(out - ref)))
        errs[name] = err
        if not np.isfinite(err) or err > 2e-5:
            raise SystemExit(
                f"kernel smoke FAILED: {name} diverges from its oracle "
                f"(max abs err {err:.3e}) on the ragged/empty/single-page "
                f"contract cases")
        empty = np.asarray(out)[..., 0, :, :] if out.ndim == 4 else \
            np.asarray(out)[0]
        if np.any(empty != 0):
            raise SystemExit(
                f"kernel smoke FAILED: {name} returned nonzero output for "
                f"an empty (length 0) row")
    return {"kernel": "smoke_gate", "shape": f"lens={list(map(int, lens))}",
            "max_abs_err": errs}


def smoke_check_ragged() -> dict:
    """CI gate for the ragged-query contract (ISSUE 5): the fused
    mixed-batch entries must (a) match their oracles on the contract edges
    — an empty padding row, a decode row, a chunk ending exactly on a page
    boundary, a ragged mid-page chunk; (b) reduce to the plain decode
    kernels BIT-FOR-BIT at q_len=1; (c) zero every padding query slot; and
    (d) ignore poisoned dead pages and dead slots. Raises on any miss."""
    rng = np.random.default_rng(11)
    L, B, Qm, H, K, D, T, MP = 2, 4, 4, 8, 4, 64, 8, 4
    P = B * MP                                     # disjoint tables
    q = jnp.asarray(rng.standard_normal((L, B, Qm, H, D)), jnp.float32)
    pk = np.asarray(rng.standard_normal((L, P, T, K, D)), np.float32)
    pv = np.asarray(rng.standard_normal((L, P, T, K, D)), np.float32)
    tbl = np.arange(P, dtype=np.int32).reshape(B, MP)
    # padding row (q_len 0) / decode row / chunk ending ON the page
    # boundary / ragged mid-page chunk
    lens = jnp.asarray([0, 5, 2 * T, T * MP - 3], jnp.int32)
    qls = jnp.asarray([0, 1, T, 3], jnp.int32)
    tbl_j = jnp.asarray(tbl)
    cases = {
        "paged_attention_ragged": (
            paged_attention_ragged(q[0], jnp.asarray(pk[0]),
                                   jnp.asarray(pv[0]), tbl_j, lens, qls,
                                   force_pallas=True),
            paged_attention_ragged_ref(q[0], jnp.asarray(pk[0]),
                                       jnp.asarray(pv[0]), tbl_j, lens,
                                       qls)),
        "paged_attention_layers_ragged": (
            paged_attention_layers_ragged(q, jnp.asarray(pk),
                                          jnp.asarray(pv), tbl_j, lens, qls,
                                          force_pallas=True),
            paged_attention_layers_ragged_ref(q, jnp.asarray(pk),
                                              jnp.asarray(pv), tbl_j, lens,
                                              qls)),
    }
    errs = {}
    for name, (out, ref) in cases.items():
        err = float(jnp.max(jnp.abs(out - ref)))
        errs[name] = err
        if not np.isfinite(err) or err > 2e-5:
            raise SystemExit(
                f"kernel smoke FAILED: {name} diverges from its oracle "
                f"(max abs err {err:.3e}) on the ragged-query contract "
                f"edges")
        o = np.asarray(out)
        if o.ndim == 4:                           # single layer (B,Qm,H,D)
            o = o[None]
        for b in range(B):
            ql = int(qls[b])
            if np.any(o[:, b, ql:] != 0):
                raise SystemExit(
                    f"kernel smoke FAILED: {name} returned nonzero output "
                    f"in padding query slots of row {b} (q_len={ql})")
    # (b) q_len=1 ≡ the existing decode kernels, bit for bit
    lens1 = jnp.asarray([3, 5, 2 * T, T * MP - 3], jnp.int32)
    qls1 = jnp.ones(B, jnp.int32)
    r1 = paged_attention_ragged(q[0, :, :1], jnp.asarray(pk[0]),
                                jnp.asarray(pv[0]), tbl_j, lens1, qls1,
                                force_pallas=True)
    d1 = paged_attention(q[0, :, 0], jnp.asarray(pk[0]), jnp.asarray(pv[0]),
                         tbl_j, lens1, force_pallas=True)
    if not np.array_equal(np.asarray(r1[:, 0]), np.asarray(d1)):
        raise SystemExit(
            "kernel smoke FAILED: paged_attention_ragged at q_len=1 is not "
            "bit-for-bit paged_attention")
    rl = paged_attention_layers_ragged(q[:, :, :1], jnp.asarray(pk),
                                       jnp.asarray(pv), tbl_j, lens1, qls1,
                                       force_pallas=True)
    dl = paged_attention_layers(q[:, :, 0], jnp.asarray(pk), jnp.asarray(pv),
                                tbl_j, lens1, force_pallas=True)
    if not np.array_equal(np.asarray(rl[:, :, 0]), np.asarray(dl)):
        raise SystemExit(
            "kernel smoke FAILED: paged_attention_layers_ragged at q_len=1 "
            "is not bit-for-bit paged_attention_layers")
    # (d) dead-page poisoning under ragged queries: slots at or past
    # lens[b] must never reach the output
    pk2, pv2 = pk.copy(), pv.copy()
    lens_np = np.asarray(lens)
    for b in range(B):
        for lp in range(MP):
            phys = tbl[b, lp]
            start = lp * T
            if start >= lens_np[b]:
                pk2[:, phys] = 1e6
                pv2[:, phys] = -1e6
            elif start + T > lens_np[b]:
                pk2[:, phys, lens_np[b] - start:] = 1e6
                pv2[:, phys, lens_np[b] - start:] = -1e6
    out_poisoned = paged_attention_layers_ragged(
        q, jnp.asarray(pk2), jnp.asarray(pv2), tbl_j, lens, qls,
        force_pallas=True)
    dead_err = float(jnp.max(jnp.abs(
        out_poisoned - cases["paged_attention_layers_ragged"][0])))
    errs["dead_page_poisoning"] = dead_err
    if not np.isfinite(dead_err) or dead_err > 1e-5:
        raise SystemExit(
            f"kernel smoke FAILED: poisoning dead pages changed the ragged "
            f"output (max abs err {dead_err:.3e})")
    return {"kernel": "smoke_gate_ragged",
            "shape": f"lens={list(map(int, lens))} qls={list(map(int, qls))}",
            "max_abs_err": errs}


def bench_paged_ragged(L=4, B=8, Qm=8, H=8, K=4, D=128, T=16, P=256, MP=16):
    """The fused mixed-batch entry: decode rows and prefill-chunk rows in
    one launch (half the rows q_len=1, half q_len=Qm)."""
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.standard_normal((L, B, Qm, H, D)), jnp.float32)
    pk = jnp.asarray(rng.standard_normal((L, P, T, K, D)), jnp.float32)
    pv = jnp.asarray(rng.standard_normal((L, P, T, K, D)), jnp.float32)
    tbl = jnp.asarray(rng.integers(0, P, (B, MP)), jnp.int32)
    qls = jnp.asarray([1 if b % 2 else Qm for b in range(B)], jnp.int32)
    lens = jnp.asarray(rng.integers(T, T * MP - Qm, B), jnp.int32) + qls
    t_ref = _time(paged_attention_layers_ragged, q, pk, pv, tbl, lens, qls)
    t_pal = _time(lambda *a: paged_attention_layers_ragged(
        *a, force_pallas=True), q, pk, pv, tbl, lens, qls)
    bytes_moved = L * B * MP * T * K * D * 2 * 2 * 4
    return {"kernel": "paged_attention_layers_ragged",
            "shape": f"L{L} B{B} Q{Qm} pages{MP}x{T}",
            "ref_us": t_ref * 1e6, "pallas_interp_us": t_pal * 1e6,
            "tpu_bytes": bytes_moved,
            "tpu_roofline_us": bytes_moved / V5E.hbm_bandwidth * 1e6}


def bench_log_patch(P=64, T=16, C=512, N=128):
    rng = np.random.default_rng(2)
    pool = jnp.asarray(rng.standard_normal((P, T, C)), jnp.float32)
    pays = jnp.asarray(rng.standard_normal((N, C)), jnp.float32)
    pg = jnp.asarray(rng.integers(0, P, N), jnp.int32)
    sl = jnp.asarray(rng.integers(0, T, N), jnp.int32)
    t_ref = _time(log_patch, pool, pays, pg, sl)
    t_pal = _time(lambda *a: log_patch(*a, force_pallas=True),
                  pool, pays, pg, sl)
    bytes_moved = P * T * C * 4 * 2 + N * C * 4
    return {"kernel": "log_patch", "shape": f"P{P} N{N} C{C}",
            "ref_us": t_ref * 1e6, "pallas_interp_us": t_pal * 1e6,
            "tpu_bytes": bytes_moved,
            "tpu_roofline_us": bytes_moved / V5E.hbm_bandwidth * 1e6}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/kernel_bench.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: interpret-mode paged_attention parity on "
                         "the block-table contract edges + small timing "
                         "rows; exits nonzero on kernel regression")
    args = ap.parse_args(argv)
    if args.smoke:
        rows = [smoke_check(), smoke_check_ragged(),
                bench_paged(B=4, K=4, D=64, T=8, P=32, MP=4),
                bench_paged_layers(L=2, B=4, K=4, D=64, T=8, P=32, MP=4),
                bench_paged_ragged(L=2, B=4, Qm=4, K=4, D=64, T=8, P=32,
                                   MP=4)]
        print("paged_attention smoke gate passed:", rows[0]["max_abs_err"])
        print("ragged-query smoke gate passed:", rows[1]["max_abs_err"])
    else:
        rows = [bench_flash(), bench_paged(), bench_paged_layers(),
                bench_paged_ragged(), bench_log_patch()]
    print("kernel,shape,ref_us,pallas_interp_us,tpu_roofline_us")
    for r in rows:
        if r["kernel"].startswith("smoke_gate"):
            continue
        print(f"{r['kernel']},{r['shape']},{r['ref_us']:.0f},"
              f"{r['pallas_interp_us']:.0f},{r['tpu_roofline_us']:.2f}")
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rows, indent=1))
    return rows


if __name__ == "__main__":
    main()
